//! Offline stand-in for the `anyhow` crate (the sealed build environment has
//! no registry). Implements the API surface this workspace actually uses —
//! `Result`, a context-carrying `Error`, the `Context` extension trait, and
//! the `anyhow!` / `bail!` / `ensure!` macros — with the same semantics:
//!
//! * `{}` displays the outermost message;
//! * `{:#}` displays the whole chain, `outer: inner: root`;
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.

use std::error::Error as StdError;
use std::fmt;

/// A context-carrying error: the outermost message plus a cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Wrap `self` under a new outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = &e.source;
        }
        items.into_iter()
    }

    /// The innermost (root) message of the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(e) = &cur.source {
            cur = e;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = &self.source;
        let mut first = true;
        while let Some(e) = cur {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {}", e.msg)?;
            cur = &e.source;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the std error chain into our context chain.
        let mut msgs = vec![e.to_string()];
        let mut cur: Option<&dyn StdError> = e.source();
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error { msg: it.next().unwrap_or_default(), source: None };
        for m in it {
            err = err.context(m);
        }
        err
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Context::context(
            std::result::Result::<(), _>::Err(io_err()),
            "reading config",
        )
        .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: no such file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "x".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn macros_work() {
        fn f(ok: bool) -> Result<u8> {
            ensure!(ok, "flag was {}", ok);
            if !ok {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        let e = f(false).unwrap_err();
        assert_eq!(format!("{e}"), "flag was false");
        let e2 = anyhow!("code {}", 7);
        assert_eq!(format!("{e2}"), "code 7");
    }

    #[test]
    fn chain_and_root() {
        let e = anyhow!("root").context("mid").context("outer");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
    }
}
