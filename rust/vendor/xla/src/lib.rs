//! Compile-only stand-in for the `xla` crate (xla_extension bindings).
//!
//! The sealed build environment has no crates registry and no XLA shared
//! library, but the `pjrt` feature's backend (`rust/src/runtime/pjrt.rs`)
//! must keep *compiling* so it cannot rot — CI runs
//! `cargo check --features pjrt --all-targets` against this stub.
//!
//! The API surface mirrors exactly what the backend uses: `PjRtClient`,
//! `PjRtLoadedExecutable`, `PjRtBuffer`, `Literal`, `HloModuleProto`,
//! `XlaComputation`. Every constructor/operation returns
//! [`Error::unavailable`] at runtime; to actually execute artifacts, point
//! the `xla` dependency in the workspace `Cargo.toml` at the real
//! xla_extension bindings from the offline mirror instead of this path.

use std::fmt;

/// Error carrying the stub's diagnosis (or, in the real crate, XLA status).
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(op: &str) -> Self {
        Self {
            msg: format!(
                "xla stub: '{op}' needs the real xla_extension bindings \
                 (this build vendored the compile-only stand-in; see \
                 rust/vendor/xla/src/lib.rs)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (tensor of f32/i32/... values).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// A device buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute on the arguments; outer Vec = devices, inner = outputs.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client (CPU platform in this repo).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module proto (from HLO text in this repo).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_operation_reports_the_stub() {
        let e = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(format!("{e}").contains("xla stub"));
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
