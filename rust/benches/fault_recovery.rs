//! **E14 — crash recovery** (DESIGN.md §11, EXPERIMENTS.md E14): how much
//! does a disappearing worker cost each schedule family?
//!
//! The paper's anchor model decouples local progress from synchronization,
//! so Overlap-Local-SGD should shrug off crashes the way it shrugs off
//! stragglers: survivors keep training, the collective averages over the
//! alive set (exactly mean-preserving — rust/tests/failure_injection.rs),
//! and a rejoiner warm-starts from the anchor. Legs:
//!
//! * **scheduled faults** — clean run vs crash-only vs crash+rejoin vs
//!   partition+heal, on overlap-m; plus a partition leg on overlap-gossip,
//!   whose minority components *keep training* (no quorum needed);
//! * **final-loss-vs-crash-rate table** — the seeded random fault process
//!   (`fault_rate`, with `rejoin_rate = 0.25`) swept over per-round
//!   per-worker crash probabilities.
//!
//! Every leg's JSON (including its `fault_trace` — the artifact CI's
//! fault-matrix job uploads) lands in `results/fault_recovery/`.

use anyhow::Result;
use olsgd::bench::experiments::BenchCtx;
use olsgd::config::Algo;
use olsgd::metrics::TrainLog;
use olsgd::util::json::{num, obj, s, Json};

fn leg_row(label: &str, log: &TrainLog) -> Json {
    obj(vec![
        ("label", s(label)),
        ("algo", s(&log.algo)),
        ("final_acc", num(log.final_acc())),
        ("final_test_loss", num(log.final_loss())),
        ("total_time_s", num(log.total_sim_time)),
        ("faults_fired", num(log.fault_trace.len() as f64)),
        (
            "min_survivors",
            num(log
                .survivors
                .iter()
                .map(|&(_, c)| c)
                .min()
                .unwrap_or(log.workers) as f64),
        ),
    ])
}

fn print_leg(label: &str, log: &TrainLog) {
    println!(
        "{:<34} {:>8.2} {:>11.4} {:>10.1} {:>8} {:>10}",
        label,
        100.0 * log.final_acc(),
        log.final_loss(),
        log.total_sim_time,
        log.fault_trace.len(),
        log.survivors
            .iter()
            .map(|&(_, c)| c)
            .min()
            .unwrap_or(log.workers)
    );
}

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("fault_recovery")?;
    ctx.base.workers = 8;
    let mut rows = Vec::new();

    println!("=== E14: crash recovery (m=8, scheduled faults) ===");
    println!(
        "{:<34} {:>8} {:>11} {:>10} {:>8} {:>10}",
        "leg", "acc%", "test_loss", "time(s)", "faults", "min_surv"
    );

    // Scheduled-fault legs. Events sit at rounds 3/5 so they fire even
    // under an OLSGD_EPOCHS-shortened smoke run.
    let legs: [(&str, Algo, &str); 5] = [
        ("overlap-m clean", Algo::OverlapM, ""),
        ("overlap-m crash (no rejoin)", Algo::OverlapM, "crash@3:1"),
        ("overlap-m crash+rejoin", Algo::OverlapM, "crash@3:1;rejoin@5:1"),
        (
            "overlap-m partition+heal",
            Algo::OverlapM,
            "partition@3:0,1,2|3,4,5,6,7;heal@5",
        ),
        (
            "overlap-gossip partition",
            Algo::OverlapGossip,
            "partition@3:0,1,2|3,4,5,6,7",
        ),
    ];
    for (label, algo, fault) in legs {
        let log = ctx.run_leg(&label.replace([' ', '(', ')', '+'], "_"), |c| {
            c.algo = algo;
            if !fault.is_empty() {
                c.set("fault", fault).expect("static fault spec");
            }
        })?;
        print_leg(label, &log);
        rows.push(leg_row(label, &log));
    }

    // Final-loss-vs-crash-rate table (the E14 record): the seeded random
    // process, crash probability per worker per round.
    println!("\n=== E14: final loss vs crash rate (overlap-m, rejoin_rate=0.25) ===");
    println!(
        "{:<34} {:>8} {:>11} {:>10} {:>8} {:>10}",
        "leg", "acc%", "test_loss", "time(s)", "faults", "min_surv"
    );
    for rate in [0.0f64, 0.02, 0.05, 0.10] {
        let label = format!("overlap-m fault_rate={rate}");
        let log = ctx.run_leg(&label.replace([' ', '=', '.'], "_"), |c| {
            c.algo = Algo::OverlapM;
            c.fault_rate = rate;
            c.rejoin_rate = if rate > 0.0 { 0.25 } else { 0.0 };
        })?;
        print_leg(&label, &log);
        rows.push(leg_row(&label, &log));
    }

    ctx.write_summary("E14_fault_recovery.json", rows)?;
    Ok(())
}
