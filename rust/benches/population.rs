//! **E17 — population-scale partial participation** (DESIGN.md §14,
//! EXPERIMENTS.md E17): the engine trains a sampled cohort of k = 16
//! machines over registered populations of N ∈ {16, 10³, 10⁵, 10⁶}
//! workers, and the O(k) worker-state store must make N free — resident
//! state bounded by `sample_k + sample_reserve` at every N, round
//! throughput flat in N, and the LRU/spill machinery invisible in the
//! trajectory.
//!
//! Every leg runs twice: once with the default reserve (evictions spill
//! through the disk codec) and once with a reserve large enough that
//! nothing ever spills. `digest_match_dense` records that the two agree
//! bit-for-bit — i.e. the store is pure mechanism — and on the N == k leg
//! additionally that the run equals the truly-dense engine
//! (`population = 0`), the strict-generalization acceptance criterion.
//! The CI `population-matrix` job gates on `digest_match_dense == true`
//! and `resident_workers_max <= sample_k + reserve` for every row.
//!
//! The summary lands in `results/population/E17_population.json`.
//!
//! **E18 — population chaos** (EXPERIMENTS.md E18) follows: the PR-9
//! lifted compositions under load. A `fault_rate = 0.01` random process at
//! N ∈ {10³, 10⁵} (two runs must replay the identical fault trace and
//! digest), an id-range partition schedule at N = 10³, and a net-backend
//! cohort leg whose killed worker process must land on the digest of the
//! equivalent per-id `crash@round` schedule. Every leg re-asserts the O(k)
//! residency cap. Summary: `results/population/E18_population_chaos.json`.

use std::time::Instant;

use anyhow::Result;
use olsgd::bench::experiments::BenchCtx;
use olsgd::config::{Algo, Execution};
use olsgd::metrics::PopulationCounters;
use olsgd::util::json::{num, obj, s, Json};

const K: usize = 16;
const POPULATIONS: [u64; 4] = [16, 1_000, 100_000, 1_000_000];

/// Per-worker persistent state footprint (bytes): params + momentum (the
/// default nesterov optimizer carries no second moment and no residual at
/// `--compress none`) + the shard index + codec overhead.
fn state_bytes(n: usize, shard_len: usize) -> u64 {
    (2 * n * 4 + shard_len * 4 + 128) as u64
}

fn leg_row(
    n_pop: u64,
    wall_s: f64,
    rounds: u64,
    c: &PopulationCounters,
    digest_match_dense: bool,
    resident_bytes: u64,
) -> Json {
    let binds = c.store_hits + c.spill_reads + c.fresh_materializations;
    obj(vec![
        ("population", num(n_pop as f64)),
        ("sample_k", num(c.sample_k as f64)),
        ("reserve", num(c.reserve as f64)),
        ("rounds", num(rounds as f64)),
        ("rounds_per_sec", num(rounds as f64 / wall_s.max(1e-9))),
        ("wall_s", num(wall_s)),
        ("resident_workers_max", num(c.resident_workers_max as f64)),
        ("resident_bytes_est", num(resident_bytes as f64)),
        ("store_hits", num(c.store_hits as f64)),
        ("spill_reads", num(c.spill_reads as f64)),
        ("fresh_materializations", num(c.fresh_materializations as f64)),
        ("evictions", num(c.evictions as f64)),
        ("spilled_bytes", num(c.spilled_bytes as f64)),
        (
            "cache_hit_rate",
            num(if binds > 0 { c.store_hits as f64 / binds as f64 } else { 1.0 }),
        ),
        ("digest_match_dense", Json::Bool(digest_match_dense)),
    ])
}

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("population")?;
    let model_n = ctx.rt.n;
    let shard_len = ctx.base.train_n / K;

    // The truly-dense reference: the same shape with the axis off.
    let dense = ctx.run_leg("dense_k16", |c| {
        c.algo = Algo::OverlapM;
        c.workers = K;
    })?;
    let dense_digest = dense.digest();

    println!("=== E17: population scale at fixed k = 16 (overlap-m, ring) ===");
    println!(
        "{:>10} {:>8} {:>10} {:>9} {:>13} {:>9} {:>9} {:>7}",
        "N", "rounds", "rounds/s", "resident", "bytes(est)", "hit%", "spilled", "dense?"
    );

    let mut rows = Vec::new();
    for n_pop in POPULATIONS {
        let t0 = Instant::now();
        let log = ctx.run_leg(&format!("pop_{n_pop}"), |c| {
            c.algo = Algo::OverlapM;
            c.workers = K;
            c.set("population", &n_pop.to_string()).expect("static key");
            c.set("sample_k", &K.to_string()).expect("static key");
        })?;
        let wall = t0.elapsed().as_secs_f64();
        let c = log.population.expect("engaged run must report population counters");

        // Control: a reserve no run can overflow — the store never evicts,
        // so a digest match proves the spill codec and LRU are pure
        // mechanism. Total distinct workers touched is at most k × rounds,
        // so this stays O(touched), far below N.
        let control = ctx.run_leg(&format!("pop_{n_pop}_nospill"), |c| {
            c.algo = Algo::OverlapM;
            c.workers = K;
            c.set("population", &n_pop.to_string()).expect("static key");
            c.set("sample_k", &K.to_string()).expect("static key");
            c.set("sample_reserve", "1000000000").expect("static key");
        })?;
        let mut matched = log.digest() == control.digest();
        if n_pop == K as u64 {
            // Strict generalization: N == k must BE the dense engine.
            matched = matched && log.digest() == dense_digest;
        }

        let resident_bytes = c.resident_workers_max * state_bytes(model_n, shard_len);
        let binds = c.store_hits + c.spill_reads + c.fresh_materializations;
        println!(
            "{:>10} {:>8} {:>10.2} {:>9} {:>13} {:>8.1}% {:>9} {:>7}",
            n_pop,
            c.rounds_sampled,
            c.rounds_sampled as f64 / wall.max(1e-9),
            c.resident_workers_max,
            resident_bytes,
            100.0 * c.store_hits as f64 / binds.max(1) as f64,
            c.spilled_bytes,
            matched,
        );
        rows.push(leg_row(n_pop, wall, c.rounds_sampled, &c, matched, resident_bytes));

        assert!(
            c.resident_workers_max <= c.sample_k + c.reserve,
            "N = {n_pop}: resident peak {} exceeds k + reserve = {}",
            c.resident_workers_max,
            c.sample_k + c.reserve
        );
        assert!(matched, "N = {n_pop}: the store changed the trajectory");
    }

    ctx.write_summary("E17_population.json", rows)?;
    e18_population_chaos(&mut ctx)?;
    Ok(())
}

/// One E18 row: which chaos leg ran, its replay/digest verdict, and the
/// residency evidence the CI gates consume.
fn e18_row(leg: &str, n_pop: u64, matched: bool, c: &PopulationCounters, extra: Vec<(&str, Json)>) -> Json {
    let mut fields = vec![
        ("leg", s(leg)),
        ("population", num(n_pop as f64)),
        ("sample_k", num(c.sample_k as f64)),
        ("reserve", num(c.reserve as f64)),
        ("rounds", num(c.rounds_sampled as f64)),
        ("resident_workers_max", num(c.resident_workers_max as f64)),
        ("resident_cap_ok", Json::Bool(c.resident_workers_max <= c.sample_k + c.reserve)),
        ("digest_match", Json::Bool(matched)),
    ];
    fields.extend(extra);
    obj(fields)
}

/// E18 — population chaos: the lifted fault compositions at scale.
fn e18_population_chaos(ctx: &mut BenchCtx) -> Result<()> {
    println!("\n=== E18: population chaos at fixed k = 16 (overlap-m, ring) ===");
    let mut rows = Vec::new();

    // Leg 1: the per-id random fault process at N ∈ {10^3, 10^5}. Two
    // identical runs must replay the identical fault trace (the lazy
    // per-id streams are pure functions of (seed, id, round)) and digest.
    for n_pop in [1_000u64, 100_000] {
        let mutate = |c: &mut olsgd::config::ExperimentConfig| {
            c.algo = Algo::OverlapM;
            c.workers = K;
            // Pinned: the chaos schedule needs its full 6 rounds even when
            // OLSGD_EPOCHS shortens the E17 legs.
            c.epochs = 6.0;
            c.eval_every = 1.0;
            c.set("population", &n_pop.to_string()).expect("static key");
            c.set("sample_k", &K.to_string()).expect("static key");
            c.set("fault_rate", "0.01").expect("static key");
            c.set("rejoin_rate", "0.2").expect("static key");
        };
        let a = ctx.run_leg(&format!("chaos_frate_{n_pop}_a"), mutate)?;
        let b = ctx.run_leg(&format!("chaos_frate_{n_pop}_b"), mutate)?;
        let replay = a.fault_trace == b.fault_trace && a.digest() == b.digest();
        let c = a.population.expect("engaged run must report population counters");
        println!(
            "  frate N={n_pop}: {} fault events, replay_match={replay}, resident={}",
            a.fault_trace.len(),
            c.resident_workers_max
        );
        rows.push(e18_row(
            "fault_rate",
            n_pop,
            replay,
            &c,
            vec![
                ("fault_rate", num(0.01)),
                ("fault_events", num(a.fault_trace.len() as f64)),
                ("fault_trace_replay_match", Json::Bool(replay)),
            ],
        ));
        assert!(replay, "N = {n_pop}: the per-id fault process failed to replay");
        assert!(
            c.resident_workers_max <= c.sample_k + c.reserve,
            "N = {n_pop}: chaos leg broke the O(k) residency cap"
        );
    }

    // Leg 2: an id-range partition schedule over N = 10^3 — the cohort
    // intersects the components, the minority parks, heal restores. Two
    // runs lock the digest.
    {
        let n_pop = 1_000u64;
        let mutate = |c: &mut olsgd::config::ExperimentConfig| {
            c.algo = Algo::OverlapM;
            c.workers = K;
            c.epochs = 6.0;
            c.eval_every = 1.0;
            c.set("population", &n_pop.to_string()).expect("static key");
            c.set("sample_k", &K.to_string()).expect("static key");
            c.set("fault", "partition@2:0-499|500-999;heal@4").expect("static key");
        };
        let a = ctx.run_leg("chaos_partition_1000_a", mutate)?;
        let b = ctx.run_leg("chaos_partition_1000_b", mutate)?;
        let matched = a.digest() == b.digest() && a.fault_trace == b.fault_trace;
        let c = a.population.expect("engaged run must report population counters");
        println!("  partition N={n_pop}: digest_match={matched}, resident={}", c.resident_workers_max);
        rows.push(e18_row(
            "partition",
            n_pop,
            matched,
            &c,
            vec![("partition_digest_match", Json::Bool(matched))],
        ));
        assert!(matched, "the id-range partition failed to replay");
    }

    // Leg 3: net backend serving cohorts, with a killed worker process.
    // Proc 1 (slots 4-7) dies after serving round 2; the engine translates
    // each dead slot through its binding into a per-id crash. Scheduling
    // those exact crashes on sim must reproduce the digest byte-for-byte.
    {
        let n_pop = 1_000u64;
        let net = ctx.run_leg("chaos_netkill_1000", |c| {
            c.algo = Algo::OverlapM;
            c.workers = K;
            c.epochs = 6.0;
            c.eval_every = 1.0;
            c.execution = Execution::Net;
            c.set("population", &n_pop.to_string()).expect("static key");
            c.set("sample_k", &K.to_string()).expect("static key");
            c.set("net_worker_bin", env!("CARGO_BIN_EXE_olsgd")).expect("static key");
            c.set("net_procs", "4").expect("static key");
            c.set("net_timeout_s", "120").expect("static key");
            c.set("net_kill", "1:2").expect("static key");
        })?;
        let crashes: Vec<String> = net
            .fault_trace
            .iter()
            .filter(|(round, ev)| *round == 3 && ev.starts_with("crash@3:"))
            .map(|(_, ev)| ev.clone())
            .collect();
        anyhow::ensure!(
            !crashes.is_empty(),
            "the killed worker process surfaced no round-3 crash events"
        );
        let schedule = crashes.join(";");
        let sim = ctx.run_leg("chaos_netkill_1000_sim", |c| {
            c.algo = Algo::OverlapM;
            c.workers = K;
            c.epochs = 6.0;
            c.eval_every = 1.0;
            c.set("population", &n_pop.to_string()).expect("static key");
            c.set("sample_k", &K.to_string()).expect("static key");
            c.set("fault", &schedule).expect("replaying the net crash schedule");
        })?;
        let matched = net.digest() == sim.digest() && net.fault_trace == sim.fault_trace;
        let c = net.population.expect("engaged run must report population counters");
        println!(
            "  netkill N={n_pop}: {} crashed ids, digest_match={matched}",
            crashes.len()
        );
        rows.push(e18_row(
            "net_kill",
            n_pop,
            matched,
            &c,
            vec![
                ("crashed_ids", num(crashes.len() as f64)),
                ("net_kill_digest_match", Json::Bool(matched)),
            ],
        ));
        assert!(matched, "net cohort kill diverged from the per-id crash schedule");
    }

    ctx.write_summary("E18_population_chaos.json", rows)?;
    Ok(())
}
