//! **E17 — population-scale partial participation** (DESIGN.md §14,
//! EXPERIMENTS.md E17): the engine trains a sampled cohort of k = 16
//! machines over registered populations of N ∈ {16, 10³, 10⁵, 10⁶}
//! workers, and the O(k) worker-state store must make N free — resident
//! state bounded by `sample_k + sample_reserve` at every N, round
//! throughput flat in N, and the LRU/spill machinery invisible in the
//! trajectory.
//!
//! Every leg runs twice: once with the default reserve (evictions spill
//! through the disk codec) and once with a reserve large enough that
//! nothing ever spills. `digest_match_dense` records that the two agree
//! bit-for-bit — i.e. the store is pure mechanism — and on the N == k leg
//! additionally that the run equals the truly-dense engine
//! (`population = 0`), the strict-generalization acceptance criterion.
//! The CI `population-matrix` job gates on `digest_match_dense == true`
//! and `resident_workers_max <= sample_k + reserve` for every row.
//!
//! The summary lands in `results/population/E17_population.json`.

use std::time::Instant;

use anyhow::Result;
use olsgd::bench::experiments::BenchCtx;
use olsgd::config::Algo;
use olsgd::metrics::PopulationCounters;
use olsgd::util::json::{num, obj, Json};

const K: usize = 16;
const POPULATIONS: [u64; 4] = [16, 1_000, 100_000, 1_000_000];

/// Per-worker persistent state footprint (bytes): params + momentum (the
/// default nesterov optimizer carries no second moment and no residual at
/// `--compress none`) + the shard index + codec overhead.
fn state_bytes(n: usize, shard_len: usize) -> u64 {
    (2 * n * 4 + shard_len * 4 + 128) as u64
}

fn leg_row(
    n_pop: u64,
    wall_s: f64,
    rounds: u64,
    c: &PopulationCounters,
    digest_match_dense: bool,
    resident_bytes: u64,
) -> Json {
    let binds = c.store_hits + c.spill_reads + c.fresh_materializations;
    obj(vec![
        ("population", num(n_pop as f64)),
        ("sample_k", num(c.sample_k as f64)),
        ("reserve", num(c.reserve as f64)),
        ("rounds", num(rounds as f64)),
        ("rounds_per_sec", num(rounds as f64 / wall_s.max(1e-9))),
        ("wall_s", num(wall_s)),
        ("resident_workers_max", num(c.resident_workers_max as f64)),
        ("resident_bytes_est", num(resident_bytes as f64)),
        ("store_hits", num(c.store_hits as f64)),
        ("spill_reads", num(c.spill_reads as f64)),
        ("fresh_materializations", num(c.fresh_materializations as f64)),
        ("evictions", num(c.evictions as f64)),
        ("spilled_bytes", num(c.spilled_bytes as f64)),
        (
            "cache_hit_rate",
            num(if binds > 0 { c.store_hits as f64 / binds as f64 } else { 1.0 }),
        ),
        ("digest_match_dense", Json::Bool(digest_match_dense)),
    ])
}

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("population")?;
    let model_n = ctx.rt.n;
    let shard_len = ctx.base.train_n / K;

    // The truly-dense reference: the same shape with the axis off.
    let dense = ctx.run_leg("dense_k16", |c| {
        c.algo = Algo::OverlapM;
        c.workers = K;
    })?;
    let dense_digest = dense.digest();

    println!("=== E17: population scale at fixed k = 16 (overlap-m, ring) ===");
    println!(
        "{:>10} {:>8} {:>10} {:>9} {:>13} {:>9} {:>9} {:>7}",
        "N", "rounds", "rounds/s", "resident", "bytes(est)", "hit%", "spilled", "dense?"
    );

    let mut rows = Vec::new();
    for n_pop in POPULATIONS {
        let t0 = Instant::now();
        let log = ctx.run_leg(&format!("pop_{n_pop}"), |c| {
            c.algo = Algo::OverlapM;
            c.workers = K;
            c.set("population", &n_pop.to_string()).expect("static key");
            c.set("sample_k", &K.to_string()).expect("static key");
        })?;
        let wall = t0.elapsed().as_secs_f64();
        let c = log.population.expect("engaged run must report population counters");

        // Control: a reserve no run can overflow — the store never evicts,
        // so a digest match proves the spill codec and LRU are pure
        // mechanism. Total distinct workers touched is at most k × rounds,
        // so this stays O(touched), far below N.
        let control = ctx.run_leg(&format!("pop_{n_pop}_nospill"), |c| {
            c.algo = Algo::OverlapM;
            c.workers = K;
            c.set("population", &n_pop.to_string()).expect("static key");
            c.set("sample_k", &K.to_string()).expect("static key");
            c.set("sample_reserve", "1000000000").expect("static key");
        })?;
        let mut matched = log.digest() == control.digest();
        if n_pop == K as u64 {
            // Strict generalization: N == k must BE the dense engine.
            matched = matched && log.digest() == dense_digest;
        }

        let resident_bytes = c.resident_workers_max * state_bytes(model_n, shard_len);
        let binds = c.store_hits + c.spill_reads + c.fresh_materializations;
        println!(
            "{:>10} {:>8} {:>10.2} {:>9} {:>13} {:>8.1}% {:>9} {:>7}",
            n_pop,
            c.rounds_sampled,
            c.rounds_sampled as f64 / wall.max(1e-9),
            c.resident_workers_max,
            resident_bytes,
            100.0 * c.store_hits as f64 / binds.max(1) as f64,
            c.spilled_bytes,
            matched,
        );
        rows.push(leg_row(n_pop, wall, c.rounds_sampled, &c, matched, resident_bytes));

        assert!(
            c.resident_workers_max <= c.sample_k + c.reserve,
            "N = {n_pop}: resident peak {} exceeds k + reserve = {}",
            c.resident_workers_max,
            c.sample_k + c.reserve
        );
        assert!(matched, "N = {n_pop}: the store changed the trajectory");
    }

    ctx.write_summary("E17_population.json", rows)?;
    Ok(())
}
