//! **E6 — Table 2 (non-IID)**: the Table-1 grid under the paper's skewed
//! partition (64% one class per node, no reshuffle, same hyper-parameters
//! as the IID case).
//!
//! Paper shape: CoCoD-SGD *diverges* at tau >= 8 while Overlap-Local-SGD
//! stays convergent; EAMSGD degrades most in accuracy; sync SGD's reference
//! is LOWER than the Local-SGD family (non-IID instability).

use anyhow::Result;
use olsgd::bench::experiments::{row, BenchCtx};
use olsgd::config::Algo;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("table2_noniid")?;
    let epochs = ctx.base.epochs;
    let taus = [1usize, 2, 8, 24];
    let algos = [
        ("CoCoD-SGD", Algo::Cocod),
        ("EAMSGD", Algo::Eamsgd),
        ("Ours", Algo::OverlapM),
    ];

    let noniid = |c: &mut olsgd::config::ExperimentConfig| {
        c.noniid = true;
        c.reshuffle = false;
    };

    let sync = ctx.run_leg("sync_ref", |c| {
        c.algo = Algo::Sync;
        noniid(c);
    })?;

    let mut rows = Vec::new();
    let mut table = vec![vec![String::new(); taus.len()]; algos.len()];
    for (ai, &(_, algo)) in algos.iter().enumerate() {
        for (ti, &tau) in taus.iter().enumerate() {
            let log = ctx.run_leg(&format!("noniid_{}_tau{tau}", algo.name()), |c| {
                c.algo = algo;
                c.tau = tau;
                noniid(c);
            })?;
            let diverged = !log.final_loss().is_finite() || log.final_loss() > 5.0;
            table[ai][ti] = if diverged {
                "Diverges".to_string()
            } else {
                format!("{:.2}%", 100.0 * log.final_acc())
            };
            rows.push(row(&format!("noniid_{}_tau{tau}", algo.name()), algo, tau, &log, epochs));
        }
    }

    println!("\n=== Table 2 — non-IID data partition: final test accuracy ===");
    print!("{:<12}", "Algorithm");
    for tau in taus {
        print!(" {:>9}", format!("tau={tau}"));
    }
    println!();
    for (ai, (name, _)) in algos.iter().enumerate() {
        print!("{:<12}", name);
        for ti in 0..taus.len() {
            print!(" {:>9}", table[ai][ti]);
        }
        println!();
    }
    println!("(reference: fully-sync SGD {:.2}%)", 100.0 * sync.final_acc());
    ctx.write_summary("table2_summary.json", rows)
}
