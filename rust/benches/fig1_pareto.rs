//! **E1 — Figure 1**: error-runtime Pareto frontier.
//!
//! Final test error vs total (virtual) training time for Local SGD and
//! Overlap-Local-SGD at tau in {1, 2, 4, 8, 24}, with fully-sync SGD as the
//! reference point. Paper claim: overlap shifts the whole frontier left
//! (same error, strictly less time), improving the Pareto efficiency.
//!
//! `OLSGD_FULL=1 cargo bench --bench fig1_pareto` for the record run.

use anyhow::Result;
use olsgd::bench::experiments::{header, print_row, row, BenchCtx};
use olsgd::config::Algo;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("fig1_pareto")?;
    let epochs = ctx.base.epochs;
    let taus = [1usize, 2, 4, 8, 24];

    header("Fig. 1 — error-runtime trade-off (Pareto frontier)");
    let mut rows = Vec::new();

    let log = ctx.run_leg("sync", |c| c.algo = Algo::Sync)?;
    print_row("sync (reference)", 1, &log, epochs);
    rows.push(row("sync", Algo::Sync, 1, &log, epochs));

    for &tau in &taus {
        let log = ctx.run_leg(&format!("local_tau{tau}"), |c| {
            c.algo = Algo::Local;
            c.tau = tau;
        })?;
        print_row("local-sgd", tau, &log, epochs);
        rows.push(row(&format!("local_tau{tau}"), Algo::Local, tau, &log, epochs));
    }

    for &tau in &taus {
        let log = ctx.run_leg(&format!("overlap_tau{tau}"), |c| {
            c.algo = Algo::OverlapM;
            c.tau = tau;
        })?;
        print_row("overlap-local-sgd", tau, &log, epochs);
        rows.push(row(&format!("overlap_tau{tau}"), Algo::OverlapM, tau, &log, epochs));
    }

    println!(
        "\nshape check: at every tau, overlap's time/epoch must be <= local's,\n\
         and approach pure-compute time (sync minus its comm overhead)."
    );
    ctx.write_summary("fig1_summary.json", rows)
}
