//! **E12/E13 — wall-clock hiding + the zero-allocation steady state**
//! (EXPERIMENTS.md): the real-time points on the perf trajectory. Every
//! other bench reports virtual simnet seconds; this one times the
//! `--execution threads` backend on real cores, where the local phase runs
//! on the persistent worker pool and each collective runs on the parked
//! communicator thread.
//!
//! Protocol (equal global steps for every leg):
//!
//! * `sync τ=1`    — blocking collective every step: the baseline;
//! * `local τ=T`   — blocking collective every T steps: amortization only;
//! * `overlap-m τ=T` — non-blocking collective under the next round's
//!   compute: amortization + hiding (the paper's schedule);
//! * `overlap-gossip τ=T` — decentralized exchange, also hidden.
//!
//! E19 rides along on the same protocol: three extra overlap-m legs vary
//! the model/kernel axis (`linear+simd`, `mlp+scalar`, `mlp+simd`), and
//! every leg reports per-step wall time and aggregate GFLOP/s computed
//! from `ModelRuntime::train_step_flops`. The bench hard-asserts the MLP
//! step costs ≥ 5× the linear model's FLOPs, so the compute-bound legs
//! are real and the SIMD tier has something to chew on.
//!
//! Each leg runs under BOTH backends; the bench hard-asserts the two
//! `TrainLog` digests are identical (the tentpole guarantee) and records
//! the threads-backend wall time. E13 instrumentation rides on every leg:
//!
//! * the tracked counters from `TrainLog::hot` — steady-state thread
//!   spawns and pooled-buffer allocations, hard-asserted **zero** on every
//!   leg (the persistent pool + buffer pool contract, DESIGN.md §10);
//! * ground-truth allocator traffic for the timed run, via the
//!   `util::memcount::CountingAlloc` global allocator installed by this
//!   binary.
//!
//! Results land in `BENCH_wallclock.json` at the repo root plus per-leg
//! JSONs under `results/wallclock/`. CI fails if the JSON is missing or a
//! steady-state counter is nonzero (the E13 gate).
//!
//! Sizing: `OLSGD_SMOKE=1` shrinks everything for CI; `OLSGD_WC_ASSERT=1`
//! additionally hard-fails unless overlap-m beats sync by ≥ 1.2× (the
//! ISSUE-3 acceptance bar — meaningful on ≥ 4 physical cores). A serial
//! vs pool-parallel mean micro-comparison rides along.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;
use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::executor::Executor;
use olsgd::metrics::{write_json, TrainLog};
use olsgd::model::simd::KernelTier;
use olsgd::model::vecmath;
use olsgd::runtime::{ModelRuntime, DEFAULT_HIDDEN};
use olsgd::util::json::{arr, num, obj, s, Json};
use olsgd::util::memcount::{self, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

struct Leg {
    label: &'static str,
    algo: Algo,
    tau: usize,
    model: &'static str,
    kernels: KernelTier,
    /// model FLOPs for one worker's train step (E19 column)
    flops_per_step: f64,
    wall_s: f64,
    /// allocator calls during the timed threads run (whole process)
    timed_allocs: u64,
    /// bytes requested during the timed threads run
    timed_alloc_bytes: u64,
    log: TrainLog,
}

impl Leg {
    /// Wall time per global step (all m workers advance one step).
    fn step_time_s(&self) -> f64 {
        self.wall_s / (self.log.steps as f64).max(1.0)
    }

    /// Aggregate training throughput: every worker executes
    /// `flops_per_step` per global step.
    fn gflops(&self, workers: usize) -> f64 {
        workers as f64 * self.flops_per_step * self.log.steps as f64 / self.wall_s / 1e9
    }
}

fn run_both(cfg: &ExperimentConfig, rt: &ModelRuntime) -> Result<(f64, u64, u64, TrainLog)> {
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

    let mut sim_cfg = cfg.clone();
    sim_cfg.execution = Execution::Sim;
    let sim_log = run_experiment(rt, &sim_cfg, &train, &test)?;

    let mut thr_cfg = cfg.clone();
    thr_cfg.execution = Execution::Threads;
    // Warm-up run (page in code/data, spin up the allocator), then timed.
    run_experiment(rt, &thr_cfg, &train, &test)?;
    let mem0 = memcount::snapshot();
    let t0 = Instant::now();
    let thr_log = run_experiment(rt, &thr_cfg, &train, &test)?;
    let wall = t0.elapsed().as_secs_f64();
    let mem = memcount::since(mem0);

    assert_eq!(
        sim_log.digest(),
        thr_log.digest(),
        "{}: threads backend drifted from sim — the digest-identity \
         guarantee is broken",
        cfg.algo.name()
    );
    Ok((wall, mem.allocs, mem.bytes, thr_log))
}

fn mean_micro(workers: usize, smoke: bool) -> (f64, f64) {
    // Paper-scale flat vectors (11.2 M params, 8 replicas); smoke mode
    // shrinks them so CI runners don't pay ~400 MB for a footnote.
    let n = if smoke { 1 << 20 } else { 11_173_962 };
    let m = 8;
    let vs: Vec<Vec<f32>> = (0..m).map(|w| vec![w as f32 * 0.25 + 0.1; n]).collect();
    let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0.0f32; n];
    // The pooled mean (E13): parked pool threads instead of per-call
    // spawns — same bit-identical chunked reduction.
    let exec = Executor::new(Execution::Threads, workers);
    // Warm both paths first so the serial leg doesn't eat the output
    // buffer's first-touch page faults (which would flatter the parallel
    // ratio); then time a second pass of each over resident memory.
    vecmath::mean_into(&refs, &mut out);
    exec.mean_into(&refs, &mut out);
    let t0 = Instant::now();
    vecmath::mean_into(&refs, &mut out);
    let serial = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    exec.mean_into(&refs, &mut out);
    let pooled = t1.elapsed().as_secs_f64();
    (serial, pooled)
}

fn main() -> Result<()> {
    let smoke = std::env::var("OLSGD_SMOKE").map(|v| v == "1").unwrap_or(false);
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);

    let mut base = ExperimentConfig::default();
    base.model = "linear".into();
    base.workers = cores.clamp(2, 8);
    if let Ok(w) = std::env::var("OLSGD_WC_WORKERS") {
        base.workers = w.parse().unwrap_or(base.workers);
    }
    base.train_n = base.workers * if smoke { 64 } else { 256 };
    base.test_n = 100;
    base.epochs = if smoke { 2.0 } else { 8.0 };
    if let Ok(e) = std::env::var("OLSGD_WC_EPOCHS") {
        base.epochs = e.parse().unwrap_or(base.epochs);
    }
    base.eval_every = base.epochs; // eval only at the end: time the training
    let tau = 8;

    println!(
        "=== E12/E13/E19 wall-clock hiding + kernel tiers (threads backend, {} cores, m={}, {} global steps) ===",
        cores,
        base.workers,
        (base.epochs * (base.train_n as f64 / base.workers as f64 / 32.0)).round()
    );
    println!(
        "{:<22} {:>6} {:>7} {:>7} {:>12} {:>14} {:>12} {:>10} {:>10} {:>12}",
        "leg", "tau", "model", "kern", "wall (s)", "step (ms)", "GFLOP/s", "spawns", "steady", "allocs/run"
    );

    // E12 schedule sweep on the linear/scalar reference, then the E19
    // kernel-tier sweep on the overlap-m schedule (the paper's).
    let specs: [(&'static str, Algo, usize, &'static str, KernelTier); 7] = [
        ("sync", Algo::Sync, 1, "linear", KernelTier::Scalar),
        ("local", Algo::Local, tau, "linear", KernelTier::Scalar),
        ("overlap-m", Algo::OverlapM, tau, "linear", KernelTier::Scalar),
        ("overlap-gossip", Algo::OverlapGossip, tau, "linear", KernelTier::Scalar),
        ("overlap-m+simd", Algo::OverlapM, tau, "linear", KernelTier::Simd),
        ("overlap-mlp", Algo::OverlapM, tau, "mlp", KernelTier::Scalar),
        ("overlap-mlp+simd", Algo::OverlapM, tau, "mlp", KernelTier::Simd),
    ];
    let mut legs: Vec<Leg> = Vec::new();
    for (label, algo, tau, model, kernels) in specs {
        let mut cfg = base.clone();
        cfg.algo = algo;
        cfg.tau = tau;
        cfg.model = model.into();
        cfg.kernels = kernels;
        let rt = ModelRuntime::native_with(model, DEFAULT_HIDDEN, kernels)?;
        let (wall_s, timed_allocs, timed_alloc_bytes, log) = run_both(&cfg, &rt)?;
        legs.push(Leg {
            label,
            algo,
            tau,
            model,
            kernels,
            flops_per_step: rt.train_step_flops(),
            wall_s,
            timed_allocs,
            timed_alloc_bytes,
            log,
        });
    }

    let sync_wall = legs[0].wall_s;
    for leg in &legs {
        println!(
            "{:<22} {:>6} {:>7} {:>7} {:>12.4} {:>14.4} {:>12.2} {:>10} {:>10} {:>12}",
            leg.label,
            leg.tau,
            leg.model,
            leg.kernels.name(),
            leg.wall_s,
            1e3 * leg.step_time_s(),
            leg.gflops(base.workers),
            leg.log.hot.thread_spawns_total,
            leg.log.hot.steady_thread_spawns + leg.log.hot.steady_buffer_allocs,
            leg.timed_allocs,
        );
    }

    // E19 gate: the MLP must be a real compute-bound model — at least 5x
    // the linear model's per-step FLOPs — or the tier comparison is noise.
    let linear_flops = legs[0].flops_per_step;
    let mlp_flops = legs[6].flops_per_step;
    anyhow::ensure!(
        mlp_flops >= 5.0 * linear_flops,
        "mlp step FLOPs {mlp_flops:.3e} < 5x linear {linear_flops:.3e}"
    );
    println!("E19: mlp step FLOPs = {:.1}x linear — PASS", mlp_flops / linear_flops);
    let overlap_speedup = sync_wall / legs[2].wall_s;
    let hiding_speedup = legs[1].wall_s / legs[2].wall_s;
    println!("\noverlap-m vs sync (equal steps): {overlap_speedup:.2}x");
    println!("overlap-m vs local@same-tau (pure hiding): {hiding_speedup:.2}x");

    // E13 hard gate: after warm-up the pooled backend must spawn no
    // threads and miss the buffer pool zero times, on every schedule.
    let mut steady_spawns_max = 0u64;
    let mut steady_allocs_max = 0u64;
    for leg in &legs {
        steady_spawns_max = steady_spawns_max.max(leg.log.hot.steady_thread_spawns);
        steady_allocs_max = steady_allocs_max.max(leg.log.hot.steady_buffer_allocs);
        anyhow::ensure!(
            leg.log.hot.steady_thread_spawns == 0,
            "{}: {} thread spawns after warm-up (want 0)",
            leg.label,
            leg.log.hot.steady_thread_spawns
        );
        anyhow::ensure!(
            leg.log.hot.steady_buffer_allocs == 0,
            "{}: {} tracked allocations after warm-up (want 0)",
            leg.label,
            leg.log.hot.steady_buffer_allocs
        );
    }
    println!("E13: steady-state spawns = 0 and tracked allocs = 0 on every leg — PASS");

    let (mean_serial, mean_pooled) = mean_micro(base.workers, smoke);
    println!(
        "mean_into x 8 replicas: serial {:.1} ms, pooled({}) {:.1} ms ({:.2}x)",
        1e3 * mean_serial,
        base.workers,
        1e3 * mean_pooled,
        mean_serial / mean_pooled
    );

    let out = Path::new("results/wallclock");
    for leg in &legs {
        let name = format!("{}_tau{}.json", leg.label.replace('+', "_"), leg.tau);
        write_json(out, &name, &leg.log.to_json())?;
    }
    let summary = obj(vec![
        ("bench", s("wallclock")),
        ("experiment", s("E12+E13+E19")),
        ("mlp_flops_vs_linear", num(mlp_flops / linear_flops)),
        ("host_cores", num(cores as f64)),
        ("workers", num(base.workers as f64)),
        ("steps", num(legs[0].log.steps as f64)),
        ("smoke", Json::Bool(smoke)),
        ("digest_identical_sim_vs_threads", Json::Bool(true)),
        (
            "legs",
            arr(legs.iter().map(|l| {
                obj(vec![
                    ("label", s(l.label)),
                    ("algo", s(l.algo.name())),
                    ("tau", num(l.tau as f64)),
                    ("model", s(l.model)),
                    ("kernels", s(l.kernels.name())),
                    ("execution", s("threads")),
                    ("wall_s", num(l.wall_s)),
                    ("step_time_s", num(l.step_time_s())),
                    ("flops_per_step", num(l.flops_per_step)),
                    ("gflops", num(l.gflops(base.workers))),
                    ("speedup_vs_sync", num(sync_wall / l.wall_s)),
                    ("virtual_sim_time_s", num(l.log.total_sim_time)),
                    ("digest", s(&format!("{:016x}", l.log.digest()))),
                    ("rounds", num(l.log.hot.rounds as f64)),
                    (
                        "thread_spawns_total",
                        num(l.log.hot.thread_spawns_total as f64),
                    ),
                    (
                        "steady_thread_spawns",
                        num(l.log.hot.steady_thread_spawns as f64),
                    ),
                    (
                        "buffer_allocs_total",
                        num(l.log.hot.buffer_allocs_total as f64),
                    ),
                    (
                        "steady_buffer_allocs",
                        num(l.log.hot.steady_buffer_allocs as f64),
                    ),
                    (
                        "steady_buffer_alloc_bytes",
                        num(l.log.hot.steady_buffer_alloc_bytes as f64),
                    ),
                    ("timed_run_allocs", num(l.timed_allocs as f64)),
                    ("timed_run_alloc_bytes", num(l.timed_alloc_bytes as f64)),
                ])
            })),
        ),
        ("speedup_overlap_vs_sync", num(overlap_speedup)),
        ("speedup_overlap_vs_local", num(hiding_speedup)),
        ("steady_thread_spawns_max", num(steady_spawns_max as f64)),
        ("steady_buffer_allocs_max", num(steady_allocs_max as f64)),
        ("mean_into_serial_s", num(mean_serial)),
        ("mean_into_pooled_s", num(mean_pooled)),
    ]);
    write_json(Path::new("."), "BENCH_wallclock.json", &summary)?;
    println!("\nwrote BENCH_wallclock.json and {}/", out.display());

    if std::env::var("OLSGD_WC_ASSERT").map(|v| v == "1").unwrap_or(false) {
        anyhow::ensure!(
            overlap_speedup >= 1.2,
            "overlap-m wall-clock speedup {overlap_speedup:.2}x < 1.2x over sync \
             (needs >= 4 physical cores to be meaningful; got {cores})"
        );
        println!("acceptance: overlap-m >= 1.2x over sync at equal steps — PASS");
    }
    Ok(())
}
