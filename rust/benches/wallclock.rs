//! **E12 — wall-clock hiding** (EXPERIMENTS.md): the first *real-time*
//! point on the perf trajectory. Every other bench reports virtual simnet
//! seconds; this one times the `--execution threads` backend on real
//! cores, where the local phase runs one OS thread per worker and each
//! collective runs on a background communicator thread.
//!
//! Protocol (equal global steps for every leg):
//!
//! * `sync τ=1`    — blocking collective every step: the baseline;
//! * `local τ=T`   — blocking collective every T steps: amortization only;
//! * `overlap-m τ=T` — non-blocking collective under the next round's
//!   compute: amortization + hiding (the paper's schedule);
//! * `overlap-gossip τ=T` — decentralized exchange, also hidden.
//!
//! Each leg runs under BOTH backends; the bench hard-asserts the two
//! `TrainLog` digests are identical (the tentpole guarantee) and records
//! the threads-backend wall time. Results land in `BENCH_wallclock.json`
//! at the repo root plus per-leg JSONs under `results/wallclock/`.
//!
//! Sizing: `OLSGD_SMOKE=1` shrinks everything for CI; `OLSGD_WC_ASSERT=1`
//! additionally hard-fails unless overlap-m beats sync by ≥ 1.2× (the
//! ISSUE-3 acceptance bar — meaningful on ≥ 4 physical cores). A serial
//! vs thread-parallel `mean_into` micro-comparison rides along.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;
use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::{write_json, TrainLog};
use olsgd::model::vecmath;
use olsgd::runtime::ModelRuntime;
use olsgd::util::json::{arr, num, obj, s, Json};

struct Leg {
    label: &'static str,
    algo: Algo,
    tau: usize,
    wall_s: f64,
    log: TrainLog,
}

fn run_both(cfg: &ExperimentConfig, rt: &ModelRuntime) -> Result<(f64, TrainLog)> {
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

    let mut sim_cfg = cfg.clone();
    sim_cfg.execution = Execution::Sim;
    let sim_log = run_experiment(rt, &sim_cfg, &train, &test)?;

    let mut thr_cfg = cfg.clone();
    thr_cfg.execution = Execution::Threads;
    // Warm-up run (page in code/data, spin up the allocator), then timed.
    run_experiment(rt, &thr_cfg, &train, &test)?;
    let t0 = Instant::now();
    let thr_log = run_experiment(rt, &thr_cfg, &train, &test)?;
    let wall = t0.elapsed().as_secs_f64();

    assert_eq!(
        sim_log.digest(),
        thr_log.digest(),
        "{}: threads backend drifted from sim — the digest-identity \
         guarantee is broken",
        cfg.algo.name()
    );
    Ok((wall, thr_log))
}

fn mean_micro(threads: usize, smoke: bool) -> (f64, f64) {
    // Paper-scale flat vectors (11.2 M params, 8 replicas); smoke mode
    // shrinks them so CI runners don't pay ~400 MB for a footnote.
    let n = if smoke { 1 << 20 } else { 11_173_962 };
    let m = 8;
    let vs: Vec<Vec<f32>> = (0..m).map(|w| vec![w as f32 * 0.25 + 0.1; n]).collect();
    let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0.0f32; n];
    // Warm both paths first so the serial leg doesn't eat the output
    // buffer's first-touch page faults (which would flatter the parallel
    // ratio); then time a second pass of each over resident memory.
    vecmath::mean_into(&refs, &mut out);
    vecmath::mean_into_parallel(&refs, &mut out, threads);
    let t0 = Instant::now();
    vecmath::mean_into(&refs, &mut out);
    let serial = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    vecmath::mean_into_parallel(&refs, &mut out, threads);
    let parallel = t1.elapsed().as_secs_f64();
    (serial, parallel)
}

fn main() -> Result<()> {
    let smoke = std::env::var("OLSGD_SMOKE").map(|v| v == "1").unwrap_or(false);
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);

    let mut base = ExperimentConfig::default();
    base.model = "linear".into();
    base.workers = cores.clamp(2, 8);
    if let Ok(w) = std::env::var("OLSGD_WC_WORKERS") {
        base.workers = w.parse().unwrap_or(base.workers);
    }
    base.train_n = base.workers * if smoke { 64 } else { 256 };
    base.test_n = 100;
    base.epochs = if smoke { 2.0 } else { 8.0 };
    if let Ok(e) = std::env::var("OLSGD_WC_EPOCHS") {
        base.epochs = e.parse().unwrap_or(base.epochs);
    }
    base.eval_every = base.epochs; // eval only at the end: time the training
    let tau = 8;

    let rt = ModelRuntime::native(&base.model)?;
    println!(
        "=== E12 wall-clock hiding (threads backend, {} cores, m={}, {} global steps) ===",
        cores,
        base.workers,
        (base.epochs * (base.train_n as f64 / base.workers as f64 / 32.0)).round()
    );
    println!("{:<22} {:>6} {:>12} {:>14} {:>12}", "leg", "tau", "wall (s)", "vs sync", "digest");

    let specs: [(&'static str, Algo, usize); 4] = [
        ("sync", Algo::Sync, 1),
        ("local", Algo::Local, tau),
        ("overlap-m", Algo::OverlapM, tau),
        ("overlap-gossip", Algo::OverlapGossip, tau),
    ];
    let mut legs: Vec<Leg> = Vec::new();
    for (label, algo, tau) in specs {
        let mut cfg = base.clone();
        cfg.algo = algo;
        cfg.tau = tau;
        let (wall_s, log) = run_both(&cfg, &rt)?;
        legs.push(Leg { label, algo, tau, wall_s, log });
    }

    let sync_wall = legs[0].wall_s;
    for leg in &legs {
        println!(
            "{:<22} {:>6} {:>12.4} {:>13.2}x {:>12}",
            leg.label,
            leg.tau,
            leg.wall_s,
            sync_wall / leg.wall_s,
            "ok"
        );
    }
    let overlap_speedup = sync_wall / legs[2].wall_s;
    let hiding_speedup = legs[1].wall_s / legs[2].wall_s;
    println!("\noverlap-m vs sync (equal steps): {overlap_speedup:.2}x");
    println!("overlap-m vs local@same-tau (pure hiding): {hiding_speedup:.2}x");

    let (mean_serial, mean_parallel) = mean_micro(base.workers, smoke);
    println!(
        "mean_into x 8 replicas: serial {:.1} ms, parallel({}) {:.1} ms ({:.2}x)",
        1e3 * mean_serial,
        base.workers,
        1e3 * mean_parallel,
        mean_serial / mean_parallel
    );

    let out = Path::new("results/wallclock");
    for leg in &legs {
        write_json(out, &format!("{}_tau{}.json", leg.algo.name(), leg.tau), &leg.log.to_json())?;
    }
    let summary = obj(vec![
        ("bench", s("wallclock")),
        ("experiment", s("E12")),
        ("host_cores", num(cores as f64)),
        ("workers", num(base.workers as f64)),
        ("steps", num(legs[0].log.steps as f64)),
        ("smoke", Json::Bool(smoke)),
        ("digest_identical_sim_vs_threads", Json::Bool(true)),
        (
            "legs",
            arr(legs.iter().map(|l| {
                obj(vec![
                    ("label", s(l.label)),
                    ("algo", s(l.algo.name())),
                    ("tau", num(l.tau as f64)),
                    ("execution", s("threads")),
                    ("wall_s", num(l.wall_s)),
                    ("speedup_vs_sync", num(sync_wall / l.wall_s)),
                    ("virtual_sim_time_s", num(l.log.total_sim_time)),
                    ("digest", s(&format!("{:016x}", l.log.digest()))),
                ])
            })),
        ),
        ("speedup_overlap_vs_sync", num(overlap_speedup)),
        ("speedup_overlap_vs_local", num(hiding_speedup)),
        ("mean_into_serial_s", num(mean_serial)),
        ("mean_into_parallel_s", num(mean_parallel)),
    ]);
    write_json(Path::new("."), "BENCH_wallclock.json", &summary)?;
    println!("\nwrote BENCH_wallclock.json and {}/", out.display());

    if std::env::var("OLSGD_WC_ASSERT").map(|v| v == "1").unwrap_or(false) {
        anyhow::ensure!(
            overlap_speedup >= 1.2,
            "overlap-m wall-clock speedup {overlap_speedup:.2}x < 1.2x over sync \
             (needs >= 4 physical cores to be meaningful; got {cores})"
        );
        println!("acceptance: overlap-m >= 1.2x over sync at equal steps — PASS");
    }
    Ok(())
}
