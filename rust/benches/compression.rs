//! **E15 — bytes-vs-final-loss Pareto for the compression axis**
//! (DESIGN.md §12, EXPERIMENTS.md E15): what does each compressor buy on
//! the wire, and what does it cost in final loss?
//!
//! Legs: {none, topk, qsgd, powersgd} × {sync, overlap-m} on the ring, plus
//! a hierarchical-topology leg (the per-topology cost formulas must see the
//! scaled payload) and a threads-backend leg (the compressed hot path must
//! stay spawn- and alloc-free on real cores). Every row records the
//! compressed `bytes_sent` next to the same leg's *uncompressed* baseline
//! bytes — the CI `compression-matrix` job gates on
//! `bytes_sent < uncompressed_bytes` for every real compressor and on
//! `steady_buffer_allocs == 0` across the board.
//!
//! The summary lands in `results/compression/E15_compression.json`.

use anyhow::Result;
use olsgd::bench::experiments::BenchCtx;
use olsgd::config::Algo;
use olsgd::metrics::TrainLog;
use olsgd::util::json::{num, obj, s, Json};

fn leg_row(label: &str, topology: &str, log: &TrainLog, uncompressed_bytes: u64) -> Json {
    obj(vec![
        ("label", s(label)),
        ("algo", s(&log.algo)),
        ("compress", s(&log.compress)),
        ("topology", s(topology)),
        ("bytes_sent", num(log.bytes_sent as f64)),
        ("uncompressed_bytes", num(uncompressed_bytes as f64)),
        ("final_acc", num(log.final_acc())),
        ("final_test_loss", num(log.final_loss())),
        ("total_time_s", num(log.total_sim_time)),
        ("comm_ratio", num(log.comm_ratio())),
        ("steady_buffer_allocs", num(log.hot.steady_buffer_allocs as f64)),
    ])
}

fn print_leg(label: &str, log: &TrainLog, uncompressed_bytes: u64) {
    println!(
        "{:<26} {:>9} {:>14} {:>8.2} {:>11.4} {:>10.1} {:>7.1}%",
        label,
        log.bytes_sent,
        uncompressed_bytes,
        100.0 * log.final_acc(),
        log.final_loss(),
        log.total_sim_time,
        100.0 * (log.bytes_sent as f64 / uncompressed_bytes.max(1) as f64),
    );
}

const KINDS: [&str; 3] = ["topk", "qsgd", "powersgd"];

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("compression")?;
    let mut rows = Vec::new();

    println!("=== E15: bytes vs final loss (per compressor x algorithm, ring) ===");
    println!(
        "{:<26} {:>9} {:>14} {:>8} {:>11} {:>10} {:>8}",
        "leg", "bytes", "uncompressed", "acc%", "test_loss", "time(s)", "wire%"
    );

    for algo in [Algo::Sync, Algo::OverlapM] {
        let base = ctx.run_leg(&format!("{}_none", algo.name()), |c| c.algo = algo)?;
        let unc = base.bytes_sent;
        print_leg(&format!("{} none", algo.name()), &base, unc);
        rows.push(leg_row(&format!("{} none", algo.name()), "ring", &base, unc));
        for kind in KINDS {
            let label = format!("{}_{kind}", algo.name());
            let log = ctx.run_leg(&label, |c| {
                c.algo = algo;
                c.set("compress", kind).expect("static compressor name");
            })?;
            print_leg(&label.replace('_', " "), &log, unc);
            rows.push(leg_row(&label.replace('_', " "), "ring", &log, unc));
        }
    }

    // The per-topology cost formulas must see the scaled payload: the same
    // sweep point on the hierarchical (intra/inter group) topology.
    println!("\n=== E15: hierarchical topology leg (sync) ===");
    let hier_base = ctx.run_leg("sync_hier_none", |c| {
        c.algo = Algo::Sync;
        c.set("topology", "hier").expect("static topology");
    })?;
    let hier_unc = hier_base.bytes_sent;
    print_leg("sync hier none", &hier_base, hier_unc);
    rows.push(leg_row("sync hier none", "hier", &hier_base, hier_unc));
    let hier_topk = ctx.run_leg("sync_hier_topk", |c| {
        c.algo = Algo::Sync;
        c.set("topology", "hier").expect("static topology");
        c.set("compress", "topk").expect("static compressor name");
    })?;
    print_leg("sync hier topk", &hier_topk, hier_unc);
    rows.push(leg_row("sync hier topk", "hier", &hier_topk, hier_unc));

    // The compressed hot path on real cores: persistent pool, zero
    // steady-state spawns/allocs, digest identical to sim (locked by
    // rust/tests/compression.rs).
    println!("\n=== E15: threads-backend leg (overlap-m + topk) ===");
    let thr_base = ctx.run_leg("overlap-m_threads_none", |c| {
        c.algo = Algo::OverlapM;
        c.set("execution", "threads").expect("static backend");
    })?;
    let thr_unc = thr_base.bytes_sent;
    print_leg("overlap-m threads none", &thr_base, thr_unc);
    rows.push(leg_row("overlap-m threads none", "ring", &thr_base, thr_unc));
    let thr_topk = ctx.run_leg("overlap-m_threads_topk", |c| {
        c.algo = Algo::OverlapM;
        c.set("execution", "threads").expect("static backend");
        c.set("compress", "topk").expect("static compressor name");
    })?;
    print_leg("overlap-m threads topk", &thr_topk, thr_unc);
    rows.push(leg_row("overlap-m threads topk", "ring", &thr_topk, thr_unc));

    ctx.write_summary("E15_compression.json", rows)?;
    Ok(())
}
