//! Design-choice ablations called out in DESIGN.md:
//!
//! * **alpha sweep** — the pullback strength (paper §4: "for tau >= 2,
//!   alpha = 0.6 consistently yields the best accuracy"; Eq. 19 shows the
//!   effective lr is (1-alpha)*gamma, so too-large alpha slows progress and
//!   too-small alpha loses the contraction that stabilizes non-IID runs).
//! * **beta sweep** — the anchor momentum (paper: 0.7 following SlowMo);
//!   beta = 0 is the vanilla Eq. (5) anchor.
//! * **local optimizer** — Nesterov (paper recipe) vs fused Adam (the §6
//!   extension, Overlap-Local-Adam).

use anyhow::Result;
use olsgd::bench::experiments::{header, print_row, row, BenchCtx};
use olsgd::config::Algo;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("ablations")?;
    let epochs = ctx.base.epochs;
    let mut rows = Vec::new();

    header("Ablation A — pullback strength alpha (overlap-m, tau=2)");
    for alpha in [0.1f32, 0.3, 0.6, 0.9] {
        let label = format!("alpha_{alpha}");
        let mut cfg = ctx.base.clone();
        cfg.algo = Algo::OverlapM;
        cfg.tau = 2;
        cfg.alpha = alpha;
        let log = ctx.run_leg_exact(&label, cfg)?;
        print_row(&format!("alpha={alpha}"), 2, &log, epochs);
        rows.push(row(&label, Algo::OverlapM, 2, &log, epochs));
    }

    header("Ablation B — anchor momentum beta (overlap, tau=2)");
    for beta in [0.0f32, 0.4, 0.7, 0.9] {
        let label = format!("beta_{beta}");
        let mut cfg = ctx.base.clone();
        cfg.algo = Algo::OverlapM;
        cfg.tau = 2;
        cfg.alpha = 0.6;
        cfg.beta = beta;
        let log = ctx.run_leg_exact(&label, cfg)?;
        print_row(&format!("beta={beta}"), 2, &log, epochs);
        rows.push(row(&label, Algo::OverlapM, 2, &log, epochs));
    }

    header("Ablation C — local optimizer (paper §6 extension)");
    for opt in ["nesterov", "adam"] {
        let label = format!("opt_{opt}");
        let mut cfg = ctx.base.clone();
        cfg.algo = Algo::OverlapM;
        cfg.tau = 2;
        cfg.alpha = 0.6;
        cfg.local_opt = opt.into();
        if opt == "adam" {
            cfg.base_lr = 0.002; // Adam's lr scale
        }
        let log = ctx.run_leg_exact(&label, cfg)?;
        print_row(opt, 2, &log, epochs);
        rows.push(row(&label, Algo::OverlapM, 2, &log, epochs));
    }

    ctx.write_summary("ablations_summary.json", rows)
}
