//! **E2/E3 — Figure 4 (IID)**: communication-efficient methods compared.
//!
//! (a) final accuracy vs per-epoch time for sync SGD, Local SGD, EAMSGD,
//!     CoCoD, Overlap-Local-SGD (tau = 2) and PowerSGD at ranks {1,2,4,8};
//! (b)/(c) loss vs time and vs iterations at tau = 2 — emitted into the
//!     per-leg result JSONs (records carry sim_time and step).
//!
//! Paper claims reproduced in shape: overlap's added latency over pure
//! compute is near zero; PowerSGD keeps a handshake-dominated latency floor
//! even at rank 1; loss-vs-iterations of overlap tracks sync SGD closely.

use anyhow::Result;
use olsgd::bench::experiments::{header, print_row, row, BenchCtx};
use olsgd::config::Algo;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("fig4_iid")?;
    let epochs = ctx.base.epochs;

    header("Fig. 4 — IID comparison of communication-efficient methods (tau=2)");
    let mut rows = Vec::new();

    for (label, algo) in [
        ("sync", Algo::Sync),
        ("local-sgd", Algo::Local),
        ("eamsgd", Algo::Eamsgd),
        ("cocod", Algo::Cocod),
        ("overlap-local-sgd", Algo::OverlapM),
    ] {
        let log = ctx.run_leg(label, |c| {
            c.algo = algo;
            c.tau = 2;
        })?;
        print_row(label, 2, &log, epochs);
        rows.push(row(label, algo, 2, &log, epochs));
    }

    for rank in [1usize, 2, 4, 8] {
        let label = format!("powersgd_r{rank}");
        let log = ctx.run_leg(&label, |c| {
            c.algo = Algo::PowerSgd;
            c.tau = 1;
            c.rank = rank;
        })?;
        print_row(&label, 1, &log, epochs);
        rows.push(row(&label, Algo::PowerSgd, 1, &log, epochs));
    }

    println!(
        "\nshape check: overlap time/epoch ~= compute-only; powersgd keeps a\n\
         handshake latency floor at every rank; all methods reach similar acc."
    );
    ctx.write_summary("fig4_summary.json", rows)
}
