//! **E5 — Figure 5 (non-IID)**: the same comparison as Fig. 4 with the
//! paper's skewed partition (64% of each worker's shard from one class,
//! data not reshuffled). Paper claims: sync SGD and Local SGD become
//! unstable; Overlap-Local-SGD both reduces runtime AND converges more
//! stably (error-versus-iterations, panel c).

use anyhow::Result;
use olsgd::bench::experiments::{header, print_row, row, BenchCtx};
use olsgd::config::Algo;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("fig5_noniid")?;
    let epochs = ctx.base.epochs;

    header("Fig. 5 — non-IID comparison (tau=2, 64% dominant class)");
    let mut rows = Vec::new();

    for (label, algo) in [
        ("sync", Algo::Sync),
        ("local-sgd", Algo::Local),
        ("eamsgd", Algo::Eamsgd),
        ("cocod", Algo::Cocod),
        ("overlap-local-sgd", Algo::OverlapM),
    ] {
        let log = ctx.run_leg(&format!("noniid_{label}"), |c| {
            c.algo = algo;
            c.tau = 2;
            c.noniid = true;
            c.reshuffle = false;
        })?;
        print_row(label, 2, &log, epochs);
        rows.push(row(label, algo, 2, &log, epochs));
    }

    for rank in [1usize, 4] {
        let label = format!("powersgd_r{rank}");
        let log = ctx.run_leg(&format!("noniid_{label}"), |c| {
            c.algo = Algo::PowerSgd;
            c.tau = 1;
            c.rank = rank;
            c.noniid = true;
            c.reshuffle = false;
        })?;
        print_row(&label, 1, &log, epochs);
        rows.push(row(&label, Algo::PowerSgd, 1, &log, epochs));
    }

    println!("\nshape check: overlap stays stable; per-iteration loss curves in the\nresult JSONs show smaller oscillation than sync/local.");
    ctx.write_summary("fig5_summary.json", rows)
}
