//! **E7 — Figure 6 (appendix B)**: loss-versus-iterations of the three
//! decoupled Local-SGD variants at tau = 2 (Overlap-Local-SGD vs CoCoD-SGD
//! vs EAMSGD). Paper claim: ours slightly improves on CoCoD and clearly
//! beats EAMSGD. The per-step loss series for the plot is in each leg's
//! result JSON (`step_losses`).

use anyhow::Result;
use olsgd::bench::experiments::{header, print_row, row, BenchCtx};
use olsgd::config::Algo;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("fig6_variants")?;
    let epochs = ctx.base.epochs;

    header("Fig. 6 — Local-SGD variants, loss vs iterations (tau=2)");
    let mut rows = Vec::new();
    for (label, algo) in [
        ("overlap-local-sgd", Algo::OverlapM),
        ("cocod", Algo::Cocod),
        ("eamsgd", Algo::Eamsgd),
    ] {
        let log = ctx.run_leg(&format!("fig6_{label}"), |c| {
            c.algo = algo;
            c.tau = 2;
        })?;
        print_row(label, 2, &log, epochs);
        // println! the last few loss points as the "curve tail"
        let tail: Vec<String> = log
            .step_losses
            .iter()
            .rev()
            .take(5)
            .map(|(k, l)| format!("(k={k}, {l:.3})"))
            .collect();
        println!("    loss tail: {}", tail.join(" "));
        rows.push(row(label, algo, 2, &log, epochs));
    }
    ctx.write_summary("fig6_summary.json", rows)
}
