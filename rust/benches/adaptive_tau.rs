//! **Adaptive-τ ablation** (AdaComm, Wang & Joshi 2018 — PAPERS.md): the
//! best error-runtime trade-off needs a τ that *varies* during training.
//! `overlap-ada` starts at a large τ (cheap rounds early) and halves it on
//! a loss-plateau signal, never below `tau_min`.
//!
//! Legs: fixed τ=1 (max communication), fixed τ=8 (max hiding), adaptive
//! 8→1, and adaptive with heterogeneous τ under a 3x straggler. Wire set to
//! 10 Gbps with a short compute step so τ=1 cannot fully hide the
//! collective — the regime where the τ schedule matters.
//!
//! Invariants (asserted in rust/tests/hiding_claim.rs): adaptive τ is
//! monotone non-increasing, and its bytes + blocked-comm never exceed a
//! fixed run at τ = tau_min.

use anyhow::Result;
use olsgd::bench::experiments::{row, BenchCtx};
use olsgd::config::Algo;
use olsgd::simnet::StragglerModel;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("adaptive_tau")?;
    ctx.base.workers = 8;
    ctx.base.net_preset = "slow10g".into();
    ctx.base.base_step_s = 0.1;
    ctx.base.tau_min = 1;
    ctx.base.ada_patience = 1;
    let epochs = ctx.base.epochs;
    let msg_bytes = ctx.base.cluster(ctx.rt.n * 4)?.message_bytes.max(1) as u64;

    println!("=== adaptive-τ ablation (m=8, 10 Gbps wire, 100 ms steps) ===");
    println!(
        "{:<26} {:>8} {:>10} {:>12} {:>14} {:>10}",
        "configuration", "acc%", "comm%", "blocked(s)", "bytes(MB)", "rounds~"
    );

    let mut rows = Vec::new();
    let legs: [(&str, Algo, usize, Option<StragglerModel>); 4] = [
        ("overlap-m tau=1", Algo::OverlapM, 1, None),
        ("overlap-m tau=8", Algo::OverlapM, 8, None),
        ("overlap-ada 8->1", Algo::OverlapAda, 8, None),
        (
            "overlap-ada + hetero-tau",
            Algo::OverlapAda,
            8,
            Some(StragglerModel::SlowNode { node: 0, factor: 3.0 }),
        ),
    ];
    for (label, algo, tau, straggler) in legs {
        let log = ctx.run_leg(&label.replace([' ', '>', '+'], "_"), |c| {
            c.algo = algo;
            c.tau = tau;
            if let Some(s) = straggler.clone() {
                c.straggler = s;
                c.tau_hetero = true;
            }
        })?;
        let rounds = log.bytes_sent / (log.workers as u64 * msg_bytes);
        println!(
            "{:<26} {:>8.2} {:>9.1}% {:>12.3} {:>14.1} {:>10}",
            label,
            100.0 * log.final_acc(),
            100.0 * log.comm_ratio(),
            log.total_comm_blocked_s,
            log.bytes_sent as f64 / 1e6,
            rounds
        );
        if !log.tau_trace.is_empty() {
            let trace: Vec<String> =
                log.tau_trace.iter().map(|&(k, t)| format!("step {k}: tau={t}")).collect();
            println!("    tau schedule: {}", trace.join(", "));
        }
        rows.push(row(label, algo, tau, &log, epochs));
    }
    ctx.write_summary("summary.json", rows)?;
    Ok(())
}
