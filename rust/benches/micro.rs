//! Component micro-benchmarks — the perf pass's measurement tool
//! (EXPERIMENTS.md §Perf). Times every hot-path component in isolation:
//! PJRT artifact executions (L2/L1), flat-vector math, the ring collective,
//! and a PowerSGD round.

use std::path::Path;

use anyhow::Result;
use olsgd::bench::{bench, black_box};
use olsgd::collective::ring_allreduce_mean;
use olsgd::compress::PowerSgd;
use olsgd::data::{self, GenConfig, PX};
use olsgd::model::vecmath;
use olsgd::runtime::load_auto;
use olsgd::util::rng::Rng;

fn main() -> Result<()> {
    let rt = load_auto(Path::new("artifacts"), "cnn")?;
    let n = rt.n;
    let b = rt.train_batch;

    let mut rng = Rng::seed_from(1);
    let params = olsgd::model::init_params(&rt.manifest, 1);
    let mom = vec![0.0f32; n];
    let gen = GenConfig::default();
    let ds = data::generate(1, 256, "train", &gen);
    let images = ds.images[..b * PX].to_vec();
    let labels = ds.labels[..b].to_vec();
    let eval_images = ds.images[..rt.eval_batch * PX].to_vec();
    let eval_labels = ds.labels[..rt.eval_batch].to_vec();

    println!("== model-kernel executions (model={}, {n} params, batch {b}) ==", rt.name);
    bench("train_step (fwd+bwd+fused nesterov)", 2, 12, || {
        rt.train_step(&params, &mom, &images, &labels, 0.1, 0.9, 1e-4).unwrap()
    });
    bench("grad_step (fwd+bwd)", 2, 12, || {
        rt.grad_step(&params, &images, &labels).unwrap()
    });
    bench("evaluate (batch 100)", 2, 12, || {
        rt.evaluate(&params, &eval_images, &eval_labels).unwrap()
    });
    let z = params.clone();
    bench("pullback artifact", 2, 20, || rt.pullback(&params, &z, 0.6).unwrap());
    let v = vec![0.0f32; n];
    bench("anchor artifact", 2, 20, || rt.anchor_update(&z, &v, &params, 0.7).unwrap());
    let g = {
        let mut g = vec![0.0f32; n];
        rng.fill_normal(&mut g, 0.01);
        g
    };
    bench("sgd_update artifact", 2, 20, || {
        rt.sgd_update(&params, &mom, &g, 0.1, 0.9, 1e-4).unwrap()
    });

    println!("\n== L3 vector math (n = {n} and paper-scale 11.2M) ==");
    for size in [n, 11_173_962] {
        let vecs: Vec<Vec<f32>> = (0..8)
            .map(|i| {
                let mut v = vec![0.0f32; size];
                Rng::seed_from(i as u64).fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = vecs.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; size];
        bench(&format!("mean_into m=8 n={size}"), 2, 10, || {
            vecmath::mean_into(black_box(&refs), &mut out)
        });
        let mut bufs = vecs.clone();
        bench(&format!("ring_allreduce m=8 n={size}"), 1, 5, || {
            ring_allreduce_mean(black_box(&mut bufs))
        });
        let zz = vecs[0].clone();
        let mut xx = vecs[1].clone();
        bench(&format!("pullback_inplace n={size}"), 2, 10, || {
            vecmath::pullback_inplace(black_box(&mut xx), &zz, 0.6)
        });
    }

    println!("\n== PowerSGD round (model=cnn manifest, m=8) ==");
    for rank in [1usize, 4] {
        let mut psgd = PowerSgd::new(&rt.manifest, rank, 8, 1);
        let grads: Vec<Vec<f32>> = (0..8)
            .map(|i| {
                let mut v = vec![0.0f32; n];
                Rng::seed_from(10 + i as u64).fill_normal(&mut v, 0.01);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        bench(&format!("powersgd round rank={rank}"), 2, 10, || {
            psgd.round(black_box(&refs))
        });
    }
    Ok(())
}
