//! **E16 — the net service plane, measured** (EXPERIMENTS.md): every leg
//! runs the same fixed-seed config under `sim` and under `--execution net`
//! (4 real worker processes over localhost TCP serving the paper's m=16
//! cluster), hard-asserts the two `TrainLog` digests are identical, and
//! records the net backend's wall time plus the steady-state hot counters
//! (which must stay zero: the coordinator spawns processes at startup and
//! *threads* never, and the round loop reuses all of its buffers).
//!
//! A kill leg rides along: a worker process is killed after serving round
//! 2 (`net_kill=1:2`) and the run must land on exactly the digest of the
//! explicit `--fault crash@3:1` schedule — process death is a scheduled
//! fault, byte for byte.
//!
//! Results land in `results/net/E16_net.json`; CI's `net-matrix` job gates
//! on every leg's `digest_match` and on zero steady-state spawns/allocs.
//! `OLSGD_SMOKE=1` shrinks the workload for CI.

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Result};
use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::{write_json, TrainLog};
use olsgd::runtime::ModelRuntime;
use olsgd::util::json::{arr, num, obj, s, Json};

struct Leg {
    label: String,
    algo: Algo,
    digest_sim: u64,
    digest_net: u64,
    wall_s: f64,
    log: TrainLog,
}

/// Run `cfg` on sim, then on net (timed), and return both digests plus the
/// net run's log. `sim_cfg` lets the kill leg pin the sim side to an
/// explicit fault schedule instead of a killed process.
fn run_pair(
    sim_cfg: &ExperimentConfig,
    net_cfg: &ExperimentConfig,
    rt: &ModelRuntime,
) -> Result<(u64, u64, f64, TrainLog)> {
    let gen = GenConfig::default();
    let train = data::generate(sim_cfg.seed, sim_cfg.train_n, "train", &gen);
    let test = data::generate(sim_cfg.seed, sim_cfg.test_n, "test", &gen);
    let mut c = sim_cfg.clone();
    c.execution = Execution::Sim;
    let sim = run_experiment(rt, &c, &train, &test)?;
    let mut n = net_cfg.clone();
    n.execution = Execution::Net;
    let t0 = Instant::now();
    let net = run_experiment(rt, &n, &train, &test)?;
    let wall_s = t0.elapsed().as_secs_f64();
    Ok((sim.digest(), net.digest(), wall_s, net))
}

fn main() -> Result<()> {
    let smoke = std::env::var("OLSGD_SMOKE").map(|v| v == "1").unwrap_or(false);

    let mut base = ExperimentConfig::default();
    base.model = "linear".into();
    base.workers = 16;
    base.train_n = base.workers * 64;
    base.test_n = 100;
    base.epochs = if smoke { 2.0 } else { 6.0 };
    base.eval_every = base.epochs;
    base.tau = 2;
    base.set("net_worker_bin", env!("CARGO_BIN_EXE_olsgd"))?;
    base.set("net_procs", "4")?;
    base.set("net_timeout_s", "120")?;

    let rt = ModelRuntime::native(&base.model)?;
    println!(
        "=== E16 net service plane (m={}, {} worker processes, localhost TCP) ===",
        base.workers, 4
    );
    println!(
        "{:<24} {:>10} {:>18} {:>18} {:>8} {:>8}",
        "leg", "wall (s)", "digest sim", "digest net", "spawns", "allocs"
    );

    let specs: [(&str, Algo, usize); 4] = [
        ("sync", Algo::Sync, 1),
        ("local", Algo::Local, 2),
        ("overlap-m", Algo::OverlapM, 2),
        ("cocod", Algo::Cocod, 2),
    ];
    let mut legs: Vec<Leg> = Vec::new();
    for (label, algo, tau) in specs {
        let mut cfg = base.clone();
        cfg.algo = algo;
        cfg.tau = tau;
        let (digest_sim, digest_net, wall_s, log) = run_pair(&cfg, &cfg, &rt)?;
        legs.push(Leg { label: label.to_string(), algo, digest_sim, digest_net, wall_s, log });
    }

    // The kill leg: net run loses worker process 1 after it serves round 2;
    // sim run schedules the equivalent crash explicitly. Same digest or bust.
    {
        let mut net_cfg = base.clone();
        net_cfg.workers = 4;
        net_cfg.train_n = net_cfg.workers * 64;
        net_cfg.algo = Algo::OverlapM;
        net_cfg.epochs = 4.0;
        net_cfg.eval_every = net_cfg.epochs;
        net_cfg.set("net_kill", "1:2")?;
        let mut sim_cfg = net_cfg.clone();
        sim_cfg.set("net_kill", "")?;
        sim_cfg.set("fault", "crash@3:1")?;
        let (digest_sim, digest_net, wall_s, log) = run_pair(&sim_cfg, &net_cfg, &rt)?;
        legs.push(Leg {
            label: "kill-proc1@round2".to_string(),
            algo: Algo::OverlapM,
            digest_sim,
            digest_net,
            wall_s,
            log,
        });
    }

    for leg in &legs {
        println!(
            "{:<24} {:>10.4} {:>18} {:>18} {:>8} {:>8}",
            leg.label,
            leg.wall_s,
            format!("{:016x}", leg.digest_sim),
            format!("{:016x}", leg.digest_net),
            leg.log.hot.steady_thread_spawns,
            leg.log.hot.steady_buffer_allocs,
        );
        ensure!(
            leg.digest_sim == leg.digest_net,
            "{}: net backend drifted from sim ({:016x} vs {:016x})",
            leg.label,
            leg.digest_sim,
            leg.digest_net
        );
        ensure!(
            leg.log.hot.steady_thread_spawns == 0,
            "{}: {} thread spawns after warm-up (want 0: net spawns processes, not threads)",
            leg.label,
            leg.log.hot.steady_thread_spawns
        );
    }
    println!("E16: all digests match sim and steady-state spawns = 0 — PASS");

    let summary = obj(vec![
        ("bench", s("net")),
        ("experiment", s("E16")),
        ("workers", num(base.workers as f64)),
        ("net_procs", num(4.0)),
        ("smoke", Json::Bool(smoke)),
        (
            "legs",
            arr(legs.iter().map(|l| {
                obj(vec![
                    ("label", s(&l.label)),
                    ("algo", s(l.algo.name())),
                    ("execution", s("net")),
                    ("wall_s", num(l.wall_s)),
                    ("digest_sim", s(&format!("{:016x}", l.digest_sim))),
                    ("digest_net", s(&format!("{:016x}", l.digest_net))),
                    ("digest_match", Json::Bool(l.digest_sim == l.digest_net)),
                    ("rounds", num(l.log.hot.rounds as f64)),
                    (
                        "steady_thread_spawns",
                        num(l.log.hot.steady_thread_spawns as f64),
                    ),
                    (
                        "steady_buffer_allocs",
                        num(l.log.hot.steady_buffer_allocs as f64),
                    ),
                ])
            })),
        ),
    ]);
    write_json(Path::new("results/net"), "E16_net.json", &summary)?;
    println!("wrote results/net/E16_net.json");
    Ok(())
}
