//! **Topology ablation** (E10): wall-clock across communication graphs on
//! the paper's 16-node cluster, at 40 and 10 Gbps, straggler off and on.
//!
//! What the table shows (EXPERIMENTS.md E10):
//!
//! * blocking `local` pays each topology's collective on the critical path —
//!   the chunked ring wins at the 44.7 MB message size, and the gap widens
//!   on the slow wire (the unchunked tree pushes full messages per hop);
//! * both overlap variants hide their exchange completely at τ = 2;
//! * with a 3× slow node, `overlap-gossip` blocks only the straggler's
//!   graph neighborhood per round instead of the whole ring — strictly less
//!   blocked-communication time at equal τ (asserted in
//!   rust/tests/topology.rs).

use anyhow::Result;
use olsgd::bench::experiments::{row, BenchCtx};
use olsgd::config::Algo;
use olsgd::simnet::StragglerModel;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("topology")?;
    ctx.base.workers = 16;
    ctx.base.tau = 2;
    let epochs = ctx.base.epochs;

    let legs: [(&str, Algo, &str); 5] = [
        ("local ring", Algo::Local, "ring"),
        ("local hier(4)", Algo::Local, "hier"),
        ("local tree", Algo::Local, "tree"),
        ("overlap ring", Algo::Overlap, "ring"),
        ("overlap-gossip k=4", Algo::OverlapGossip, "ring"), // derives its own graph
    ];

    let mut rows = Vec::new();
    for (net, straggler) in [
        ("paper40g", None),
        ("slow10g", None),
        ("paper40g", Some(StragglerModel::SlowNode { node: 0, factor: 3.0 })),
    ] {
        let strag_tag = if straggler.is_some() { "slow-node 3x" } else { "uniform" };
        println!("\n=== topologies @ {net}, {strag_tag} (m=16, tau=2) ===");
        println!(
            "{:<20} {:>8} {:>11} {:>14} {:>12} {:>10} {:>10}",
            "series", "acc%", "test_loss", "time/epoch(s)", "blocked(s)", "idle(s)", "comm%"
        );
        for (label, algo, topology) in legs {
            let tag = format!("{}_{}_{}", label.replace(' ', "_"), net, strag_tag.replace(' ', "_"));
            let log = ctx.run_leg(&tag, |c| {
                c.algo = algo;
                c.topology = topology.into();
                c.net_preset = net.into();
                c.gossip_degree = 4;
                c.hier_groups = 4;
                if let Some(s) = straggler.clone() {
                    c.straggler = s;
                }
            })?;
            println!(
                "{:<20} {:>8.2} {:>11.4} {:>14.3} {:>12.2} {:>10.2} {:>9.1}%",
                label,
                100.0 * log.final_acc(),
                log.final_loss(),
                log.time_per_epoch(epochs),
                log.total_comm_blocked_s,
                log.total_idle_s,
                100.0 * log.comm_ratio()
            );
            if log.neighbor_bytes.iter().any(|&b| b > 0) {
                let (min, max) = (
                    log.neighbor_bytes.iter().min().copied().unwrap_or(0),
                    log.neighbor_bytes.iter().max().copied().unwrap_or(0),
                );
                println!(
                    "    per-worker neighbor bytes: min {:.1} MB, max {:.1} MB",
                    min as f64 / 1e6,
                    max as f64 / 1e6
                );
            }
            rows.push(row(&format!("{label} @ {net} {strag_tag}"), algo, 2, &log, epochs));
        }
    }
    ctx.write_summary("summary.json", rows)?;
    Ok(())
}
