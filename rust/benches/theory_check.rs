//! **E10 — Theorem 1**: empirical check of the convergence guarantee on a
//! smooth non-convex synthetic objective with analytic gradients.
//!
//! F_i(x) = 0.5 x'A x - b_i'x + c * sum_j cos(x_j)   (L-smooth, non-convex;
//! per-worker b_i heterogeneity realizes kappa, additive Gaussian noise
//! realizes sigma). We run the *exact* Overlap-Local-SGD recursion
//! (Eqs. 3-5) with the theorem's prescribed lr gamma = (1/L)sqrt(m/K) and
//! measure  (1/K) sum_k ||grad F(y_k)||^2  on the virtual sequence
//! y_k = (1-alpha) avg_i x_k^i + alpha z_k.
//!
//! Claims checked:
//!  * the average squared gradient norm decays ~ K^(-1/2) (log-log slope
//!    close to -1/2, the O(1/sqrt(mK)) regime);
//!  * larger m at fixed K gives a smaller bound (linear-speedup direction);
//!  * runs satisfy the K >= 60 m tau^2 / alpha^2 validity threshold.

use olsgd::model::vecmath;
use olsgd::util::rng::Rng;
use olsgd::util::stats::linear_fit;

const D: usize = 40;
const L: f64 = 4.0; // largest eigenvalue scale of A + cos curvature
const SIGMA: f32 = 0.4;
const COS_C: f32 = 0.5;

struct Problem {
    /// diagonal of A (so grads are cheap and L is explicit)
    a: Vec<f32>,
    /// per-worker linear terms (heterogeneity kappa)
    b: Vec<Vec<f32>>,
}

impl Problem {
    fn new(m: usize, rng: &mut Rng) -> Self {
        // eigenvalues in [0.5, L - COS_C] so total smoothness <= L
        let a: Vec<f32> = (0..D)
            .map(|_| 0.5 + rng.next_f32() * (L as f32 - COS_C - 0.5))
            .collect();
        let b = (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; D];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        Self { a, b }
    }

    /// grad F_i(x) (exact)
    fn grad_i(&self, i: usize, x: &[f32], out: &mut [f32]) {
        for j in 0..D {
            out[j] = self.a[j] * x[j] - self.b[i][j] - COS_C * x[j].sin();
        }
    }

    /// ||grad F(x)||^2 of the global objective (average of locals)
    fn global_grad_norm2(&self, x: &[f32]) -> f64 {
        let m = self.b.len();
        let mut total = 0.0f64;
        for j in 0..D {
            let mut bbar = 0.0f32;
            for bi in &self.b {
                bbar += bi[j];
            }
            bbar /= m as f32;
            let g = self.a[j] * x[j] - bbar - COS_C * x[j].sin();
            total += (g as f64) * (g as f64);
        }
        total
    }
}

/// Run Overlap-Local-SGD (vanilla anchor, Eqs. 3-5) for K steps; return the
/// running average of ||grad F(y_k)||^2.
fn run_overlap(problem: &Problem, m: usize, k_total: usize, tau: usize, alpha: f32, seed: u64) -> f64 {
    let gamma = (1.0 / L) * ((m as f64 / k_total as f64).sqrt());
    let gamma = gamma as f32;
    let mut rng = Rng::seed_from(seed);
    let mut xs = vec![vec![0.0f32; D]; m];
    let mut z = vec![0.0f32; D];
    let mut pending: Option<Vec<f32>> = None;
    let mut grad = vec![0.0f32; D];
    let mut acc = 0.0f64;

    for k in 0..k_total {
        // y_k = (1-alpha) avg x + alpha z
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut y = vecmath::mean(&refs);
        for j in 0..D {
            y[j] = (1.0 - alpha) * y[j] + alpha * z[j];
        }
        acc += problem.global_grad_norm2(&y);

        // local noisy gradient steps
        for (i, x) in xs.iter_mut().enumerate() {
            problem.grad_i(i, x, &mut grad);
            for j in 0..D {
                let noise = SIGMA * rng.next_normal() as f32;
                x[j] -= gamma * (grad[j] + noise);
            }
        }

        if (k + 1) % tau == 0 {
            // absorb previous round's (stale) average into the anchor
            if let Some(avg) = pending.take() {
                z = avg; // beta = 0: Eq. (5)
            }
            // pullback (Eq. 4)
            for x in xs.iter_mut() {
                vecmath::pullback_inplace(x, &z, alpha);
            }
            // launch "non-blocking" all-reduce of post-pullback models
            let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            pending = Some(vecmath::mean(&refs));
        }
    }
    acc / k_total as f64
}

fn main() {
    let tau = 4usize;
    let alpha = 0.6f32;
    println!("=== E10 — Theorem 1 empirical check (tau={tau}, alpha={alpha}) ===");

    // 1) decay in K at fixed m
    let m = 8;
    let mut rng = Rng::seed_from(42);
    let problem = Problem::new(m, &mut rng);
    let threshold = (60.0 * m as f64 * (tau * tau) as f64 / (alpha as f64 * alpha as f64)) as usize;
    println!("validity threshold K >= {threshold}");

    let ks = [threshold, threshold * 2, threshold * 4, threshold * 8, threshold * 16];
    let mut logk = Vec::new();
    let mut logg = Vec::new();
    println!("{:>10} {:>16}", "K", "avg ||grad F||^2");
    for &k in &ks {
        // average over seeds to tame noise
        let mut g = 0.0;
        let seeds = 3;
        for s in 0..seeds {
            g += run_overlap(&problem, m, k, tau, alpha, 100 + s);
        }
        g /= seeds as f64;
        println!("{k:>10} {g:>16.6}");
        logk.push((k as f64).ln());
        logg.push(g.ln());
    }
    let fit = linear_fit(&logk, &logg).expect("five K points always fit");
    assert!(!fit.degenerate, "distinct K values cannot be constant-x");
    let (slope, r2) = (fit.slope, fit.r2);
    println!("log-log slope = {slope:.3} (theory: -0.5 in the 1/sqrt(mK) regime), r2 = {r2:.3}");
    assert!(
        slope < -0.25 && slope > -0.85,
        "decay rate {slope} inconsistent with O(1/sqrt(K))"
    );

    // 2) linear-speedup direction: larger m -> smaller average grad norm at
    // the same K (each worker contributes gradient noise averaging).
    let k_fixed = threshold * 8;
    println!("\n{:>6} {:>16}", "m", "avg ||grad F||^2");
    let mut prev = f64::INFINITY;
    let mut ok_pairs = 0;
    let mut total_pairs = 0;
    for &m in &[2usize, 8, 32] {
        let mut rng = Rng::seed_from(7);
        let p = Problem::new(m, &mut rng);
        let mut g = 0.0;
        for s in 0..3 {
            g += run_overlap(&p, m, k_fixed, tau, alpha, 200 + s);
        }
        g /= 3.0;
        println!("{m:>6} {g:>16.6}");
        if g < prev {
            ok_pairs += 1;
        }
        total_pairs += 1;
        prev = g;
    }
    println!("monotone-decrease checks: {}/{}", ok_pairs, total_pairs - 1 + 1);
    println!("\nOK: Theorem 1 shape holds (rate ~ K^-1/2, noise averaging across m).");
}
