//! **E9 — §2 straggler mitigation**: sweep a deterministic slow node's
//! factor and a shifted-exponential slowdown; compare per-epoch time and
//! idle time across schedules. Paper claim (Fig. 3): with non-blocking
//! anchor synchronization there is no idle time waiting for slow nodes.

use anyhow::Result;
use olsgd::bench::experiments::{row, BenchCtx};
use olsgd::config::Algo;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("straggler")?;
    ctx.base.epochs = 2.0;
    ctx.base.eval_every = 2.0;
    ctx.base.tau = 4;
    let epochs = ctx.base.epochs;

    println!("=== E9 — straggler resilience (m=8, tau=4) ===");
    println!(
        "{:<14} {:<18} {:>14} {:>12} {:>10}",
        "algorithm", "straggler", "time/epoch(s)", "idle(s)", "slowdown"
    );

    let mut rows = Vec::new();
    for (algo, label) in [
        (Algo::Sync, "sync"),
        (Algo::Local, "local"),
        (Algo::Cocod, "cocod"),
        (Algo::OverlapM, "overlap"),
    ] {
        let mut base_tpe = 0.0f64;
        for (slabel, sspec) in [
            ("none", "none"),
            ("slow node 3x", "slow:0:3.0"),
            ("shifted-exp 0.3", "exp:0.3"),
        ] {
            let log = ctx.run_leg(&format!("{label}_{}", slabel.replace(' ', "_")), |c| {
                c.algo = algo;
                c.set("straggler", sspec).unwrap();
            })?;
            let tpe = log.time_per_epoch(epochs);
            if slabel == "none" {
                base_tpe = tpe;
            }
            println!(
                "{:<14} {:<18} {:>14.3} {:>12.2} {:>9.2}x",
                label,
                slabel,
                tpe,
                log.total_idle_s,
                tpe / base_tpe
            );
            rows.push(row(&format!("{label}/{slabel}"), algo, 4, &log, epochs));
        }
    }

    println!(
        "\nshape check: sync slows ~3x under a 3x straggler with large idle;\n\
         overlap's fast workers log ZERO idle (non-blocking collective)."
    );
    ctx.write_summary("straggler_summary.json", rows)
}
