//! **E8 — §4 "Negligible Communication Cost"**: the paper's headline
//! communication-to-computation numbers. With the calibrated 16-node /
//! 40 Gbps cluster model and ResNet-18-size messages:
//!
//! * fully-sync SGD: comm/compute ~ 34.6 %
//! * Overlap-Local-SGD tau=2: ~ 1.5 % (communication hidden)
//! * per-epoch added latency ~ 1.5 s (sync) vs ~ 0.1 s (overlap)
//!
//! Also reproduces the "slow interconnect magnifies the win" remark at
//! 10 Gbps. This bench uses the paper's m=16 topology (timing only depends
//! on the schedule, so a short run suffices).

use anyhow::Result;
use olsgd::bench::experiments::{row, BenchCtx};
use olsgd::config::Algo;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("comm_ratio")?;
    // Paper topology: 16 workers. Keep the workload small — ratios are
    // schedule properties, not accuracy properties.
    ctx.base.workers = 16;
    ctx.base.train_n = ctx.base.train_n.max(1024);
    ctx.base.epochs = 2.0;
    ctx.base.eval_every = 2.0;
    let epochs = ctx.base.epochs;

    println!("=== E8 — communication-to-computation ratio (m=16, ResNet-18-size messages) ===");
    println!(
        "{:<26} {:>10} {:>14} {:>16}",
        "configuration", "comm%", "time/epoch(s)", "added latency(s)"
    );

    let mut rows = Vec::new();
    let mut compute_only_epoch = 0.0f64;
    for (label, algo, tau, net) in [
        ("sync @40Gbps", Algo::Sync, 1usize, "paper40g"),
        ("local tau=2 @40Gbps", Algo::Local, 2, "paper40g"),
        ("overlap tau=2 @40Gbps", Algo::OverlapM, 2, "paper40g"),
        ("sync @10Gbps", Algo::Sync, 1, "slow10g"),
        ("overlap tau=2 @10Gbps", Algo::OverlapM, 2, "slow10g"),
    ] {
        let log = ctx.run_leg(&label.replace([' ', '@'], "_"), |c| {
            c.algo = algo;
            c.tau = tau;
            c.net_preset = net.into();
        })?;
        let tpe = log.time_per_epoch(epochs);
        if label == "sync @40Gbps" {
            // compute-only epoch time = sync minus its comm share
            compute_only_epoch =
                tpe * log.total_compute_s / (log.total_compute_s + log.total_comm_blocked_s + log.total_idle_s);
        }
        println!(
            "{:<26} {:>9.1}% {:>14.3} {:>16.3}",
            label,
            100.0 * log.comm_ratio(),
            tpe,
            tpe - compute_only_epoch
        );
        rows.push(row(label, algo, tau, &log, epochs));
    }

    println!(
        "\npaper: 34.6% (sync) -> 1.5% (overlap tau=2); added latency 1.5s -> 0.1s per epoch.\n\
         shape check: sync ratio ~30-35%, overlap ratio <2%, and the 10Gbps gap is larger."
    );
    ctx.write_summary("comm_ratio_summary.json", rows)
}
