//! **E4 — Table 1 (IID)**: final test accuracy of {CoCoD-SGD, EAMSGD,
//! Overlap-Local-SGD} x tau in {1, 2, 8, 24}, sync SGD as reference.
//!
//! Paper shape: ours >= cocod >= eamsgd at every tau; accuracy of all
//! methods decays as tau grows; at tau <= 2 ours matches or beats sync.

use anyhow::Result;
use olsgd::bench::experiments::{row, BenchCtx};
use olsgd::config::Algo;

fn main() -> Result<()> {
    let mut ctx = BenchCtx::new("table1_iid")?;
    let epochs = ctx.base.epochs;
    let taus = [1usize, 2, 8, 24];
    let algos = [
        ("CoCoD-SGD", Algo::Cocod),
        ("EAMSGD", Algo::Eamsgd),
        ("Ours", Algo::OverlapM),
    ];

    let sync = ctx.run_leg("sync_ref", |c| c.algo = Algo::Sync)?;

    let mut rows = Vec::new();
    let mut table = vec![vec![String::new(); taus.len()]; algos.len()];
    for (ai, &(_, algo)) in algos.iter().enumerate() {
        for (ti, &tau) in taus.iter().enumerate() {
            let log = ctx.run_leg(&format!("{}_tau{tau}", algo.name()), |c| {
                c.algo = algo;
                c.tau = tau;
            })?;
            table[ai][ti] = format!("{:.2}%", 100.0 * log.final_acc());
            rows.push(row(&format!("{}_tau{tau}", algo.name()), algo, tau, &log, epochs));
        }
    }

    println!("\n=== Table 1 — IID data partition: final test accuracy ===");
    print!("{:<12}", "Algorithm");
    for tau in taus {
        print!(" {:>9}", format!("tau={tau}"));
    }
    println!();
    for (ai, (name, _)) in algos.iter().enumerate() {
        print!("{:<12}", name);
        for ti in 0..taus.len() {
            print!(" {:>9}", table[ai][ti]);
        }
        println!();
    }
    println!("(reference: fully-sync SGD {:.2}%)", 100.0 * sync.final_acc());
    ctx.write_summary("table1_summary.json", rows)
}
