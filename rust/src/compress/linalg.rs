//! Small dense row-major linear algebra for the compression seam.
//!
//! Shapes are tiny (rows/cols ≤ a few thousand, rank ≤ 8); these simple
//! ikj-ordered loops auto-vectorize and are nowhere near the profile's top
//! (see EXPERIMENTS.md §Perf). The `_into` variants write into caller
//! scratch so steady-state compression rounds allocate nothing.

use crate::util::rng::Rng;

/// C (m x n) = A (m x k) @ B (k x n), row-major.
pub fn matmul_nn(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_nn_into(a, m, k, b, n, &mut c);
    c
}

/// C (m x n) = A (m x k) @ B (k x n) into caller scratch (overwritten).
pub fn matmul_nn_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        for kk in 0..k {
            let aik = a[i * k + kk];
            let brow = &b[kk * n..(kk + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// C (k x n) = Aᵀ @ B where A is (m x k), B is (m x n), row-major.
pub fn matmul_tn(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; k * n];
    matmul_tn_into(a, m, k, b, n, &mut c);
    c
}

/// C (k x n) = Aᵀ @ B into caller scratch (overwritten).
pub fn matmul_tn_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = arow[kk];
            let crow = &mut c[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// M̂ (rows x cols) = P (rows x r) @ Qᵀ where Q is (cols x r), row-major.
pub fn matmul_pqt(p: &[f32], rows: usize, r: usize, q: &[f32], cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * cols];
    matmul_pqt_into(p, rows, r, q, cols, &mut out);
    out
}

/// M̂ (rows x cols) = P @ Qᵀ into caller scratch (overwritten).
pub fn matmul_pqt_into(p: &[f32], rows: usize, r: usize, q: &[f32], cols: usize, out: &mut [f32]) {
    assert_eq!(p.len(), rows * r);
    assert_eq!(q.len(), cols * r);
    assert_eq!(out.len(), rows * cols);
    for i in 0..rows {
        let prow = &p[i * r..(i + 1) * r];
        let orow = &mut out[i * cols..(i + 1) * cols];
        for c in 0..cols {
            let qrow = &q[c * r..(c + 1) * r];
            let mut acc = 0.0f32;
            for j in 0..r {
                acc += prow[j] * qrow[j];
            }
            orow[c] = acc;
        }
    }
}

/// acc (rows x r) += (g + e) @ Q, where g/e are (rows x cols), Q (cols x r).
/// The fused add avoids materializing M = g + e (PowerSGD hot loop).
pub fn matmul_fused_add_acc(
    g: &[f32],
    e: &[f32],
    rows: usize,
    cols: usize,
    q: &[f32],
    r: usize,
    acc: &mut [f32],
) {
    assert_eq!(g.len(), rows * cols);
    assert_eq!(e.len(), rows * cols);
    assert_eq!(q.len(), cols * r);
    assert_eq!(acc.len(), rows * r);
    for i in 0..rows {
        let grow = &g[i * cols..(i + 1) * cols];
        let erow = &e[i * cols..(i + 1) * cols];
        let arow = &mut acc[i * r..(i + 1) * r];
        for c in 0..cols {
            let m = grow[c] + erow[c];
            let qrow = &q[c * r..(c + 1) * r];
            for j in 0..r {
                arow[j] += m * qrow[j];
            }
        }
    }
}

/// acc (cols x r) += (g + e)ᵀ @ P, where g/e are (rows x cols), P (rows x r).
pub fn matmul_tn_fused_add_acc(
    g: &[f32],
    e: &[f32],
    rows: usize,
    cols: usize,
    p: &[f32],
    r: usize,
    acc: &mut [f32],
) {
    assert_eq!(g.len(), rows * cols);
    assert_eq!(e.len(), rows * cols);
    assert_eq!(p.len(), rows * r);
    assert_eq!(acc.len(), cols * r);
    for i in 0..rows {
        let grow = &g[i * cols..(i + 1) * cols];
        let erow = &e[i * cols..(i + 1) * cols];
        let prow = &p[i * r..(i + 1) * r];
        for c in 0..cols {
            let m = grow[c] + erow[c];
            let arow = &mut acc[c * r..(c + 1) * r];
            for j in 0..r {
                arow[j] += m * prow[j];
            }
        }
    }
}

/// Modified Gram–Schmidt on the columns of P (rows x r, row-major).
///
/// A rank-deficient column (all-zero gradient, crashed-worker round, or a
/// target whose rank is below r) leaves a residual that is pure f32 noise;
/// normalizing it would amplify that noise into a junk basis direction, and
/// the old behavior of zeroing it left a dead direction in the warm-started
/// basis forever. Instead the column is replaced by a **seeded** random
/// direction, orthogonalized against the previous columns and normalized —
/// deterministic in (rows, r, j, attempt), identical on every worker (the
/// basis stays shared), and harmless for reconstruction: Qᵀ projects the
/// target onto it, and a direction orthogonal to the target's span picks up
/// only f32 noise.
pub fn orthonormalize_columns(p: &mut [f32], rows: usize, r: usize) {
    assert_eq!(p.len(), rows * r);
    const EPS: f32 = 1e-8;
    /// Fixed stream seed for the rank-deficiency fallback: the column must
    /// come out identical everywhere, independent of the experiment seed.
    const FALLBACK_SEED: u64 = 0x6f6c7367645f6773; // "olsgd_gs"
    for j in 0..r {
        // Subtract projections onto previous columns.
        for prev in 0..j {
            let mut dot = 0.0f32;
            for i in 0..rows {
                dot += p[i * r + j] * p[i * r + prev];
            }
            for i in 0..rows {
                p[i * r + j] -= dot * p[i * r + prev];
            }
        }
        let mut norm = 0.0f32;
        for i in 0..rows {
            norm += p[i * r + j] * p[i * r + j];
        }
        let mut norm = norm.sqrt();
        if norm < 1e-6 {
            if j >= rows {
                // No orthogonal direction exists (more columns than rows):
                // zeroing is the only rank-honest option.
                for i in 0..rows {
                    p[i * r + j] = 0.0;
                }
                continue;
            }
            // Epsilon fallback: draw a fresh seeded direction and
            // re-orthogonalize. A retry is astronomically unlikely (a
            // random Gaussian vector lands in a j-dimensional subspace of
            // R^rows with probability 0) but keeps the loop total.
            let mut col = vec![0.0f32; rows];
            for attempt in 0..4u32 {
                let mut rng =
                    Rng::stream(FALLBACK_SEED, &format!("gs-fallback/{rows}/{r}/{j}/{attempt}"));
                rng.fill_normal(&mut col, 1.0);
                for prev in 0..j {
                    let mut dot = 0.0f32;
                    for i in 0..rows {
                        dot += col[i] * p[i * r + prev];
                    }
                    for i in 0..rows {
                        col[i] -= dot * p[i * r + prev];
                    }
                }
                let n2: f32 = col.iter().map(|v| v * v).sum();
                norm = n2.sqrt();
                if norm >= 1e-6 {
                    break;
                }
            }
            for i in 0..rows {
                p[i * r + j] = col[i];
            }
            if norm < 1e-6 {
                // All retries degenerate: give up on the direction.
                for i in 0..rows {
                    p[i * r + j] = 0.0;
                }
                continue;
            }
        }
        let inv = 1.0 / (norm + EPS);
        for i in 0..rows {
            p[i * r + j] *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, property};
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn nn_matches_naive() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let b = vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]; // 3x2
        let c = matmul_nn(&a, 2, 3, &b, 2);
        assert_close(&c, &[58.0, 64.0, 139.0, 154.0], 1e-6, 0.0);
    }

    #[test]
    fn tn_matches_nn_of_transpose() {
        property("tn == nn(t)", 50, |g| {
            let m = g.usize_in(1, 12);
            let k = g.usize_in(1, 12);
            let n = g.usize_in(1, 8);
            let a = g.vec_f32(m * k, 2.0);
            let b = g.vec_f32(m * n, 2.0);
            // explicit transpose
            let mut at = vec![0.0f32; k * m];
            for i in 0..m {
                for j in 0..k {
                    at[j * m + i] = a[i * k + j];
                }
            }
            let want = matmul_nn(&at, k, m, &b, n);
            let got = matmul_tn(&a, m, k, &b, n);
            assert_close(&got, &want, 1e-4, 1e-5);
        });
    }

    #[test]
    fn pqt_matches_nn_of_qt() {
        property("pqt == nn(qt)", 50, |g| {
            let rows = g.usize_in(1, 10);
            let r = g.usize_in(1, 4);
            let cols = g.usize_in(1, 10);
            let p = g.vec_f32(rows * r, 2.0);
            let q = g.vec_f32(cols * r, 2.0);
            let mut qt = vec![0.0f32; r * cols];
            for c in 0..cols {
                for j in 0..r {
                    qt[j * cols + c] = q[c * r + j];
                }
            }
            let want = matmul_nn(&p, rows, r, &qt, cols);
            let got = matmul_pqt(&p, rows, r, &q, cols);
            assert_close(&got, &want, 1e-4, 1e-5);
        });
    }

    #[test]
    fn fused_variants_match_unfused() {
        property("fused == add then mm", 50, |g| {
            let rows = g.usize_in(1, 10);
            let cols = g.usize_in(1, 10);
            let r = g.usize_in(1, 4);
            let gv = g.vec_f32(rows * cols, 2.0);
            let ev = g.vec_f32(rows * cols, 2.0);
            let q = g.vec_f32(cols * r, 2.0);
            let p = g.vec_f32(rows * r, 2.0);
            let m: Vec<f32> = gv.iter().zip(&ev).map(|(&a, &b)| a + b).collect();

            let mut acc1 = vec![0.0f32; rows * r];
            matmul_fused_add_acc(&gv, &ev, rows, cols, &q, r, &mut acc1);
            assert_close(&acc1, &matmul_nn(&m, rows, cols, &q, r), 1e-4, 1e-5);

            let mut acc2 = vec![0.0f32; cols * r];
            matmul_tn_fused_add_acc(&gv, &ev, rows, cols, &p, r, &mut acc2);
            assert_close(&acc2, &matmul_tn(&m, rows, cols, &p, r), 1e-4, 1e-5);
        });
    }

    #[test]
    fn gram_schmidt_orthonormalizes() {
        let rows = 20;
        let r = 4;
        let mut p = randn(rows * r, 3);
        orthonormalize_columns(&mut p, rows, r);
        for j1 in 0..r {
            for j2 in 0..=j1 {
                let mut dot = 0.0f32;
                for i in 0..rows {
                    dot += p[i * r + j1] * p[i * r + j2];
                }
                let want = if j1 == j2 { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "P'P[{j1},{j2}] = {dot}");
            }
        }
    }

    #[test]
    fn gram_schmidt_replaces_zero_column_with_seeded_orthonormal_direction() {
        let rows = 5;
        let r = 2;
        let mut p = vec![0.0f32; rows * r];
        for i in 0..rows {
            p[i * r] = 1.0; // col 0 constant, col 1 zero
        }
        let mut p2 = p.clone();
        orthonormalize_columns(&mut p, rows, r);
        assert!(p.iter().all(|v| v.is_finite()));
        // The rank-deficient column must come back as a *live* unit-norm
        // direction, orthogonal to column 0 — not the old dead zero column.
        let mut n1 = 0.0f32;
        let mut dot = 0.0f32;
        for i in 0..rows {
            n1 += p[i * r + 1] * p[i * r + 1];
            dot += p[i * r] * p[i * r + 1];
        }
        assert!((n1.sqrt() - 1.0).abs() < 1e-4, "fallback column norm {}", n1.sqrt());
        assert!(dot.abs() < 1e-4, "fallback column not orthogonal: {dot}");
        // Deterministic: a second run reproduces the same fallback bits.
        orthonormalize_columns(&mut p2, rows, r);
        assert_eq!(p, p2, "seeded fallback must be bit-deterministic");
    }

    #[test]
    fn gram_schmidt_zeroes_columns_beyond_the_row_count() {
        // More columns than rows: only `rows` orthonormal directions exist;
        // the surplus column must be zeroed, never NaN.
        let rows = 2;
        let r = 3;
        let mut p = vec![0.0f32; rows * r];
        p[0] = 1.0; // col 0 = e0
        p[1 * r + 1] = 1.0; // col 1 = e1; col 2 = zero
        orthonormalize_columns(&mut p, rows, r);
        assert!(p.iter().all(|v| v.is_finite()));
        assert_eq!(p[2], 0.0);
        assert_eq!(p[1 * r + 2], 0.0);
    }

    #[test]
    fn all_zero_input_stays_finite_and_orthonormal() {
        // The regression the issue names: a crashed-worker round can hand
        // the compressor an all-zero target; the old code normalized by a
        // near-zero norm in later columns after projections. Every output
        // column must now be finite and the live ones pairwise orthonormal.
        let rows = 8;
        let r = 3;
        let mut p = vec![0.0f32; rows * r];
        orthonormalize_columns(&mut p, rows, r);
        assert!(p.iter().all(|v| v.is_finite()));
        for j1 in 0..r {
            for j2 in 0..=j1 {
                let mut dot = 0.0f32;
                for i in 0..rows {
                    dot += p[i * r + j1] * p[i * r + j2];
                }
                let want = if j1 == j2 { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "P'P[{j1},{j2}] = {dot}");
            }
        }
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        property("into == allocating", 30, |g| {
            let m = g.usize_in(1, 8);
            let k = g.usize_in(1, 8);
            let n = g.usize_in(1, 6);
            let a = g.vec_f32(m * k, 2.0);
            let b = g.vec_f32(k * n, 2.0);
            let mut c = vec![7.0f32; m * n]; // dirty scratch must be overwritten
            matmul_nn_into(&a, m, k, &b, n, &mut c);
            assert_eq!(c, matmul_nn(&a, m, k, &b, n));

            let bt = g.vec_f32(m * n, 2.0);
            let mut ct = vec![7.0f32; k * n];
            matmul_tn_into(&a, m, k, &bt, n, &mut ct);
            assert_eq!(ct, matmul_tn(&a, m, k, &bt, n));

            let p = g.vec_f32(m * k, 2.0);
            let q = g.vec_f32(n * k, 2.0);
            let mut out = vec![7.0f32; m * n];
            matmul_pqt_into(&p, m, k, &q, n, &mut out);
            assert_eq!(out, matmul_pqt(&p, m, k, &q, n));
        });
    }
}
