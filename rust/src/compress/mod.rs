//! Collective-payload compression: the composable `--compress` axis.
//!
//! Three compressors plug into the collective layer behind one seam
//! ([`CompressState`], DESIGN.md §12): **PowerSGD** low-rank factorization
//! (Vogels et al., NeurIPS 2019 [5] — the gradient-compression comparator
//! in Fig. 4/5), **top-k** sparsification, and **QSGD**-style scalar
//! quantization. All three carry per-worker error-feedback residuals as
//! first-class engine state, so they compose with every mixing strategy,
//! every topology, and the fault model: a crash freezes the worker's
//! residual with its replica, a rejoin zeroes it, and masked rounds
//! average compressed contributions exactly mean-preservingly over the
//! survivor set ([`PowerSgd::round_among`], `CompressState::encode_*`).
//!
//! PowerSGD keeps rank-r compression with the three ingredients of the
//! reference implementation:
//! * **warm start** — Q persists across rounds (single power iteration per
//!   round converges because gradients change slowly);
//! * **error feedback** — each worker re-injects last round's compression
//!   residual before compressing;
//! * **orthogonalization** — modified Gram–Schmidt on the averaged P.
//!
//! Per round and per weight matrix M (rows x cols, from the manifest's
//! matricization):
//! ```text
//!   M_w  <- grad_w + error_w                 (feedback)
//!   P    <- mean_w(M_w Q);  orthonormalize P  (all-reduce #1: rows*r)
//!   Q    <- mean_w(M_wᵀ P)                    (all-reduce #2: cols*r)
//!   M̂    <- P Qᵀ           (shared by all workers)
//!   error_w <- M_w - M̂
//! ```
//! Bias vectors (manifest `compress = false`) are all-reduced raw, exactly
//! as the reference implementation does.
//!
//! Q is identical on every worker (seeded identically, updated only from
//! all-reduced quantities), so it is stored once. Errors are per-worker.

use crate::runtime::manifest::ModelManifest;
use crate::util::rng::Rng;

mod linalg;
mod state;

pub use linalg::{matmul_nn, matmul_pqt, matmul_tn, orthonormalize_columns};
pub use state::{
    ideal_message_bytes, resolve_topk_k, wire_plan, CompressKind, CompressState, WirePlan,
    GEMM_FLOPS,
};

/// Persistent PowerSGD state for one model + worker group.
pub struct PowerSgd {
    /// configured compression rank r
    pub rank: usize,
    n: usize,
    workers: usize,
    /// (offset, rows, cols) of each compressed matrix
    mats: Vec<(usize, usize, usize)>,
    /// (offset, len) of each raw (uncompressed) tensor
    raws: Vec<(usize, usize)>,
    /// per-matrix Q, cols x r row-major — shared across workers
    qs: Vec<Vec<f32>>,
    /// per-worker error-feedback buffer (full flat length)
    errors: Vec<Vec<f32>>,
    /// reusable P scratch (largest rows x r), zeroed before each use
    p_buf: Vec<f32>,
    /// reusable Q scratch (largest cols x r), zeroed before each use
    q_buf: Vec<f32>,
    /// reusable decode scratch (largest rows x cols)
    approx_buf: Vec<f32>,
    /// full member list, so `round` can delegate without reallocating
    all: Vec<usize>,
}

/// Result of one compression round.
pub struct RoundOutput {
    /// the decompressed averaged gradient (what every worker applies)
    pub avg_grad: Vec<f32>,
    /// bytes each worker put on the wire this round
    pub bytes_per_worker: usize,
    /// FLOPs spent in encode/decode GEMMs per worker (for the latency model)
    pub encode_flops: f64,
}

impl PowerSgd {
    /// Fresh state (warm-start Qs seeded identically on every worker).
    pub fn new(manifest: &ModelManifest, rank: usize, workers: usize, seed: u64) -> Self {
        assert!(rank >= 1, "rank must be >= 1");
        let mut mats = Vec::new();
        let mut raws = Vec::new();
        let mut qs = Vec::new();
        let mut p_max = 0;
        let mut q_max = 0;
        let mut a_max = 0;
        for t in &manifest.tensors {
            if t.compress && t.rows > 1 {
                let r = rank.min(t.rows).min(t.cols);
                let mut q = vec![0.0f32; t.cols * r];
                // Same seed on every worker -> identical Q, like the paper's
                // shared PRNG trick.
                let mut rng = Rng::stream(seed, &format!("powersgd/q/{}", t.name));
                rng.fill_normal(&mut q, 1.0);
                mats.push((t.offset, t.rows, t.cols));
                qs.push(q);
                p_max = p_max.max(t.rows * r);
                q_max = q_max.max(t.cols * r);
                a_max = a_max.max(t.rows * t.cols);
            } else {
                raws.push((t.offset, t.size));
            }
        }
        Self {
            rank,
            n: manifest.param_count,
            workers,
            mats,
            raws,
            qs,
            errors: vec![vec![0.0f32; manifest.param_count]; workers],
            p_buf: vec![0.0f32; p_max],
            q_buf: vec![0.0f32; q_max],
            approx_buf: vec![0.0f32; a_max],
            all: (0..workers).collect(),
        }
    }

    /// Effective rank of matrix `i` (capped by its dimensions).
    fn eff_rank(&self, rows: usize, cols: usize) -> usize {
        self.rank.min(rows).min(cols)
    }

    /// Wire bytes per worker per round: compressed P and Q halves + raw
    /// tensors. (Both all-reduces move rows*r and cols*r floats.)
    pub fn bytes_per_round(&self) -> usize {
        let compressed: usize = self
            .mats
            .iter()
            .map(|&(_, rows, cols)| {
                let r = self.eff_rank(rows, cols);
                (rows + cols) * r * 4
            })
            .sum();
        let raw: usize = self.raws.iter().map(|&(_, len)| len * 4).sum();
        compressed + raw
    }

    /// One compression round over the full worker group. `grads[w]` is
    /// worker w's raw gradient (len = param_count); it is not mutated.
    pub fn round(&mut self, grads: &[&[f32]]) -> RoundOutput {
        assert_eq!(grads.len(), self.workers, "worker count changed");
        let members = std::mem::take(&mut self.all);
        let mut avg = vec![0.0f32; self.n];
        let flops = self.round_among(grads, &members, &mut avg);
        self.all = members;
        RoundOutput { avg_grad: avg, bytes_per_worker: self.bytes_per_round(), encode_flops: flops }
    }

    /// One compression round over a **member subset** (the fault model's
    /// survivor set). `grads[j]` is member `members[j]`'s gradient in
    /// ascending member order; only member residuals are read or updated
    /// (a parked worker's error buffer stays frozen with its replica), and
    /// the decompressed mean in `avg` is the exact survivor mean — the
    /// masked, mean-preserving redistribution that lets PowerSGD run under
    /// crash/rejoin. With the full member list this is bit-identical to
    /// the legacy full-group round. Returns the per-worker encode/decode
    /// FLOPs.
    pub fn round_among(&mut self, grads: &[&[f32]], members: &[usize], avg: &mut [f32]) -> f64 {
        assert_eq!(grads.len(), members.len(), "one gradient per member");
        for g in grads {
            assert_eq!(g.len(), self.n, "gradient length mismatch");
        }
        assert_eq!(avg.len(), self.n);
        let m = members.len() as f32;
        let mut flops = 0.0f64;

        // Feedback: M_w = grad_w + error_w (materialized lazily per matrix).
        for mi in 0..self.mats.len() {
            let (off, rows, cols) = self.mats[mi];
            let r = self.eff_rank(rows, cols);
            let size = rows * cols;

            // P = mean_w((g_w + e_w) Q)
            {
                let p = &mut self.p_buf[..rows * r];
                p.fill(0.0);
                let q = &self.qs[mi];
                for (j, &w) in members.iter().enumerate() {
                    let gw = &grads[j][off..off + size];
                    let ew = &self.errors[w][off..off + size];
                    // fused (g+e) @ Q accumulation
                    linalg::matmul_fused_add_acc(gw, ew, rows, cols, q, r, p);
                }
                for v in p.iter_mut() {
                    *v /= m;
                }
                orthonormalize_columns(p, rows, r);
            }

            // Q = mean_w(M_wᵀ P)
            {
                let q_new = &mut self.q_buf[..cols * r];
                q_new.fill(0.0);
                let p = &self.p_buf[..rows * r];
                for (j, &w) in members.iter().enumerate() {
                    let gw = &grads[j][off..off + size];
                    let ew = &self.errors[w][off..off + size];
                    linalg::matmul_tn_fused_add_acc(gw, ew, rows, cols, p, r, q_new);
                }
                for v in q_new.iter_mut() {
                    *v /= m;
                }
            }

            // decompress: M̂ = P Qᵀ
            linalg::matmul_pqt_into(
                &self.p_buf[..rows * r],
                rows,
                r,
                &self.q_buf[..cols * r],
                cols,
                &mut self.approx_buf[..size],
            );
            avg[off..off + size].copy_from_slice(&self.approx_buf[..size]);

            // error_w = (g_w + e_w) - M̂, members only
            for (j, &w) in members.iter().enumerate() {
                let gw = &grads[j][off..off + size];
                let e = &mut self.errors[w][off..off + size];
                for i in 0..size {
                    e[i] = gw[i] + e[i] - self.approx_buf[i];
                }
            }

            self.qs[mi].copy_from_slice(&self.q_buf[..cols * r]);
            // GEMM flops per worker: P (2*rows*cols*r), Q (2*rows*cols*r),
            // decode (2*rows*cols*r).
            flops += 6.0 * rows as f64 * cols as f64 * r as f64;
        }

        // Raw tensors: plain mean over the members, no error.
        for &(off, len) in &self.raws {
            for i in off..off + len {
                let mut sum = 0.0f32;
                for g in grads {
                    sum += g[i];
                }
                avg[i] = sum / m;
            }
        }

        flops
    }

    /// Zero a worker's error-feedback residual — the rejoin protocol: a
    /// returning worker warm-starts its replica from the anchor (PR 5
    /// semantics) and has no residual history to re-inject.
    pub fn reset_worker(&mut self, worker: usize) {
        self.errors[worker].fill(0.0);
    }

    /// L2 norm of a worker's error-feedback buffer (diagnostics/tests).
    pub fn error_norm(&self, worker: usize) -> f64 {
        crate::model::vecmath::l2_norm(&self.errors[worker])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorManifest;
    use crate::util::proptest::assert_close;

    fn manifest_one_matrix(rows: usize, cols: usize, bias: usize) -> ModelManifest {
        let mut tensors = vec![TensorManifest {
            name: "w".into(),
            offset: 0,
            size: rows * cols,
            shape: vec![rows, cols],
            init: "he_normal".into(),
            std: 0.1,
            rows,
            cols,
            compress: true,
        }];
        if bias > 0 {
            tensors.push(TensorManifest {
                name: "b".into(),
                offset: rows * cols,
                size: bias,
                shape: vec![bias],
                init: "zeros".into(),
                std: 0.0,
                rows: 1,
                cols: bias,
                compress: false,
            });
        }
        ModelManifest { param_count: rows * cols + bias, tensors, modules: Default::default() }
    }

    fn rank1_matrix(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let u: Vec<f32> = (0..rows).map(|_| rng.next_normal() as f32).collect();
        let v: Vec<f32> = (0..cols).map(|_| rng.next_normal() as f32).collect();
        let mut m = vec![0.0f32; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                m[i * cols + j] = u[i] * v[j];
            }
        }
        m
    }

    #[test]
    fn rank1_gradient_reconstructed_exactly() {
        let mm = manifest_one_matrix(6, 5, 0);
        let mut ps = PowerSgd::new(&mm, 2, 1, 1);
        let g = rank1_matrix(6, 5, 3);
        let out = ps.round(&[&g]);
        assert_close(&out.avg_grad, &g, 1e-4, 1e-5);
        assert!(ps.error_norm(0) < 1e-4, "error {}", ps.error_norm(0));
    }

    #[test]
    fn biases_pass_through_as_exact_mean() {
        let mm = manifest_one_matrix(4, 4, 3);
        let mut ps = PowerSgd::new(&mm, 1, 2, 1);
        let mut g0 = rank1_matrix(4, 4, 5);
        let mut g1 = rank1_matrix(4, 4, 6);
        g0.extend_from_slice(&[1.0, 2.0, 3.0]);
        g1.extend_from_slice(&[3.0, 2.0, 1.0]);
        let out = ps.round(&[&g0, &g1]);
        assert_close(&out.avg_grad[16..], &[2.0, 2.0, 2.0], 1e-6, 0.0);
    }

    #[test]
    fn error_feedback_reinjects_residual() {
        // With a rank-2 true gradient but rank-1 compression, the sum of the
        // decompressed outputs over rounds must approach the true repeated
        // gradient (EF-SGD guarantee), even though each round is lossy.
        let rows = 8;
        let cols = 6;
        let mm = manifest_one_matrix(rows, cols, 0);
        let mut ps = PowerSgd::new(&mm, 1, 1, 2);
        // fixed rank-2 gradient
        let mut g = rank1_matrix(rows, cols, 10);
        let g2 = rank1_matrix(rows, cols, 11);
        for i in 0..g.len() {
            g[i] += 0.5 * g2[i];
        }
        let rounds = 60;
        let mut applied = vec![0.0f32; g.len()];
        for _ in 0..rounds {
            let out = ps.round(&[&g]);
            for i in 0..g.len() {
                applied[i] += out.avg_grad[i];
            }
        }
        let want: Vec<f32> = g.iter().map(|&x| x * rounds as f32).collect();
        let err = crate::model::vecmath::max_abs_diff(&applied, &want);
        let scale = want.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
        assert!(err / scale < 0.05, "EF bias too large: {err} / {scale}");
    }

    #[test]
    fn bytes_per_round_formula() {
        let mm = manifest_one_matrix(10, 7, 4);
        let ps = PowerSgd::new(&mm, 3, 2, 1);
        // (10 + 7) * 3 floats + 4 raw floats
        assert_eq!(ps.bytes_per_round(), (17 * 3 + 4) * 4);
    }

    #[test]
    fn rank_capped_by_dims() {
        let mm = manifest_one_matrix(2, 9, 0);
        let mut ps = PowerSgd::new(&mm, 8, 1, 1);
        // effective rank = 2; round must still work and bytes reflect cap
        assert_eq!(ps.bytes_per_round(), (2 + 9) * 2 * 4);
        let g = rank1_matrix(2, 9, 7);
        let out = ps.round(&[&g]);
        assert_close(&out.avg_grad, &g, 1e-4, 1e-5);
    }

    #[test]
    fn multi_worker_average_is_unbiased_for_low_rank() {
        // Two workers with rank-1 gradients sharing the same column space:
        // compression is exact and the output equals the plain mean.
        let rows = 5;
        let cols = 4;
        let mm = manifest_one_matrix(rows, cols, 0);
        let mut ps = PowerSgd::new(&mm, 2, 2, 1);
        let base = rank1_matrix(rows, cols, 20);
        let g0: Vec<f32> = base.iter().map(|&x| 2.0 * x).collect();
        let g1: Vec<f32> = base.iter().map(|&x| 4.0 * x).collect();
        let out = ps.round(&[&g0, &g1]);
        let want: Vec<f32> = base.iter().map(|&x| 3.0 * x).collect();
        assert_close(&out.avg_grad, &want, 1e-4, 1e-5);
    }
}
