//! The compression seam (DESIGN.md §12): [`CompressKind`] selects a
//! compressor, [`CompressState`] owns the per-worker error-feedback
//! residuals and scratch as first-class engine state, and [`wire_plan`]
//! maps the compressor's ideal payload onto the run's scaled message size
//! so every topology cost formula and byte counter sees compressed bytes.
//!
//! All compressors share one error-feedback algebra. For a value `x_w`
//! transmitted against a reference `ref` every receiver already holds
//! (zero for the sync family's gradients, the anchor/center/last-average
//! for the parameter-averaging strategies):
//!
//! ```text
//!   target_w  = (x_w - ref) + e_w         (re-inject last residual)
//!   approx_w  = C(target_w)               (the lossy wire payload)
//!   e_w       = target_w - approx_w       (carry the loss forward)
//!   contrib_w = ref + approx_w            (what enters the collective)
//! ```
//!
//! The survivor mean of the contributions is `ref + mean_w(approx_w)`
//! over exactly the member set — masked redistribution is mean-preserving
//! by construction, which is what lets every compressor (PowerSGD
//! included) run under the PR 5 fault model: a crash freezes `e_w` with
//! the replica, a rejoin zeroes it ([`CompressState::reset_worker`]).

use anyhow::{bail, Result};

use super::{linalg, PowerSgd};
use crate::config::ExperimentConfig;
use crate::model::vecmath;
use crate::runtime::manifest::ModelManifest;
use crate::util::rng::Rng;

/// Effective GEMM throughput assumed for encode/decode cost (Titan X era,
/// f32): 5 TFLOP/s — the constant the legacy PowerSGD strategy used, now
/// shared by every compressor's latency model.
pub const GEMM_FLOPS: f64 = 5.0e12;

/// Which collective-payload compressor a run uses (`--compress ...`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CompressKind {
    /// No compression — the bit-exact legacy paths, digest-identical to
    /// every pre-compression golden.
    #[default]
    None,
    /// Rank-r PowerSGD low-rank factorization with warm-started Q
    /// (`--set compress_rank=`; shares the `rank` config key).
    PowerSgd,
    /// Top-k magnitude sparsification (`--set compress_k=`; 0 = auto, 1%
    /// of the message). Lossless to the bit at k = d.
    TopK,
    /// QSGD-style scalar quantization (`--set compress_bits=`, 2..=32).
    /// Bits = 32 is a bit-exact passthrough (the lossless limit).
    Qsgd,
}

impl CompressKind {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => CompressKind::None,
            "powersgd" => CompressKind::PowerSgd,
            "topk" | "top-k" | "top_k" => CompressKind::TopK,
            "qsgd" => CompressKind::Qsgd,
            _ => bail!("unknown compressor '{s}' (want none|powersgd|topk|qsgd)"),
        })
    }

    /// Canonical name (round-trips through [`CompressKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            CompressKind::None => "none",
            CompressKind::PowerSgd => "powersgd",
            CompressKind::TopK => "topk",
            CompressKind::Qsgd => "qsgd",
        }
    }

    /// Every compressor, in sweep order.
    pub fn all() -> &'static [CompressKind] {
        &[CompressKind::None, CompressKind::PowerSgd, CompressKind::TopK, CompressKind::Qsgd]
    }
}

/// Resolve the top-k budget: `0` means auto — 1% of the message, at least
/// one entry; explicit values clamp to the message length.
pub fn resolve_topk_k(k: usize, n: usize) -> usize {
    if k == 0 {
        (n / 100).max(1).min(n)
    } else {
        k.min(n)
    }
}

/// Ideal (unscaled) wire bytes of one compressed message for a model.
/// Top-k pays 8 bytes per kept entry (index + value), QSGD packs `bits`
/// per entry plus a 4-byte scale, PowerSGD sends its P/Q factors plus raw
/// (uncompressible) tensors — the same formula as
/// [`PowerSgd::bytes_per_round`].
pub fn ideal_message_bytes(
    kind: CompressKind,
    k: usize,
    bits: u32,
    rank: usize,
    manifest: &ModelManifest,
) -> usize {
    let n = manifest.param_count;
    match kind {
        CompressKind::None => n * 4,
        CompressKind::TopK => resolve_topk_k(k, n) * 8,
        CompressKind::Qsgd => {
            if bits >= 32 {
                n * 4
            } else {
                (n * bits as usize).div_ceil(8) + 4
            }
        }
        CompressKind::PowerSgd => manifest
            .tensors
            .iter()
            .map(|t| {
                if t.compress && t.rows > 1 {
                    let r = rank.min(t.rows).min(t.cols);
                    (t.rows + t.cols) * r * 4
                } else {
                    t.size * 4
                }
            })
            .sum(),
    }
}

/// How a compressed message maps onto the run's timing model.
#[derive(Clone, Copy, Debug)]
pub struct WirePlan {
    /// bytes of one compressed message at the configured (paper-scale)
    /// message size — what every `NetworkModel` formula and byte counter
    /// is charged with
    pub scaled_bytes: usize,
    /// paper-size FLOP scaling for encode/decode latency (1.0 when the
    /// message models the actual parameter count)
    pub flops_scale: f64,
}

/// Compute the wire plan for a config; `None` when compression is off.
/// The compressed *fraction* of the real model's bytes scales the
/// configured message size, exactly as the legacy PowerSGD strategy did,
/// so paper-scale runs charge paper-scale compressed messages.
pub fn wire_plan(
    cfg: &ExperimentConfig,
    manifest: &ModelManifest,
    cluster_message_bytes: usize,
) -> Option<WirePlan> {
    if cfg.compress == CompressKind::None {
        return None;
    }
    let full_bytes = manifest.message_bytes();
    let ideal =
        ideal_message_bytes(cfg.compress, cfg.compress_k, cfg.compress_bits, cfg.rank, manifest);
    let frac = ideal as f64 / full_bytes as f64;
    let scaled_bytes = (cluster_message_bytes as f64 * frac) as usize;
    let flops_scale = (full_bytes as f64 / (manifest.param_count * 4) as f64).max(1.0);
    Some(WirePlan { scaled_bytes, flops_scale })
}

/// Top-k sparsification: keep the k largest-|v| entries of `target`
/// bit-exactly, zero the rest. The kept set is a total order
/// (|v| descending, index ascending), so it is deterministic across
/// platforms; at k = n the output is the input to the bit.
fn topk_encode(target: &[f32], k: usize, idx: &mut Vec<u32>, approx: &mut [f32]) {
    let n = target.len();
    let k = k.min(n);
    if k == n {
        approx.copy_from_slice(target);
        return;
    }
    approx.fill(0.0);
    if k == 0 {
        return;
    }
    idx.clear();
    idx.extend(0..n as u32);
    idx.select_nth_unstable_by(k - 1, |a, b| {
        target[*b as usize]
            .abs()
            .total_cmp(&target[*a as usize].abs())
            .then(a.cmp(b))
    });
    for &i in &idx[..k] {
        approx[i as usize] = target[i as usize];
    }
}

/// QSGD-style deterministic scalar quantization: round-to-nearest onto
/// `2^(bits-1) - 1` levels of |v| / max|v|, sign preserved. Bits >= 32 is
/// an exact passthrough (the lossless limit).
fn qsgd_encode(target: &[f32], bits: u32, approx: &mut [f32]) {
    if bits >= 32 {
        approx.copy_from_slice(target);
        return;
    }
    let mut scale = 0.0f32;
    for &v in target {
        scale = scale.max(v.abs());
    }
    if scale == 0.0 {
        approx.fill(0.0);
        return;
    }
    let s = ((1u64 << (bits - 1)) - 1) as f32;
    for (a, &v) in approx.iter_mut().zip(target) {
        let q = (v.abs() / scale * s).round();
        *a = v.signum() * q * scale / s;
    }
}

/// Per-worker low-rank state for the **parameter-delta** path (overlap,
/// gossip, local, elastic, cocod under `--compress powersgd`): each worker
/// compresses its own delta against the reference with its own
/// warm-started Q, all seeded identically at start and re-seeded from the
/// shared init on rejoin.
struct LowRank {
    /// (offset, rows, cols, effective rank) of each compressed matrix
    mats: Vec<(usize, usize, usize, usize)>,
    /// (offset, len) of each raw (uncompressed) tensor
    raws: Vec<(usize, usize)>,
    /// the shared seeded warm-start basis (rejoin restore point)
    q_init: Vec<Vec<f32>>,
    /// per-worker warm-started Q, `[worker][mat]`
    qs: Vec<Vec<Vec<f32>>>,
    p_buf: Vec<f32>,
    q_buf: Vec<f32>,
}

impl LowRank {
    fn new(manifest: &ModelManifest, rank: usize, workers: usize, seed: u64) -> Self {
        let mut mats = Vec::new();
        let mut raws = Vec::new();
        let mut q_init = Vec::new();
        let mut p_max = 0;
        let mut q_max = 0;
        for t in &manifest.tensors {
            if t.compress && t.rows > 1 {
                let r = rank.min(t.rows).min(t.cols);
                let mut q = vec![0.0f32; t.cols * r];
                let mut rng = Rng::stream(seed, &format!("powersgd/q/{}", t.name));
                rng.fill_normal(&mut q, 1.0);
                mats.push((t.offset, t.rows, t.cols, r));
                q_init.push(q);
                p_max = p_max.max(t.rows * r);
                q_max = q_max.max(t.cols * r);
            } else {
                raws.push((t.offset, t.size));
            }
        }
        let qs = vec![q_init.clone(); workers];
        Self { mats, raws, q_init, qs, p_buf: vec![0.0; p_max], q_buf: vec![0.0; q_max] }
    }

    /// Rank-r approximate `target` into `out` (full flat length) with
    /// worker w's warm-started basis; returns the encode/decode FLOPs.
    fn encode(&mut self, w: usize, target: &[f32], out: &mut [f32]) -> f64 {
        let mut flops = 0.0f64;
        for mi in 0..self.mats.len() {
            let (off, rows, cols, r) = self.mats[mi];
            let size = rows * cols;
            let tmat = &target[off..off + size];
            {
                let p = &mut self.p_buf[..rows * r];
                linalg::matmul_nn_into(tmat, rows, cols, &self.qs[w][mi], r, p);
                linalg::orthonormalize_columns(p, rows, r);
            }
            {
                let q_new = &mut self.q_buf[..cols * r];
                linalg::matmul_tn_into(tmat, rows, cols, &self.p_buf[..rows * r], r, q_new);
            }
            linalg::matmul_pqt_into(
                &self.p_buf[..rows * r],
                rows,
                r,
                &self.q_buf[..cols * r],
                cols,
                &mut out[off..off + size],
            );
            self.qs[w][mi].copy_from_slice(&self.q_buf[..cols * r]);
            flops += 6.0 * rows as f64 * cols as f64 * r as f64;
        }
        for &(off, len) in &self.raws {
            out[off..off + len].copy_from_slice(&target[off..off + len]);
        }
        flops
    }

    /// Restore worker w's basis to the shared seeded init (rejoin).
    fn reset_worker(&mut self, w: usize) {
        for (q, init) in self.qs[w].iter_mut().zip(&self.q_init) {
            q.copy_from_slice(init);
        }
    }
}

/// First-class engine state for a compressed run: per-worker residuals,
/// persistent contribution buffers for parameter-path collectives, launch
/// snapshots for the delay-corrected pullback, and the compressor itself.
/// Built once by the engine (`Engine::compress`); `--compress none` runs
/// carry no state at all, so every uncompressed path stays bit-identical.
pub struct CompressState {
    /// which compressor the run uses (never [`CompressKind::None`])
    pub kind: CompressKind,
    n: usize,
    k: usize,
    bits: u32,
    /// wire bytes of one compressed message in the run's scaled model
    pub scaled_bytes: usize,
    /// paper-size FLOP scaling for encode/decode latency
    pub flops_scale: f64,
    /// per-worker error-feedback residuals (the engine state the tentpole
    /// names; frozen on crash, zeroed on rejoin)
    errors: Vec<Vec<f32>>,
    /// per-worker encoded contributions — what parameter-path collectives
    /// reduce instead of the raw replicas
    pub contrib: Vec<Vec<f32>>,
    /// per-worker post-pullback snapshot at each collective launch: the
    /// model that fed the in-flight (compressed, hence sparser/staler)
    /// average, used by [`CompressState::pullback`]
    snap: Vec<Vec<f32>>,
    snap_valid: Vec<bool>,
    target: Vec<f32>,
    approx: Vec<f32>,
    avg: Vec<f32>,
    idx: Vec<u32>,
    /// joint full-group PowerSGD for the sync-family gradient path —
    /// the exact legacy `--algo powersgd` arithmetic
    joint: Option<PowerSgd>,
    /// per-worker low-rank state for the parameter-delta path
    lowrank: Option<LowRank>,
}

impl CompressState {
    /// Build the state for a config; `None` when compression is off.
    pub fn build(
        cfg: &ExperimentConfig,
        manifest: &ModelManifest,
        cluster_message_bytes: usize,
    ) -> Option<Self> {
        let plan = wire_plan(cfg, manifest, cluster_message_bytes)?;
        let n = manifest.param_count;
        let m = cfg.workers;
        let is_psgd = cfg.compress == CompressKind::PowerSgd;
        Some(Self {
            kind: cfg.compress,
            n,
            k: resolve_topk_k(cfg.compress_k, n),
            bits: cfg.compress_bits,
            scaled_bytes: plan.scaled_bytes,
            flops_scale: plan.flops_scale,
            errors: vec![vec![0.0f32; n]; m],
            contrib: vec![vec![0.0f32; n]; m],
            snap: vec![vec![0.0f32; n]; m],
            snap_valid: vec![false; m],
            target: vec![0.0f32; n],
            approx: vec![0.0f32; n],
            avg: vec![0.0f32; n],
            idx: Vec::with_capacity(n),
            joint: is_psgd.then(|| PowerSgd::new(manifest, cfg.rank, m, cfg.seed)),
            lowrank: is_psgd.then(|| LowRank::new(manifest, cfg.rank, m, cfg.seed)),
        })
    }

    /// Encode/decode latency (seconds) for a per-worker FLOP count.
    pub fn encode_time(&self, flops: f64) -> f64 {
        flops * self.flops_scale / GEMM_FLOPS
    }

    /// Joint gradient round for the sync family: compress each member's
    /// gradient (with its residual) and decode the survivor mean into the
    /// internal average buffer ([`CompressState::avg`]). `grads[j]` is
    /// member `members[j]`'s gradient in ascending member order. Returns
    /// the per-worker encode/decode FLOPs. For PowerSGD this is the exact
    /// legacy joint round ([`PowerSgd::round_among`]).
    pub fn encode_grads_mean(&mut self, grads: &[&[f32]], members: &[usize]) -> f64 {
        debug_assert_eq!(grads.len(), members.len());
        if self.kind == CompressKind::PowerSgd {
            let joint = self.joint.as_mut().expect("powersgd state present");
            return joint.round_among(grads, members, &mut self.avg);
        }
        self.avg.fill(0.0);
        for (j, &w) in members.iter().enumerate() {
            let g = grads[j];
            let e = &self.errors[w];
            for i in 0..self.n {
                self.target[i] = g[i] + e[i];
            }
            match self.kind {
                CompressKind::TopK => {
                    topk_encode(&self.target, self.k, &mut self.idx, &mut self.approx)
                }
                CompressKind::Qsgd => qsgd_encode(&self.target, self.bits, &mut self.approx),
                _ => unreachable!("gradient path: powersgd handled above, none never builds"),
            }
            let e = &mut self.errors[w];
            for i in 0..self.n {
                e[i] = self.target[i] - self.approx[i];
                self.avg[i] += self.approx[i];
            }
        }
        let m = members.len() as f32;
        for v in self.avg.iter_mut() {
            *v /= m;
        }
        // one fused scan per entry to select/quantize, one to decode
        2.0 * self.n as f64
    }

    /// The decoded mean of the last [`CompressState::encode_grads_mean`].
    pub fn avg(&self) -> &[f32] {
        &self.avg
    }

    /// Parameter-path encode for one worker: compress `value - reference`
    /// (plus the worker's residual) and write the reconstructed
    /// contribution `reference + approx` into [`CompressState::contrib`].
    /// Returns the per-worker encode/decode FLOPs.
    pub fn encode_param(&mut self, w: usize, value: &[f32], reference: &[f32]) -> f64 {
        debug_assert_eq!(value.len(), self.n);
        debug_assert_eq!(reference.len(), self.n);
        {
            let e = &self.errors[w];
            for i in 0..self.n {
                self.target[i] = value[i] - reference[i] + e[i];
            }
        }
        let flops = match self.kind {
            CompressKind::TopK => {
                topk_encode(&self.target, self.k, &mut self.idx, &mut self.approx);
                2.0 * self.n as f64
            }
            CompressKind::Qsgd => {
                qsgd_encode(&self.target, self.bits, &mut self.approx);
                2.0 * self.n as f64
            }
            CompressKind::PowerSgd => self
                .lowrank
                .as_mut()
                .expect("powersgd state present")
                .encode(w, &self.target, &mut self.approx),
            CompressKind::None => unreachable!("none never builds a CompressState"),
        };
        let e = &mut self.errors[w];
        let c = &mut self.contrib[w];
        for i in 0..self.n {
            e[i] = self.target[i] - self.approx[i];
            c[i] = reference[i] + self.approx[i];
        }
        flops
    }

    /// Copy a replica verbatim into its contribution row (parked workers
    /// on the gossip path: they exchange nothing, but the launch snapshots
    /// every row — their residuals must stay frozen).
    pub fn passthrough(&mut self, w: usize, value: &[f32]) {
        self.contrib[w].copy_from_slice(value);
    }

    /// Record worker w's post-pullback model at a collective launch — the
    /// state whose (compressed) average the *next* boundary will absorb.
    pub fn note_launch(&mut self, w: usize, params: &[f32]) {
        self.snap[w].copy_from_slice(params);
        self.snap_valid[w] = true;
    }

    /// Delay-corrected pullback (LOSCAR-style, PAPERS.md) for the
    /// overlap/gossip paths: contract toward the anchor using the gap the
    /// absorbed average actually measured — `x -= α(x_launch - z)` with
    /// the launch-time snapshot — so the staleness a sparse mask
    /// introduces is corrected at pullback instead of eating the τ local
    /// steps' progress. Falls back to the plain Eq. 4 pullback when no
    /// snapshot exists yet (first round, fresh rejoiner).
    pub fn pullback(&mut self, w: usize, params: &mut [f32], z: &[f32], alpha: f32) {
        if self.snap_valid[w] {
            vecmath::pullback_stale_inplace(params, &self.snap[w], z, alpha);
        } else {
            vecmath::pullback_inplace(params, z, alpha);
        }
    }

    /// Rejoin protocol: zero the worker's residual, restore its warm-start
    /// basis, and invalidate its launch snapshot — the replica itself is
    /// warm-started from the anchor by the strategy (PR 5 semantics).
    pub fn reset_worker(&mut self, w: usize) {
        self.errors[w].fill(0.0);
        if let Some(joint) = self.joint.as_mut() {
            joint.reset_worker(w);
        }
        if let Some(lr) = self.lowrank.as_mut() {
            lr.reset_worker(w);
        }
        self.snap_valid[w] = false;
    }

    /// L2 norm of a worker's residual (diagnostics/tests).
    pub fn residual_norm(&self, w: usize) -> f64 {
        vecmath::l2_norm(&self.errors[w])
    }

    /// Population slot re-bind (DESIGN.md §14): swap slot `w`'s
    /// error-feedback residual with the incoming worker's persisted one —
    /// the residual travels with the *worker*, not the slot — and
    /// invalidate the slot's launch snapshot, which described the outgoing
    /// worker's model ([`CompressState::pullback`] then takes its
    /// fresh-rejoiner fallback). Alloc-free: a plain `mem::swap` of the
    /// vectors. Never called while the cohort is stable, so dense (N == k)
    /// runs keep their digests bit-for-bit.
    pub fn swap_residual(&mut self, w: usize, residual: &mut Vec<f32>) {
        std::mem::swap(&mut self.errors[w], residual);
        self.snap_valid[w] = false;
    }

    /// Population slot re-bind for `--compress powersgd` (DESIGN.md §14):
    /// swap slot `w`'s joint gradient-path residual and warm `Q` bases
    /// with the incoming worker's persisted ones (`psgd_error` /
    /// `psgd_qs` travel with the worker, exactly as the generic residual
    /// does in [`CompressState::swap_residual`]). The caller gates on
    /// [`CompressKind::PowerSgd`]; like the residual swap, this never
    /// runs while the cohort is stable, so `N == k` digests are
    /// untouched.
    pub fn swap_powersgd_state(
        &mut self,
        w: usize,
        error: &mut Vec<f32>,
        qs: &mut Vec<Vec<f32>>,
    ) {
        let joint = self.joint.as_mut().expect("powersgd state present");
        std::mem::swap(&mut joint.errors[w], error);
        let lr = self.lowrank.as_mut().expect("powersgd state present");
        std::mem::swap(&mut lr.qs[w], qs);
        self.snap_valid[w] = false;
    }

    /// The shared seeded PowerSGD `Q` inits, one per factorized matrix —
    /// the fresh-worker template population mode materializes never-seen
    /// ids with (bit-identical to what [`CompressState::reset_worker`]
    /// restores on a dense rejoin). `None` unless `--compress powersgd`.
    pub fn powersgd_qs_init(&self) -> Option<Vec<Vec<f32>>> {
        self.lowrank.as_ref().map(|lr| lr.q_init.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorManifest;

    fn manifest_flat(n: usize) -> ModelManifest {
        ModelManifest {
            param_count: n,
            tensors: vec![TensorManifest {
                name: "w".into(),
                offset: 0,
                size: n,
                shape: vec![n],
                init: "zeros".into(),
                std: 0.0,
                rows: 1,
                cols: n,
                compress: false,
            }],
            modules: Default::default(),
        }
    }

    fn manifest_matrix(rows: usize, cols: usize) -> ModelManifest {
        ModelManifest {
            param_count: rows * cols,
            tensors: vec![TensorManifest {
                name: "w".into(),
                offset: 0,
                size: rows * cols,
                shape: vec![rows, cols],
                init: "he_normal".into(),
                std: 0.1,
                rows,
                cols,
                compress: true,
            }],
            modules: Default::default(),
        }
    }

    fn cfg_with(kind: CompressKind, workers: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = workers;
        cfg.compress = kind;
        cfg
    }

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn kind_round_trips_and_rejects_garbage() {
        for k in CompressKind::all() {
            assert_eq!(CompressKind::parse(k.name()).unwrap(), *k);
        }
        assert!(CompressKind::parse("zip").is_err());
        assert_eq!(CompressKind::all().len(), 4);
    }

    #[test]
    fn topk_auto_budget_and_clamping() {
        assert_eq!(resolve_topk_k(0, 1000), 10);
        assert_eq!(resolve_topk_k(0, 50), 1);
        assert_eq!(resolve_topk_k(7, 1000), 7);
        assert_eq!(resolve_topk_k(5000, 1000), 1000);
    }

    #[test]
    fn ideal_bytes_per_kind() {
        let mm = manifest_flat(1000);
        assert_eq!(ideal_message_bytes(CompressKind::None, 0, 8, 4, &mm), 4000);
        assert_eq!(ideal_message_bytes(CompressKind::TopK, 10, 8, 4, &mm), 80);
        assert_eq!(ideal_message_bytes(CompressKind::Qsgd, 0, 8, 4, &mm), 1004);
        assert_eq!(ideal_message_bytes(CompressKind::Qsgd, 0, 32, 4, &mm), 4000);
        // PowerSGD on an uncompressible (flat) manifest is all raw bytes;
        // on a matrix manifest it matches PowerSgd::bytes_per_round.
        assert_eq!(ideal_message_bytes(CompressKind::PowerSgd, 0, 8, 4, &mm), 4000);
        let mx = manifest_matrix(10, 7);
        let ps = PowerSgd::new(&mx, 3, 2, 1);
        assert_eq!(
            ideal_message_bytes(CompressKind::PowerSgd, 0, 8, 3, &mx),
            ps.bytes_per_round()
        );
    }

    #[test]
    fn wire_plan_scales_the_paper_message() {
        let mm = manifest_flat(1000);
        let mut cfg = cfg_with(CompressKind::TopK, 4);
        cfg.compress_k = 10; // 80 ideal bytes of 4000 -> 2%
        let plan = wire_plan(&cfg, &mm, 1_000_000).unwrap();
        assert_eq!(plan.scaled_bytes, 20_000);
        assert_eq!(plan.flops_scale, 1.0);
        cfg.compress = CompressKind::None;
        assert!(wire_plan(&cfg, &mm, 1_000_000).is_none());
    }

    #[test]
    fn topk_is_bitwise_lossless_at_full_k_and_residual_conserves() {
        let n = 64;
        let t = randv(n, 3);
        let mut idx = Vec::new();
        let mut approx = vec![0.0f32; n];
        topk_encode(&t, n, &mut idx, &mut approx);
        assert_eq!(approx, t, "k = d must reproduce the input to the bit");
        // k < n: kept entries are bit-exact copies, so approx + residual
        // reassembles the target exactly.
        topk_encode(&t, 5, &mut idx, &mut approx);
        let kept = approx.iter().filter(|v| **v != 0.0).count();
        assert_eq!(kept, 5);
        for i in 0..n {
            let e = t[i] - approx[i];
            assert_eq!(approx[i] + e, t[i], "top-k residual must conserve bitwise");
            assert!(approx[i] == 0.0 || approx[i] == t[i]);
        }
    }

    #[test]
    fn topk_keeps_the_largest_magnitudes_deterministically() {
        let t = vec![0.1f32, -5.0, 3.0, 3.0, -0.2, 0.0];
        let mut idx = Vec::new();
        let mut approx = vec![0.0f32; t.len()];
        topk_encode(&t, 3, &mut idx, &mut approx);
        // |−5| > |3| == |3| (tie broken by index) > the rest.
        assert_eq!(approx, vec![0.0, -5.0, 3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn qsgd_full_bits_is_bitwise_passthrough() {
        let t = randv(100, 9);
        let mut approx = vec![0.0f32; t.len()];
        qsgd_encode(&t, 32, &mut approx);
        assert_eq!(approx, t, "bits = 32 must be the exact passthrough");
    }

    #[test]
    fn qsgd_quantizes_within_half_a_level() {
        let t = randv(256, 11);
        let scale = t.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for bits in [2u32, 4, 8, 16] {
            let mut approx = vec![0.0f32; t.len()];
            qsgd_encode(&t, bits, &mut approx);
            let s = ((1u64 << (bits - 1)) - 1) as f32;
            let half_level = 0.5 * scale / s;
            for (a, &v) in approx.iter().zip(&t) {
                assert!(
                    (a - v).abs() <= half_level * 1.001,
                    "bits={bits}: |{a} - {v}| > {half_level}"
                );
            }
        }
        // All-zero input stays exactly zero (no 0/0).
        let mut approx = vec![1.0f32; 8];
        qsgd_encode(&[0.0; 8], 8, &mut approx);
        assert_eq!(approx, vec![0.0; 8]);
    }

    #[test]
    fn masked_grad_mean_is_survivor_mean_for_every_compressor() {
        // Survivor-set mean preservation: for each compressor, the decoded
        // mean over the member subset equals the mean of the members'
        // (compressed + residual-corrected) contributions, and each
        // member's approx + residual reassembles its target within fp
        // tolerance. Non-members' residuals stay frozen.
        let rows = 8;
        let cols = 6;
        let n = rows * cols;
        let mm = manifest_matrix(rows, cols);
        let members = vec![0usize, 2, 3];
        for &kind in &[CompressKind::TopK, CompressKind::Qsgd, CompressKind::PowerSgd] {
            let mut cfg = cfg_with(kind, 4);
            cfg.compress_k = 9;
            cfg.compress_bits = 8;
            cfg.rank = 2;
            let mut cs = CompressState::build(&cfg, &mm, n * 4).unwrap();
            cs.errors[1] = vec![42.0; n]; // a parked worker's frozen residual
            let grads: Vec<Vec<f32>> = (0..4).map(|w| randv(n, 20 + w as u64)).collect();
            let grefs: Vec<&[f32]> = members.iter().map(|&w| grads[w].as_slice()).collect();
            cs.encode_grads_mean(&grefs, &members);

            // approx_w + e_w == grad_w (old e_w = 0) per member, so the
            // decoded mean plus the mean post-encode residual reconstructs
            // the survivor mean exactly: avg = mean(g) - mean(e).
            let mut want = vec![0.0f64; n];
            for &w in &members {
                for i in 0..n {
                    want[i] += grads[w][i] as f64;
                }
            }
            let scale: f64 =
                want.iter().map(|v| v.abs()).fold(0.0, f64::max) / members.len() as f64;
            for i in 0..n {
                let got = cs.avg()[i] as f64;
                let exact = want[i] / members.len() as f64 - mean_residual(&cs, &members, i);
                assert!(
                    (got - exact).abs() <= 1e-4 * scale.max(1.0),
                    "{kind:?}: avg[{i}] = {got}, want {exact}"
                );
            }
            assert_eq!(cs.errors[1], vec![42.0; n], "{kind:?}: non-member residual moved");
        }
    }

    /// Mean post-encode residual over the members, from wherever the
    /// compressor keeps it (the joint PowerSGD state owns its own buffers).
    fn mean_residual(cs: &CompressState, members: &[usize], i: usize) -> f64 {
        let res = |w: usize| match cs.joint.as_ref() {
            Some(j) => j.errors[w][i] as f64,
            None => cs.errors[w][i] as f64,
        };
        members.iter().map(|&w| res(w)).sum::<f64>() / members.len() as f64
    }

    #[test]
    fn param_path_contribution_is_ref_plus_approx_and_conserves() {
        let rows = 6;
        let cols = 5;
        let n = rows * cols;
        let mm = manifest_matrix(rows, cols);
        for &kind in &[CompressKind::TopK, CompressKind::Qsgd, CompressKind::PowerSgd] {
            let mut cfg = cfg_with(kind, 2);
            cfg.compress_k = 4;
            cfg.compress_bits = 6;
            cfg.rank = 2;
            let mut cs = CompressState::build(&cfg, &mm, n * 4).unwrap();
            let value = randv(n, 31);
            let reference = randv(n, 32);
            cs.encode_param(0, &value, &reference);
            // contrib - ref == approx and approx + e == value - ref: the
            // compressed-plus-residual decomposition of the delta.
            for i in 0..n {
                let approx = cs.contrib[0][i] - reference[i];
                let delta = value[i] - reference[i];
                assert!(
                    (approx + cs.errors[0][i] - delta).abs() <= 1e-4 * delta.abs().max(1.0),
                    "{kind:?}: conservation broke at {i}"
                );
            }
        }
    }

    #[test]
    fn reset_worker_zeroes_residual_and_restores_basis() {
        let rows = 6;
        let cols = 5;
        let n = rows * cols;
        let mm = manifest_matrix(rows, cols);
        let mut cfg = cfg_with(CompressKind::PowerSgd, 2);
        cfg.rank = 2;
        let mut cs = CompressState::build(&cfg, &mm, n * 4).unwrap();
        let value = randv(n, 41);
        let reference = vec![0.0f32; n];
        cs.encode_param(0, &value, &reference);
        cs.note_launch(0, &value);
        assert!(cs.residual_norm(0) > 0.0);
        assert!(cs.snap_valid[0]);
        let basis_before = cs.lowrank.as_ref().unwrap().qs[0].clone();
        let init = cs.lowrank.as_ref().unwrap().q_init.clone();
        assert_ne!(basis_before, init, "encode must have warm-started the basis");
        cs.reset_worker(0);
        assert_eq!(cs.residual_norm(0), 0.0);
        assert!(!cs.snap_valid[0]);
        assert_eq!(cs.lowrank.as_ref().unwrap().qs[0], init);
    }

    #[test]
    fn delay_corrected_pullback_uses_the_launch_snapshot() {
        let n = 4;
        let mm = manifest_flat(n);
        let cfg = cfg_with(CompressKind::TopK, 1);
        let mut cs = CompressState::build(&cfg, &mm, n * 4).unwrap();
        let z = vec![0.0f32; n];
        let snap = vec![1.0f32; n];
        let mut x = vec![2.0f32; n];
        // No snapshot yet: plain Eq. 4 pullback, x -= α(x - z).
        cs.pullback(0, &mut x, &z, 0.5);
        assert_eq!(x, vec![1.0; n]);
        // With a snapshot: x -= α(snap - z) — local progress survives.
        cs.note_launch(0, &snap);
        let mut y = vec![2.0f32; n];
        cs.pullback(0, &mut y, &z, 0.5);
        assert_eq!(y, vec![1.5; n]);
    }
}
