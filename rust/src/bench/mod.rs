//! Criterion-like micro-benchmark harness (criterion is not in the offline
//! crate mirror). Warmup, timed iterations, mean/std/p50/p99, and a
//! stable one-line report format the perf pass greps.

pub mod experiments;

use std::time::Instant;

use crate::util::stats::{percentile, Summary};

/// Timing summary of one micro-benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// benchmark label
    pub name: String,
    /// timed iterations
    pub iters: usize,
    /// mean seconds per iteration
    pub mean_s: f64,
    /// sample standard deviation
    pub std_s: f64,
    /// median seconds
    pub p50_s: f64,
    /// 99th-percentile seconds
    pub p99_s: f64,
}

impl BenchResult {
    /// The stable one-line report format the perf pass greps.
    pub fn report(&self) -> String {
        format!(
            "bench {:<40} iters={:<5} mean={:>12} p50={:>12} p99={:>12} std={:>10}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p99_s),
            fmt_time(self.std_s),
        )
    }
}

/// Human-readable seconds (s / ms / µs / ns).
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` for `warmup` + `iters` iterations, timing each of the latter.
/// The closure's return value is black-boxed to keep LLVM honest.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    let mut summary = Summary::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        samples.push(dt);
        summary.add(dt);
    }
    // `percentile` returns None only on empty samples; a smoke-skipped leg
    // (OLSGD_SMOKE=1) reporting NaN beats a panic mid-bench-suite.
    let result = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: summary.mean(),
        std_s: summary.std(),
        p50_s: percentile(&samples, 50.0).unwrap_or(f64::NAN),
        p99_s: percentile(&samples, 99.0).unwrap_or(f64::NAN),
    };
    println!("{}", result.report());
    result
}

/// Optimization barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 2, 20, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_s > 0.0);
        assert!(r.p99_s >= r.p50_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
