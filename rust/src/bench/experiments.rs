//! Shared harness for the paper-reproduction benches (rust/benches/*.rs).
//!
//! Every bench binary = a set of experiment *legs* (algorithm + τ + data
//! setting) over the same runtime/dataset, printed as the paper's
//! table/figure rows and written to `results/<bench>/`.
//!
//! Sizing: the full paper grid at CIFAR scale is hours of CPU; benches
//! default to a scaled workload that preserves the *shape* of every claim
//! and finishes in minutes. Environment overrides:
//!
//! * `OLSGD_FULL=1`      — paper-scaled sizes (longer; for the record runs)
//! * `OLSGD_EPOCHS=N`    — explicit epoch override
//! * `OLSGD_TRAIN_N=N`   — explicit dataset-size override

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::{Algo, ExperimentConfig};
use crate::coordinator::run_experiment;
use crate::data::{self, Dataset, GenConfig};
use crate::metrics::{write_json, TrainLog};
use crate::runtime::{self, ModelRuntime};
use crate::util::json::{arr, num, obj, s, Json};

/// Bench-wide context: loaded model + datasets + output dir.
pub struct BenchCtx {
    /// the loaded model runtime shared by every leg
    pub rt: ModelRuntime,
    /// the base config each leg clones and mutates
    pub base: ExperimentConfig,
    /// results directory (`results/<bench>/`)
    pub out: PathBuf,
    train_iid: Dataset,
    train_cache_seed: u64,
    /// the shared test split
    pub test: Dataset,
}

impl BenchCtx {
    /// Standard bench configuration; `bench_name` names the results dir.
    pub fn new(bench_name: &str) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let full = std::env::var("OLSGD_FULL").map(|v| v == "1").unwrap_or(false);
        cfg.workers = 8;
        cfg.model = "cnn".into();
        cfg.train_n = if full { 4096 } else { 1024 };
        cfg.test_n = 500;
        cfg.epochs = if full { 30.0 } else { 6.0 };
        cfg.eval_every = cfg.epochs / 6.0;
        if let Ok(e) = std::env::var("OLSGD_EPOCHS") {
            cfg.epochs = e.parse().unwrap_or(cfg.epochs);
            cfg.eval_every = cfg.epochs / 6.0;
        }
        if let Ok(n) = std::env::var("OLSGD_TRAIN_N") {
            cfg.train_n = n.parse().unwrap_or(cfg.train_n);
        }

        let rt = runtime::load_for(Path::new(&cfg.artifacts_dir), &cfg)?;
        let gen = GenConfig::default();
        let train_iid = data::generate(cfg.seed, cfg.train_n, "train", &gen);
        let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
        let out = PathBuf::from(format!("results/{bench_name}"));
        Ok(Self {
            rt,
            train_cache_seed: cfg.seed,
            base: cfg,
            out,
            train_iid,
            test,
        })
    }

    /// Run one leg. The paper's α-rule (0.5 for τ=1, 0.6 otherwise) is
    /// applied automatically unless the caller overrode α.
    pub fn run_leg(&mut self, label: &str, mutate: impl FnOnce(&mut ExperimentConfig)) -> Result<TrainLog> {
        let mut cfg = self.base.clone();
        mutate(&mut cfg);
        // paper's tuned alpha rule
        cfg.alpha = if cfg.tau <= 1 { 0.5 } else { 0.6 };
        if cfg.train_n != self.train_iid.n || cfg.seed != self.train_cache_seed {
            let gen = GenConfig::default();
            self.train_iid = data::generate(cfg.seed, cfg.train_n, "train", &gen);
            self.train_cache_seed = cfg.seed;
        }
        eprintln!(
            "[leg] {label}: algo={} tau={} noniid={} epochs={}",
            cfg.algo.name(),
            cfg.tau,
            cfg.noniid,
            cfg.epochs
        );
        let log = run_experiment(&self.rt, &cfg, &self.train_iid, &self.test)?;
        write_json(&self.out, &format!("{label}.json"), &log.to_json())?;
        Ok(log)
    }

    /// Run one leg from a fully specified config (no alpha-rule override) —
    /// for ablations that sweep the hyper-parameters themselves.
    pub fn run_leg_exact(&mut self, label: &str, cfg: ExperimentConfig) -> Result<TrainLog> {
        if cfg.train_n != self.train_iid.n || cfg.seed != self.train_cache_seed {
            let gen = GenConfig::default();
            self.train_iid = data::generate(cfg.seed, cfg.train_n, "train", &gen);
            self.train_cache_seed = cfg.seed;
        }
        eprintln!(
            "[leg] {label}: algo={} tau={} alpha={} beta={} opt={}",
            cfg.algo.name(),
            cfg.tau,
            cfg.alpha,
            cfg.beta,
            cfg.local_opt
        );
        let log = run_experiment(&self.rt, &cfg, &self.train_iid, &self.test)?;
        write_json(&self.out, &format!("{label}.json"), &log.to_json())?;
        Ok(log)
    }

    /// Write the bench-level summary JSON.
    pub fn write_summary(&self, name: &str, rows: Vec<Json>) -> Result<()> {
        write_json(&self.out, name, &arr(rows))?;
        println!("\nwrote results dir: {}", self.out.display());
        Ok(())
    }
}

/// One row of a paper table/figure, JSON-ready.
pub fn row(label: &str, algo: Algo, tau: usize, log: &TrainLog, epochs: f64) -> Json {
    obj(vec![
        ("label", s(label)),
        ("algo", s(algo.name())),
        ("tau", num(tau as f64)),
        ("final_acc", num(log.final_acc())),
        ("final_test_loss", num(log.final_loss())),
        ("time_per_epoch_s", num(log.time_per_epoch(epochs))),
        ("total_time_s", num(log.total_sim_time)),
        ("comm_ratio", num(log.comm_ratio())),
        ("idle_s", num(log.total_idle_s)),
    ])
}

/// Print a figure-style series header.
pub fn header(title: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("================================================================");
    println!(
        "{:<22} {:>6} {:>8} {:>11} {:>14} {:>11}",
        "series", "tau", "acc%", "test_loss", "time/epoch(s)", "comm%"
    );
}

/// Print one figure-style series row.
pub fn print_row(label: &str, tau: usize, log: &TrainLog, epochs: f64) {
    println!(
        "{:<22} {:>6} {:>8.2} {:>11.4} {:>14.3} {:>11.1}",
        label,
        tau,
        100.0 * log.final_acc(),
        log.final_loss(),
        log.time_per_epoch(epochs),
        100.0 * log.comm_ratio()
    );
}
