//! Communication substrate: collectives over the in-process worker set.
//!
//! Two planes, deliberately separated:
//!
//! * **Data plane** — real byte movement. `ring_allreduce_mean` executes the
//!   actual chunked reduce-scatter + all-gather schedule NCCL uses (each of
//!   the `2(m-1)` steps moves one `n/m`-element chunk per rank), so the
//!   arithmetic, chunking, and accumulation order of a production ring are
//!   faithfully exercised — not just `mean()`.
//! * **Timing plane** — the simnet cost model assigns the virtual duration
//!   (`NetworkModel::allreduce_time`), because wall-clock on this 1-core box
//!   says nothing about a 16-node 40 Gbps cluster.
//!
//! Non-blocking collectives (the paper's key mechanism) dispatch through
//! the execution backend: [`launch_collective`] snapshots the inputs into
//! **pooled** buffers (`util::pool::BufferPool` — recycled across rounds,
//! so the steady-state loop allocates nothing; DESIGN.md §10) and hands the
//! data-plane reduction to `Executor::start_reduce`, which computes it
//! inline on the `sim` backend (the deterministic DES mode, eager like the
//! seed) or on the pool's parked **communicator thread** on `threads` — the
//! real overlap `rust/benches/wallclock.rs` measures. Either way the result
//! is bit-identical (pooled buffers are fully overwritten before any
//! arithmetic reads them) and the virtual completion time comes from the
//! simnet cost model.
//!
//! Every reduce schedule threads a [`ReduceScratch`] through its working
//! storage: the ring's snapshot arena, the tree's broadcast root, and the
//! hierarchy's leader set all live in one reusable bundle owned by the
//! executing thread, so repeated collectives stop allocating once warm.
//!
//! The payload size is always *caller-supplied* — collectives reduce
//! whatever buffers they are handed and charge whatever byte count the
//! caller quotes. That indifference is what makes the compression axis
//! (DESIGN.md §12) free to implement here: a compressed strategy hands the
//! reconstructed contributions to the same launch/absorb machinery with the
//! `wire_plan`-scaled byte size, and both planes — reduce schedule and cost
//! formula — follow without a compressed variant of anything.

use crate::clock::Clocks;
use crate::executor::{Executor, ReduceHandle};
use crate::fault::AliveSet;
use crate::simnet::NetworkModel;
use crate::topology::Topology;
use crate::util::pool::BufferPool;

/// Reusable working storage for the exact reduce schedules, owned by
/// whichever thread executes the data plane (the pool's communicator
/// thread keeps one for its lifetime; the coordinator keeps one in the
/// `Executor` for inline reductions). Grows to the run's working-set size
/// during warm-up and allocates nothing afterwards.
#[derive(Default)]
pub struct ReduceScratch {
    /// the ring's "simultaneous send" snapshot arena (§Perf it. 3)
    pub(crate) arena: Vec<f32>,
    /// the tree's reduced-root broadcast copy
    pub(crate) root: Vec<f32>,
    /// the hierarchy's size-scaled leader buffers
    pub(crate) leaders: Vec<Vec<f32>>,
    /// swap slots the alive-masked in-place reduces compact survivor
    /// buffers into (DESIGN.md §11; pointer swaps, never copies)
    pub(crate) active: Vec<Vec<f32>>,
    /// survivor subgroup bounds of the masked hierarchical schedule
    pub(crate) bounds: Vec<(usize, usize)>,
}

/// In-place chunked ring all-reduce (mean) across `m` equal-length buffers.
///
/// Implements reduce-scatter + all-gather exactly as a ring would: after
/// `m-1` reduce-scatter steps rank r owns the fully-reduced chunk
/// `(r+1) mod m`; `m-1` all-gather steps then circulate the reduced chunks.
/// Allocates a fresh arena per call; hot paths use
/// [`ring_allreduce_mean_with`] to reuse one.
pub fn ring_allreduce_mean(buffers: &mut [Vec<f32>]) {
    ring_allreduce_mean_with(buffers, &mut Vec::new());
}

/// [`ring_allreduce_mean`] with a caller-provided snapshot arena (grown as
/// needed, never shrunk — every element is overwritten before it is read,
/// so reuse cannot change a bit of the result).
pub fn ring_allreduce_mean_with(buffers: &mut [Vec<f32>], arena: &mut Vec<f32>) {
    let m = buffers.len();
    assert!(m > 0, "no buffers");
    let n = buffers[0].len();
    for b in buffers.iter() {
        assert_eq!(b.len(), n, "ragged buffers");
    }
    if m == 1 {
        return;
    }

    // Chunk c spans [start(c), end(c)).
    let start = |c: usize| c * n / m;
    let end = |c: usize| (c + 1) * n / m;

    // One reusable snapshot arena for the "simultaneous send" semantics:
    // chunk c of rank r lands at arena[r * max_chunk ..] (§Perf it. 3 —
    // removes 2(m-1)·m transient allocations per collective).
    let max_chunk = (0..m).map(|c| end(c) - start(c)).max().unwrap_or(0);
    if arena.len() < m * max_chunk {
        arena.resize(m * max_chunk, 0.0);
    }

    // Reduce-scatter: at step s, rank r sends chunk (r - s) mod m to r+1,
    // which accumulates it into its own copy of that chunk.
    for s in 0..m - 1 {
        for r in 0..m {
            let c = (r + m - s) % m;
            let (lo, hi) = (start(c), end(c));
            arena[r * max_chunk..r * max_chunk + (hi - lo)]
                .copy_from_slice(&buffers[r][lo..hi]);
        }
        for r in 0..m {
            let dst = (r + 1) % m;
            let c = (r + m - s) % m;
            let (lo, hi) = (start(c), end(c));
            let src = &arena[r * max_chunk..r * max_chunk + (hi - lo)];
            for (i, &v) in src.iter().enumerate() {
                buffers[dst][lo + i] += v;
            }
        }
    }

    // Rank r now owns reduced chunk (r + 1) mod m. Scale it to a mean.
    for r in 0..m {
        let c = (r + 1) % m;
        let inv = 1.0f32 / m as f32;
        for v in buffers[r][start(c)..end(c)].iter_mut() {
            *v *= inv;
        }
    }

    // All-gather: at step s, rank r sends chunk (r + 1 - s) mod m to r+1,
    // which overwrites its copy.
    for s in 0..m - 1 {
        for r in 0..m {
            let c = (r + 1 + m - s) % m;
            let (lo, hi) = (start(c), end(c));
            arena[r * max_chunk..r * max_chunk + (hi - lo)]
                .copy_from_slice(&buffers[r][lo..hi]);
        }
        for r in 0..m {
            let dst = (r + 1) % m;
            let c = (r + 1 + m - s) % m;
            let (lo, hi) = (start(c), end(c));
            buffers[dst][lo..hi]
                .copy_from_slice(&arena[r * max_chunk..r * max_chunk + (hi - lo)]);
        }
    }
}

/// Result of a non-blocking all-reduce: the averaged vector plus the virtual
/// time at which it becomes visible to the workers.
#[derive(Clone, Debug)]
pub struct NonBlockingAllReduce {
    /// the exact mean of the inputs (every exact topology produces it)
    pub result: Vec<f32>,
    /// virtual time the collective was launched
    pub start_time: f64,
    /// virtual wire duration (simnet cost model)
    pub duration: f64,
}

impl NonBlockingAllReduce {
    /// Virtual time at which the result becomes visible to the workers.
    pub fn ready_at(&self) -> f64 {
        self.start_time + self.duration
    }

    /// Absorb the collective on the virtual timeline: every worker
    /// independently waits (blocked-on-comm) until the result is ready —
    /// a no-op for workers whose clock is already past `ready_at()`, which
    /// is exactly the paper's "communication hidden behind τ local steps".
    pub fn absorb(&self, clocks: &mut crate::clock::Clocks) {
        let t = self.ready_at();
        for w in 0..clocks.len() {
            clocks.wait_comm_until(w, t);
        }
    }
}

/// Launch a (virtually) non-blocking mean all-reduce of the workers'
/// vectors. The data plane runs the real ring schedule; the timing plane
/// stamps the completion with the simnet cost. (The seed's entrypoint —
/// kept as the ring special case of [`start_collective`].)
pub fn start_allreduce(
    inputs: &[&[f32]],
    net: &NetworkModel,
    message_bytes: usize,
    start_time: f64,
) -> NonBlockingAllReduce {
    start_collective(&Topology::ring(inputs.len()), inputs, net, message_bytes, start_time)
}

/// Launch a non-blocking exact collective on an arbitrary topology: the data
/// plane runs the topology's real reduce schedule (ring / hierarchical /
/// tree — all exact, so one result vector serves every worker), the timing
/// plane stamps the completion with the topology's cost formula. Gossip is
/// not an exact collective and has its own launcher in
/// `coordinator::gossip`. (Eager and allocating — the reference-loop
/// semantics; the engine's hot path goes through [`launch_collective`].)
pub fn start_collective(
    topo: &Topology,
    inputs: &[&[f32]],
    net: &NetworkModel,
    message_bytes: usize,
    start_time: f64,
) -> NonBlockingAllReduce {
    assert_eq!(inputs.len(), topo.m, "participant count != topology size");
    let mut buffers: Vec<Vec<f32>> = inputs.iter().map(|v| v.to_vec()).collect();
    topo.allreduce_mean(&mut buffers);
    let result = buffers.into_iter().next().expect("non-empty");
    NonBlockingAllReduce {
        result,
        start_time,
        duration: topo.collective_time(net, message_bytes),
    }
}

/// A non-blocking exact collective whose data plane may still be running
/// on the pool's communicator thread (`--execution threads`) or already
/// holds its result (`sim`). Produced by [`launch_collective`]; virtual
/// timing is fixed at launch, so observables never depend on wall clock.
/// Its buffers come from — and return to — the run's `BufferPool`.
pub struct PendingCollective {
    handle: ReduceHandle,
    pool: BufferPool,
    /// virtual time the collective was launched
    pub start_time: f64,
    /// virtual wire duration (simnet cost model)
    pub duration: f64,
}

impl PendingCollective {
    /// Virtual time at which the result becomes visible to the workers.
    pub fn ready_at(&self) -> f64 {
        self.start_time + self.duration
    }

    /// Block (for real, on the threads backend) until the data plane is
    /// done and return the completed collective. Instant on `sim`. All
    /// buffers except the result vector are recycled back into the pool;
    /// callers recycle the result itself once they are done with it.
    pub fn wait(self) -> NonBlockingAllReduce {
        let mut buffers = self.handle.wait();
        let result = buffers.swap_remove(0);
        self.pool.put_set(buffers);
        NonBlockingAllReduce {
            result,
            start_time: self.start_time,
            duration: self.duration,
        }
    }

    /// Convenience: wait for the data plane, charge each worker's virtual
    /// clock up to `ready_at` (no-op for workers already past it — the
    /// paper's hidden communication), and return the averaged vector.
    pub fn absorb(self, clocks: &mut Clocks) -> Vec<f32> {
        let h = self.wait();
        h.absorb(clocks);
        h.result
    }

    /// [`PendingCollective::absorb`] under faults: only the alive set's
    /// *stepping* workers wait for the result — a crashed worker's clock
    /// stays frozen, a partitioned-away worker never hears about the
    /// quorum's collective. Identical to `absorb` when the alive set is
    /// full.
    pub fn absorb_masked(self, clocks: &mut Clocks, alive: &AliveSet) -> Vec<f32> {
        let h = self.wait();
        let t = h.ready_at();
        for w in 0..clocks.len() {
            if alive.steps(w) {
                clocks.wait_comm_until(w, t);
            }
        }
        h.result
    }
}

/// Launch a non-blocking exact collective through the execution backend:
/// the inputs are snapshotted into pooled buffers (bit-exact copies, zero
/// steady-state allocations once the pool is warm), and the data plane —
/// the topology's real reduce schedule over that snapshot — runs inline on
/// the `sim` backend or on the parked communicator thread on `threads`;
/// the timing plane stamps the completion with the topology's cost formula
/// either way. (The ring's `Topology` clone is allocation-free; hier and
/// gossip graphs carry small structure vectors — see DESIGN.md §10.)
pub fn launch_collective(
    exec: &Executor,
    topo: &Topology,
    inputs: &[&[f32]],
    net: &NetworkModel,
    message_bytes: usize,
    start_time: f64,
) -> PendingCollective {
    assert_eq!(inputs.len(), topo.m, "participant count != topology size");
    let duration = topo.collective_time(net, message_bytes);
    let pool = exec.buffers().clone();
    let mut buffers = pool.take_set_copy(inputs);
    let topo = topo.clone();
    let handle = exec.start_reduce(move |scratch| {
        topo.allreduce_mean_with(&mut buffers, scratch);
        buffers
    });
    PendingCollective { handle, pool, start_time, duration }
}

/// [`launch_collective`] under faults (DESIGN.md §11): only the alive
/// set's *members* — the quorum side's survivors — contribute. Their
/// inputs are snapshotted into a compact pooled buffer set, the data plane
/// runs the topology's real schedule over the survivor sub-graph
/// (`Topology::allreduce_mean_compact`), and the timing plane charges the
/// survivor-shaped cost (`Topology::collective_time_alive`). Every compact
/// buffer holds the exact survivor mean on completion. Delegates to
/// [`launch_collective`] — bit-identically — when the alive set is full.
pub fn launch_collective_among(
    exec: &Executor,
    topo: &Topology,
    inputs: &[&[f32]],
    alive: &AliveSet,
    net: &NetworkModel,
    message_bytes: usize,
    start_time: f64,
) -> PendingCollective {
    assert_eq!(inputs.len(), topo.m, "participant count != topology size");
    if alive.is_full() {
        return launch_collective(exec, topo, inputs, net, message_bytes, start_time);
    }
    let duration = topo.collective_time_alive(net, message_bytes, alive);
    let pool = exec.buffers().clone();
    let member_refs: Vec<&[f32]> = alive.members().iter().map(|&w| inputs[w]).collect();
    let mut buffers = pool.take_set_copy(&member_refs);
    let members: Vec<usize> = alive.members().to_vec();
    let topo = topo.clone();
    let handle = exec.start_reduce(move |scratch| {
        topo.allreduce_mean_compact(&mut buffers, &members, scratch);
        buffers
    });
    PendingCollective { handle, pool, start_time, duration }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Execution;
    use crate::model::vecmath;
    use crate::util::proptest::{assert_close, property};

    #[test]
    fn ring_matches_mean_small() {
        let mut bufs = vec![vec![1.0f32, 2.0, 3.0, 4.0], vec![3.0, 6.0, 9.0, 12.0]];
        ring_allreduce_mean(&mut bufs);
        for b in &bufs {
            assert_close(b, &[2.0, 4.0, 6.0, 8.0], 1e-6, 0.0);
        }
    }

    #[test]
    fn ring_single_worker_identity() {
        let mut bufs = vec![vec![5.0f32, -1.0]];
        ring_allreduce_mean(&mut bufs);
        assert_close(&bufs[0], &[5.0, -1.0], 0.0, 0.0);
    }

    #[test]
    fn ring_with_reused_arena_is_bit_identical() {
        // One arena across many differently-shaped collectives: stale
        // contents must never surface (every slot is written before read).
        let mut arena = vec![7.0f32; 3]; // poisoned + deliberately small
        for (m, n) in [(1usize, 40usize), (4, 300), (10, 7), (3, 1), (8, 128), (2, 33)] {
            let inputs: Vec<Vec<f32>> = (0..m)
                .map(|w| (0..n).map(|i| ((w * 37 + i * 11) % 97) as f32 * 0.21 - 9.0).collect())
                .collect();
            let mut fresh = inputs.clone();
            ring_allreduce_mean(&mut fresh);
            let mut reused = inputs;
            ring_allreduce_mean_with(&mut reused, &mut arena);
            for (a, b) in fresh.iter().zip(&reused) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "arena reuse drifted (m={m}, n={n})");
                }
            }
        }
    }

    #[test]
    fn property_ring_equals_mean_everywhere() {
        property("ring == mean", 120, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(1, 500);
            let inputs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 4.0)).collect();
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let want = vecmath::mean(&refs);
            let mut bufs = inputs.clone();
            ring_allreduce_mean(&mut bufs);
            for b in &bufs {
                assert_close(b, &want, 1e-4, 1e-5);
            }
        });
    }

    #[test]
    fn property_ring_handles_n_smaller_than_m() {
        property("ring ragged chunks", 60, |g| {
            let m = g.usize_in(2, 10);
            let n = g.usize_in(1, m); // chunks of size 0 exist
            let inputs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 2.0)).collect();
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let want = vecmath::mean(&refs);
            let mut bufs = inputs.clone();
            ring_allreduce_mean(&mut bufs);
            for b in &bufs {
                assert_close(b, &want, 1e-4, 1e-5);
            }
        });
    }

    #[test]
    fn start_collective_is_exact_on_every_topology() {
        let net = NetworkModel::paper_40gbps();
        let inputs: Vec<Vec<f32>> = vec![
            vec![1.0, 2.0, 3.0],
            vec![5.0, 4.0, 3.0],
            vec![0.0, -6.0, 9.0],
            vec![2.0, 8.0, 1.0],
        ];
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let want = vecmath::mean(&refs);
        for topo in [Topology::ring(4), Topology::hier(4, 2), Topology::tree(4)] {
            let h = start_collective(&topo, &refs, &net, 1 << 20, 3.0);
            assert_close(&h.result, &want, 1e-5, 1e-6);
            assert_eq!(h.duration, topo.collective_time(&net, 1 << 20));
            assert_eq!(h.start_time, 3.0);
        }
    }

    #[test]
    fn nonblocking_timestamps() {
        let net = NetworkModel::paper_40gbps();
        let a = vec![1.0f32; 10];
        let b = vec![3.0f32; 10];
        let h = start_allreduce(&[&a, &b], &net, 1 << 20, 100.0);
        assert_close(&h.result, &vec![2.0f32; 10], 1e-6, 0.0);
        assert!(h.duration > 0.0);
        assert_eq!(h.ready_at(), 100.0 + h.duration);
    }

    #[test]
    fn absorb_blocks_only_workers_behind_the_wire() {
        use crate::clock::Clocks;
        let net = NetworkModel::paper_40gbps();
        let a = vec![1.0f32; 8];
        let b = vec![3.0f32; 8];
        let h = start_allreduce(&[&a, &b], &net, 1 << 20, 10.0);
        let mut clocks = Clocks::new(2);
        clocks.compute(0, 10.0 + h.duration + 5.0); // already past ready_at
        clocks.compute(1, 10.0); // must wait the full wire duration
        h.absorb(&mut clocks);
        assert_eq!(clocks.worker(0).comm_blocked_s, 0.0);
        assert!((clocks.worker(1).comm_blocked_s - h.duration).abs() < 1e-12);
        assert_eq!(clocks.now(1), h.ready_at());
        clocks.check_invariants();
    }

    #[test]
    fn launch_collective_is_backend_invariant_and_pooled() {
        let net = NetworkModel::paper_40gbps();
        let inputs: Vec<Vec<f32>> =
            vec![vec![1.0, 2.0, 3.0], vec![5.0, 4.0, 3.0], vec![0.0, -6.0, 9.0]];
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let sim_exec = Executor::new(Execution::Sim, 3);
        let thr_exec = Executor::new(Execution::Threads, 3);
        for topo in [Topology::ring(3), Topology::tree(3)] {
            let eager = start_collective(&topo, &refs, &net, 1 << 20, 2.0);
            let sim = launch_collective(&sim_exec, &topo, &refs, &net, 1 << 20, 2.0);
            let thr = launch_collective(&thr_exec, &topo, &refs, &net, 1 << 20, 2.0);
            assert_eq!(sim.ready_at(), eager.ready_at());
            assert_eq!(thr.ready_at(), eager.ready_at());
            let (sim, thr) = (sim.wait(), thr.wait());
            // Bit-identical across backends AND against the eager seed path.
            for (a, b) in sim.result.iter().zip(&eager.result) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in thr.result.iter().zip(&eager.result) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // Second launch on each backend reuses the first launch's buffers
        // (the result vector is the one buffer the caller keeps).
        for exec in [&sim_exec, &thr_exec] {
            let warm = exec.snapshot();
            let h = launch_collective(exec, &Topology::ring(3), &refs, &net, 1 << 20, 2.0);
            exec.buffers().put(h.wait().result);
            let h = launch_collective(exec, &Topology::ring(3), &refs, &net, 1 << 20, 2.0);
            let steady = exec.snapshot();
            assert_eq!(
                steady.buffer_allocs,
                warm.buffer_allocs + 1,
                "only the not-yet-returned result slot may allocate"
            );
            assert!(steady.buffer_hits > warm.buffer_hits);
            exec.buffers().put(h.wait().result);
        }
    }

    #[test]
    fn pending_collective_absorb_matches_eager_absorb() {
        use crate::clock::Clocks;
        let net = NetworkModel::paper_40gbps();
        let a = vec![1.0f32; 8];
        let b = vec![3.0f32; 8];
        let exec = Executor::new(Execution::Threads, 2);
        let pending =
            launch_collective(&exec, &Topology::ring(2), &[&a, &b], &net, 1 << 20, 10.0);
        let ready = pending.ready_at();
        let mut clocks = Clocks::new(2);
        clocks.compute(0, ready + 5.0);
        clocks.compute(1, 10.0);
        let result = pending.absorb(&mut clocks);
        assert_close(&result, &vec![2.0f32; 8], 1e-6, 0.0);
        assert_eq!(clocks.worker(0).comm_blocked_s, 0.0);
        assert_eq!(clocks.now(1), ready);
        clocks.check_invariants();
    }
}
