//! Virtual-time substrate: per-worker discrete-event clocks.
//!
//! Every figure in the paper has a time axis; this module produces it. Each
//! worker owns a monotonic virtual clock; algorithm drivers advance it with
//! compute/communication durations and the clock keeps a per-category
//! breakdown (compute, blocked-on-comm, idle-at-barrier) so the paper's
//! communication-to-computation ratio (E8) and straggler idle-time (E9)
//! fall straight out of the accounting.
//!
//! Invariants (property-tested):
//! * per-worker time never decreases;
//! * total = compute + comm_blocked + idle for every worker;
//! * after `barrier()` all participating workers share the same time.

/// Time accounting for one worker.
#[derive(Clone, Debug, Default)]
pub struct WorkerClock {
    now: f64,
    /// seconds spent computing
    pub compute_s: f64,
    /// seconds blocked waiting on communication
    pub comm_blocked_s: f64,
    /// seconds idle at barriers (waiting for stragglers)
    pub idle_s: f64,
}

/// Clocks for a cluster of m workers.
#[derive(Clone, Debug)]
pub struct Clocks {
    workers: Vec<WorkerClock>,
}

impl Clocks {
    /// All-zero clocks for `m` workers.
    pub fn new(m: usize) -> Self {
        assert!(m > 0);
        Self { workers: vec![WorkerClock::default(); m] }
    }

    /// Worker count.
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    /// Whether there are zero workers (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Worker `w`'s current virtual time.
    pub fn now(&self, w: usize) -> f64 {
        self.workers[w].now
    }

    /// Latest worker time — the experiment's wall-clock.
    pub fn max_now(&self) -> f64 {
        self.workers.iter().map(|w| w.now).fold(0.0, f64::max)
    }

    /// Earliest worker time.
    pub fn min_now(&self) -> f64 {
        self.workers.iter().map(|w| w.now).fold(f64::INFINITY, f64::min)
    }

    /// Spread between the fastest and slowest worker — the straggler lag
    /// the E9 scenarios quantify (0 right after a barrier).
    pub fn lag(&self) -> f64 {
        self.max_now() - self.min_now()
    }

    /// Worker `w`'s full time breakdown.
    pub fn worker(&self, w: usize) -> &WorkerClock {
        &self.workers[w]
    }

    /// Advance `w` by a compute interval.
    pub fn compute(&mut self, w: usize, dt: f64) {
        assert!(dt >= 0.0, "negative compute dt {dt}");
        self.workers[w].now += dt;
        self.workers[w].compute_s += dt;
    }

    /// Advance `w` by a *blocking* communication interval.
    pub fn comm_blocked(&mut self, w: usize, dt: f64) {
        assert!(dt >= 0.0, "negative comm dt {dt}");
        self.workers[w].now += dt;
        self.workers[w].comm_blocked_s += dt;
    }

    /// Block `w` until absolute time `t` (no-op if already past), counted as
    /// communication wait. Used for "anchor not ready yet" stalls.
    pub fn wait_comm_until(&mut self, w: usize, t: f64) {
        let c = &mut self.workers[w];
        if t > c.now {
            c.comm_blocked_s += t - c.now;
            c.now = t;
        }
    }

    /// Block `w` until absolute time `t` (no-op if already past), counted as
    /// idle. Used for crash downtime: a dead worker's clock freezes, and on
    /// rejoin it jumps to the cluster's current time with the gap charged
    /// here (DESIGN.md §11).
    pub fn wait_idle_until(&mut self, w: usize, t: f64) {
        let c = &mut self.workers[w];
        if t > c.now {
            c.idle_s += t - c.now;
            c.now = t;
        }
    }

    /// Synchronize all workers to the max time; the gap is idle (waiting for
    /// stragglers). Returns the barrier time.
    pub fn barrier(&mut self) -> f64 {
        let t = self.max_now();
        for c in self.workers.iter_mut() {
            if t > c.now {
                c.idle_s += t - c.now;
                c.now = t;
            }
        }
        t
    }

    /// [`Clocks::barrier`] over a subset of workers (the alive-set barrier
    /// of the blocking strategies under faults): synchronizes exactly the
    /// listed workers to their common max time, leaving everyone else —
    /// crashed or partitioned-away — untouched. With the full worker list
    /// this is bit-identical to [`Clocks::barrier`].
    pub fn barrier_among(&mut self, workers: &[usize]) -> f64 {
        let t = workers.iter().map(|&w| self.workers[w].now).fold(0.0, f64::max);
        for &w in workers {
            self.wait_idle_until(w, t);
        }
        t
    }

    /// Total blocked-on-communication seconds across workers.
    pub fn total_comm_blocked(&self) -> f64 {
        self.workers.iter().map(|w| w.comm_blocked_s).sum()
    }

    /// Total compute seconds across workers.
    pub fn total_compute(&self) -> f64 {
        self.workers.iter().map(|w| w.compute_s).sum()
    }

    /// Total barrier-idle seconds across workers.
    pub fn total_idle(&self) -> f64 {
        self.workers.iter().map(|w| w.idle_s).sum()
    }

    /// The paper's communication-to-computation ratio over the run so far.
    pub fn comm_to_compute_ratio(&self) -> f64 {
        let c = self.total_compute();
        if c == 0.0 {
            0.0
        } else {
            (self.total_comm_blocked() + self.total_idle()) / c
        }
    }

    /// Accounting invariant: now == compute + comm + idle per worker.
    pub fn check_invariants(&self) {
        for (i, w) in self.workers.iter().enumerate() {
            let sum = w.compute_s + w.comm_blocked_s + w.idle_s;
            assert!(
                (w.now - sum).abs() <= 1e-9 * (1.0 + w.now.abs()),
                "worker {i}: now {} != breakdown {}",
                w.now,
                sum
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn barrier_charges_idle_to_fast_workers() {
        let mut c = Clocks::new(3);
        c.compute(0, 1.0);
        c.compute(1, 3.0);
        c.compute(2, 2.0);
        let t = c.barrier();
        assert_eq!(t, 3.0);
        assert_eq!(c.worker(0).idle_s, 2.0);
        assert_eq!(c.worker(1).idle_s, 0.0);
        assert_eq!(c.worker(2).idle_s, 1.0);
        c.check_invariants();
    }

    #[test]
    fn wait_comm_until_noop_if_past() {
        let mut c = Clocks::new(1);
        c.compute(0, 5.0);
        c.wait_comm_until(0, 3.0);
        assert_eq!(c.now(0), 5.0);
        assert_eq!(c.worker(0).comm_blocked_s, 0.0);
        c.wait_comm_until(0, 7.5);
        assert_eq!(c.now(0), 7.5);
        assert_eq!(c.worker(0).comm_blocked_s, 2.5);
        c.check_invariants();
    }

    #[test]
    fn barrier_among_leaves_outsiders_frozen() {
        let mut c = Clocks::new(4);
        c.compute(0, 1.0);
        c.compute(1, 3.0);
        c.compute(2, 2.0);
        c.compute(3, 9.0); // crashed-ahead worker: not in the barrier
        let t = c.barrier_among(&[0, 1, 2]);
        assert_eq!(t, 3.0);
        assert_eq!(c.now(0), 3.0);
        assert_eq!(c.worker(0).idle_s, 2.0);
        assert_eq!(c.now(3), 9.0, "outsiders must be untouched");
        assert_eq!(c.worker(3).idle_s, 0.0);
        // Downtime accounting: idle jump + no-op when already past.
        c.wait_idle_until(0, 5.0);
        assert_eq!(c.now(0), 5.0);
        assert_eq!(c.worker(0).idle_s, 4.0);
        c.wait_idle_until(3, 5.0);
        assert_eq!(c.now(3), 9.0);
        c.check_invariants();
    }

    #[test]
    fn lag_tracks_spread_and_barrier_zeroes_it() {
        let mut c = Clocks::new(3);
        c.compute(0, 1.0);
        c.compute(1, 4.0);
        c.compute(2, 2.5);
        assert_eq!(c.min_now(), 1.0);
        assert_eq!(c.lag(), 3.0);
        c.barrier();
        assert_eq!(c.lag(), 0.0);
        c.check_invariants();
    }

    #[test]
    fn ratio_definition() {
        let mut c = Clocks::new(2);
        c.compute(0, 10.0);
        c.compute(1, 10.0);
        c.comm_blocked(0, 2.0);
        c.comm_blocked(1, 2.0);
        assert!((c.comm_to_compute_ratio() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn property_random_interleavings_keep_invariants() {
        property("clock invariants", 300, |g| {
            let m = g.usize_in(1, 8);
            let mut c = Clocks::new(m);
            let mut last = vec![0.0f64; m];
            for _ in 0..g.usize_in(0, 60) {
                match g.usize_in(0, 3) {
                    0 => {
                        let w = g.usize_in(0, m - 1);
                        c.compute(w, g.f64_in(0.0, 2.0));
                    }
                    1 => {
                        let w = g.usize_in(0, m - 1);
                        c.comm_blocked(w, g.f64_in(0.0, 1.0));
                    }
                    2 => {
                        let w = g.usize_in(0, m - 1);
                        let t = g.f64_in(0.0, 10.0);
                        c.wait_comm_until(w, t);
                    }
                    _ => {
                        c.barrier();
                        let t = c.max_now();
                        for w in 0..m {
                            assert_eq!(c.now(w), t, "barrier must equalize");
                        }
                    }
                }
                for w in 0..m {
                    assert!(c.now(w) >= last[w], "clock went backwards");
                    last[w] = c.now(w);
                }
                c.check_invariants();
            }
        });
    }
}
