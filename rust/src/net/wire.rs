//! Length-prefixed binary frames for the `net` execution backend
//! (DESIGN.md §13).
//!
//! Every message on a coordinator↔worker TCP connection is one frame:
//!
//! ```text
//!   [u32 magic "OLSG"][u16 version][u16 kind][u32 payload_len][payload]
//! ```
//!
//! all integers little-endian. The handshake payloads (`Hello`/`Welcome`)
//! are JSON (`util::json`) because they carry config metadata; the per-round
//! phase payloads are hand-rolled binary — a few megabytes of `f32` state
//! per frame has no business being stringified. The codec helpers below
//! (`put_*` / [`Cursor`]) are the only way payload bytes are produced or
//! consumed, so the layout lives in exactly one place per message kind.

use std::io::{Read, Write};

use anyhow::{ensure, Context, Result};

/// Frame magic: `"OLSG"` as a big-endian u32 literal, written little-endian.
pub const MAGIC: u32 = 0x4F4C_5347;
/// Wire protocol version; bumped on any layout change. A mismatch is a hard
/// handshake error, never a silent reinterpretation. v2: `PhaseReq` grew
/// per-slot population extras (bound id + batcher + straggler-RNG state)
/// when the population axis is on, and workers take `--timeout`.
pub const VERSION: u16 = 2;

/// Worker → coordinator greeting (JSON payload: `lanes`, `proc`).
pub const KIND_HELLO: u16 = 1;
/// Coordinator → worker slot grant (JSON payload: `slots`, `consumed`,
/// `config`).
pub const KIND_WELCOME: u16 = 2;
/// Coordinator → worker batched round-phase request (binary payload).
pub const KIND_PHASE_REQ: u16 = 3;
/// Worker → coordinator batched round-phase result (binary payload).
pub const KIND_PHASE_RESP: u16 = 4;
/// Coordinator → worker liveness probe (empty payload).
pub const KIND_PING: u16 = 5;
/// Worker → coordinator liveness reply (empty payload).
pub const KIND_PONG: u16 = 6;
/// Coordinator → worker clean end-of-run (empty payload).
pub const KIND_SHUTDOWN: u16 = 7;

/// Upper bound on a single frame's payload, as a defense against a corrupt
/// or hostile length prefix allocating unbounded memory.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Write one frame (header + payload) and flush it onto the wire.
pub fn write_frame(w: &mut impl Write, kind: u16, payload: &[u8]) -> Result<()> {
    ensure!(payload.len() <= MAX_FRAME_BYTES, "frame payload of {} bytes", payload.len());
    let mut head = [0u8; 12];
    head[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    head[4..6].copy_from_slice(&VERSION.to_le_bytes());
    head[6..8].copy_from_slice(&kind.to_le_bytes());
    head[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&head).context("writing frame header")?;
    w.write_all(payload).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one complete frame into `buf` (cleared and reused across calls) and
/// return its kind. The whole payload is read before returning, so a caller
/// never observes — or acts on — a partially received message.
pub fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>) -> Result<u16> {
    let mut head = [0u8; 12];
    r.read_exact(&mut head).context("reading frame header")?;
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    ensure!(magic == MAGIC, "bad frame magic {magic:#010x} (want {MAGIC:#010x})");
    let version = u16::from_le_bytes(head[4..6].try_into().unwrap());
    ensure!(
        version == VERSION,
        "wire protocol version mismatch: peer speaks v{version}, this build speaks v{VERSION}"
    );
    let kind = u16::from_le_bytes(head[6..8].try_into().unwrap());
    let len = u32::from_le_bytes(head[8..12].try_into().unwrap()) as usize;
    ensure!(len <= MAX_FRAME_BYTES, "frame payload of {len} bytes exceeds the cap");
    buf.clear();
    buf.resize(len, 0);
    r.read_exact(buf).context("reading frame payload")?;
    Ok(kind)
}

/// Append one `u8`.
pub fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

/// Append one little-endian `u32`.
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append one little-endian `u64`.
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append one little-endian `f32`.
pub fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a length-prefixed `f32` slice (`u32` count + raw LE words).
pub fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    put_u32(buf, xs.len() as u32);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append a length-prefixed `f64` slice (`u32` count + raw LE words).
pub fn put_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    put_u32(buf, xs.len() as u32);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Sequential payload reader over one received frame. Every accessor is
/// bounds-checked — a short or corrupt payload is a loud decode error, not
/// an out-of-bounds read or a zero-filled value.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    /// Start reading `buf` from the beginning.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "truncated frame payload: wanted {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one `u8`.
    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read one little-endian `u32`.
    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read one little-endian `u64`.
    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read one little-endian `f32`.
    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a length-prefixed `f32` slice into `out`, requiring the wire
    /// count to match `out.len()` exactly — a state-size mismatch between
    /// the two processes is a protocol error, never a silent resize.
    pub fn get_f32s_into(&mut self, out: &mut [f32]) -> Result<()> {
        let n = self.get_u32()? as usize;
        ensure!(
            n == out.len(),
            "f32 slice length mismatch: wire has {n}, receiver expects {}",
            out.len()
        );
        let bytes = self.take(n * 4)?;
        for (o, w) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            *o = f32::from_le_bytes(w.try_into().unwrap());
        }
        Ok(())
    }

    /// Read a length-prefixed `f32` slice into an owned vector (gradient
    /// payloads, whose receiver has no preallocated destination).
    pub fn get_f32s_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.get_u32()? as usize;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|w| f32::from_le_bytes(w.try_into().unwrap())).collect())
    }

    /// Read a length-prefixed `f64` slice, appending onto `out`.
    pub fn get_f64s_into(&mut self, out: &mut Vec<f64>) -> Result<()> {
        let n = self.get_u32()? as usize;
        let bytes = self.take(n * 8)?;
        out.extend(bytes.chunks_exact(8).map(|w| f64::from_le_bytes(w.try_into().unwrap())));
        Ok(())
    }

    /// Require the payload to be fully consumed — trailing bytes mean the
    /// two sides disagree about the layout.
    pub fn finish(self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "frame payload has {} undecoded trailing bytes",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip_over_a_byte_pipe() {
        let mut pipe: Vec<u8> = Vec::new();
        let payload: Vec<u8> = (0..=255).collect();
        write_frame(&mut pipe, KIND_PHASE_REQ, &payload).unwrap();
        write_frame(&mut pipe, KIND_PING, &[]).unwrap();
        let mut r = pipe.as_slice();
        let mut buf = Vec::new();
        assert_eq!(read_frame(&mut r, &mut buf).unwrap(), KIND_PHASE_REQ);
        assert_eq!(buf, payload);
        assert_eq!(read_frame(&mut r, &mut buf).unwrap(), KIND_PING);
        assert!(buf.is_empty());
        assert!(r.is_empty(), "pipe fully drained");
    }

    #[test]
    fn corrupt_headers_are_loud() {
        let mut good: Vec<u8> = Vec::new();
        write_frame(&mut good, KIND_PONG, b"xy").unwrap();
        let mut buf = Vec::new();

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        assert!(read_frame(&mut bad_magic.as_slice(), &mut buf).is_err());

        let mut bad_version = good.clone();
        bad_version[4] = 99;
        assert!(read_frame(&mut bad_version.as_slice(), &mut buf).is_err());

        let truncated = &good[..good.len() - 1];
        assert!(read_frame(&mut &truncated[..], &mut buf).is_err());
    }

    #[test]
    fn codec_round_trips_bit_exactly() {
        let mut p = Vec::new();
        put_u8(&mut p, 7);
        put_u32(&mut p, 0xDEAD_BEEF);
        put_u64(&mut p, u64::MAX - 1);
        put_f32(&mut p, -0.0);
        put_f32s(&mut p, &[1.5, f32::MIN_POSITIVE, -3.25]);
        put_f64s(&mut p, &[std::f64::consts::PI]);
        let mut c = Cursor::new(&p);
        assert_eq!(c.get_u8().unwrap(), 7);
        assert_eq!(c.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(c.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(c.get_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        let mut xs = [0.0f32; 3];
        c.get_f32s_into(&mut xs).unwrap();
        assert_eq!(xs[1].to_bits(), f32::MIN_POSITIVE.to_bits());
        let mut ys = Vec::new();
        c.get_f64s_into(&mut ys).unwrap();
        assert_eq!(ys[0].to_bits(), std::f64::consts::PI.to_bits());
        c.finish().unwrap();
    }

    #[test]
    fn decode_errors_are_loud_not_silent() {
        let mut p = Vec::new();
        put_f32s(&mut p, &[1.0, 2.0]);
        // Length mismatch against the receiver's buffer.
        let mut c = Cursor::new(&p);
        let mut three = [0.0f32; 3];
        assert!(c.get_f32s_into(&mut three).is_err());
        // Truncated payload.
        let mut c = Cursor::new(&p[..p.len() - 2]);
        let mut two = [0.0f32; 2];
        assert!(c.get_f32s_into(&mut two).is_err());
        // Trailing bytes.
        let mut c = Cursor::new(&p);
        let mut ok = [0.0f32; 2];
        c.get_f32s_into(&mut ok).unwrap();
        assert!(Cursor::new(&p[..0]).finish().is_ok());
        let mut extra = p.clone();
        put_u8(&mut extra, 0);
        let mut c2 = Cursor::new(&extra);
        c2.get_f32s_into(&mut ok).unwrap();
        assert!(c2.finish().is_err());
        let _ = c;
    }
}
