//! The `net` service plane: a real coordinator/worker split over TCP
//! (DESIGN.md §13, `--execution net`).
//!
//! The other two execution backends schedule one process's threads; this
//! one schedules *processes*. The coordinator (the engine process, see
//! `executor::net`) listens on a socket, worker processes connect, and each
//! round's local phase travels the wire:
//!
//! ```text
//!   worker  → Hello   {lanes, proc}                      (JSON)
//!   coord   → Welcome {slots, consumed, config}          (JSON)
//!   coord   → PhaseReq  [phase start_step | per slot: w steps params mom mom2 adam_t
//!                        (+ population id, batcher, straggler RNG when the axis is on)]
//!   worker  → PhaseResp [per slot: w losses params mom mom2 adam_t grad?]
//!   coord   → Ping / worker → Pong                       (liveness, each round)
//!   coord   → Shutdown                                   (end of run)
//! ```
//!
//! framed as in [`wire`]. The coordinator keeps the *canonical* replicas:
//! it ships each stepping slot's state out, receives the stepped state
//! back, and **replays the slot's stochastic draws locally**
//! (`StepView::replay_draws`) so its batcher and straggler-RNG streams stay
//! bit-identical to the `sim` backend. That is the whole determinism
//! argument: the worker computes the same kernels on the same bits, the
//! coordinator's streams never diverge, and a dead connection degrades to
//! running the slot locally — same bits again — plus a `crash@round` event
//! injected into the PR-5 fault machinery.
//!
//! This module owns the worker side ([`run_worker`], the `olsgd worker`
//! subcommand) and the handshake/phase codecs both sides share; the
//! coordinator side lives in `executor::net` behind the `Executor` seam, so
//! every mixing strategy, topology, compressor, and fault schedule composes
//! with the service plane unchanged.

pub mod wire;

use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::engine::LocalPhase;
use crate::coordinator::{self, StepView, TrainContext, Workers};
use crate::data::{self, Batcher, GenConfig};
use crate::executor::{drive_worker, WorkerRound};
use crate::optim::LrSchedule;
use crate::runtime;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// A worker's `Hello`: how many slots it can serve, and (for fleet children
/// spawned by the coordinator) its stable process index, which pins its
/// slot assignment deterministically.
pub(crate) struct Hello {
    /// number of worker slots this process offers to serve
    pub lanes: usize,
    /// spawner-assigned process index (`None` for external workers)
    pub proc: Option<usize>,
}

pub(crate) fn encode_hello(h: &Hello) -> String {
    json::obj(vec![
        ("lanes", json::num(h.lanes as f64)),
        ("proc", json::num(h.proc.map_or(-1.0, |p| p as f64))),
    ])
    .to_string_compact()
}

pub(crate) fn decode_hello(payload: &[u8]) -> Result<Hello> {
    let j = Json::parse(std::str::from_utf8(payload).context("Hello is not UTF-8")?)?;
    let lanes = j.get("lanes")?.as_usize()?;
    ensure!(lanes >= 1, "Hello offers zero lanes");
    let proc = j.get("proc")?.as_f64()?;
    Ok(Hello { lanes, proc: if proc < 0.0 { None } else { Some(proc as usize) } })
}

pub(crate) fn encode_welcome(
    slots: &[usize],
    consumed: &[u64],
    kv: &[(String, String)],
) -> String {
    json::obj(vec![
        ("slots", json::arr(slots.iter().map(|&s| json::num(s as f64)))),
        ("consumed", json::arr(consumed.iter().map(|&c| json::num(c as f64)))),
        (
            "config",
            json::arr(
                kv.iter().map(|(k, v)| json::arr([json::s(k), json::s(v)])),
            ),
        ),
    ])
    .to_string_compact()
}

pub(crate) fn decode_welcome(payload: &[u8]) -> Result<(Vec<usize>, Vec<u64>, ExperimentConfig)> {
    let j = Json::parse(std::str::from_utf8(payload).context("Welcome is not UTF-8")?)?;
    let slots: Vec<usize> =
        j.get("slots")?.as_arr()?.iter().map(|s| s.as_usize()).collect::<Result<_>>()?;
    let consumed: Vec<u64> = j
        .get("consumed")?
        .as_arr()?
        .iter()
        .map(|c| Ok(c.as_f64()? as u64))
        .collect::<Result<_>>()?;
    ensure!(
        slots.len() == consumed.len(),
        "Welcome slot/consumed length mismatch ({} vs {})",
        slots.len(),
        consumed.len()
    );
    // The config rides the handshake as ordered (key, value) pairs and is
    // replayed through `ExperimentConfig::set` — the exact round-trip
    // config::tests::to_kv_round_trips_through_set pins.
    let mut cfg = ExperimentConfig::default();
    for pair in j.get("config")?.as_arr()? {
        let kv = pair.as_arr()?;
        ensure!(kv.len() == 2, "Welcome config entry is not a (key, value) pair");
        cfg.set(kv[0].as_str()?, kv[1].as_str()?)?;
    }
    Ok((slots, consumed, cfg))
}

/// Append one RNG's exact state (`Rng::state`): 4 little-endian `u64`
/// words plus the spare-normal flag and bits. Wire twin of the population
/// spill codec's record, so restore is bit-for-bit.
fn put_rng(out: &mut Vec<u8>, rng: &Rng) {
    let (s, spare) = rng.state();
    for w in s {
        wire::put_u64(out, w);
    }
    match spare {
        Some(z) => {
            wire::put_u8(out, 1);
            wire::put_u64(out, z.to_bits());
        }
        None => wire::put_u8(out, 0),
    }
}

fn get_rng(c: &mut wire::Cursor) -> Result<Rng> {
    let s = [c.get_u64()?, c.get_u64()?, c.get_u64()?, c.get_u64()?];
    let spare = match c.get_u8()? {
        0 => None,
        1 => Some(f64::from_bits(c.get_u64()?)),
        other => bail!("bad spare-normal flag {other} in PhaseReq"),
    };
    Ok(Rng::from_state(s, spare))
}

/// Append a batch sampler's exact state (`Batcher::spill_parts` plus the
/// public cursor fields).
fn put_batcher(out: &mut Vec<u8>, b: &Batcher) {
    let (shard, pos, brng) = b.spill_parts();
    wire::put_u32(out, shard.len() as u32);
    for &s in shard {
        wire::put_u32(out, s);
    }
    wire::put_u64(out, pos as u64);
    wire::put_u64(out, b.epochs_completed as u64);
    wire::put_u8(out, b.reshuffle as u8);
    put_rng(out, brng);
}

fn get_batcher(c: &mut wire::Cursor) -> Result<Batcher> {
    let n = c.get_u32()? as usize;
    let mut shard = Vec::with_capacity(n);
    for _ in 0..n {
        shard.push(c.get_u32()?);
    }
    let pos = c.get_u64()? as usize;
    let epochs = c.get_u64()? as usize;
    let reshuffle = match c.get_u8()? {
        0 => false,
        1 => true,
        other => bail!("bad reshuffle flag {other} in PhaseReq"),
    };
    let rng = get_rng(c)?;
    Ok(Batcher::from_spill_parts(shard, pos, rng, epochs, reshuffle))
}

/// Encode one batched `PhaseReq` payload for the slots of one worker
/// process: frame-level phase/step header, then each slot's planned step
/// count and full replica state. `views` is indexed by worker id.
///
/// `pop_ids` is the slot → population-id binding when the population axis
/// is on (`None` otherwise, leaving the dense layout byte-identical).
/// Under population the worker process cannot rebuild a slot's stochastic
/// streams itself — its slot-keyed streams would belong to the wrong
/// worker after a rebind — so each slot also carries its bound id and the
/// bound worker's exact batcher + straggler-RNG state. The worker installs
/// them, drives, and discards them; the coordinator's canonical streams
/// advance by local replay exactly as in dense mode.
pub(crate) fn encode_phase_req(
    out: &mut Vec<u8>,
    phase: LocalPhase,
    start_step: usize,
    slots: &[usize],
    steps: &[usize],
    views: &[StepView<'_>],
    pop_ids: Option<&[Option<u64>]>,
) {
    out.clear();
    wire::put_u8(out, match phase {
        LocalPhase::FusedSteps => 0,
        LocalPhase::GradOnly => 1,
    });
    wire::put_u64(out, start_step as u64);
    wire::put_u32(out, slots.len() as u32);
    for &w in slots {
        let (params, mom, mom2, adam_t) = views[w].state_ref();
        wire::put_u32(out, w as u32);
        wire::put_u32(out, steps[w] as u32);
        wire::put_f32s(out, params);
        wire::put_f32s(out, mom);
        wire::put_f32s(out, mom2);
        wire::put_f32(out, adam_t);
        if let Some(ids) = pop_ids {
            let id = ids[w].expect("population slot bound before the phase ships");
            wire::put_u64(out, id);
            let (batcher, rng) = views[w].streams_ref();
            put_batcher(out, batcher);
            put_rng(out, rng);
        }
    }
}

/// Worker side of one `PhaseReq`: decode each slot's state into this
/// process's own replica, run exactly the backend-shared
/// [`drive_worker`] loop, and encode the stepped state (plus losses and
/// the optional gradient) into `resp`.
pub(crate) fn serve_phase_req(
    payload: &[u8],
    ctx: &TrainContext,
    workers: &mut Workers,
    scratch: &mut WorkerRound,
    resp: &mut Vec<u8>,
) -> Result<()> {
    let mut c = wire::Cursor::new(payload);
    let phase = match c.get_u8()? {
        0 => LocalPhase::FusedSteps,
        1 => LocalPhase::GradOnly,
        other => bail!("unknown phase code {other} in PhaseReq"),
    };
    let start_step = c.get_u64()? as usize;
    let nslots = c.get_u32()? as usize;
    resp.clear();
    wire::put_u32(resp, nslots as u32);
    for _ in 0..nslots {
        let w = c.get_u32()? as usize;
        ensure!(w < workers.m, "PhaseReq names slot {w} of a {}-worker cluster", workers.m);
        let steps = c.get_u32()? as usize;
        let mut view = workers.view_at(w);
        {
            let (params, mom, mom2, adam_t) = view.state_mut();
            c.get_f32s_into(params)?;
            c.get_f32s_into(mom)?;
            c.get_f32s_into(mom2)?;
            *adam_t = c.get_f32()?;
        }
        // Population extras (both sides gate on the shipped config, so the
        // layouts cannot disagree): install the bound worker's streams so
        // this slot steps with the *id-keyed* batcher and straggler RNG,
        // not the slot-keyed streams this process rebuilt at startup.
        if ctx.cfg.population > 0 {
            let id = c.get_u64()?;
            ensure!(
                id < ctx.cfg.population,
                "PhaseReq binds slot {w} to id {id} outside the population (N = {})",
                ctx.cfg.population
            );
            let batcher = get_batcher(&mut c)?;
            let rng = get_rng(&mut c)?;
            view.install_streams(batcher, rng);
        }
        drive_worker(&mut view, ctx, steps, start_step, phase, scratch)?;
        wire::put_u32(resp, w as u32);
        wire::put_f64s(resp, &scratch.losses);
        let (params, mom, mom2, adam_t) = view.state_ref();
        wire::put_f32s(resp, params);
        wire::put_f32s(resp, mom);
        wire::put_f32s(resp, mom2);
        wire::put_f32(resp, adam_t);
        match &scratch.grad {
            Some(g) => {
                wire::put_u8(resp, 1);
                wire::put_f32s(resp, g);
            }
            None => wire::put_u8(resp, 0),
        }
    }
    c.finish()
}

/// Connect with retry until `deadline` — the coordinator may still be
/// binding (or a previous run may still own the port) when a worker starts.
/// The retry delay is a deterministic capped exponential backoff (10 ms
/// doubling to 640 ms): quick reconnects while the coordinator races to
/// bind, without hammering the listener for the long tail of a large
/// `net_timeout_s`.
fn connect_retry(addr: &str, deadline: Duration) -> Result<TcpStream> {
    let t0 = Instant::now();
    let mut delay = Duration::from_millis(10);
    const DELAY_CAP: Duration = Duration::from_millis(640);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if t0.elapsed() >= deadline {
                    return Err(e).with_context(|| format!("connecting to coordinator {addr}"));
                }
                std::thread::sleep(delay.min(deadline.saturating_sub(t0.elapsed())));
                delay = (delay * 2).min(DELAY_CAP);
            }
        }
    }
}

/// Whether an error is the peer going away (EOF / reset / broken pipe) as
/// opposed to a protocol violation. A vanished coordinator ends the worker
/// cleanly; a corrupt frame does not.
fn is_disconnect(e: &anyhow::Error) -> bool {
    e.chain().any(|c| {
        c.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::BrokenPipe
            )
        })
    })
}

/// Run one worker process to completion: connect to the coordinator at
/// `addr`, offer `lanes` slots, rebuild the experiment from the `Welcome`
/// config, fast-forward each claimed slot's stochastic streams by its
/// consumed-step count, then serve phase requests until shutdown.
///
/// `die_after` is the chaos hook behind the `net_kill` config key: the
/// process exits cleanly after serving that many phase requests, simulating
/// a mid-run worker loss — which the coordinator must (and does, see
/// rust/tests/net_backend.rs) replay bit-identically to the equivalent
/// explicit `--fault crash@round:worker` schedule.
///
/// `timeout_s` bounds the connect retry (`--timeout`, default 10 s); a
/// coordinator-spawned fleet child inherits the run's `net_timeout_s`, so
/// the two sides of the rendezvous always agree on how long to wait.
pub fn run_worker(
    addr: &str,
    lanes: usize,
    proc_index: Option<usize>,
    die_after: Option<u64>,
    timeout_s: f64,
) -> Result<()> {
    ensure!(lanes >= 1, "a worker needs at least one lane");
    ensure!(timeout_s > 0.0, "--timeout must be positive, got {timeout_s}");
    let mut stream = connect_retry(addr, Duration::from_secs_f64(timeout_s))?;
    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
    wire::write_frame(
        &mut stream,
        wire::KIND_HELLO,
        encode_hello(&Hello { lanes, proc: proc_index }).as_bytes(),
    )?;
    let mut buf = Vec::new();
    let kind = wire::read_frame(&mut stream, &mut buf)?;
    ensure!(kind == wire::KIND_WELCOME, "expected Welcome, got frame kind {kind}");
    let (slots, consumed, cfg) = decode_welcome(&buf)?;

    // Rebuild the run exactly as `coordinator::run_experiment` assembles it
    // on the coordinator: same model runtime, same generated data, same
    // shards, schedule, and cluster model — all derived from the shipped
    // config, so every per-worker stream matches the canonical ones.
    let rt = runtime::load_for(Path::new(&cfg.artifacts_dir), &cfg)?;
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    let shards = coordinator::make_shards(&cfg, &train);
    let steps_per_epoch = (shards[0].len() / rt.train_batch).max(1);
    let cluster = cfg.cluster(rt.n * 4)?;
    let schedule = LrSchedule::paper_scaled(cfg.base_lr, cfg.epochs, steps_per_epoch);
    let ctx = TrainContext {
        rt: &rt,
        cfg: &cfg,
        cluster,
        schedule,
        train: &train,
        test: &test,
        shards,
    };
    let mut workers = Workers::new(&ctx);
    // A rejoiner claims slots that already consumed draws; replay them so
    // the slot's batcher/RNG streams resume exactly where they left off.
    // Population mode skips this: every `PhaseReq` ships the bound id's
    // exact stream state, so the slot-keyed streams built above are never
    // consulted (and fast-forwarding them would be fast-forwarding the
    // wrong worker's streams).
    if cfg.population == 0 {
        for (&w, &n) in slots.iter().zip(&consumed) {
            let mut view = workers.view_at(w);
            for _ in 0..n {
                view.replay_draws(&ctx);
            }
        }
    }

    let mut scratch = WorkerRound::default();
    let mut resp = Vec::new();
    let mut served = 0u64;
    loop {
        let kind = match wire::read_frame(&mut stream, &mut buf) {
            Ok(k) => k,
            Err(e) if is_disconnect(&e) => return Ok(()), // coordinator gone: run over
            Err(e) => return Err(e),
        };
        match kind {
            wire::KIND_PING => wire::write_frame(&mut stream, wire::KIND_PONG, &[])?,
            wire::KIND_SHUTDOWN => return Ok(()),
            wire::KIND_PHASE_REQ => {
                serve_phase_req(&buf, &ctx, &mut workers, &mut scratch, &mut resp)?;
                wire::write_frame(&mut stream, wire::KIND_PHASE_RESP, &resp)?;
                served += 1;
                if die_after.is_some_and(|k| served >= k) {
                    return Ok(()); // chaos hook: simulate a worker loss
                }
            }
            other => bail!("unexpected frame kind {other} from coordinator"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handshake_payloads_round_trip() {
        let h = Hello { lanes: 3, proc: Some(1) };
        let back = decode_hello(encode_hello(&h).as_bytes()).unwrap();
        assert_eq!(back.lanes, 3);
        assert_eq!(back.proc, Some(1));
        let ext = decode_hello(encode_hello(&Hello { lanes: 1, proc: None }).as_bytes()).unwrap();
        assert_eq!(ext.proc, None);
        assert!(decode_hello(br#"{"lanes":0,"proc":-1}"#).is_err(), "zero lanes rejected");

        let mut cfg = ExperimentConfig::default();
        cfg.set("algo", "overlap-m").unwrap();
        cfg.set("workers", "16").unwrap();
        cfg.set("execution", "net").unwrap();
        cfg.set("fault", "crash@3:2").unwrap();
        let kv = cfg.to_kv();
        let enc = encode_welcome(&[2, 5], &[7, 0], &kv);
        let (slots, consumed, cfg2) = decode_welcome(enc.as_bytes()).unwrap();
        assert_eq!(slots, vec![2, 5]);
        assert_eq!(consumed, vec![7, 0]);
        assert_eq!(cfg2.to_kv(), kv, "config survives the handshake bit-for-bit");
    }

    #[test]
    fn welcome_rejects_ragged_slot_lists() {
        let kv = ExperimentConfig::default().to_kv();
        let enc = encode_welcome(&[1, 2], &[0], &kv);
        assert!(decode_welcome(enc.as_bytes()).is_err());
    }
}
