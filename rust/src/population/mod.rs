//! Population-scale partial participation (DESIGN.md §14, E17).
//!
//! The paper's anchor model is exactly what makes training over a huge,
//! partially-participating population viable: an overlap round touches the
//! anchor, not every peer, so the cluster the engine simulates no longer
//! has to *be* the population. This module makes the split first-class:
//!
//! * a **registered population** of N workers (`population`, N up to 10^6
//!   and beyond) identified by stable ids `0..N`;
//! * a **deterministic cohort sampler** ([`sample_cohort`]): each round
//!   draws k distinct eligible ids from its own seeded stream
//!   (`sample/{round}`), so any round's cohort is replayable from
//!   `(sample_seed, round)` alone and independent of every other stream
//!   in the run;
//! * a **lazily-materialized worker store** ([`PopulationStore`]): the
//!   engine keeps k dense slots (the machines); sampled workers bind to
//!   slots by swapping their persistent state in — params, momenta, Adam
//!   counter, batch-sampler position, straggler RNG stream, and the
//!   error-feedback residual, all keyed by stable worker id. Unbound
//!   states are held in an LRU of configurable `sample_reserve` depth and
//!   evicted to a disk **spill file** through a bit-exact codec, so
//!   resident memory is O(k + reserve), never O(N);
//! * **fault composition** over ids, not slots: a crashed id leaves the
//!   sampling pool until its rejoin, the seeded random process draws from
//!   per-id streams (`fault/{id}`, lazily — O(touched) cost), and a
//!   partition assigns id sets to components that the engine projects
//!   onto the cohort's slots each round (`fault::PopulationFaults`). The
//!   slot-level alive-set machinery engages exactly when a cohort member
//!   is down or partitioned off, mirroring the dense engine.
//!
//! The correctness spine is strict generalization: with `population == k
//! == workers` the sampler selects every id each round, ids coincide with
//! slots, every derived stream label (`batcher/{id}`, `straggler/{id}`)
//! matches the dense path's slot-keyed label, and no slot ever re-binds —
//! so every observable is bit-identical to the dense engine
//! (rust/tests/population.rs locks digests against `population = 0`).

use std::collections::{BTreeSet, HashMap, VecDeque};
use std::fs::File;
use std::io::{Read as _, Seek, SeekFrom, Write as _};
use std::path::PathBuf;

use anyhow::{bail, ensure, Context, Result};

use crate::compress::CompressKind;
use crate::coordinator::TrainContext;
use crate::data::Batcher;
use crate::fault::PopulationFaults;
use crate::metrics::PopulationCounters;
use crate::util::rng::Rng;

/// One population worker's complete persistent training state — everything
/// that must travel with the worker across bind/evict/rematerialize cycles
/// for its training trajectory to be independent of *when* it was sampled.
pub struct WorkerState {
    /// stable population id
    pub id: u64,
    /// model replica
    pub params: Vec<f32>,
    /// first-moment buffer
    pub mom: Vec<f32>,
    /// second-moment buffer (Adam local optimizer only; empty otherwise)
    pub mom2: Vec<f32>,
    /// 1-based Adam step counter (bias correction)
    pub adam_t: f32,
    /// batch sampler — shard order *and* cursor, so consumed draws persist
    pub batcher: Batcher,
    /// straggler-draw stream, keyed `straggler/{id}`
    pub rng: Rng,
    /// error-feedback residual (compression on only)
    pub residual: Option<Vec<f32>>,
    /// PowerSGD gradient-path error-feedback residual (`--compress
    /// powersgd` only)
    pub psgd_error: Option<Vec<f32>>,
    /// PowerSGD warm low-rank bases, one `Q` per factorized matrix
    /// (`--compress powersgd` only)
    pub psgd_qs: Option<Vec<Vec<f32>>>,
}

/// Everything needed to materialize a never-seen worker from scratch —
/// the same construction [`crate::coordinator::Workers::new`] performs
/// per slot, keyed by stable id instead.
struct Materializer {
    n: usize,
    use_adam: bool,
    seed: u64,
    reshuffle: bool,
    init: Vec<f32>,
    /// residual length (model size when compression is on, else 0 → None)
    residual_len: usize,
    /// PowerSGD fresh-worker template: the shared seeded `Q` inits, one
    /// per factorized matrix (`--compress powersgd` only). A fresh id's
    /// gradient residual is zeros(n) and its bases are these inits —
    /// exactly what `CompressState::reset_worker` installs on a dense
    /// rejoin, so fresh-vs-reset state is indistinguishable.
    psgd_qs_init: Option<Vec<Vec<f32>>>,
}

impl Materializer {
    /// Fresh state for id: init params, zero momenta, shard
    /// `shards[id % k]`, streams keyed by the stable id. When `id` equals
    /// the slot index (the N == k case) every field is bit-identical to
    /// the dense `Workers::new` slot state.
    fn fresh(&self, id: u64, shards: &[Vec<u32>]) -> WorkerState {
        let shard = shards[(id % shards.len() as u64) as usize].clone();
        WorkerState {
            id,
            params: self.init.clone(),
            mom: vec![0.0; self.n],
            mom2: vec![0.0; if self.use_adam { self.n } else { 0 }],
            adam_t: 0.0,
            batcher: Batcher::new(shard, self.seed, id as usize, self.reshuffle),
            rng: Rng::stream(self.seed, &format!("straggler/{id}")),
            residual: if self.residual_len > 0 {
                Some(vec![0.0; self.residual_len])
            } else {
                None
            },
            psgd_error: self.psgd_qs_init.as_ref().map(|_| vec![0.0; self.n]),
            psgd_qs: self.psgd_qs_init.clone(),
        }
    }
}

// ---------------------------------------------------------------------------
// Spill codec — hand-rolled little-endian record, bit-exact both ways
// ---------------------------------------------------------------------------

/// Bumped 1 → 2 when the PowerSGD warm-basis fields joined the record;
/// version-1 records are rejected loudly (spill files never outlive a
/// run, so there is no migration path to maintain).
const SPILL_VERSION: u8 = 2;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// FNV-1a over the record body — the integrity trailer [`encode_state`]
/// appends and [`decode_state`] verifies, so a flipped bit anywhere in a
/// spilled record fails loudly instead of silently resuming a worker from
/// corrupt state.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_rng(out: &mut Vec<u8>, rng: &Rng) {
    let (s, spare) = rng.state();
    for w in s {
        put_u64(out, w);
    }
    match spare {
        Some(z) => {
            out.push(1);
            put_u64(out, z.to_bits());
        }
        None => out.push(0),
    }
}

/// Byte-cursor reader over one spill record.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // `pos <= len` always holds, so `len - pos` cannot underflow — and
        // phrasing the bound this way keeps a corrupt (huge) length prefix
        // from overflowing `pos + n` into a silent wraparound.
        ensure!(n <= self.buf.len() - self.pos, "truncated spill record");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let bytes = n.checked_mul(4).context("corrupt length prefix in spill record")?;
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn rng(&mut self) -> Result<Rng> {
        let s = [self.u64()?, self.u64()?, self.u64()?, self.u64()?];
        let spare = match self.u8()? {
            0 => None,
            1 => Some(f64::from_bits(self.u64()?)),
            other => bail!("bad spare-normal flag {other} in spill record"),
        };
        Ok(Rng::from_state(s, spare))
    }
}

/// Serialize a worker's state into `out` (cleared first). Everything is
/// exact bits: f32/f64 via `to_le_bytes`/`to_bits`, so
/// [`decode_state`] ∘ [`encode_state`] is the identity. The record ends
/// with an FNV-1a trailer over the body, verified on decode.
pub fn encode_state(st: &WorkerState, out: &mut Vec<u8>) {
    out.clear();
    out.push(SPILL_VERSION);
    put_u64(out, st.id);
    put_f32s(out, &st.params);
    put_f32s(out, &st.mom);
    put_f32s(out, &st.mom2);
    out.extend_from_slice(&st.adam_t.to_le_bytes());
    let (shard, pos, brng) = st.batcher.spill_parts();
    put_u64(out, shard.len() as u64);
    for &s in shard {
        out.extend_from_slice(&s.to_le_bytes());
    }
    put_u64(out, pos as u64);
    put_u64(out, st.batcher.epochs_completed as u64);
    out.push(st.batcher.reshuffle as u8);
    put_rng(out, brng);
    put_rng(out, &st.rng);
    match &st.residual {
        Some(r) => {
            out.push(1);
            put_f32s(out, r);
        }
        None => out.push(0),
    }
    // PowerSGD warm state: gradient residual + one Q basis per matrix.
    // Either both are present (`--compress powersgd`) or neither is.
    match (&st.psgd_error, &st.psgd_qs) {
        (Some(err), Some(qs)) => {
            out.push(1);
            put_f32s(out, err);
            put_u64(out, qs.len() as u64);
            for q in qs {
                put_f32s(out, q);
            }
        }
        (None, None) => out.push(0),
        _ => unreachable!("psgd error and bases travel together"),
    }
    let sum = fnv1a(out);
    put_u64(out, sum);
}

/// Rebuild a worker's state from an [`encode_state`] record, bit-for-bit.
pub fn decode_state(buf: &[u8]) -> Result<WorkerState> {
    let mut r = Reader { buf, pos: 0 };
    let version = r.u8()?;
    ensure!(version == SPILL_VERSION, "unknown spill record version {version}");
    let id = r.u64()?;
    let params = r.f32s()?;
    let mom = r.f32s()?;
    let mom2 = r.f32s()?;
    let adam_t = f32::from_le_bytes(r.take(4)?.try_into().unwrap());
    let shard_len = r.u64()? as usize;
    let shard_bytes = shard_len.checked_mul(4).context("corrupt shard length in spill record")?;
    let raw = r.take(shard_bytes)?;
    let shard: Vec<u32> =
        raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
    let pos = r.u64()? as usize;
    let epochs = r.u64()? as usize;
    let reshuffle = match r.u8()? {
        0 => false,
        1 => true,
        other => bail!("bad reshuffle flag {other} in spill record"),
    };
    let brng = r.rng()?;
    let rng = r.rng()?;
    let residual = match r.u8()? {
        0 => None,
        1 => Some(r.f32s()?),
        other => bail!("bad residual flag {other} in spill record"),
    };
    let (psgd_error, psgd_qs) = match r.u8()? {
        0 => (None, None),
        1 => {
            let err = r.f32s()?;
            let n_qs = r.u64()? as usize;
            ensure!(n_qs <= 1 << 20, "implausible psgd basis count {n_qs} in spill record");
            let mut qs = Vec::with_capacity(n_qs);
            for _ in 0..n_qs {
                qs.push(r.f32s()?);
            }
            (Some(err), Some(qs))
        }
        other => bail!("bad psgd flag {other} in spill record"),
    };
    let body = r.pos;
    let sum = r.u64()?;
    ensure!(
        sum == fnv1a(&buf[..body]),
        "spill record checksum mismatch (corrupted record)"
    );
    ensure!(r.pos == buf.len(), "trailing bytes in spill record");
    Ok(WorkerState {
        id,
        params,
        mom,
        mom2,
        adam_t,
        batcher: Batcher::from_spill_parts(shard, pos, brng, epochs, reshuffle),
        rng,
        residual,
        psgd_error,
        psgd_qs,
    })
}

// ---------------------------------------------------------------------------
// Disk spill — append-only record file with an in-memory directory
// ---------------------------------------------------------------------------

static SPILL_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Append-only spill file holding evicted worker records. A re-evicted
/// worker appends a fresh record and the directory forgets the old offset
/// (dead bytes are never compacted — bounded by touched workers × state
/// size, and the file dies with the run). Created lazily: a run whose
/// reserve never overflows touches no disk.
struct Spill {
    file: Option<File>,
    path: PathBuf,
    /// id → (offset, record length) of the *live* record
    index: HashMap<u64, (u64, u32)>,
    end: u64,
}

impl Spill {
    fn new() -> Self {
        let tag = SPILL_COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("olsgd-popspill-{}-{tag}.bin", std::process::id()));
        Self { file: None, path, index: HashMap::new(), end: 0 }
    }

    fn append(&mut self, id: u64, bytes: &[u8]) -> Result<()> {
        if self.file.is_none() {
            let f = File::options()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(&self.path)
                .with_context(|| format!("creating spill file {}", self.path.display()))?;
            self.file = Some(f);
        }
        let f = self.file.as_mut().unwrap();
        f.seek(SeekFrom::Start(self.end))?;
        f.write_all(bytes)?;
        self.index.insert(id, (self.end, bytes.len() as u32));
        self.end += bytes.len() as u64;
        Ok(())
    }

    fn contains(&self, id: u64) -> bool {
        self.index.contains_key(&id)
    }

    /// Read id's live record into `out`; `false` when never spilled.
    fn read(&mut self, id: u64, out: &mut Vec<u8>) -> Result<bool> {
        let Some(&(off, len)) = self.index.get(&id) else {
            return Ok(false);
        };
        let f = self.file.as_mut().context("spill directory entry without a file")?;
        out.resize(len as usize, 0);
        f.seek(SeekFrom::Start(off))?;
        f.read_exact(out)?;
        Ok(true)
    }
}

impl Drop for Spill {
    fn drop(&mut self) {
        if self.file.take().is_some() {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

// ---------------------------------------------------------------------------
// LRU store
// ---------------------------------------------------------------------------

/// The O(k) worker-state store: up to `reserve` unbound states stay
/// resident (LRU over bind recency); overflow is encoded to the disk
/// spill and rematerialized bit-exactly on the next bind. Ids never seen
/// anywhere are materialized fresh from init.
pub struct PopulationStore {
    mat: Materializer,
    resident: HashMap<u64, WorkerState>,
    /// bind-recency order over `resident` keys; front = coldest
    lru: VecDeque<u64>,
    reserve: usize,
    spill: Spill,
    /// recycled state shells (empty buffers) for alloc-free unbind swaps
    spares: Vec<WorkerState>,
    scratch: Vec<u8>,
    /// store-side counters (hits/reads/fresh/evictions/bytes); the
    /// remaining fields are owned by [`PopulationState`]
    pub counters: PopulationCounters,
}

impl PopulationStore {
    /// A contentless state shell to swap an outgoing worker into.
    pub fn blank(&mut self) -> WorkerState {
        self.spares.pop().unwrap_or_else(|| WorkerState {
            id: u64::MAX,
            params: Vec::new(),
            mom: Vec::new(),
            mom2: Vec::new(),
            adam_t: 0.0,
            batcher: Batcher::from_spill_parts(Vec::new(), 0, Rng::seed_from(0), 0, false),
            rng: Rng::seed_from(0),
            residual: None,
            psgd_error: None,
            psgd_qs: None,
        })
    }

    /// Return a drained shell (post-bind leftovers) to the spare pool.
    pub fn recycle(&mut self, st: WorkerState) {
        if self.spares.len() < 8 {
            self.spares.push(st);
        }
    }

    /// Park an unbound worker's state in the resident LRU (cap enforced
    /// separately by [`PopulationStore::enforce_cap`], so a whole round's
    /// unbinds land before anything is evicted).
    pub fn park(&mut self, id: u64, mut st: WorkerState) {
        st.id = id;
        self.resident.insert(id, st);
        self.lru.push_back(id);
    }

    /// Produce id's state: resident hit (alloc-free), bit-exact spill
    /// rematerialization, or fresh materialization from init. The flag is
    /// `true` when the worker has trained before (resident or spilled).
    pub fn take_or_materialize(
        &mut self,
        id: u64,
        shards: &[Vec<u32>],
    ) -> Result<(WorkerState, bool)> {
        if let Some(st) = self.resident.remove(&id) {
            self.lru.retain(|&x| x != id);
            self.counters.store_hits += 1;
            return Ok((st, true));
        }
        let mut scratch = std::mem::take(&mut self.scratch);
        let found = self.spill.read(id, &mut scratch)?;
        let out = if found {
            let st = decode_state(&scratch)?;
            ensure!(st.id == id, "spill record id {} under directory key {id}", st.id);
            self.counters.spill_reads += 1;
            (st, true)
        } else {
            self.counters.fresh_materializations += 1;
            (self.mat.fresh(id, shards), false)
        };
        self.scratch = scratch;
        Ok(out)
    }

    /// Evict coldest resident states to the spill until the reserve cap
    /// holds — the store invariant `resident_len() <= reserve` that keeps
    /// memory O(k), hard-asserted by rust/tests/population.rs.
    pub fn enforce_cap(&mut self) -> Result<()> {
        while self.resident.len() > self.reserve {
            let id = self.lru.pop_front().context("LRU queue out of sync with resident map")?;
            let st = self
                .resident
                .remove(&id)
                .context("LRU queue names a non-resident worker")?;
            let mut scratch = std::mem::take(&mut self.scratch);
            encode_state(&st, &mut scratch);
            self.spill.append(id, &scratch)?;
            self.counters.evictions += 1;
            self.counters.spilled_bytes += scratch.len() as u64;
            self.scratch = scratch;
            self.recycle(st);
        }
        Ok(())
    }

    /// Unbound states currently resident.
    pub fn resident_len(&self) -> usize {
        self.resident.len()
    }

    /// Whether this id has ever been evicted to disk (tests).
    pub fn spilled(&self, id: u64) -> bool {
        self.spill.contains(id)
    }
}

// ---------------------------------------------------------------------------
// Sampler
// ---------------------------------------------------------------------------

/// Deterministically sample the 1-based `round`'s cohort: k distinct ids
/// from `0..n_pop`, skipping `down` ids, returned ascending (slot order).
/// Each round draws from its own stream (`sample/{round}` keyed by
/// `sample_seed`), so cohorts replay from the seed alone, differ across
/// rounds, and never perturb any other stream in the run.
pub fn sample_cohort(
    n_pop: u64,
    k: usize,
    sample_seed: u64,
    round: usize,
    down: &BTreeSet<u64>,
) -> Result<Vec<u64>> {
    let eligible = n_pop - down.len() as u64;
    ensure!(
        eligible >= k as u64,
        "sample_k = {k} exceeds the eligible population ({eligible} of {n_pop} up)"
    );
    let mut rng = Rng::stream(sample_seed, &format!("sample/{round}"));
    let mut picked = BTreeSet::new();
    while picked.len() < k {
        let id = rng.next_below(n_pop);
        if !down.contains(&id) {
            picked.insert(id);
        }
    }
    Ok(picked.into_iter().collect())
}

// ---------------------------------------------------------------------------
// Per-run state
// ---------------------------------------------------------------------------

/// The engine's population-axis state: sampler parameters, the fault
/// eligibility pool, the LRU store, and the current slot → id binding.
/// `None` (axis off) costs nothing and changes nothing.
pub struct PopulationState {
    /// registered population size N
    pub n_pop: u64,
    /// cohort size k (= the engine's slot count)
    pub k: usize,
    /// resolved sampler seed
    pub sample_seed: u64,
    /// population-id fault replay (crash ⇒ out of the pool until rejoin)
    pub faults: PopulationFaults,
    /// the O(k) worker-state store
    pub store: PopulationStore,
    /// population id bound to each slot (`None` before round 1)
    pub bound: Vec<Option<u64>>,
    /// ids that rejoined the pool while *unbound* (random draw or explicit
    /// event): the engine warm-starts them from the anchor when they are
    /// next sampled, completing the dense rejoin protocol over ids
    pub pending_warm: BTreeSet<u64>,
    /// last value pushed to the survivor series (starts at N): the engine
    /// notes a new point only when the value moves, which at `N == k`
    /// reproduces the dense `stepping_count`-changed rule exactly
    pub last_survivors: usize,
    rounds_sampled: u64,
    resident_max: u64,
}

impl PopulationState {
    /// Build the axis state from a *resolved* config (`None` when
    /// `population == 0`). `psgd_qs_init` is the compressor's shared
    /// seeded PowerSGD basis template (`CompressState::powersgd_qs_init`)
    /// — `Some` exactly when `--compress powersgd` is active, so fresh
    /// population workers materialize with the same warm state a dense
    /// worker starts with. Engaging with an unresolved config — where the
    /// slot count and cohort size disagree — is a hard error, not a guess.
    pub fn build(ctx: &TrainContext, psgd_qs_init: Option<Vec<Vec<f32>>>) -> Result<Option<Self>> {
        let cfg = ctx.cfg;
        if cfg.population == 0 {
            return Ok(None);
        }
        ensure!(
            cfg.sample_k == cfg.workers,
            "population mode needs a resolved config (sample_k {} != workers {}); \
             call ExperimentConfig::resolved() first",
            cfg.sample_k,
            cfg.workers
        );
        let k = cfg.workers;
        let sample_seed = if cfg.sample_seed != 0 { cfg.sample_seed } else { cfg.seed };
        let mat = Materializer {
            n: ctx.rt.n,
            use_adam: cfg.local_opt == "adam",
            seed: cfg.seed,
            reshuffle: cfg.reshuffle,
            init: crate::model::init_params(&ctx.rt.manifest, cfg.seed),
            residual_len: if cfg.compress != CompressKind::None { ctx.rt.n } else { 0 },
            psgd_qs_init,
        };
        let counters = PopulationCounters {
            population: cfg.population,
            sample_k: k as u64,
            reserve: cfg.sample_reserve as u64,
            ..PopulationCounters::default()
        };
        Ok(Some(Self {
            n_pop: cfg.population,
            k,
            sample_seed,
            faults: PopulationFaults::new(
                &cfg.fault,
                cfg.population,
                cfg.fault_rate,
                cfg.rejoin_rate,
                cfg.seed,
            )?,
            store: PopulationStore {
                mat,
                resident: HashMap::new(),
                lru: VecDeque::new(),
                reserve: cfg.sample_reserve,
                spill: Spill::new(),
                spares: Vec::new(),
                scratch: Vec::new(),
                counters,
            },
            bound: vec![None; k],
            pending_warm: BTreeSet::new(),
            last_survivors: cfg.population as usize,
            rounds_sampled: 0,
            resident_max: 0,
        }))
    }

    /// This round's cohort (ascending ids, one per slot). When the downed
    /// set squeezes the eligible pool below k — the N ≈ k regime; at
    /// scale the sampler never gets near it — every eligible id
    /// participates and the smallest downed ids pad the remaining slots
    /// as *parked* workers (their slots are not alive and take no steps).
    /// That padding is what keeps `bound[slot] == slot` under faults at
    /// `N == k`, so a crash there replays the dense engine bit-for-bit.
    pub fn sample(&self, round: usize) -> Result<Vec<u64>> {
        let down = self.faults.down();
        if self.faults.eligible() < self.k as u64 {
            let mut cohort: Vec<u64> =
                (0..self.n_pop).filter(|id| !down.contains(id)).collect();
            for &id in down {
                if cohort.len() >= self.k {
                    break;
                }
                cohort.push(id);
            }
            cohort.sort_unstable();
            ensure!(
                cohort.len() == self.k,
                "population {} cannot fill a cohort of {}",
                self.n_pop,
                self.k
            );
            return Ok(cohort);
        }
        sample_cohort(self.n_pop, self.k, self.sample_seed, round, down)
    }

    /// Close one bound round: bump the round counter and fold the
    /// materialized-state peak (k bound + resident reserve).
    pub fn note_round(&mut self) {
        self.rounds_sampled += 1;
        let total = (self.k + self.store.resident_len()) as u64;
        self.resident_max = self.resident_max.max(total);
    }

    /// The run's population counters (`TrainLog::population`).
    pub fn counters(&self) -> PopulationCounters {
        PopulationCounters {
            rounds_sampled: self.rounds_sampled,
            resident_workers_max: self.resident_max,
            ..self.store.counters
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    fn toy_state(id: u64, n: usize, draws: usize) -> WorkerState {
        // A batcher mid-epoch (nonzero cursor, one epoch behind it) so the
        // codec must carry stream positions, not just fresh construction.
        let fresh = Batcher::new((0..24u32).collect(), 7, id as usize, true);
        let (shard, _, brng) = fresh.spill_parts();
        let (s, spare) = brng.state();
        let batcher = Batcher::from_spill_parts(
            shard.to_vec(),
            draws % 24,
            Rng::from_state(s, spare),
            1,
            true,
        );
        let mut rng = Rng::stream(7, &format!("straggler/{id}"));
        for _ in 0..draws {
            rng.next_normal();
        }
        WorkerState {
            id,
            params: (0..n).map(|i| (i as f32).sin()).collect(),
            mom: (0..n).map(|i| (i as f32) * 0.25 - 1.0).collect(),
            mom2: Vec::new(),
            adam_t: 3.0,
            batcher,
            rng,
            residual: Some((0..n).map(|i| 1.0 / (1.0 + i as f32)).collect()),
            psgd_error: Some((0..n).map(|i| (i as f32) * 0.5 - 2.0).collect()),
            psgd_qs: Some(vec![
                (0..6).map(|i| (i as f32).cos()).collect(),
                (0..4).map(|i| 0.1 * i as f32 + 0.75).collect(),
            ]),
        }
    }

    #[test]
    fn codec_round_trips_bit_for_bit() {
        let st = toy_state(42, 33, 5);
        let mut buf = Vec::new();
        encode_state(&st, &mut buf);
        let back = decode_state(&buf).unwrap();
        assert_eq!(back.id, 42);
        for (a, b) in st.params.iter().zip(&back.params) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in st.mom.iter().zip(&back.mom) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(st.adam_t.to_bits(), back.adam_t.to_bits());
        let (sa, pa, ra) = st.batcher.spill_parts();
        let (sb, pb, rb) = back.batcher.spill_parts();
        assert_eq!(sa, sb);
        assert_eq!(pa, pb);
        assert_eq!(ra.state(), rb.state());
        assert_eq!(st.rng.state(), back.rng.state());
        let (x, y) = (st.residual.unwrap(), back.residual.unwrap());
        for (a, b) in x.iter().zip(&y) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let (ea, eb) = (st.psgd_error.unwrap(), back.psgd_error.unwrap());
        for (a, b) in ea.iter().zip(&eb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let (qa, qb) = (st.psgd_qs.unwrap(), back.psgd_qs.unwrap());
        assert_eq!(qa.len(), qb.len());
        for (x, y) in qa.iter().zip(&qb) {
            assert_eq!(x.len(), y.len());
            for (a, b) in x.iter().zip(y) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // The restored stream continues exactly where the original would.
        let mut orig = st.rng;
        let mut restored = back.rng;
        for _ in 0..4 {
            assert_eq!(orig.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn codec_rejects_corruption() {
        let st = toy_state(1, 8, 0);
        let mut buf = Vec::new();
        encode_state(&st, &mut buf);
        assert!(decode_state(&buf[..buf.len() - 1]).is_err(), "truncation");
        let mut long = buf.clone();
        long.push(0);
        assert!(decode_state(&long).is_err(), "trailing bytes");
        let mut bad = buf;
        bad[0] = 99;
        assert!(decode_state(&bad).is_err(), "unknown version");
        // A state without psgd fields has the psgd flag byte right before
        // the 8-byte checksum trailer — flip it.
        let mut st = toy_state(2, 8, 0);
        st.psgd_error = None;
        st.psgd_qs = None;
        let mut buf = Vec::new();
        encode_state(&st, &mut buf);
        assert!(decode_state(&buf).is_ok());
        let flag = buf.len() - 9;
        buf[flag] = 9;
        assert!(decode_state(&buf).is_err(), "bad psgd flag");
    }

    #[test]
    fn property_sampler_is_deterministic_distinct_and_round_varying() {
        property("cohort sampler", 60, |g| {
            let k = g.usize_in(1, 12);
            let n_pop = g.usize_in(k, 4 * k + 100) as u64;
            let seed = g.rng().next_u64();
            let round = g.usize_in(1, 50);
            let none = BTreeSet::new();
            let a = sample_cohort(n_pop, k, seed, round, &none).unwrap();
            let b = sample_cohort(n_pop, k, seed, round, &none).unwrap();
            assert_eq!(a, b, "replay must reproduce the cohort");
            assert_eq!(a.len(), k);
            assert!(a.windows(2).all(|w| w[0] < w[1]), "ascending distinct ids");
            assert!(a.iter().all(|&id| id < n_pop));
        });
    }

    #[test]
    fn sampler_respects_the_down_set_and_eligibility() {
        let mut down = BTreeSet::new();
        down.insert(3u64);
        down.insert(7u64);
        for round in 1..=40 {
            let c = sample_cohort(10, 8, 5, round, &down).unwrap();
            assert!(!c.contains(&3) && !c.contains(&7), "downed ids sampled");
        }
        // k exceeding the eligible pool is a loud error.
        assert!(sample_cohort(10, 9, 5, 1, &down).is_err());
        // n == k with nobody down selects everyone.
        let all = sample_cohort(8, 8, 123, 17, &BTreeSet::new()).unwrap();
        assert_eq!(all, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn store_caps_residency_and_round_trips_through_the_spill() {
        let mat = Materializer {
            n: 16,
            use_adam: false,
            seed: 9,
            reshuffle: true,
            init: vec![0.5; 16],
            residual_len: 16,
            psgd_qs_init: Some(vec![vec![0.25; 8]]),
        };
        let shards: Vec<Vec<u32>> = (0..4).map(|s| (s..s + 32).collect()).collect();
        let mut store = PopulationStore {
            mat,
            resident: HashMap::new(),
            lru: VecDeque::new(),
            reserve: 2,
            spill: Spill::new(),
            spares: Vec::new(),
            scratch: Vec::new(),
            counters: PopulationCounters::default(),
        };
        // Materialize five workers fresh, mutate them distinctly, park all.
        for id in 0..5u64 {
            let (mut st, seen) = store.take_or_materialize(id, &shards).unwrap();
            assert!(!seen);
            st.params[0] = id as f32 + 0.125;
            st.rng.next_u64();
            store.park(id, st);
        }
        store.enforce_cap().unwrap();
        assert!(store.resident_len() <= 2, "reserve cap violated");
        assert_eq!(store.counters.evictions, 3);
        assert!(store.spilled(0) && store.spilled(1) && store.spilled(2));
        // LRU keeps the most recently parked ids resident.
        let (st3, seen3) = store.take_or_materialize(3, &shards).unwrap();
        assert!(seen3);
        assert_eq!(store.counters.store_hits, 1);
        assert_eq!(st3.params[0].to_bits(), (3.0f32 + 0.125).to_bits());
        // Spilled ids rematerialize bit-for-bit (params + consumed draws).
        let (st0, seen0) = store.take_or_materialize(0, &shards).unwrap();
        assert!(seen0);
        assert_eq!(store.counters.spill_reads, 1);
        assert_eq!(st0.params[0].to_bits(), 0.125f32.to_bits());
        let mut expect = Rng::stream(9, "straggler/0");
        expect.next_u64(); // the draw consumed before parking
        assert_eq!(st0.rng.state().0, expect.state().0);
        // Re-evicting a re-parked worker overwrites its directory entry.
        store.park(0, st0);
        store.park(3, st3);
        store.enforce_cap().unwrap();
        assert!(store.resident_len() <= 2);
        assert_eq!(store.counters.fresh_materializations, 5);
    }
}
