//! # olsgd — Overlap Local-SGD, reproduced as a Rust + JAX + Pallas stack
//!
//! Reproduction of *"Overlap Local-SGD: An Algorithmic Approach to Hide
//! Communication Delays in Distributed SGD"* (Wang, Liang, Joshi, 2020).
//!
//! Layer 3 (this crate) is the distributed-training coordinator: the
//! discrete-event round engine (`coordinator::engine`), the paper's
//! overlapped anchor synchronization and every baseline as mixing
//! strategies, the simulated 16-node cluster, and the experiment harness.
//! Layers 2/1 (JAX model + Pallas kernels) are AOT-compiled to HLO text by
//! `python/compile/` and executed here through PJRT (feature `pjrt`) —
//! Python is never on the training path. Without the feature the same
//! coordinator runs on the pure-Rust native backend (`runtime::native`), so
//! the whole stack builds and tests on a sealed machine.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

// The fused-kernel signatures mirror the AOT artifact calling convention
// (params, moments, batch, scalars) and legitimately carry many arguments.
#![allow(clippy::too_many_arguments)]
// Every public item is documented; CI keeps `cargo doc --no-deps` clean
// with RUSTDOCFLAGS=-Dwarnings.
#![warn(missing_docs)]

pub mod bench;
pub mod clock;
pub mod collective;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod executor;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod net;
pub mod optim;
pub mod population;
pub mod runtime;
pub mod simnet;
pub mod topology;
pub mod util;
