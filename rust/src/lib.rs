//! # olsgd — Overlap Local-SGD, reproduced as a Rust + JAX + Pallas stack
//!
//! Reproduction of *"Overlap Local-SGD: An Algorithmic Approach to Hide
//! Communication Delays in Distributed SGD"* (Wang, Liang, Joshi, 2020).
//!
//! Layer 3 (this crate) is the distributed-training coordinator: worker
//! scheduling, the paper's overlapped anchor synchronization, every baseline
//! algorithm, the simulated 16-node cluster, and the experiment harness.
//! Layers 2/1 (JAX model + Pallas kernels) are AOT-compiled to HLO text by
//! `python/compile/` and executed here through PJRT — Python is never on the
//! training path.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod bench;
pub mod clock;
pub mod collective;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod simnet;
pub mod util;
