//! Fully synchronous SGD (the paper's baseline) and its PowerSGD variant.
//!
//! Every step: all workers compute a gradient on their own shard, a
//! *blocking* all-reduce averages the gradients (everyone waits for the
//! slowest worker, then for the wire), and the identical averaged update is
//! applied everywhere through the fused Pallas `update` artifact.

use anyhow::Result;

use super::{Recorder, TrainContext, Workers};
use crate::clock::Clocks;
use crate::collective::ring_allreduce_mean;
use crate::compress::PowerSgd;
use crate::metrics::TrainLog;

pub fn run_sync(ctx: &TrainContext) -> Result<TrainLog> {
    let m = ctx.cfg.workers;
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();
    let comm_t = ctx.cluster.allreduce_time();

    for k in 0..total {
        // Parallel gradient computation.
        let mut grads = Vec::with_capacity(m);
        let mut loss_sum = 0.0;
        for w in 0..m {
            let (loss, g) = workers.local_grad(w, ctx, &mut clocks)?;
            loss_sum += loss;
            grads.push(g);
        }
        // Blocking collective: stragglers idle everyone, then the wire.
        clocks.barrier();
        for w in 0..m {
            clocks.comm_blocked(w, comm_t);
        }
        ring_allreduce_mean(&mut grads);
        rec.add_bytes((m * ctx.cluster.message_bytes) as u64);

        // Identical update on every replica: apply once, copy (replicas are
        // bit-identical in sync SGD, so this is exact, not an approximation).
        let lr = ctx.schedule.lr_at_step(k);
        let (p, mom) = ctx.rt.sgd_update(
            &workers.params[0],
            &workers.mom[0],
            &grads[0],
            lr,
            ctx.cfg.mu,
            ctx.cfg.wd,
        )?;
        for w in 0..m {
            workers.params[w].copy_from_slice(&p);
            workers.mom[w].copy_from_slice(&mom);
        }

        rec.push_loss(k, loss_sum / m as f64);
        rec.maybe_eval(k + 1, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}

/// PowerSGD: sync SGD with rank-r compressed gradients. Two collectives per
/// step (P then Q+raw) — two handshakes, the latency floor the paper points
/// at — plus modeled encode/decode GEMM time on the accelerator.
pub fn run_powersgd(ctx: &TrainContext) -> Result<TrainLog> {
    /// Effective GEMM throughput assumed for encode/decode cost (Titan X
    /// era, f32): 5 TFLOP/s.
    const GEMM_FLOPS: f64 = 5.0e12;

    let m = ctx.cfg.workers;
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let mut psgd = PowerSgd::new(&ctx.rt.manifest, ctx.cfg.rank, m, ctx.cfg.seed);
    let total = ctx.total_steps();

    // Wire cost: the compressed message replaces the full one, but the
    // *fraction* of compressed bytes in our scaled model equals the paper's
    // fraction, so scale the paper-size message by it.
    let full_bytes = ctx.rt.manifest.message_bytes();
    let frac = psgd.bytes_per_round() as f64 / full_bytes as f64;
    let scaled_bytes = (ctx.cluster.message_bytes as f64 * frac) as usize;
    // The reference implementation flattens all P factors into ONE buffer
    // (single all-reduce), then all Q factors + raw tensors into another,
    // launched back-to-back in one comm group: one handshake, two wire
    // passes' worth of bytes.
    let comm_t = ctx.cluster.net.allreduce_time(scaled_bytes, m);

    for k in 0..total {
        let mut grads = Vec::with_capacity(m);
        let mut loss_sum = 0.0;
        for w in 0..m {
            let (loss, g) = workers.local_grad(w, ctx, &mut clocks)?;
            loss_sum += loss;
            grads.push(g);
        }
        let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let out = psgd.round(&grad_refs);

        // encode/decode compute, scaled to paper-model FLOPs.
        let enc_t = out.encode_flops * (full_bytes as f64 / (ctx.rt.n * 4) as f64).max(1.0)
            / GEMM_FLOPS;
        for w in 0..m {
            clocks.compute(w, enc_t);
        }
        clocks.barrier();
        for w in 0..m {
            clocks.comm_blocked(w, comm_t);
        }
        rec.add_bytes((m * scaled_bytes) as u64);

        let lr = ctx.schedule.lr_at_step(k);
        let (p, mom) = ctx.rt.sgd_update(
            &workers.params[0],
            &workers.mom[0],
            &out.avg_grad,
            lr,
            ctx.cfg.mu,
            ctx.cfg.wd,
        )?;
        for w in 0..m {
            workers.params[w].copy_from_slice(&p);
            workers.mom[w].copy_from_slice(&mom);
        }

        rec.push_loss(k, loss_sum / m as f64);
        rec.maybe_eval(k + 1, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}
