//! Fully synchronous SGD (the paper's baseline) and its PowerSGD variant,
//! as engine strategies.
//!
//! Every round is one step: all workers compute a gradient on their own
//! shard (the engine's `GradOnly` phase), then the mixing decision runs a
//! *blocking* all-reduce (everyone waits for the slowest worker, then for
//! the wire) and applies the identical averaged update everywhere through
//! the fused `update` kernel.

use anyhow::Result;

use super::engine::{Engine, LocalPhase, MixingStrategy, RoundOutcome, RoundPlan};
use super::{
    account_collective, account_collective_among, charge_blocking_exchange, TrainContext,
};
use crate::compress::PowerSgd;

/// Blocking per-step gradient averaging (mixing matrix = (1/m) 11ᵀ each step).
pub struct SyncStrategy {
    comm_t: f64,
}

impl SyncStrategy {
    /// Strategy with the per-step blocking collective cost precomputed.
    pub fn new(ctx: &TrainContext) -> Self {
        Self { comm_t: ctx.cluster.collective_time() }
    }
}

/// Apply one identical averaged-gradient update to every participating
/// replica (replicas are bit-identical within the sync family's alive
/// members, so apply once and copy is exact). Under faults the template is
/// the first member and parked replicas stay frozen — they are re-seeded
/// from a member on rejoin.
fn apply_shared_update(
    eng: &mut Engine,
    ctx: &TrainContext,
    avg_grad: &[f32],
    step: usize,
) -> Result<()> {
    let lead = eng.fault.alive.members().first().copied().unwrap_or(0);
    let lr = ctx.schedule.lr_at_step(step);
    let (p, mom) = ctx.rt.sgd_update(
        &eng.workers.params[lead],
        &eng.workers.mom[lead],
        avg_grad,
        lr,
        ctx.cfg.mu,
        ctx.cfg.wd,
    )?;
    for w in 0..eng.workers.m {
        if !eng.fault.alive.is_member(w) {
            continue;
        }
        eng.workers.params[w].copy_from_slice(&p);
        eng.workers.mom[w].copy_from_slice(&mom);
    }
    Ok(())
}

impl MixingStrategy for SyncStrategy {
    fn phase(&self) -> LocalPhase {
        LocalPhase::GradOnly
    }

    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        RoundPlan { steps: vec![1; eng.workers.m], advance: 1 }
    }

    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, mut out: RoundOutcome) -> Result<()> {
        // Blocking collective: stragglers idle everyone (alive members
        // under faults — parked workers neither barrier nor pay the wire),
        // then the wire.
        charge_blocking_exchange(eng, ctx, self.comm_t);
        if eng.fault.alive.is_full() {
            // Inline reduce on the coordinator, over the executor's
            // reusable scratch (bit-identical to fresh scratch; §10).
            ctx.cluster
                .topology
                .allreduce_mean_with(&mut out.grads, &mut *eng.exec.reduce_scratch());
        } else {
            // Parked workers produced no gradient, so `out.grads` is
            // already compact in member order: reduce it with the survivor
            // sub-schedule (exact mean over the members).
            ctx.cluster.topology.allreduce_mean_compact(
                &mut out.grads,
                eng.fault.alive.members(),
                &mut eng.exec.reduce_scratch(),
            );
        }
        account_collective_among(
            &mut eng.rec,
            &ctx.cluster.topology,
            ctx.cluster.message_bytes,
            &eng.fault.alive,
        );
        apply_shared_update(eng, ctx, &out.grads[0], out.start_step)
    }
}

/// PowerSGD: sync SGD with rank-r compressed gradients. Two collectives per
/// step (P then Q+raw) — two handshakes, the latency floor the paper points
/// at — plus modeled encode/decode GEMM time on the accelerator.
pub struct PowerSgdStrategy {
    psgd: PowerSgd,
    comm_t: f64,
    scaled_bytes: usize,
    flops_scale: f64,
}

impl PowerSgdStrategy {
    /// Effective GEMM throughput assumed for encode/decode cost (Titan X
    /// era, f32): 5 TFLOP/s.
    const GEMM_FLOPS: f64 = 5.0e12;

    /// Strategy with the compressed wire cost and FLOP scaling precomputed.
    pub fn new(ctx: &TrainContext) -> Self {
        let m = ctx.cfg.workers;
        let psgd = PowerSgd::new(&ctx.rt.manifest, ctx.cfg.rank, m, ctx.cfg.seed);
        // Wire cost: the compressed message replaces the full one, but the
        // *fraction* of compressed bytes in our scaled model equals the
        // paper's fraction, so scale the paper-size message by it.
        let full_bytes = ctx.rt.manifest.message_bytes();
        let frac = psgd.bytes_per_round() as f64 / full_bytes as f64;
        let scaled_bytes = (ctx.cluster.message_bytes as f64 * frac) as usize;
        // The reference implementation flattens all P factors into ONE
        // buffer (single all-reduce), then all Q factors + raw tensors into
        // another, launched back-to-back in one comm group: one handshake,
        // two wire passes' worth of bytes. The wire cost follows the
        // configured exact topology at the compressed size.
        let comm_t = ctx.cluster.topology.collective_time(&ctx.cluster.net, scaled_bytes);
        let flops_scale = (full_bytes as f64 / (ctx.rt.n * 4) as f64).max(1.0);
        Self { psgd, comm_t, scaled_bytes, flops_scale }
    }
}

impl MixingStrategy for PowerSgdStrategy {
    fn phase(&self) -> LocalPhase {
        LocalPhase::GradOnly
    }

    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        RoundPlan { steps: vec![1; eng.workers.m], advance: 1 }
    }

    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, out: RoundOutcome) -> Result<()> {
        let m = eng.workers.m;
        let grad_refs: Vec<&[f32]> = out.grads.iter().map(|g| g.as_slice()).collect();
        let round = self.psgd.round(&grad_refs);

        // encode/decode compute, scaled to paper-model FLOPs.
        let enc_t = round.encode_flops * self.flops_scale / Self::GEMM_FLOPS;
        for w in 0..m {
            eng.clocks.compute(w, enc_t);
        }
        eng.clocks.barrier();
        for w in 0..m {
            eng.clocks.comm_blocked(w, self.comm_t);
        }
        account_collective(&mut eng.rec, &ctx.cluster.topology, self.scaled_bytes);
        apply_shared_update(eng, ctx, &round.avg_grad, out.start_step)
    }
}
