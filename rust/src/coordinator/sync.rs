//! Fully synchronous SGD (the paper's baseline) as an engine strategy.
//!
//! Every round is one step: all workers compute a gradient on their own
//! shard (the engine's `GradOnly` phase), then the mixing decision runs a
//! *blocking* all-reduce (everyone waits for the slowest worker, then for
//! the wire) and applies the identical averaged update everywhere through
//! the fused `update` kernel.
//!
//! Under `--compress` (DESIGN.md §12) the same schedule runs on compressed
//! gradients: each member re-injects its error-feedback residual, the
//! collective carries the compressed payload (the wire and the byte
//! accounting are charged at the scaled compressed size), and the decoded
//! survivor mean feeds the shared update. `--algo powersgd` is exactly
//! this strategy under `--compress powersgd` — bit-identical to the
//! retired dedicated strategy, with a crash/rejoin protocol the old one
//! refused to have.

use anyhow::Result;

use super::engine::{Engine, LocalPhase, MixingStrategy, RoundOutcome, RoundPlan};
use super::{
    account_collective_among, charge_blocking_exchange, charge_blocking_exchange_bytes,
    TrainContext,
};
use crate::compress::{wire_plan, WirePlan};

/// Blocking per-step gradient averaging (mixing matrix = (1/m) 11ᵀ each
/// step), optionally over compressed gradients.
pub struct SyncStrategy {
    comm_t: f64,
    /// compressed wire size + FLOP scaling; `None` for `--compress none`
    wire: Option<WirePlan>,
}

impl SyncStrategy {
    /// Strategy with the per-step blocking collective cost precomputed —
    /// at the compressed payload size when a compressor is configured.
    pub fn new(ctx: &TrainContext) -> Self {
        let wire = wire_plan(ctx.cfg, &ctx.rt.manifest, ctx.cluster.message_bytes);
        let comm_t = match &wire {
            // The compressed message replaces the full one; its wire cost
            // follows the configured exact topology at the scaled size.
            Some(w) => ctx.cluster.topology.collective_time(&ctx.cluster.net, w.scaled_bytes),
            None => ctx.cluster.collective_time(),
        };
        Self { comm_t, wire }
    }
}

/// Apply one identical averaged-gradient update to every participating
/// replica (replicas are bit-identical within the sync family's alive
/// members, so apply once and copy is exact). Under faults the template is
/// the first member and parked replicas stay frozen — they are re-seeded
/// from a member on rejoin.
pub(crate) fn apply_shared_update(
    eng: &mut Engine,
    ctx: &TrainContext,
    avg_grad: &[f32],
    step: usize,
) -> Result<()> {
    let lead = eng.fault.alive.members().first().copied().unwrap_or(0);
    let lr = ctx.schedule.lr_at_step(step);
    let (p, mom) = ctx.rt.sgd_update(
        &eng.workers.params[lead],
        &eng.workers.mom[lead],
        avg_grad,
        lr,
        ctx.cfg.mu,
        ctx.cfg.wd,
    )?;
    for w in 0..eng.workers.m {
        if !eng.fault.alive.is_member(w) {
            continue;
        }
        eng.workers.params[w].copy_from_slice(&p);
        eng.workers.mom[w].copy_from_slice(&mom);
    }
    Ok(())
}

impl MixingStrategy for SyncStrategy {
    fn phase(&self) -> LocalPhase {
        LocalPhase::GradOnly
    }

    fn plan(&mut self, eng: &Engine, _ctx: &TrainContext) -> RoundPlan {
        RoundPlan { steps: vec![1; eng.workers.m], advance: 1 }
    }

    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, mut out: RoundOutcome) -> Result<()> {
        if self.wire.is_some() {
            // Compressed round: encode each member's gradient (with its
            // residual), charge the modeled encode/decode GEMM time, then
            // the blocking collective at the compressed payload size.
            let mut cs = eng.compress.take().expect("wire plan implies compress state");
            let members: Vec<usize> = eng.fault.alive.members().to_vec();
            let grad_refs: Vec<&[f32]> = out.grads.iter().map(|g| g.as_slice()).collect();
            debug_assert_eq!(grad_refs.len(), members.len());
            let flops = cs.encode_grads_mean(&grad_refs, &members);
            let enc_t = cs.encode_time(flops);
            for &w in &members {
                eng.clocks.compute(w, enc_t);
            }
            charge_blocking_exchange_bytes(eng, ctx, self.comm_t, cs.scaled_bytes);
            account_collective_among(
                &mut eng.rec,
                &ctx.cluster.topology,
                cs.scaled_bytes,
                &eng.fault.alive,
            );
            let res = apply_shared_update(eng, ctx, cs.avg(), out.start_step);
            eng.compress = Some(cs);
            return res;
        }
        // Blocking collective: stragglers idle everyone (alive members
        // under faults — parked workers neither barrier nor pay the wire),
        // then the wire.
        charge_blocking_exchange(eng, ctx, self.comm_t);
        if eng.fault.alive.is_full() {
            // Inline reduce on the coordinator, over the executor's
            // reusable scratch (bit-identical to fresh scratch; §10).
            ctx.cluster
                .topology
                .allreduce_mean_with(&mut out.grads, &mut *eng.exec.reduce_scratch());
        } else {
            // Parked workers produced no gradient, so `out.grads` is
            // already compact in member order: reduce it with the survivor
            // sub-schedule (exact mean over the members).
            ctx.cluster.topology.allreduce_mean_compact(
                &mut out.grads,
                eng.fault.alive.members(),
                &mut eng.exec.reduce_scratch(),
            );
        }
        account_collective_among(
            &mut eng.rec,
            &ctx.cluster.topology,
            ctx.cluster.message_bytes,
            &eng.fault.alive,
        );
        apply_shared_update(eng, ctx, &out.grads[0], out.start_step)
    }
}
