//! **Overlap-Gossip** (`--algo overlap-gossip`) — the decentralized variant
//! of the paper's anchor pullback, over the k-regular gossip topology
//! (DESIGN.md §8, EXPERIMENTS.md E10).
//!
//! The mixing-matrix framing (Eq. 8) never required `W = (1/m)·11ᵀ`: any
//! doubly-stochastic W over a connected graph has the same consensus fixed
//! point. Here each worker keeps its **own** anchor `z_i`, pulled toward the
//! *push-sum neighbor average* of the post-pullback models instead of the
//! global mean — one column-stochastic mixing round per boundary, de-biased
//! by the push-sum weight so the fixed point stays the exact global average
//! (cf. Stochastic Gradient Push, Assran et al. 2018, PAPERS.md).
//!
//! Per round, mirroring `overlap.rs`:
//!
//! 1. *absorb* the exchange launched at the previous boundary — each worker
//!    waits only for its **own neighborhood** (no cluster rendezvous, no
//!    handshake: the decisive difference from every exact collective here);
//! 2. `z_i ←` de-biased neighbor mix of the boundary models (vanilla Eq. 5
//!    assignment, β = 0 — the `overlap` baseline this variant is measured
//!    against in E10);
//! 3. pull every local model toward its own anchor (Eq. 4);
//! 4. launch the next exchange of the post-pullback models. Its per-worker
//!    completion time is `max(own, neighbors' launch clocks) + degree·(lat +
//!    bytes/BW)` — a straggler delays only its graph neighborhood, one hop
//!    per round, instead of stalling all m workers at once (E10's
//!    strictly-lower blocked-communication claim, asserted in
//!    rust/tests/topology.rs).
//!
//! τ-family plans (`tau_hetero` included) work unchanged.

use std::sync::Arc;

use anyhow::Result;

use super::engine::{plan_tau, Engine, MixingStrategy, PULLBACK_S, RoundOutcome, RoundPlan};
use super::{account_collective_among, copy_row, TrainContext};
use crate::config::Algo;
use crate::executor::ReduceHandle;
use crate::fault::AliveSet;
use crate::topology::{Topology, TopologyKind};

/// An in-flight gossip exchange: the per-worker de-biased mixes (possibly
/// still computing on the communicator thread) plus per-worker virtual
/// completion times (no single global `ready_at`).
struct PendingGossip {
    mixed: ReduceHandle,
    ready: Vec<f64>,
    /// which output rows carry a de-biased mix (the workers alive at
    /// launch); `None` on the fault-free fast path, where every row is
    /// valid. A worker that rejoined after the launch has an all-zero row
    /// here — its warm-started anchor must not be clobbered by it.
    valid: Option<Vec<bool>>,
}

/// Pullback-to-neighbor-averaged-anchor mixing on the gossip graph. The
/// graph lives behind an `Arc` so each round's mix job shares it with the
/// communicator thread without cloning adjacency lists.
pub struct GossipStrategy {
    topo: Arc<Topology>,
    /// push-sum input weights (all-ones under full participation)
    ones: Arc<Vec<f64>>,
    z: Vec<Vec<f32>>,
    pending: Option<PendingGossip>,
}

impl GossipStrategy {
    /// Uses the configured topology when it is a gossip graph; on the
    /// default ring config it derives one from `--gossip-degree`, so
    /// `--algo overlap-gossip` works without an explicit `--topology`. Any
    /// *other* explicit topology is rejected loudly by `coordinator::run`
    /// before this constructor is reached.
    pub fn new(ctx: &TrainContext) -> Result<Self> {
        debug_assert_eq!(ctx.cfg.algo, Algo::OverlapGossip);
        let topo = if ctx.cluster.topology.kind == TopologyKind::Gossip {
            ctx.cluster.topology.clone()
        } else {
            Topology::gossip(ctx.cfg.workers, ctx.cfg.gossip_degree, ctx.cfg.seed)?
        };
        Ok(Self {
            topo: Arc::new(topo),
            ones: Arc::new(vec![1.0f64; ctx.cfg.workers]),
            z: Vec::new(),
            pending: None,
        })
    }
}

impl MixingStrategy for GossipStrategy {
    fn on_run_start(&mut self, eng: &mut Engine, _ctx: &TrainContext) -> Result<()> {
        // Every anchor starts at the common init (x_0^(i) = z_0^(i)).
        self.z = vec![eng.workers.params[0].clone(); eng.workers.m];
        Ok(())
    }

    fn plan(&mut self, eng: &Engine, ctx: &TrainContext) -> RoundPlan {
        plan_tau(eng, ctx, ctx.cfg.tau)
    }

    fn decentralized(&self) -> bool {
        // No quorum, no rendezvous: under a partition every component
        // keeps mixing on its own sub-graph, so every alive worker keeps
        // stepping (DESIGN.md §11) — the decentralized advantage E14
        // measures against the quorum-parked exact collectives.
        true
    }

    fn on_rejoin(
        &mut self,
        eng: &mut Engine,
        _ctx: &TrainContext,
        w: usize,
        _src: usize,
    ) -> Result<()> {
        // Warm-start from the nearest *reachable* live anchor: an allowed
        // graph neighbor's z when one exists (the node it will gossip with
        // first), else any live worker in the same partition component.
        // State never crosses an active partition — if no live peer is
        // reachable at all, the rejoiner restarts from its own frozen
        // anchor (the only state it could actually hold).
        let donor = self
            .topo
            .neighbors(w)
            .iter()
            .copied()
            .find(|&j| eng.fault.alive.edge_allowed(w, j))
            .or_else(|| (0..eng.workers.m).find(|&j| j != w && eng.fault.alive.edge_allowed(w, j)))
            .unwrap_or(w);
        copy_row(&mut self.z, donor, w); // no-op when the rejoiner is its own donor
        eng.workers.warm_start(w, &self.z[w]);
        Ok(())
    }

    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, _out: RoundOutcome) -> Result<()> {
        let m = eng.workers.m;
        // Split the compression seam off the engine for the duration of the
        // mixing decision (disjoint borrows); restored before returning.
        let mut cs_opt = eng.compress.take();

        // --- absorb the previous boundary's exchange, per neighborhood ----
        if let Some(p) = self.pending.take() {
            for w in 0..m {
                if eng.fault.alive.steps(w) {
                    eng.clocks.wait_comm_until(w, p.ready[w]);
                }
            }
            // Join the communicator thread (threads backend) / take the
            // eager result (sim) — bit-identical either way. The displaced
            // anchors return to the buffer pool, balancing the buffers the
            // next launch takes out (zero steady-state allocations).
            let PendingGossip { mixed, ready: _, valid } = p;
            let mut new_z = mixed.wait();
            match valid {
                None => {
                    // Fault-free fast path: every row is a fresh anchor.
                    let old = std::mem::replace(&mut self.z, new_z);
                    eng.exec.buffers().put_set(old);
                }
                Some(valid) => {
                    // Dead workers received nothing (their push-sum weight
                    // is exactly 0): keep their frozen anchors. A worker
                    // that rejoined after the launch keeps its warm-started
                    // anchor (its row is all-zero, `valid[w] == false`).
                    for w in 0..m {
                        if valid[w] && eng.fault.alive.steps(w) {
                            std::mem::swap(&mut self.z[w], &mut new_z[w]);
                        }
                    }
                    eng.exec.buffers().put_set(new_z);
                }
            }
        }

        // --- pullback toward the per-worker anchor (Eq. 4) ----------------
        // Compressed runs use the delay-corrected form (DESIGN.md §12):
        // contract by the launch-time gap, so the staleness the compressed
        // mask introduces is corrected without discarding local progress.
        for w in 0..m {
            if !eng.fault.alive.steps(w) {
                continue; // crashed: frozen replica, frozen clock
            }
            if let Some(cs) = cs_opt.as_mut() {
                cs.pullback(w, &mut eng.workers.params[w], &self.z[w], ctx.cfg.alpha);
            } else {
                ctx.rt.pullback_inplace(&mut eng.workers.params[w], &self.z[w], ctx.cfg.alpha)?;
            }
            eng.clocks.compute(w, PULLBACK_S);
        }

        // --- launch the next push-sum exchange ----------------------------
        // Data plane: one column-stochastic mixing round over the boundary
        // models, de-biased by the push-sum weights (exactly 1 on a regular
        // graph; the correction is what keeps irregular/partial rounds
        // exact — property-tested in rust/tests/topology.rs). Both backends
        // mix over a pooled bit-exact snapshot of the boundary models: sim
        // computes the job eagerly at launch (the seed's sequence point),
        // the threads backend runs it on the parked communicator thread
        // under the next round's local compute — same inputs, same code,
        // bit-identical output. Under faults the mix runs over the alive
        // edges only (`Topology::gossip_mix_alive_into`): dead workers
        // neither send nor receive, partitions localize the exchange to
        // each component, and the push-sum weights keep every component's
        // survivor mean exact.
        // Under `--compress` each stepping worker first encodes its
        // post-pullback model against its own anchor (error feedback in
        // `cs`) and the exchange mixes the reconstructed contributions at
        // the compressed wire size; a parked worker's row passes through
        // verbatim (it exchanges nothing and its residual stays frozen).
        if let Some(cs) = cs_opt.as_mut() {
            for w in 0..m {
                if eng.fault.alive.steps(w) {
                    let flops = cs.encode_param(w, &eng.workers.params[w], &self.z[w]);
                    eng.clocks.compute(w, cs.encode_time(flops));
                    cs.note_launch(w, &eng.workers.params[w]);
                } else {
                    cs.passthrough(w, &eng.workers.params[w]);
                }
            }
        }
        let wire_bytes = match cs_opt.as_ref() {
            Some(cs) => cs.scaled_bytes,
            None => ctx.cluster.message_bytes,
        };
        let pool = eng.exec.buffers().clone();
        let snapshot = {
            let refs: Vec<&[f32]> = match cs_opt.as_ref() {
                Some(cs) => cs.contrib.iter().map(|p| p.as_slice()).collect(),
                None => eng.workers.params.iter().map(|p| p.as_slice()).collect(),
            };
            pool.take_set_copy(&refs)
        };
        let mut out = pool.take_set_zeroed(m, ctx.rt.n);
        let topo = Arc::clone(&self.topo);
        let ones = Arc::clone(&self.ones);
        let alive_snap: Option<Arc<AliveSet>> = if eng.fault.alive.is_full() {
            None
        } else {
            Some(Arc::new(eng.fault.alive.clone()))
        };
        let alive_job = alive_snap.clone();
        let mixed = eng.exec.start_reduce(move |_scratch| {
            let mut w_out = vec![0.0f64; ones.len()];
            match &alive_job {
                Some(alive) => {
                    topo.gossip_mix_alive_into(&snapshot, &ones, alive, &mut out, &mut w_out)
                }
                None => topo.gossip_mix_into(&snapshot, &ones, &mut out, &mut w_out),
            }
            // De-bias in place: estimate = value / weight. Rows with zero
            // weight (dead workers) stay zeroed; the absorb skips them.
            for (v, &wt) in out.iter_mut().zip(w_out.iter()) {
                if wt > 0.0 {
                    let inv = (1.0 / wt) as f32;
                    for x in v.iter_mut() {
                        *x *= inv;
                    }
                }
            }
            pool.put_set(snapshot);
            out
        });
        // Timing plane: worker i's exchange completes once its whole (live)
        // neighborhood has joined and its live-degree's worth of neighbor
        // messages have moved — no global handshake, no cluster-wide
        // rendezvous. Dead workers exchange nothing.
        let g_t = ctx.cluster.net.gossip_time(wire_bytes, self.topo.degree());
        let ready = (0..m)
            .map(|i| {
                if let Some(alive) = &alive_snap {
                    if !alive.steps(i) {
                        return eng.clocks.now(i);
                    }
                    let mut t = eng.clocks.now(i);
                    let mut live_degree = 0usize;
                    for &j in self.topo.neighbors(i) {
                        if alive.edge_allowed(i, j) {
                            live_degree += 1;
                            t = t.max(eng.clocks.now(j));
                        }
                    }
                    t + ctx.cluster.net.gossip_time(wire_bytes, live_degree)
                } else {
                    let mut t = eng.clocks.now(i);
                    for &j in self.topo.neighbors(i) {
                        t = t.max(eng.clocks.now(j));
                    }
                    t + g_t
                }
            })
            .collect();
        let valid = alive_snap.map(|alive| (0..m).map(|w| alive.steps(w)).collect());
        self.pending = Some(PendingGossip { mixed, ready, valid });
        account_collective_among(&mut eng.rec, &self.topo, wire_bytes, &eng.fault.alive);
        eng.compress = cs_opt;
        Ok(())
    }
}
