//! **Overlap-Gossip** (`--algo overlap-gossip`) — the decentralized variant
//! of the paper's anchor pullback, over the k-regular gossip topology
//! (DESIGN.md §8, EXPERIMENTS.md E10).
//!
//! The mixing-matrix framing (Eq. 8) never required `W = (1/m)·11ᵀ`: any
//! doubly-stochastic W over a connected graph has the same consensus fixed
//! point. Here each worker keeps its **own** anchor `z_i`, pulled toward the
//! *push-sum neighbor average* of the post-pullback models instead of the
//! global mean — one column-stochastic mixing round per boundary, de-biased
//! by the push-sum weight so the fixed point stays the exact global average
//! (cf. Stochastic Gradient Push, Assran et al. 2018, PAPERS.md).
//!
//! Per round, mirroring `overlap.rs`:
//!
//! 1. *absorb* the exchange launched at the previous boundary — each worker
//!    waits only for its **own neighborhood** (no cluster rendezvous, no
//!    handshake: the decisive difference from every exact collective here);
//! 2. `z_i ←` de-biased neighbor mix of the boundary models (vanilla Eq. 5
//!    assignment, β = 0 — the `overlap` baseline this variant is measured
//!    against in E10);
//! 3. pull every local model toward its own anchor (Eq. 4);
//! 4. launch the next exchange of the post-pullback models. Its per-worker
//!    completion time is `max(own, neighbors' launch clocks) + degree·(lat +
//!    bytes/BW)` — a straggler delays only its graph neighborhood, one hop
//!    per round, instead of stalling all m workers at once (E10's
//!    strictly-lower blocked-communication claim, asserted in
//!    rust/tests/topology.rs).
//!
//! τ-family plans (`tau_hetero` included) work unchanged.

use std::sync::Arc;

use anyhow::Result;

use super::engine::{plan_tau, Engine, MixingStrategy, PULLBACK_S, RoundOutcome, RoundPlan};
use super::{account_collective, TrainContext};
use crate::config::Algo;
use crate::executor::ReduceHandle;
use crate::topology::{Topology, TopologyKind};

/// An in-flight gossip exchange: the per-worker de-biased mixes (possibly
/// still computing on the communicator thread) plus per-worker virtual
/// completion times (no single global `ready_at`).
struct PendingGossip {
    mixed: ReduceHandle,
    ready: Vec<f64>,
}

/// Pullback-to-neighbor-averaged-anchor mixing on the gossip graph. The
/// graph lives behind an `Arc` so each round's mix job shares it with the
/// communicator thread without cloning adjacency lists.
pub struct GossipStrategy {
    topo: Arc<Topology>,
    /// push-sum input weights (all-ones under full participation)
    ones: Arc<Vec<f64>>,
    z: Vec<Vec<f32>>,
    pending: Option<PendingGossip>,
}

impl GossipStrategy {
    /// Uses the configured topology when it is a gossip graph; on the
    /// default ring config it derives one from `--gossip-degree`, so
    /// `--algo overlap-gossip` works without an explicit `--topology`. Any
    /// *other* explicit topology is rejected loudly by `coordinator::run`
    /// before this constructor is reached.
    pub fn new(ctx: &TrainContext) -> Result<Self> {
        debug_assert_eq!(ctx.cfg.algo, Algo::OverlapGossip);
        let topo = if ctx.cluster.topology.kind == TopologyKind::Gossip {
            ctx.cluster.topology.clone()
        } else {
            Topology::gossip(ctx.cfg.workers, ctx.cfg.gossip_degree, ctx.cfg.seed)?
        };
        Ok(Self {
            topo: Arc::new(topo),
            ones: Arc::new(vec![1.0f64; ctx.cfg.workers]),
            z: Vec::new(),
            pending: None,
        })
    }
}

impl MixingStrategy for GossipStrategy {
    fn on_run_start(&mut self, eng: &mut Engine, _ctx: &TrainContext) -> Result<()> {
        // Every anchor starts at the common init (x_0^(i) = z_0^(i)).
        self.z = vec![eng.workers.params[0].clone(); eng.workers.m];
        Ok(())
    }

    fn plan(&mut self, eng: &Engine, ctx: &TrainContext) -> RoundPlan {
        plan_tau(eng, ctx, ctx.cfg.tau)
    }

    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, _out: RoundOutcome) -> Result<()> {
        let m = eng.workers.m;

        // --- absorb the previous boundary's exchange, per neighborhood ----
        if let Some(p) = self.pending.take() {
            for w in 0..m {
                eng.clocks.wait_comm_until(w, p.ready[w]);
            }
            // Join the communicator thread (threads backend) / take the
            // eager result (sim) — bit-identical either way. The displaced
            // anchors return to the buffer pool, balancing the buffers the
            // next launch takes out (zero steady-state allocations).
            let old = std::mem::replace(&mut self.z, p.mixed.wait());
            eng.exec.buffers().put_set(old);
        }

        // --- pullback toward the per-worker anchor (Eq. 4) ----------------
        for w in 0..m {
            ctx.rt.pullback_inplace(&mut eng.workers.params[w], &self.z[w], ctx.cfg.alpha)?;
            eng.clocks.compute(w, PULLBACK_S);
        }

        // --- launch the next push-sum exchange ----------------------------
        // Data plane: one column-stochastic mixing round over the boundary
        // models, de-biased by the push-sum weights (exactly 1 on a regular
        // graph; the correction is what keeps irregular/partial rounds
        // exact — property-tested in rust/tests/topology.rs). Both backends
        // mix over a pooled bit-exact snapshot of the boundary models: sim
        // computes the job eagerly at launch (the seed's sequence point),
        // the threads backend runs it on the parked communicator thread
        // under the next round's local compute — same inputs, same code,
        // bit-identical output.
        let pool = eng.exec.buffers().clone();
        let snapshot = {
            let refs: Vec<&[f32]> = eng.workers.params.iter().map(|p| p.as_slice()).collect();
            pool.take_set_copy(&refs)
        };
        let mut out = pool.take_set_zeroed(m, ctx.rt.n);
        let topo = Arc::clone(&self.topo);
        let ones = Arc::clone(&self.ones);
        let mixed = eng.exec.start_reduce(move |_scratch| {
            let mut w_out = vec![0.0f64; ones.len()];
            topo.gossip_mix_into(&snapshot, &ones, &mut out, &mut w_out);
            // De-bias in place: estimate = value / weight.
            for (v, &wt) in out.iter_mut().zip(w_out.iter()) {
                let inv = (1.0 / wt) as f32;
                for x in v.iter_mut() {
                    *x *= inv;
                }
            }
            pool.put_set(snapshot);
            out
        });
        // Timing plane: worker i's exchange completes once its whole
        // neighborhood has joined and `degree` neighbor messages have moved
        // — no global handshake, no cluster-wide rendezvous.
        let g_t = ctx.cluster.net.gossip_time(ctx.cluster.message_bytes, self.topo.degree());
        let ready = (0..m)
            .map(|i| {
                let mut t = eng.clocks.now(i);
                for &j in self.topo.neighbors(i) {
                    t = t.max(eng.clocks.now(j));
                }
                t + g_t
            })
            .collect();
        self.pending = Some(PendingGossip { mixed, ready });
        account_collective(&mut eng.rec, &self.topo, ctx.cluster.message_bytes);
        Ok(())
    }
}
