//! Local SGD (periodic averaging) — the paper's starting point — as an
//! engine strategy.
//!
//! Each worker takes τ local steps, then a *blocking* all-reduce replaces
//! every replica with the average (momentum buffers stay local, the
//! standard recipe). Communication cost is amortized by τ but still sits
//! on the critical path — exactly the trade-off Fig. 1 plots. Under
//! `tau_hetero` a straggler runs fewer local steps per round (E9).
//!
//! Under `--compress` (DESIGN.md §12) each member transmits its compressed
//! *delta* against the last shared average (the reference every receiver
//! already holds) with error feedback; the reduced mean of the
//! reconstructed contributions replaces the member replicas, and the wire
//! is charged at the compressed payload size.

use anyhow::Result;

use super::engine::{plan_tau, Engine, MixingStrategy, RoundOutcome, RoundPlan};
use super::{
    account_collective_among, charge_blocking_exchange, charge_blocking_exchange_bytes,
    TrainContext,
};
use crate::compress::{wire_plan, WirePlan};

/// Blocking parameter averaging every τ steps, on the configured exact
/// topology (ring / hierarchical / tree — see DESIGN.md §8).
pub struct LocalAvgStrategy {
    comm_t: f64,
    /// compressed wire size + FLOP scaling; `None` for `--compress none`
    wire: Option<WirePlan>,
    /// the last shared average — the compression reference (empty when
    /// compression is off)
    ref_model: Vec<f32>,
}

impl LocalAvgStrategy {
    /// Strategy with the per-round blocking collective cost precomputed —
    /// at the compressed payload size when a compressor is configured.
    pub fn new(ctx: &TrainContext) -> Self {
        let wire = wire_plan(ctx.cfg, &ctx.rt.manifest, ctx.cluster.message_bytes);
        let comm_t = match &wire {
            Some(w) => ctx.cluster.topology.collective_time(&ctx.cluster.net, w.scaled_bytes),
            None => ctx.cluster.collective_time(),
        };
        Self { comm_t, wire, ref_model: Vec::new() }
    }
}

impl MixingStrategy for LocalAvgStrategy {
    fn on_run_start(&mut self, eng: &mut Engine, _ctx: &TrainContext) -> Result<()> {
        if self.wire.is_some() {
            // All replicas are identical at init: worker 0's is the shared
            // reference every receiver can reconstruct against.
            self.ref_model = eng.workers.params[0].clone();
        }
        Ok(())
    }

    fn plan(&mut self, eng: &Engine, ctx: &TrainContext) -> RoundPlan {
        plan_tau(eng, ctx, ctx.cfg.tau)
    }

    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, _out: RoundOutcome) -> Result<()> {
        if self.wire.is_some() {
            // Compressed round: members encode their delta vs the shared
            // reference (error feedback in `cs`), the blocking collective
            // reduces the reconstructed contributions at the compressed
            // size, and the mean becomes the next reference.
            let mut cs = eng.compress.take().expect("wire plan implies compress state");
            let members: Vec<usize> = eng.fault.alive.members().to_vec();
            for &w in &members {
                let flops = cs.encode_param(w, &eng.workers.params[w], &self.ref_model);
                eng.clocks.compute(w, cs.encode_time(flops));
            }
            charge_blocking_exchange_bytes(eng, ctx, self.comm_t, cs.scaled_bytes);
            ctx.cluster.topology.allreduce_mean_alive_with(
                &mut cs.contrib,
                &eng.fault.alive,
                &mut eng.exec.reduce_scratch(),
            );
            let lead = members.first().copied().unwrap_or(0);
            self.ref_model.copy_from_slice(&cs.contrib[lead]);
            for &w in &members {
                eng.workers.params[w].copy_from_slice(&self.ref_model);
            }
            account_collective_among(
                &mut eng.rec,
                &ctx.cluster.topology,
                cs.scaled_bytes,
                &eng.fault.alive,
            );
            eng.compress = Some(cs);
            return Ok(());
        }
        // Blocking param averaging on the topology's real reduce schedule,
        // inline on the coordinator over the executor's reusable scratch
        // (bit-identical to fresh scratch; DESIGN.md §10). Under faults the
        // barrier, the wire charge, and the reduce all cover only the alive
        // set's members — parked workers stay frozen (DESIGN.md §11).
        charge_blocking_exchange(eng, ctx, self.comm_t);
        ctx.cluster.topology.allreduce_mean_alive_with(
            &mut eng.workers.params,
            &eng.fault.alive,
            &mut eng.exec.reduce_scratch(),
        );
        account_collective_among(
            &mut eng.rec,
            &ctx.cluster.topology,
            ctx.cluster.message_bytes,
            &eng.fault.alive,
        );
        Ok(())
    }
}
