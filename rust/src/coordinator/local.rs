//! Local SGD (periodic averaging) — the paper's starting point.
//!
//! Each worker takes τ local steps, then a *blocking* all-reduce replaces
//! every replica with the average (momentum buffers stay local, the
//! standard recipe). Communication cost is amortized by τ but still sits
//! on the critical path — exactly the trade-off Fig. 1 plots.

use anyhow::Result;

use super::{Recorder, TrainContext, Workers};
use crate::clock::Clocks;
use crate::collective::ring_allreduce_mean;
use crate::metrics::TrainLog;

pub fn run(ctx: &TrainContext) -> Result<TrainLog> {
    let m = ctx.cfg.workers;
    let tau = ctx.cfg.tau.max(1);
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();
    let comm_t = ctx.cluster.allreduce_time();

    let mut k = 0;
    while k < total {
        let steps = tau.min(total - k);
        let mut loss_sum = 0.0;
        let mut loss_n = 0;
        for w in 0..m {
            for s in 0..steps {
                loss_sum += workers.local_step(w, ctx, &mut clocks, k + s)?;
                loss_n += 1;
            }
        }
        k += steps;

        // Blocking param averaging.
        clocks.barrier();
        for w in 0..m {
            clocks.comm_blocked(w, comm_t);
        }
        ring_allreduce_mean(&mut workers.params);
        rec.add_bytes((m * ctx.cluster.message_bytes) as u64);

        rec.push_loss(k - 1, loss_sum / loss_n as f64);
        rec.maybe_eval(k, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}
