//! Local SGD (periodic averaging) — the paper's starting point — as an
//! engine strategy.
//!
//! Each worker takes τ local steps, then a *blocking* all-reduce replaces
//! every replica with the average (momentum buffers stay local, the
//! standard recipe). Communication cost is amortized by τ but still sits
//! on the critical path — exactly the trade-off Fig. 1 plots. Under
//! `tau_hetero` a straggler runs fewer local steps per round (E9).

use anyhow::Result;

use super::engine::{plan_tau, Engine, MixingStrategy, RoundOutcome, RoundPlan};
use super::{account_collective_among, charge_blocking_exchange, TrainContext};

/// Blocking parameter averaging every τ steps, on the configured exact
/// topology (ring / hierarchical / tree — see DESIGN.md §8).
pub struct LocalAvgStrategy {
    comm_t: f64,
}

impl LocalAvgStrategy {
    /// Strategy with the per-round blocking collective cost precomputed.
    pub fn new(ctx: &TrainContext) -> Self {
        Self { comm_t: ctx.cluster.collective_time() }
    }
}

impl MixingStrategy for LocalAvgStrategy {
    fn plan(&mut self, eng: &Engine, ctx: &TrainContext) -> RoundPlan {
        plan_tau(eng, ctx, ctx.cfg.tau)
    }

    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, _out: RoundOutcome) -> Result<()> {
        // Blocking param averaging on the topology's real reduce schedule,
        // inline on the coordinator over the executor's reusable scratch
        // (bit-identical to fresh scratch; DESIGN.md §10). Under faults the
        // barrier, the wire charge, and the reduce all cover only the alive
        // set's members — parked workers stay frozen (DESIGN.md §11).
        charge_blocking_exchange(eng, ctx, self.comm_t);
        ctx.cluster.topology.allreduce_mean_alive_with(
            &mut eng.workers.params,
            &eng.fault.alive,
            &mut eng.exec.reduce_scratch(),
        );
        account_collective_among(
            &mut eng.rec,
            &ctx.cluster.topology,
            ctx.cluster.message_bytes,
            &eng.fault.alive,
        );
        Ok(())
    }
}
