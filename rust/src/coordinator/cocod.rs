//! CoCoD-SGD baseline (Shen et al., IJCAI 2019 [20]) as an engine strategy.
//!
//! The other communication/computation-decoupled Local SGD variant the
//! paper compares against. Per round:
//!
//! ```text
//!   at boundary r:   launch all-reduce of the current models  (non-blocking)
//!   during round r+1: τ local steps accumulate a delta Δ_i
//!   at boundary r+1: x_i ← avg(x at boundary r) + Δ_i
//! ```
//!
//! i.e. the local updates are applied on top of a τ-stale average. Same
//! overlap benefit as Overlap-Local-SGD (and the same timing model here),
//! but no pullback contraction — which is why it diverges for large τ in
//! the non-IID setting (Table 2) while Overlap-Local-SGD does not.
//!
//! On the engine, the launch is the `before_local` hook (the collective
//! runs under the round's compute) and the absorb is the mixing decision.

use anyhow::Result;

use super::engine::{plan_tau, Engine, MixingStrategy, RoundOutcome, RoundPlan};
use super::{account_collective_among, TrainContext};
use crate::collective::{launch_collective_among, PendingCollective};

/// Delta-on-stale-average mixing with a non-blocking collective. Under
/// `--compress` (DESIGN.md §12) the launched collective carries each
/// member's compressed delta against the last absorbed average (with error
/// feedback), at the compressed wire size; the local delta is still
/// applied on top of the absorbed mean unchanged.
#[derive(Default)]
pub struct CocodStrategy {
    /// each worker's model snapshot at the launch boundary (for the delta
    /// the round accumulates on top of the stale average)
    snapshots: Vec<Vec<f32>>,
    pending: Option<PendingCollective>,
    /// the last absorbed average — the compression reference (empty when
    /// compression is off)
    ref_model: Vec<f32>,
}

impl CocodStrategy {
    /// Fresh strategy state (snapshots fill at the first launch).
    pub fn new() -> Self {
        Self::default()
    }
}

impl MixingStrategy for CocodStrategy {
    fn on_run_start(&mut self, eng: &mut Engine, _ctx: &TrainContext) -> Result<()> {
        if eng.compress.is_some() {
            // All replicas are identical at init: worker 0's is the shared
            // reference every receiver can reconstruct against.
            self.ref_model = eng.workers.params[0].clone();
        }
        Ok(())
    }

    fn plan(&mut self, eng: &Engine, ctx: &TrainContext) -> RoundPlan {
        plan_tau(eng, ctx, ctx.cfg.tau)
    }

    fn before_local(&mut self, eng: &mut Engine, ctx: &TrainContext) -> Result<()> {
        if eng.compress.is_some() {
            // Compressed launch: members encode their delta vs the last
            // absorbed average before the collective goes on the wire; the
            // reduce runs over the reconstructed contributions at the
            // compressed size. Snapshots still record the *raw* replicas —
            // the round's delta semantics are untouched by compression.
            let mut cs = eng.compress.take().expect("checked is_some");
            let members: Vec<usize> = eng.fault.alive.members().to_vec();
            for &w in &members {
                let flops = cs.encode_param(w, &eng.workers.params[w], &self.ref_model);
                eng.clocks.compute(w, cs.encode_time(flops));
            }
            let start = eng.launch_clock();
            account_collective_among(
                &mut eng.rec,
                &ctx.cluster.topology,
                cs.scaled_bytes,
                &eng.fault.alive,
            );
            self.snapshots.clone_from(&eng.workers.params);
            let refs: Vec<&[f32]> = cs.contrib.iter().map(|p| p.as_slice()).collect();
            self.pending = Some(launch_collective_among(
                &eng.exec,
                &ctx.cluster.topology,
                &refs,
                &eng.fault.alive,
                &ctx.cluster.net,
                cs.scaled_bytes,
                start,
            ));
            eng.compress = Some(cs);
            return Ok(());
        }
        // Launch the collective of the boundary models on the configured
        // exact topology; it runs under the round's compute — genuinely so
        // on the threads backend, where the parked communicator thread
        // reduces (over a pooled snapshot) while the worker threads take
        // their τ local steps. `clone_from` reuses the delta snapshots'
        // capacity, so this hook allocates nothing once warm. Fault events
        // fire before this hook, so the alive set is constant between the
        // launch here and the absorb at this round's boundary (and a
        // frozen clock never sets the start time — `Engine::launch_clock`).
        let start = eng.launch_clock();
        account_collective_among(
            &mut eng.rec,
            &ctx.cluster.topology,
            ctx.cluster.message_bytes,
            &eng.fault.alive,
        );
        self.snapshots.clone_from(&eng.workers.params);
        let refs: Vec<&[f32]> = eng.workers.params.iter().map(|p| p.as_slice()).collect();
        self.pending = Some(launch_collective_among(
            &eng.exec,
            &ctx.cluster.topology,
            &refs,
            &eng.fault.alive,
            &ctx.cluster.net,
            ctx.cluster.message_bytes,
            start,
        ));
        Ok(())
    }

    fn mix(&mut self, eng: &mut Engine, _ctx: &TrainContext, _out: RoundOutcome) -> Result<()> {
        // Absorb: x_i = avg(boundary models) + (x_i - snapshot_i), on the
        // stepping workers (the survivor average under faults).
        let h = self.pending.take().expect("cocod launch precedes absorb");
        let avg = h.absorb_masked(&mut eng.clocks, &eng.fault.alive);
        if eng.compress.is_some() {
            // The absorbed mean of reconstructed contributions is the next
            // round's compression reference.
            self.ref_model.copy_from_slice(&avg);
        }
        for w in 0..eng.workers.m {
            if !eng.fault.alive.steps(w) {
                continue; // parked: frozen replica
            }
            let p = &mut eng.workers.params[w];
            let snap = &self.snapshots[w];
            for (i, pi) in p.iter_mut().enumerate() {
                *pi = avg[i] + (*pi - snap[i]);
            }
        }
        // The absorbed average returns to the pool for the next launch.
        eng.exec.buffers().put(avg);
        Ok(())
    }
}
