//! CoCoD-SGD baseline (Shen et al., IJCAI 2019 [20]).
//!
//! The other communication/computation-decoupled Local SGD variant the
//! paper compares against. Per round:
//!
//! ```text
//!   at boundary r:   launch all-reduce of the current models  (non-blocking)
//!   during round r+1: τ local steps accumulate a delta Δ_i
//!   at boundary r+1: x_i ← avg(x at boundary r) + Δ_i
//! ```
//!
//! i.e. the local updates are applied on top of a τ-stale average. Same
//! overlap benefit as Overlap-Local-SGD (and the same timing model here),
//! but no pullback contraction — which is why it diverges for large τ in
//! the non-IID setting (Table 2) while Overlap-Local-SGD does not.

use anyhow::Result;

use super::{Recorder, TrainContext, Workers};
use crate::clock::Clocks;
use crate::collective::{start_allreduce, NonBlockingAllReduce};
use crate::metrics::TrainLog;

pub fn run(ctx: &TrainContext) -> Result<TrainLog> {
    let m = ctx.cfg.workers;
    let tau = ctx.cfg.tau.max(1);
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();

    // Round-r bookkeeping: each worker's model snapshot at the boundary
    // (for the delta the round accumulates on top of the stale average).
    let mut snapshots: Vec<Vec<f32>> = workers.params.clone();

    let mut k = 0;
    while k < total {
        // Launch the all-reduce of the boundary models; it runs under the
        // round's compute.
        let pending: NonBlockingAllReduce = {
            let refs: Vec<&[f32]> = workers.params.iter().map(|p| p.as_slice()).collect();
            let start = (0..m).map(|w| clocks.now(w)).fold(0.0, f64::max);
            rec.add_bytes((m * ctx.cluster.message_bytes) as u64);
            snapshots.clone_from(&workers.params);
            start_allreduce(&refs, &ctx.cluster.net, ctx.cluster.message_bytes, start)
        };

        // τ local steps per worker.
        let steps = tau.min(total - k);
        let mut loss_sum = 0.0;
        let mut loss_n = 0;
        for w in 0..m {
            for s in 0..steps {
                loss_sum += workers.local_step(w, ctx, &mut clocks, k + s)?;
                loss_n += 1;
            }
        }
        k += steps;

        // Absorb: x_i = avg(boundary models) + (x_i - snapshot_i).
        let h = pending;
        for w in 0..m {
            clocks.wait_comm_until(w, h.ready_at());
            let p = &mut workers.params[w];
            let snap = &snapshots[w];
            for i in 0..p.len() {
                p[i] = h.result[i] + (p[i] - snap[i]);
            }
        }

        rec.push_loss(k - 1, loss_sum / loss_n as f64);
        rec.maybe_eval(k, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}
