//! Layer-3 coordinator — the paper's system contribution.
//!
//! All scheduling runs on one **discrete-event round engine** (`engine.rs`):
//! the engine owns the per-worker event timeline, the virtual cluster clock,
//! loss/eval recording, and byte accounting, and delegates only the *mixing
//! decision* to a `MixingStrategy` — one small impl per algorithm (see
//! DESIGN.md §4). Numerics run for real through the model runtime (PJRT
//! artifacts or the native backend); time comes from the simnet (see simnet/
//! for why that split reproduces the paper's observables).
//!
//! The algorithms differ ONLY in their mixing schedule — exactly the
//! paper's framing (the mixing matrix W_k of Eq. 8):
//!
//! | strategy    | schedule                                                  |
//! |-------------|-----------------------------------------------------------|
//! | sync        | all-reduce grads every step, blocking                     |
//! | powersgd    | alias: sync under `--compress powersgd` (DESIGN.md §12)   |
//! | local       | all-reduce params every τ steps, blocking                 |
//! | overlap     | pullback to stale anchor, NON-blocking all-reduce (Eq. 3-5)|
//! | overlap-m   | + anchor momentum (Eq. 10-11) — the headline algorithm    |
//! | overlap-ada | overlap-m with AdaComm-style adaptive τ (plateau-shrink)  |
//! | overlap-gossip | anchors ← push-sum neighbor averages, NO rendezvous (E10) |
//! | easgd       | symmetric elastic x↔z exchange, blocking                  |
//! | eamsgd      | easgd + local Nesterov momentum                           |
//! | cocod       | local delta applied onto a τ-stale average, overlapped    |
//!
//! Every τ-family strategy additionally supports per-worker heterogeneous τ
//! (`tau_hetero`): see `engine::hetero_plan` (paper §straggler mitigation).
//! Every exact-collective strategy additionally runs on any exact topology
//! (`--topology ring|hier|tree`, DESIGN.md §8): the data plane executes that
//! graph's real reduce schedule and the timing plane charges its cost.
//! Every strategy also runs unchanged on either execution backend
//! (`--execution sim|threads`, DESIGN.md §9): the engine's executor decides
//! whether the local phase and the collectives run sequentially or on real
//! OS threads, with bit-identical observables either way.
//! Compression (`--compress none|powersgd|topk|qsgd`, DESIGN.md §12) is a
//! fourth orthogonal axis: every strategy above runs compressed over any
//! topology and under the fault model, with per-worker error-feedback
//! residuals held as engine state (`engine::Engine::compress`).

pub mod cocod;
pub mod elastic;
pub mod engine;
pub mod gossip;
pub mod local;
pub mod overlap;
pub mod sync;

use anyhow::{bail, Result};

use crate::clock::Clocks;
use crate::compress::CompressKind;
use crate::config::{Algo, ExperimentConfig};
use crate::data::{Batcher, Dataset, PX};
use crate::fault::AliveSet;
use crate::metrics::{EvalRecord, HotPathCounters, PopulationCounters, TrainLog};
use crate::optim::LrSchedule;
use crate::runtime::ModelRuntime;
use crate::simnet::ClusterModel;
use crate::topology::{Topology, TopologyKind};
use crate::util::rng::Rng;

/// Everything a driver needs for one run.
pub struct TrainContext<'a> {
    /// the loaded model runtime (PJRT artifacts or the native backend)
    pub rt: &'a ModelRuntime,
    /// the full experiment description
    pub cfg: &'a ExperimentConfig,
    /// the cluster timing model + communication graph
    pub cluster: ClusterModel,
    /// learning-rate schedule (warmup + paper decay)
    pub schedule: LrSchedule,
    /// training split
    pub train: &'a Dataset,
    /// held-out evaluation split
    pub test: &'a Dataset,
    /// per-worker sample-index shards
    pub shards: Vec<Vec<u32>>,
}

impl<'a> TrainContext<'a> {
    /// Global steps per epoch (drop-last semantics on shard 0).
    pub fn steps_per_epoch(&self) -> usize {
        (self.shards[0].len() / self.rt.train_batch).max(1)
    }

    /// Total global steps of the run (`epochs × steps_per_epoch`, min 1).
    pub fn total_steps(&self) -> usize {
        ((self.cfg.epochs * self.steps_per_epoch() as f64).round() as usize).max(1)
    }
}

/// Mutable per-worker training state shared by all drivers.
///
/// Storage is struct-of-arrays so strategies can mix over `params`
/// directly, but every array is strictly per-worker — including the
/// straggler RNG stream and the batch staging buffers — so the executor
/// can hand each worker's slice of state to its own OS thread
/// ([`Workers::step_views`]) without changing a single draw or bit
/// relative to the sequential backend (DESIGN.md §9).
pub struct Workers {
    /// cluster size m
    pub m: usize,
    /// per-worker model replicas (flat f32)
    pub params: Vec<Vec<f32>>,
    /// per-worker momentum buffers
    pub mom: Vec<Vec<f32>>,
    /// second-moment buffers (Adam local optimizer only)
    pub mom2: Vec<Vec<f32>>,
    /// per-worker 1-based Adam step counters (bias correction)
    adam_t: Vec<f32>,
    use_adam: bool,
    batchers: Vec<Batcher>,
    /// per-worker straggler-draw streams: worker w consumes only its own
    /// stream, so the draw sequence is independent of which thread (or
    /// interleaving) runs the steps
    straggler_rngs: Vec<Rng>,
    img_bufs: Vec<Vec<f32>>,
    label_bufs: Vec<Vec<i32>>,
    /// per-worker gradient scratch: every fused step reuses it, so the
    /// steady-state training kernels allocate nothing (DESIGN.md §10)
    grad_bufs: Vec<Vec<f32>>,
}

/// One worker's complete mutable state, borrowed disjointly from
/// [`Workers`] — the unit of work the executor schedules (sequentially on
/// the `sim` backend, one OS thread each on `threads`).
///
/// All training numerics live on this view ([`StepView::fused_step`],
/// [`StepView::grad_only`]); both backends drive the *same* code over the
/// same per-worker state, which is the digest-identity argument of
/// DESIGN.md §9.
pub struct StepView<'a> {
    w: usize,
    use_adam: bool,
    params: &'a mut Vec<f32>,
    mom: &'a mut Vec<f32>,
    mom2: &'a mut Vec<f32>,
    adam_t: &'a mut f32,
    batcher: &'a mut Batcher,
    rng: &'a mut Rng,
    img_buf: &'a mut Vec<f32>,
    label_buf: &'a mut Vec<i32>,
    grad_buf: &'a mut Vec<f32>,
}

impl StepView<'_> {
    /// One fused local train step at global step index `step`. Returns
    /// `(mini-batch loss, virtual compute seconds)` — the caller charges
    /// the duration to this worker's clock.
    pub fn fused_step(&mut self, ctx: &TrainContext, step: usize) -> Result<(f64, f64)> {
        let b = ctx.rt.train_batch;
        self.batcher.next_batch(ctx.train, b, self.img_buf, self.label_buf);
        let lr = ctx.schedule.lr_at_step(step);
        // Every kernel below runs in place over this worker's buffers with
        // the gradient landing in the per-worker scratch — bit-identical to
        // the allocating forms (asserted in runtime tests), with zero
        // steady-state allocations (DESIGN.md §10).
        let loss = if self.use_adam {
            // §6 extension (Overlap-Local-Adam): grad + fused Adam kernel.
            let loss =
                ctx.rt.grad_step_into(self.params, self.img_buf, self.label_buf, self.grad_buf)?;
            *self.adam_t += 1.0;
            ctx.rt.adam_update_inplace(
                self.params,
                self.mom,
                self.mom2,
                self.grad_buf,
                lr,
                *self.adam_t,
            )?;
            loss
        } else {
            ctx.rt.train_step_inplace(
                self.params,
                self.mom,
                self.img_buf,
                self.label_buf,
                lr,
                ctx.cfg.mu,
                ctx.cfg.wd,
                self.grad_buf,
            )?
        };
        let dt = ctx.cluster.compute.step_time(self.w, self.rng);
        Ok((loss as f64, dt))
    }

    /// Gradient-only step (sync / PowerSGD path). Returns
    /// `(loss, virtual compute seconds, gradient)`.
    pub fn grad_only(&mut self, ctx: &TrainContext) -> Result<(f64, f64, Vec<f32>)> {
        let b = ctx.rt.train_batch;
        self.batcher.next_batch(ctx.train, b, self.img_buf, self.label_buf);
        let (loss, g) = ctx.rt.grad_step(self.params, self.img_buf, self.label_buf)?;
        let dt = ctx.cluster.compute.step_time(self.w, self.rng);
        Ok((loss as f64, dt, g))
    }

    /// This view's worker index (the `net` backend's wire slot id).
    pub(crate) fn worker(&self) -> usize {
        self.w
    }

    /// Read-only replica state for the wire: `(params, mom, mom2, adam_t)`.
    /// The `net` coordinator ships these to the worker process each round.
    pub(crate) fn state_ref(&self) -> (&[f32], &[f32], &[f32], f32) {
        (self.params, self.mom, self.mom2, *self.adam_t)
    }

    /// Mutable replica state for the wire: `(params, mom, mom2, adam_t)`.
    /// Both wire endpoints write decoded state through this — the worker
    /// before stepping, the coordinator when absorbing the result.
    pub(crate) fn state_mut(&mut self) -> (&mut [f32], &mut [f32], &mut [f32], &mut f32) {
        (self.params, self.mom, self.mom2, self.adam_t)
    }

    /// The slot's batch sampler and straggler stream — under the
    /// population axis these are the *bound worker's* streams (swapped in
    /// at the round boundary), and the `net` coordinator ships them with
    /// the replica so the worker process steps with the right draws.
    pub(crate) fn streams_ref(&self) -> (&Batcher, &Rng) {
        (self.batcher, self.rng)
    }

    /// Install shipped stream state (the `net` worker's side of
    /// [`StepView::streams_ref`]): under population a rebind changes which
    /// worker a slot serves, so the slot-keyed streams this process built
    /// at startup are replaced wholesale each phase.
    pub(crate) fn install_streams(&mut self, batcher: Batcher, rng: Rng) {
        *self.batcher = batcher;
        *self.rng = rng;
    }

    /// Consume exactly one local step's worth of stochastic draws — the
    /// batch draw and the straggler-model draw — without touching the
    /// replica, returning the step's virtual compute seconds.
    ///
    /// Two `net`-backend uses (DESIGN.md §13): the coordinator replays the
    /// draws of every step a *remote* worker executed, keeping its canonical
    /// batcher/RNG streams bit-identical to the `sim` backend (and making
    /// the drop-to-local fallback seamless); a rejoining worker process
    /// fast-forwards a claimed slot's streams by the slot's consumed-step
    /// count from the `Welcome` handshake.
    pub(crate) fn replay_draws(&mut self, ctx: &TrainContext) -> f64 {
        let b = ctx.rt.train_batch;
        self.batcher.next_batch(ctx.train, b, self.img_buf, self.label_buf);
        ctx.cluster.compute.step_time(self.w, self.rng)
    }
}

impl Workers {
    /// Build fresh per-worker state (identical replicas) for one run.
    pub fn new(ctx: &TrainContext) -> Self {
        let m = ctx.cfg.workers;
        let n = ctx.rt.n;
        let init = crate::model::init_params(&ctx.rt.manifest, ctx.cfg.seed);
        let batchers = (0..m)
            .map(|w| {
                Batcher::new(
                    ctx.shards[w].clone(),
                    ctx.cfg.seed,
                    w,
                    ctx.cfg.reshuffle,
                )
            })
            .collect();
        let use_adam = ctx.cfg.local_opt == "adam";
        Self {
            m,
            params: vec![init.clone(); m],
            mom: vec![vec![0.0f32; n]; m],
            mom2: vec![vec![0.0f32; if use_adam { n } else { 0 }]; m],
            adam_t: vec![0.0; m],
            use_adam,
            batchers,
            straggler_rngs: (0..m)
                .map(|w| Rng::stream(ctx.cfg.seed, &format!("straggler/{w}")))
                .collect(),
            img_bufs: vec![vec![0.0f32; ctx.rt.train_batch * PX]; m],
            label_bufs: vec![vec![0i32; ctx.rt.train_batch]; m],
            // Lazily sized: the first fused step grows each worker's
            // scratch to n (warm-up); grad-mode algorithms (sync/powersgd)
            // never touch it and never pay for it.
            grad_bufs: vec![Vec::new(); m],
        }
    }

    /// Disjoint mutable views, one per worker in worker order — everything
    /// the executor needs to run the round's local phase (possibly on m OS
    /// threads at once).
    pub fn step_views(&mut self) -> Vec<StepView<'_>> {
        let Workers {
            m,
            params,
            mom,
            mom2,
            adam_t,
            use_adam,
            batchers,
            straggler_rngs,
            img_bufs,
            label_bufs,
            grad_bufs,
        } = self;
        let mut views = Vec::with_capacity(*m);
        let it = params
            .iter_mut()
            .zip(mom.iter_mut())
            .zip(mom2.iter_mut())
            .zip(adam_t.iter_mut())
            .zip(batchers.iter_mut())
            .zip(straggler_rngs.iter_mut())
            .zip(img_bufs.iter_mut())
            .zip(label_bufs.iter_mut())
            .zip(grad_bufs.iter_mut())
            .enumerate();
        for (w, ((((((((p, mo), m2), at), b), r), ib), lb), gb)) in it {
            views.push(StepView {
                w,
                use_adam: *use_adam,
                params: p,
                mom: mo,
                mom2: m2,
                adam_t: at,
                batcher: b,
                rng: r,
                img_buf: ib,
                label_buf: lb,
                grad_buf: gb,
            });
        }
        views
    }

    /// Single-worker view (the sequential entrypoints below build on it;
    /// the `net` worker process uses it to fast-forward claimed slots).
    pub(crate) fn view_at(&mut self, w: usize) -> StepView<'_> {
        StepView {
            w,
            use_adam: self.use_adam,
            params: &mut self.params[w],
            mom: &mut self.mom[w],
            mom2: &mut self.mom2[w],
            adam_t: &mut self.adam_t[w],
            batcher: &mut self.batchers[w],
            rng: &mut self.straggler_rngs[w],
            img_buf: &mut self.img_bufs[w],
            label_buf: &mut self.label_bufs[w],
            grad_buf: &mut self.grad_bufs[w],
        }
    }

    /// One fused local train step for worker `w` (real numerics + virtual
    /// time). Returns the mini-batch loss.
    pub fn local_step(
        &mut self,
        w: usize,
        ctx: &TrainContext,
        clocks: &mut Clocks,
        step: usize,
    ) -> Result<f64> {
        let (loss, dt) = self.view_at(w).fused_step(ctx, step)?;
        clocks.compute(w, dt);
        Ok(loss)
    }

    /// Gradient-only step (sync / PowerSGD path). Returns (loss, grad).
    pub fn local_grad(
        &mut self,
        w: usize,
        ctx: &TrainContext,
        clocks: &mut Clocks,
    ) -> Result<(f64, Vec<f32>)> {
        let (loss, dt, g) = self.view_at(w).grad_only(ctx)?;
        clocks.compute(w, dt);
        Ok((loss, g))
    }

    /// Consensus model for evaluation: plain average of worker replicas.
    pub fn mean_params(&self) -> Vec<f32> {
        let refs: Vec<&[f32]> = self.params.iter().map(|p| p.as_slice()).collect();
        crate::model::vecmath::mean(&refs)
    }

    /// Consensus model over the alive set's *stepping* workers — a crashed
    /// (or quorum-parked) worker's stale replica must not pollute the
    /// evaluation (DESIGN.md §11). Bit-identical to
    /// [`Workers::mean_params`] when the set is full.
    pub fn mean_params_alive(&self, alive: &AliveSet) -> Vec<f32> {
        if alive.is_full() {
            return self.mean_params();
        }
        let refs: Vec<&[f32]> = (0..self.m)
            .filter(|&w| alive.steps(w))
            .map(|w| self.params[w].as_slice())
            .collect();
        crate::model::vecmath::mean(&refs)
    }

    /// Re-seed worker `w`'s full replica state (params, momenta, Adam step
    /// counter) from worker `src` — the engine's default rejoin warm start
    /// for strategies without an anchor. Allocation-free.
    pub fn reseed_from(&mut self, w: usize, src: usize) {
        if w == src {
            return;
        }
        copy_row(&mut self.params, src, w);
        copy_row(&mut self.mom, src, w);
        copy_row(&mut self.mom2, src, w);
        self.adam_t[w] = self.adam_t[src];
    }

    /// Warm-start worker `w` from an anchor vector (the paper's pullback
    /// target): params ← anchor, momenta zeroed, Adam step reset. Used by
    /// the anchor-bearing strategies' rejoin hooks. Allocation-free.
    pub fn warm_start(&mut self, w: usize, anchor: &[f32]) {
        self.params[w].copy_from_slice(anchor);
        self.mom[w].fill(0.0);
        self.mom2[w].fill(0.0);
        self.adam_t[w] = 0.0;
    }

    /// Population slot bind/unbind (DESIGN.md §14): exchange slot `w`'s
    /// complete per-worker training state — replica, momenta, Adam
    /// counter, batch sampler, straggler stream — with a
    /// [`crate::population::WorkerState`]. Pure `mem::swap`s of the owned
    /// buffers, so a steady cohort (or an LRU hit) binds without a single
    /// allocation; the per-slot batch *staging* buffers (`img_bufs`,
    /// `grad_bufs`, ...) are contentless scratch and stay with the slot.
    pub(crate) fn swap_state(&mut self, w: usize, st: &mut crate::population::WorkerState) {
        std::mem::swap(&mut self.params[w], &mut st.params);
        std::mem::swap(&mut self.mom[w], &mut st.mom);
        std::mem::swap(&mut self.mom2[w], &mut st.mom2);
        std::mem::swap(&mut self.adam_t[w], &mut st.adam_t);
        std::mem::swap(&mut self.batchers[w], &mut st.batcher);
        std::mem::swap(&mut self.straggler_rngs[w], &mut st.rng);
    }
}

/// Copy `rows[src]` into `rows[dst]` without allocating (disjoint split
/// borrows; no-op when the indices coincide). Rows must be equal length.
pub(crate) fn copy_row(rows: &mut [Vec<f32>], src: usize, dst: usize) {
    if src == dst {
        return;
    }
    if src < dst {
        let (head, tail) = rows.split_at_mut(dst);
        tail[0].copy_from_slice(&head[src]);
    } else {
        let (head, tail) = rows.split_at_mut(src);
        head[dst].copy_from_slice(&tail[0]);
    }
}

/// Loss accumulation + eval cadence + byte accounting.
pub struct Recorder {
    records: Vec<EvalRecord>,
    step_losses: Vec<(usize, f64)>,
    loss_acc: f64,
    loss_count: usize,
    last_train_loss: f64,
    bytes_sent: u64,
    /// per-worker transmitted bytes on the topology axis (stays all-zero —
    /// and out of the digest — on the seed's uniform ring path)
    neighbor_bytes: Vec<u64>,
    next_eval_step: usize,
    eval_stride: usize,
    tau_trace: Vec<(usize, usize)>,
    /// applied fault events as (1-based round, canonical spec) pairs; empty
    /// — and out of the digest — when no fault fires (DESIGN.md §11)
    fault_trace: Vec<(usize, String)>,
    /// (round, stepping-worker count) series, recorded when it changes
    survivors: Vec<(usize, usize)>,
    /// tracked hot-path counters (set by the engine at run end; all-zero
    /// for the reference loops, and never part of the digest)
    hot: HotPathCounters,
    /// population-store counters (set by the engine when the
    /// partial-participation axis is on; never part of the digest)
    population: Option<PopulationCounters>,
}

impl Recorder {
    /// Fresh recorder with the eval cadence derived from the config.
    pub fn new(ctx: &TrainContext) -> Self {
        let stride = ((ctx.cfg.eval_every * ctx.steps_per_epoch() as f64).round() as usize).max(1);
        Self {
            records: Vec::new(),
            step_losses: Vec::new(),
            loss_acc: 0.0,
            loss_count: 0,
            last_train_loss: f64::NAN,
            bytes_sent: 0,
            neighbor_bytes: vec![0; ctx.cfg.workers],
            next_eval_step: stride,
            eval_stride: stride,
            tau_trace: Vec::new(),
            fault_trace: Vec::new(),
            survivors: Vec::new(),
            hot: HotPathCounters::default(),
            population: None,
        }
    }

    /// Install the run's tracked hot-path counters (engine only; see
    /// `TrainLog::hot`). Counters are reporting-only: they are excluded
    /// from the digest by construction.
    pub fn set_hot(&mut self, hot: HotPathCounters) {
        self.hot = hot;
    }

    /// Install the run's population-store counters (engine only; see
    /// `TrainLog::population`). Reporting-only, never part of the digest.
    pub fn set_population(&mut self, counters: PopulationCounters) {
        self.population = Some(counters);
    }

    /// Record the mean training loss of one sync round at global step `k`.
    pub fn push_loss(&mut self, k: usize, loss: f64) {
        self.step_losses.push((k, loss));
        self.loss_acc += loss;
        self.loss_count += 1;
    }

    /// Credit `b` transmitted bytes to the run total.
    pub fn add_bytes(&mut self, b: u64) {
        self.bytes_sent += b;
    }

    /// Credit per-worker transmitted bytes (topology axis; see
    /// [`account_collective`]).
    pub fn add_neighbor_bytes(&mut self, per_worker: &[u64]) {
        assert_eq!(per_worker.len(), self.neighbor_bytes.len(), "worker count mismatch");
        for (acc, &b) in self.neighbor_bytes.iter_mut().zip(per_worker) {
            *acc += b;
        }
    }

    /// Record a (global step, τ) point of an adaptive-τ controller.
    pub fn note_tau(&mut self, step: usize, tau: usize) {
        self.tau_trace.push((step, tau));
    }

    /// Record one applied fault event (`TrainLog::fault_trace`).
    pub fn note_fault(&mut self, round: usize, event: String) {
        self.fault_trace.push((round, event));
    }

    /// Record a (round, stepping-worker count) point of the survivor
    /// series (`TrainLog::survivors`).
    pub fn note_survivors(&mut self, round: usize, count: usize) {
        self.survivors.push((round, count));
    }

    /// The shared eval-cadence gate: `true` (advancing the cadence) when
    /// global step `k` is due for an evaluation.
    fn eval_due(&mut self, k: usize) -> bool {
        if k < self.next_eval_step {
            return false;
        }
        self.next_eval_step += self.eval_stride;
        true
    }

    /// Called after every global step; runs the (virtually free) test-set
    /// evaluation at the configured cadence.
    pub fn maybe_eval(
        &mut self,
        k: usize,
        ctx: &TrainContext,
        workers: &Workers,
        clocks: &Clocks,
    ) -> Result<()> {
        if !self.eval_due(k) {
            return Ok(());
        }
        self.force_eval(k, ctx, workers, clocks)
    }

    /// [`Recorder::maybe_eval`] under faults: the consensus model averages
    /// only the alive set's stepping workers. Bit-identical to the
    /// unmasked form when the set is full.
    pub fn maybe_eval_masked(
        &mut self,
        k: usize,
        ctx: &TrainContext,
        workers: &Workers,
        clocks: &Clocks,
        alive: &AliveSet,
    ) -> Result<()> {
        if !self.eval_due(k) {
            return Ok(());
        }
        self.force_eval_masked(k, ctx, workers, clocks, alive)
    }

    /// Evaluate the consensus model now, regardless of cadence.
    pub fn force_eval(
        &mut self,
        k: usize,
        ctx: &TrainContext,
        workers: &Workers,
        clocks: &Clocks,
    ) -> Result<()> {
        self.eval_model(k, ctx, workers.mean_params(), clocks)
    }

    /// [`Recorder::force_eval`] under faults (survivor-only consensus).
    pub fn force_eval_masked(
        &mut self,
        k: usize,
        ctx: &TrainContext,
        workers: &Workers,
        clocks: &Clocks,
        alive: &AliveSet,
    ) -> Result<()> {
        self.eval_model(k, ctx, workers.mean_params_alive(alive), clocks)
    }

    /// Shared eval body: score `mean` on the test split and push a record.
    fn eval_model(
        &mut self,
        k: usize,
        ctx: &TrainContext,
        mean: Vec<f32>,
        clocks: &Clocks,
    ) -> Result<()> {
        let (test_loss, test_acc) =
            ctx.rt.evaluate_set(&mean, &ctx.test.images, &ctx.test.labels)?;
        let train_loss = if self.loss_count > 0 {
            self.loss_acc / self.loss_count as f64
        } else {
            // No new losses since the last record (e.g. final force_eval
            // right after a cadence eval): carry the last window forward.
            self.last_train_loss
        };
        self.last_train_loss = train_loss;
        self.loss_acc = 0.0;
        self.loss_count = 0;
        self.records.push(EvalRecord {
            epoch: k as f64 / ctx.steps_per_epoch() as f64,
            step: k,
            sim_time: clocks.max_now(),
            train_loss,
            test_loss,
            test_acc,
        });
        Ok(())
    }

    /// Seal the run into its `TrainLog` (checks the clock invariants).
    pub fn finish(self, ctx: &TrainContext, clocks: &Clocks, steps: usize) -> TrainLog {
        clocks.check_invariants();
        TrainLog {
            algo: ctx.cfg.algo.name().to_string(),
            compress: ctx.cfg.compress.name().to_string(),
            tau: ctx.cfg.tau,
            workers: ctx.cfg.workers,
            records: self.records,
            step_losses: self.step_losses,
            tau_trace: self.tau_trace,
            fault_trace: self.fault_trace,
            survivors: self.survivors,
            total_sim_time: clocks.max_now(),
            total_compute_s: clocks.total_compute(),
            total_comm_blocked_s: clocks.total_comm_blocked(),
            total_idle_s: clocks.total_idle(),
            bytes_sent: self.bytes_sent,
            neighbor_bytes: self.neighbor_bytes,
            steps,
            hot: self.hot,
            population: self.population,
        }
    }
}

/// Account one collective on `rec`. The ring keeps the seed's convention —
/// `m · message_bytes` total, no per-worker split — so every pre-topology
/// digest is bit-identical. The other topologies record true per-link
/// traffic: `bytes_sent` becomes the sum of per-worker transmissions and
/// `TrainLog::neighbor_bytes` picks up the (non-uniform) per-worker split.
pub fn account_collective(rec: &mut Recorder, topo: &Topology, message_bytes: usize) {
    if topo.kind == TopologyKind::Ring {
        rec.add_bytes((topo.m * message_bytes) as u64);
    } else {
        let per = topo.neighbor_bytes(message_bytes);
        rec.add_bytes(per.iter().sum());
        rec.add_neighbor_bytes(&per);
    }
}

/// [`account_collective`] under faults: dead and quorum-parked workers
/// transmit nothing. The ring keeps its per-participant convention at the
/// member count; the other topologies record the survivor sub-graph's true
/// per-link traffic (`Topology::neighbor_bytes_alive`). Identical to
/// [`account_collective`] when the alive set is full.
pub fn account_collective_among(
    rec: &mut Recorder,
    topo: &Topology,
    message_bytes: usize,
    alive: &AliveSet,
) {
    if alive.is_full() {
        return account_collective(rec, topo, message_bytes);
    }
    if topo.kind == TopologyKind::Ring {
        rec.add_bytes((alive.member_count() * message_bytes) as u64);
    } else {
        let per = topo.neighbor_bytes_alive(message_bytes, alive);
        rec.add_bytes(per.iter().sum());
        rec.add_neighbor_bytes(&per);
    }
}

/// Charge one *blocking* exchange to the virtual clocks: barrier over the
/// alive members, then the wire time — `full_comm_t` (the strategy's
/// precomputed full-cluster cost, for bit-identity with the pre-fault
/// path) when everyone is up, the survivor-shaped
/// `Topology::collective_time_alive` otherwise. Shared by every blocking
/// strategy (sync / local / elastic); parked workers are untouched.
pub(crate) fn charge_blocking_exchange(
    eng: &mut engine::Engine,
    ctx: &TrainContext,
    full_comm_t: f64,
) {
    charge_blocking_exchange_bytes(eng, ctx, full_comm_t, ctx.cluster.message_bytes);
}

/// [`charge_blocking_exchange`] at an explicit wire size — the compressed
/// strategy paths pass their scaled payload so the survivor-shaped cost
/// formulas see compressed bytes (DESIGN.md §12).
pub(crate) fn charge_blocking_exchange_bytes(
    eng: &mut engine::Engine,
    ctx: &TrainContext,
    full_comm_t: f64,
    message_bytes: usize,
) {
    if eng.fault.alive.is_full() {
        eng.clocks.barrier();
        for w in 0..eng.workers.m {
            eng.clocks.comm_blocked(w, full_comm_t);
        }
    } else {
        let comm_t = ctx.cluster.topology.collective_time_alive(
            &ctx.cluster.net,
            message_bytes,
            &eng.fault.alive,
        );
        eng.clocks.barrier_among(eng.fault.alive.members());
        for &w in eng.fault.alive.members() {
            eng.clocks.comm_blocked(w, comm_t);
        }
    }
}

/// Run the configured algorithm to completion: pick its mixing strategy and
/// hand it to the round engine (no driver keeps a private round loop).
pub fn run(ctx: &TrainContext) -> Result<TrainLog> {
    // The gossip graph is an *inexact* per-round mix: only the push-sum
    // decentralized strategy knows how to de-bias it. Every exact-collective
    // algorithm must refuse it loudly instead of averaging wrong — and the
    // mismatch in the other direction is just as loud: overlap-gossip never
    // silently discards an explicitly requested exact topology (the default
    // ring is the one exception, standing in for "derive a gossip graph
    // from --gossip-degree").
    match (ctx.cluster.topology.kind, ctx.cfg.algo) {
        (TopologyKind::Gossip, algo) if algo != Algo::OverlapGossip => bail!(
            "topology 'gossip' is an inexact mixing graph; only --algo overlap-gossip \
             can use it (got --algo {})",
            algo.name()
        ),
        (kind, Algo::OverlapGossip)
            if kind != TopologyKind::Gossip && kind != TopologyKind::Ring =>
        {
            bail!(
                "--algo overlap-gossip runs on the gossip topology; got --topology {} \
                 (use 'gossip', or omit the flag to derive a graph from --gossip-degree)",
                kind.name()
            )
        }
        _ => {}
    }
    // `--algo powersgd` is the compression axis spelled as an algorithm:
    // it is exactly `--algo sync --compress powersgd` (bit-identical
    // schedule, DESIGN.md §12), so an explicit conflicting --compress is a
    // contradiction worth refusing loudly.
    if ctx.cfg.algo == Algo::PowerSgd
        && !matches!(ctx.cfg.compress, CompressKind::None | CompressKind::PowerSgd)
    {
        bail!(
            "--algo powersgd already selects --compress powersgd; it cannot run under \
             --compress {} (use --algo sync to combine sync with that compressor)",
            ctx.cfg.compress.name()
        );
    }
    if ctx.cfg.compress == CompressKind::PowerSgd || ctx.cfg.algo == Algo::PowerSgd {
        anyhow::ensure!(ctx.cfg.rank >= 1, "powersgd compression needs rank >= 1");
    }
    match ctx.cfg.algo {
        Algo::Sync => engine::run(ctx, &mut sync::SyncStrategy::new(ctx)),
        Algo::PowerSgd => {
            // Re-express the legacy spelling on the compression seam: the
            // per-worker error-feedback residuals are engine state with a
            // rejoin protocol (zero residual, warm-start from the shared
            // basis), so faults compose instead of being refused.
            let mut cfg = ctx.cfg.clone();
            cfg.compress = CompressKind::PowerSgd;
            let scoped = TrainContext {
                rt: ctx.rt,
                cfg: &cfg,
                cluster: ctx.cluster.clone(),
                schedule: ctx.schedule.clone(),
                train: ctx.train,
                test: ctx.test,
                shards: ctx.shards.clone(),
            };
            // The log still reports algo "powersgd": only `compress`
            // changed, and the recorder names the algo from the config.
            engine::run(&scoped, &mut sync::SyncStrategy::new(&scoped))
        }
        Algo::Local => engine::run(ctx, &mut local::LocalAvgStrategy::new(ctx)),
        Algo::Overlap => engine::run(ctx, &mut overlap::OverlapStrategy::new(ctx, 0.0, false)),
        Algo::OverlapM => {
            engine::run(ctx, &mut overlap::OverlapStrategy::new(ctx, ctx.cfg.beta, false))
        }
        Algo::OverlapAda => {
            engine::run(ctx, &mut overlap::OverlapStrategy::new(ctx, ctx.cfg.beta, true))
        }
        Algo::OverlapGossip => {
            engine::run(ctx, &mut gossip::GossipStrategy::new(ctx)?)
        }
        Algo::Easgd => elastic::run(ctx, 0.0),
        Algo::Eamsgd => elastic::run(ctx, ctx.cfg.mu),
        Algo::Cocod => engine::run(ctx, &mut cocod::CocodStrategy::new()),
    }
}

/// Convenience: build shards per the config's IID / non-IID setting.
pub fn make_shards(cfg: &ExperimentConfig, train: &Dataset) -> Vec<Vec<u32>> {
    let mut rng = Rng::stream(cfg.seed, "partition");
    if cfg.noniid {
        crate::data::partition_noniid(&train.labels, cfg.workers, cfg.dominant_frac, &mut rng)
    } else {
        crate::data::partition_iid(train.n, cfg.workers, &mut rng)
    }
}

/// Assemble a context, run, and return the log — the one-call entrypoint
/// used by the CLI, examples, and benches.
pub fn run_experiment(
    rt: &ModelRuntime,
    cfg: &ExperimentConfig,
    train: &Dataset,
    test: &Dataset,
) -> Result<TrainLog> {
    // Resolve the population axis first (`workers` normalizes to the
    // cohort size; invalid compositions are refused before any state
    // exists). With `population = 0` this clone is bit-inert.
    let cfg = &cfg.resolved()?;
    let shards = make_shards(cfg, train);
    let steps_per_epoch = (shards[0].len() / rt.train_batch).max(1);
    let cluster = cfg.cluster(rt.n * 4)?;
    let schedule = LrSchedule::paper_scaled(cfg.base_lr, cfg.epochs, steps_per_epoch);
    let ctx = TrainContext { rt, cfg, cluster, schedule, train, test, shards };
    run(&ctx)
}
