//! EASGD / EAMSGD baseline (Zhang, Choromanska, LeCun 2015 [19]) as an
//! engine strategy.
//!
//! The ancestor of the paper's pullback idea: local models and a center
//! variable z exchange *symmetrically* every τ steps,
//!
//! ```text
//!   x_i ← (1 − α) x_i + α z          (local pull toward center)
//!   z   ← (1 − α) z  + α · avg(x)    (center pull toward the average)
//! ```
//!
//! using the *pre-update* values on both sides (one simultaneous elastic
//! exchange — the symmetric doubly-stochastic mixing the paper contrasts
//! its column-stochastic W against). Unlike Overlap-Local-SGD the exchange
//! is **blocking**: the center update needs the fresh average before anyone
//! proceeds, so stragglers and wire time hit the critical path.
//!
//! EAMSGD is the same schedule with local Nesterov momentum (`mu` > 0);
//! `mu = 0` gives plain EASGD. The paper's Tables 1–2 show this family
//! degrading fastest as τ grows — the center lags too far behind.

use anyhow::Result;

use super::engine::{self, plan_tau, Engine, MixingStrategy, RoundOutcome, RoundPlan};
use super::{
    account_collective_among, charge_blocking_exchange, charge_blocking_exchange_bytes,
    TrainContext,
};
use crate::compress::{wire_plan, WirePlan};
use crate::metrics::TrainLog;
use crate::model::vecmath;

/// Blocking symmetric elastic exchange every τ steps. The exchange cost
/// follows the configured exact topology; the center average itself is the
/// exact mean (which every exact topology produces). Under `--compress`
/// each member transmits its compressed delta against the center z (with
/// error feedback) and the center pulls toward the mean of the
/// reconstructed contributions, at the compressed wire size.
pub struct ElasticStrategy {
    comm_t: f64,
    /// compressed wire size + FLOP scaling; `None` for `--compress none`
    wire: Option<WirePlan>,
    /// center variable, same init as the replicas
    z: Vec<f32>,
}

impl ElasticStrategy {
    /// Strategy with the per-round exchange cost precomputed; the center
    /// variable initializes at `on_run_start`.
    pub fn new(ctx: &TrainContext) -> Self {
        let wire = wire_plan(ctx.cfg, &ctx.rt.manifest, ctx.cluster.message_bytes);
        let comm_t = match &wire {
            Some(w) => ctx.cluster.topology.collective_time(&ctx.cluster.net, w.scaled_bytes),
            None => ctx.cluster.collective_time(),
        };
        Self { comm_t, wire, z: Vec::new() }
    }
}

impl MixingStrategy for ElasticStrategy {
    fn on_run_start(&mut self, eng: &mut Engine, _ctx: &TrainContext) -> Result<()> {
        self.z = eng.workers.params[0].clone();
        Ok(())
    }

    fn plan(&mut self, eng: &Engine, ctx: &TrainContext) -> RoundPlan {
        plan_tau(eng, ctx, ctx.cfg.tau)
    }

    fn on_rejoin(
        &mut self,
        eng: &mut Engine,
        _ctx: &TrainContext,
        w: usize,
        _src: usize,
    ) -> Result<()> {
        // The elastic family's center variable z is its anchor: the state
        // every replica is being pulled toward — the natural warm start.
        eng.workers.warm_start(w, &self.z);
        Ok(())
    }

    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, _out: RoundOutcome) -> Result<()> {
        let m = eng.workers.m;
        let alpha = ctx.cfg.alpha;
        if self.wire.is_some() {
            // Compressed round: members encode their delta vs the center z
            // (error feedback in `cs`); the center pulls toward the mean
            // of the reconstructed contributions. The symmetric local pull
            // stays the plain Eq. 4 toward the *current* z — the exchange
            // is blocking, so there is no staleness to correct.
            let mut cs = eng.compress.take().expect("wire plan implies compress state");
            let members: Vec<usize> = eng.fault.alive.members().to_vec();
            for &w in &members {
                let flops = cs.encode_param(w, &eng.workers.params[w], &self.z);
                eng.clocks.compute(w, cs.encode_time(flops));
            }
            charge_blocking_exchange_bytes(eng, ctx, self.comm_t, cs.scaled_bytes);
            let mut avg = eng.exec.buffers().take_for_overwrite(ctx.rt.n);
            {
                let refs: Vec<&[f32]> =
                    members.iter().map(|&w| cs.contrib[w].as_slice()).collect();
                eng.exec.mean_into(&refs, &mut avg);
            }
            for w in 0..m {
                if !eng.fault.alive.steps(w) {
                    continue;
                }
                vecmath::pullback_inplace(&mut eng.workers.params[w], &self.z, alpha);
            }
            vecmath::axpby(alpha, &avg, 1.0 - alpha, &mut self.z);
            eng.exec.buffers().put(avg);
            account_collective_among(
                &mut eng.rec,
                &ctx.cluster.topology,
                cs.scaled_bytes,
                &eng.fault.alive,
            );
            eng.compress = Some(cs);
            return Ok(());
        }
        // Blocking elastic exchange (over the alive members under faults —
        // parked workers neither barrier nor feed the center).
        charge_blocking_exchange(eng, ctx, self.comm_t);
        // Center average (over the members) into a pooled buffer, through
        // the executor's mean (serial on sim; chunked over the parked pool
        // threads on the threads backend — bit-identical either way, so
        // the digest cannot see the backend). With a full alive set the
        // member list is every worker, so this is the legacy average.
        let mut avg = eng.exec.buffers().take_for_overwrite(ctx.rt.n);
        {
            let refs: Vec<&[f32]> = eng
                .fault
                .alive
                .members()
                .iter()
                .map(|&w| eng.workers.params[w].as_slice())
                .collect();
            eng.exec.mean_into(&refs, &mut avg);
        }
        // Simultaneous symmetric update (pre-update values on both sides).
        for w in 0..m {
            if !eng.fault.alive.steps(w) {
                continue; // parked: frozen replica
            }
            vecmath::pullback_inplace(&mut eng.workers.params[w], &self.z, alpha);
        }
        vecmath::axpby(alpha, &avg, 1.0 - alpha, &mut self.z);
        eng.exec.buffers().put(avg);
        account_collective_among(
            &mut eng.rec,
            &ctx.cluster.topology,
            ctx.cluster.message_bytes,
            &eng.fault.alive,
        );
        Ok(())
    }
}

/// Run EASGD (`mu = 0`) or EAMSGD (`mu > 0`). The local momentum is the only
/// difference from the surrounding algorithms; a scoped config clone keeps
/// `Workers::local_step` uniform.
pub fn run(ctx: &TrainContext, mu: f32) -> Result<TrainLog> {
    let mut cfg = ctx.cfg.clone();
    cfg.mu = mu;
    let scoped = TrainContext {
        rt: ctx.rt,
        cfg: &cfg,
        cluster: ctx.cluster.clone(),
        schedule: ctx.schedule.clone(),
        train: ctx.train,
        test: ctx.test,
        shards: ctx.shards.clone(),
    };
    let mut strategy = ElasticStrategy::new(&scoped);
    engine::run(&scoped, &mut strategy)
}
