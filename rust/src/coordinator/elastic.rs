//! EASGD / EAMSGD baseline (Zhang, Choromanska, LeCun 2015 [19]).
//!
//! The ancestor of the paper's pullback idea: local models and a center
//! variable z exchange *symmetrically* every τ steps,
//!
//! ```text
//!   x_i ← (1 − α) x_i + α z          (local pull toward center)
//!   z   ← (1 − α) z  + α · avg(x)    (center pull toward the average)
//! ```
//!
//! using the *pre-update* values on both sides (one simultaneous elastic
//! exchange — the symmetric doubly-stochastic mixing the paper contrasts
//! its column-stochastic W against). Unlike Overlap-Local-SGD the exchange
//! is **blocking**: the center update needs the fresh average before anyone
//! proceeds, so stragglers and wire time hit the critical path.
//!
//! EAMSGD is the same schedule with local Nesterov momentum (`mu` > 0);
//! `mu = 0` gives plain EASGD. The paper's Tables 1–2 show this family
//! degrading fastest as τ grows — the center lags too far behind.

use anyhow::Result;

use super::{Recorder, TrainContext, Workers};
use crate::clock::Clocks;
use crate::metrics::TrainLog;
use crate::model::vecmath;

pub fn run(ctx: &TrainContext, mu: f32) -> Result<TrainLog> {
    let m = ctx.cfg.workers;
    let tau = ctx.cfg.tau.max(1);
    let alpha = ctx.cfg.alpha;
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();
    let comm_t = ctx.cluster.allreduce_time();

    // Center variable, same init as the replicas.
    let mut z = workers.params[0].clone();

    // EASGD/EAMSGD differ from the surrounding algorithms only in mu; a
    // scoped config clone keeps Workers::local_step uniform.
    let mut cfg = ctx.cfg.clone();
    cfg.mu = mu;
    let ctx = TrainContext {
        rt: ctx.rt,
        cfg: &cfg,
        cluster: ctx.cluster.clone(),
        schedule: ctx.schedule.clone(),
        train: ctx.train,
        test: ctx.test,
        shards: ctx.shards.clone(),
    };
    let ctx = &ctx;

    let mut k = 0;
    while k < total {
        let steps = tau.min(total - k);
        let mut loss_sum = 0.0;
        let mut loss_n = 0;
        for w in 0..m {
            for s in 0..steps {
                loss_sum += workers.local_step(w, ctx, &mut clocks, k + s)?;
                loss_n += 1;
            }
        }
        k += steps;

        // Blocking elastic exchange.
        clocks.barrier();
        for w in 0..m {
            clocks.comm_blocked(w, comm_t);
        }
        let avg = workers.mean_params();
        // Simultaneous symmetric update (pre-update values on both sides).
        for w in 0..m {
            vecmath::pullback_inplace(&mut workers.params[w], &z, alpha);
        }
        vecmath::axpby(alpha, &avg, 1.0 - alpha, &mut z);
        rec.add_bytes((m * ctx.cluster.message_bytes) as u64);

        rec.push_loss(k - 1, loss_sum / loss_n as f64);
        rec.maybe_eval(k, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}
