//! **Overlap-Local-SGD** — the paper's contribution (Eqs. 3–5, 10–11).
//!
//! Each node keeps a local model x_i and an *anchor* z (a stale synchronized
//! average, identical on every node). The round-r boundary does, in order:
//!
//! 1. *absorb* the all-reduce launched at boundary r-1 (waiting only if it
//!    hasn't finished — with τ large enough it has, and the wait is zero:
//!    communication fully hidden behind the τ local steps);
//! 2. update the anchor from the arrived average — vanilla assignment
//!    (Eq. 5, `beta = 0`) or the momentum form (Eqs. 10–11);
//! 3. *pull back* every local model toward the anchor (Eq. 4,
//!    `x ← x − α(x − z)`) — pure local math, no communication;
//! 4. launch the next non-blocking all-reduce over the post-pullback models.
//!
//! There is **no barrier anywhere**: a straggler delays only the moment the
//! *collective* completes (it is the last to contribute), never the other
//! workers' compute — the paper's straggler-mitigation claim, which E9
//! measures.
//!
//! The pullback and anchor updates run through the AOT Pallas artifacts
//! (Layer 1 on the hot path); their virtual-time cost is charged at HBM
//! bandwidth (they are single-pass elementwise kernels).

use anyhow::Result;

use super::{Recorder, TrainContext, Workers};
use crate::clock::Clocks;
use crate::collective::{start_allreduce, NonBlockingAllReduce};
use crate::metrics::TrainLog;

/// Virtual cost of one fused elementwise pass over the paper-size model
/// (44.7 MB / ~500 GB/s HBM ≈ 0.1 ms) — negligible but accounted.
const PULLBACK_S: f64 = 1e-4;

pub fn run(ctx: &TrainContext, beta: f32) -> Result<TrainLog> {
    let m = ctx.cfg.workers;
    let tau = ctx.cfg.tau.max(1);
    let alpha = ctx.cfg.alpha;
    let mut workers = Workers::new(ctx);
    let mut clocks = Clocks::new(m);
    let mut rec = Recorder::new(ctx);
    let total = ctx.total_steps();

    // Anchor state: z starts at the common init (paper: x_0^(i) = z_0);
    // v is the anchor momentum buffer (Eq. 10), zero-initialized.
    let mut z = workers.params[0].clone();
    let mut v = vec![0.0f32; ctx.rt.n];
    let mut pending: Option<NonBlockingAllReduce> = None;

    let mut k = 0;
    while k < total {
        // --- τ local steps per worker, fully asynchronous ----------------
        let steps = tau.min(total - k);
        let mut loss_sum = 0.0;
        let mut loss_n = 0;
        for w in 0..m {
            for s in 0..steps {
                loss_sum += workers.local_step(w, ctx, &mut clocks, k + s)?;
                loss_n += 1;
            }
        }
        k += steps;

        // --- absorb the previous round's collective (Eq. 5 / 10-11) ------
        if let Some(h) = pending.take() {
            // Each worker independently waits until the anchor is ready; if
            // the wire finished during the τ steps this is a no-op.
            for w in 0..m {
                clocks.wait_comm_until(w, h.ready_at());
            }
            let (z2, v2) = ctx.rt.anchor_update(&z, &v, &h.result, beta)?;
            z = z2;
            v = v2;
        }

        // --- pullback (Eq. 4), local on every node ------------------------
        for w in 0..m {
            workers.params[w] = ctx.rt.pullback(&workers.params[w], &z, alpha)?;
            clocks.compute(w, PULLBACK_S);
        }

        // --- launch the next non-blocking all-reduce ----------------------
        // The ring effectively starts once the last participant joins.
        let start = (0..m).map(|w| clocks.now(w)).fold(0.0, f64::max);
        let refs: Vec<&[f32]> = workers.params.iter().map(|p| p.as_slice()).collect();
        pending = Some(start_allreduce(
            &refs,
            &ctx.cluster.net,
            ctx.cluster.message_bytes,
            start,
        ));
        rec.add_bytes((m * ctx.cluster.message_bytes) as u64);

        rec.push_loss(k - 1, loss_sum / loss_n as f64);
        rec.maybe_eval(k, ctx, &workers, &clocks)?;
    }
    rec.force_eval(total, ctx, &workers, &clocks)?;
    Ok(rec.finish(ctx, &clocks, total))
}
