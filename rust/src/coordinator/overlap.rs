//! **Overlap-Local-SGD** — the paper's contribution (Eqs. 3–5, 10–11) — as
//! an engine strategy, plus the AdaComm-style adaptive-τ controller.
//!
//! Each node keeps a local model x_i and an *anchor* z (a stale synchronized
//! average, identical on every node). The round-r mixing decision does, in
//! order:
//!
//! 1. *absorb* the all-reduce launched at boundary r-1 (waiting only if it
//!    hasn't finished — with τ large enough it has, and the wait is zero:
//!    communication fully hidden behind the τ local steps);
//! 2. update the anchor from the arrived average — vanilla assignment
//!    (Eq. 5, `beta = 0`) or the momentum form (Eqs. 10–11);
//! 3. *pull back* every local model toward the anchor (Eq. 4,
//!    `x ← x − α(x − z)`) — pure local math, no communication;
//! 4. launch the next non-blocking all-reduce over the post-pullback models.
//!
//! There is **no barrier anywhere**: a straggler delays only the moment the
//! *collective* completes (it is the last to contribute), never the other
//! workers' compute — the paper's straggler-mitigation claim, which E9
//! measures. Heterogeneous τ (`tau_hetero`) tightens this further by giving
//! the straggler a shorter local burst.
//!
//! The pullback and anchor updates run through the runtime's fused kernels
//! (Layer 1 on the hot path); their virtual-time cost is charged at HBM
//! bandwidth (they are single-pass elementwise kernels).
//!
//! **Adaptive τ** (`--algo overlap-ada`, AdaComm: Wang & Joshi 2018): the
//! best error-runtime trade-off needs a τ that *varies* during training —
//! large early (cheap, fast progress per wall-second), small late (tight
//! consensus). The controller starts at the configured τ and halves it,
//! down to `tau_min`, whenever the round-mean loss has not improved by
//! `ada_threshold` (relative) for `ada_patience` consecutive rounds. τ is
//! monotone non-increasing, so total communication (rounds, hence bytes and
//! potential blocking) never exceeds a fixed run at τ = `tau_min`.

use anyhow::Result;

use super::engine::{plan_tau, Engine, MixingStrategy, PULLBACK_S, RoundOutcome, RoundPlan};
use super::{account_collective_among, TrainContext};
use crate::collective::{launch_collective_among, PendingCollective};

/// Loss-plateau τ controller (AdaComm-style, shrink-only).
#[derive(Clone, Debug)]
pub struct AdaptiveTau {
    tau_min: usize,
    patience: usize,
    threshold: f64,
    best: f64,
    stall: usize,
}

impl AdaptiveTau {
    /// Controller from the config's `tau_min` / `ada_*` knobs.
    pub fn new(ctx: &TrainContext) -> Self {
        Self {
            tau_min: ctx.cfg.tau_min.max(1),
            patience: ctx.cfg.ada_patience.max(1),
            threshold: ctx.cfg.ada_threshold,
            best: f64::INFINITY,
            stall: 0,
        }
    }

    /// Feed one round-mean loss; returns the τ for the next round.
    pub fn observe(&mut self, loss: f64, tau: usize) -> usize {
        if !loss.is_finite() {
            return tau;
        }
        if loss < self.best * (1.0 - self.threshold) {
            self.best = loss;
            self.stall = 0;
        } else {
            self.stall += 1;
            if self.stall >= self.patience && tau > self.tau_min {
                self.stall = 0;
                return (tau / 2).max(self.tau_min);
            }
        }
        tau
    }
}

/// Pullback-to-stale-anchor mixing with a non-blocking collective.
pub struct OverlapStrategy {
    beta: f32,
    /// current τ (constant unless the adaptive controller shrinks it)
    tau: usize,
    adaptive: Option<AdaptiveTau>,
    z: Vec<f32>,
    v: Vec<f32>,
    pending: Option<PendingCollective>,
}

impl OverlapStrategy {
    /// `beta = 0` gives the vanilla anchor update (Eq. 5); the paper's
    /// headline algorithm uses the momentum form (Eqs. 10–11). `adaptive`
    /// enables the AdaComm-style τ controller (`--algo overlap-ada`).
    pub fn new(ctx: &TrainContext, beta: f32, adaptive: bool) -> Self {
        Self {
            beta,
            tau: ctx.cfg.tau.max(1),
            adaptive: if adaptive { Some(AdaptiveTau::new(ctx)) } else { None },
            z: Vec::new(),
            v: Vec::new(),
            pending: None,
        }
    }
}

impl MixingStrategy for OverlapStrategy {
    fn on_run_start(&mut self, eng: &mut Engine, ctx: &TrainContext) -> Result<()> {
        // Anchor state: z starts at the common init (paper: x_0^(i) = z_0);
        // v is the anchor momentum buffer (Eq. 10), zero-initialized.
        self.z = eng.workers.params[0].clone();
        self.v = vec![0.0f32; ctx.rt.n];
        if self.adaptive.is_some() {
            eng.rec.note_tau(0, self.tau);
        }
        Ok(())
    }

    fn plan(&mut self, eng: &Engine, ctx: &TrainContext) -> RoundPlan {
        plan_tau(eng, ctx, self.tau)
    }

    fn on_rejoin(
        &mut self,
        eng: &mut Engine,
        _ctx: &TrainContext,
        w: usize,
        _src: usize,
    ) -> Result<()> {
        // The paper's warm start: the anchor z is exactly the state every
        // survivor's pullback is contracting toward — the right consensus
        // snapshot for a returning worker (DESIGN.md §11). Local momentum
        // restarts from zero, as at run start.
        eng.workers.warm_start(w, &self.z);
        Ok(())
    }

    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, out: RoundOutcome) -> Result<()> {
        let m = eng.workers.m;
        // Split the compression seam off the engine for the duration of the
        // mixing decision (disjoint borrows); restored before returning.
        let mut cs_opt = eng.compress.take();

        // --- absorb the previous round's collective (Eq. 5 / 10-11) ------
        if let Some(h) = self.pending.take() {
            // Join the communicator (threads backend) / take the eager
            // result (sim), then each worker independently waits on the
            // virtual timeline until the anchor is ready; if the wire
            // finished during the τ steps that wait is a no-op. Under
            // faults only the stepping workers wait — a crashed worker's
            // clock stays frozen — and the survivor mean is still the
            // exact anchor target. The anchor update runs in place
            // (bit-identical to the allocating form) and the absorbed
            // average goes back into the buffer pool — the return half of
            // the zero-allocation steady state.
            let avg = h.absorb_masked(&mut eng.clocks, &eng.fault.alive);
            ctx.rt.anchor_update_inplace(&mut self.z, &mut self.v, &avg, self.beta)?;
            eng.exec.buffers().put(avg);
        }

        // --- pullback (Eq. 4), local on every stepping node ---------------
        // Compressed runs use the delay-corrected form (LOSCAR-style,
        // DESIGN.md §12): contract by the gap the absorbed average actually
        // measured — α(x_launch − z) with the launch-time snapshot — so the
        // staleness the sparse/quantized mask introduces is corrected at
        // pullback without discarding the τ local steps since launch.
        for w in 0..m {
            if !eng.fault.alive.steps(w) {
                continue; // parked: frozen replica, frozen clock
            }
            if let Some(cs) = cs_opt.as_mut() {
                cs.pullback(w, &mut eng.workers.params[w], &self.z, ctx.cfg.alpha);
            } else {
                ctx.rt.pullback_inplace(&mut eng.workers.params[w], &self.z, ctx.cfg.alpha)?;
            }
            eng.clocks.compute(w, PULLBACK_S);
        }

        // --- launch the next non-blocking collective ----------------------
        // An exact collective effectively starts once the last participant
        // joins (the topology axis changes the wire cost, not the rendezvous
        // — only overlap-gossip drops the global rendezvous). On the threads
        // backend the launch dispatches to the pool's parked communicator
        // thread, which the τ local steps of the NEXT round genuinely
        // overlap; its snapshot reuses pooled buffers. Under faults only
        // the alive set's members contribute (a frozen clock never sets
        // the start time), the reduce runs the survivor sub-schedule, and
        // the wire cost is the survivor-shaped formula.
        if let Some(cs) = cs_opt.as_mut() {
            // Compressed launch: each member encodes its post-pullback
            // model against the anchor (the reference every receiver
            // holds), records its launch snapshot for the next boundary's
            // delay-corrected pullback, and the collective reduces the
            // reconstructed contributions at the compressed wire size.
            let members: Vec<usize> = eng.fault.alive.members().to_vec();
            for &w in &members {
                let flops = cs.encode_param(w, &eng.workers.params[w], &self.z);
                eng.clocks.compute(w, cs.encode_time(flops));
                cs.note_launch(w, &eng.workers.params[w]);
            }
            let start = eng.launch_clock();
            let refs: Vec<&[f32]> = cs.contrib.iter().map(|p| p.as_slice()).collect();
            self.pending = Some(launch_collective_among(
                &eng.exec,
                &ctx.cluster.topology,
                &refs,
                &eng.fault.alive,
                &ctx.cluster.net,
                cs.scaled_bytes,
                start,
            ));
            account_collective_among(
                &mut eng.rec,
                &ctx.cluster.topology,
                cs.scaled_bytes,
                &eng.fault.alive,
            );
        } else {
            let start = eng.launch_clock();
            let refs: Vec<&[f32]> = eng.workers.params.iter().map(|p| p.as_slice()).collect();
            self.pending = Some(launch_collective_among(
                &eng.exec,
                &ctx.cluster.topology,
                &refs,
                &eng.fault.alive,
                &ctx.cluster.net,
                ctx.cluster.message_bytes,
                start,
            ));
            account_collective_among(
                &mut eng.rec,
                &ctx.cluster.topology,
                ctx.cluster.message_bytes,
                &eng.fault.alive,
            );
        }
        eng.compress = cs_opt;

        // --- adaptive-τ controller ---------------------------------------
        if let Some(ada) = self.adaptive.as_mut() {
            let next = ada.observe(out.mean_loss, self.tau);
            if next != self.tau {
                self.tau = next;
                eng.rec.note_tau(eng.k, next);
            }
        }
        Ok(())
    }
}
