//! Discrete-event round engine — the shared schedule machinery every
//! algorithm plugs into.
//!
//! The paper's whole contribution is a *schedule*: how τ local steps, the
//! collective, and the mixing step interleave on the virtual timeline. The
//! engine owns everything the schedules share — the per-worker event
//! timeline (local steps → mixing decision → eval cadence), the `Clocks`,
//! the `Recorder`, loss aggregation, and the global step counter — and
//! delegates only the *mixing decision* to a [`MixingStrategy`] (one impl
//! per algorithm, matching the mixing-matrix framing of Eq. 8; see the
//! driver table in `mod.rs` / DESIGN.md §4).
//!
//! One round on the engine's timeline:
//!
//! ```text
//!   before_local   (CoCoD launches its non-blocking collective here)
//!   plan           (steps per worker: uniform τ, adaptive τ, or hetero-τ)
//!   local phase    (fused optimizer steps, or one gradient for sync-family)
//!   mix            (absorb pending collective / barrier+all-reduce / pullback)
//!   record         (round loss, eval cadence)
//! ```
//!
//! Two scenario axes the old per-driver lockstep loops could not express
//! live here as *plans*:
//!
//! * **adaptive τ** (AdaComm, Wang & Joshi 2018): start with a large τ and
//!   shrink it on a loss-plateau signal — see `overlap.rs::AdaptiveTau`,
//!   exposed as `--algo overlap-ada`;
//! * **heterogeneous τ** (paper §straggler mitigation): [`hetero_plan`]
//!   scales each worker's per-round step count by its *observed* step rate,
//!   so a straggler runs fewer local steps and every worker reaches the
//!   round boundary at ≈ the same virtual time (E9).

use anyhow::{ensure, Context as _, Result};

use super::{Recorder, TrainContext, Workers};
use crate::clock::Clocks;
use crate::compress::{CompressKind, CompressState};
use crate::executor::{ExecSnapshot, Executor};
use crate::fault::{FaultEvent, FaultPlan, FaultState};
use crate::metrics::{HotPathCounters, TrainLog};

/// Virtual cost of one fused elementwise pass over the paper-size model
/// (44.7 MB / ~500 GB/s HBM ≈ 0.1 ms) — negligible but accounted. Charged
/// for the pullback/anchor math at round boundaries.
pub const PULLBACK_S: f64 = 1e-4;

/// Rounds counted as warm-up before the steady-state window of the
/// hot-path counters (`TrainLog::hot`). Two rounds prime every pooled
/// path: round 1 allocates the collective snapshot buffers (the pool is
/// empty), round 2 is the first whose absorb returns them — from then on
/// launches must hit the free list and the executor must spawn nothing
/// (hard-asserted by `rust/tests/hot_path.rs`).
pub const WARMUP_ROUNDS: usize = 2;

/// How the engine drives workers during a round's local phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalPhase {
    /// τ fused optimizer steps per worker (the Local-SGD family).
    FusedSteps,
    /// One gradient computation per worker, no local update — the strategy
    /// applies the averaged update itself (sync / PowerSGD family).
    GradOnly,
}

/// Per-round work assignment produced by a strategy's `plan`.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    /// Local steps for each worker this round (each in `[1, advance]`,
    /// enforced by [`run`]).
    pub steps: Vec<usize>,
    /// How far the global step counter advances (the nominal τ, capped by
    /// the steps remaining; `steps[w] <= advance` for every worker).
    pub advance: usize,
}

/// What the local phase produced, handed to the mixing decision.
pub struct RoundOutcome {
    /// Global step index at the round start.
    pub start_step: usize,
    /// Steps actually taken per worker.
    pub steps: Vec<usize>,
    /// Per-worker raw gradients (`GradOnly` phase only, in worker order).
    pub grads: Vec<Vec<f32>>,
    /// Mean mini-batch loss over all local steps of the round.
    pub mean_loss: f64,
}

/// Engine-owned mutable run state: replicas, clocks, recorder, counters.
/// Strategies receive `&mut Engine` and touch exactly these — no driver
/// keeps private copies of the shared infrastructure.
pub struct Engine {
    /// per-worker training state (replicas, batchers, RNG streams)
    pub workers: Workers,
    /// per-worker virtual clocks
    pub clocks: Clocks,
    /// loss/eval/byte recorder
    pub rec: Recorder,
    /// Global step counter (completed steps of the nominal schedule).
    pub k: usize,
    /// Total global steps in the run.
    pub total: usize,
    /// Completed rounds.
    pub round: usize,
    /// Per-worker completed local steps (diverges from `k` under hetero-τ).
    pub steps_done: Vec<usize>,
    /// Execution backend object (from `cfg.execution`): runs the local
    /// phase and dispatches reduction jobs — inline on `sim`, on the
    /// persistent worker pool on `threads` — and owns the run's recycled
    /// hot-path memory (`executor::Executor`, DESIGN.md §10). Strategies
    /// launch their collectives through it (`collective::launch_collective`
    /// / `Executor::start_reduce`) and recycle absorbed result buffers into
    /// `exec.buffers()`.
    pub exec: Executor,
    /// Fault-injection replay state (DESIGN.md §11): the configured
    /// crash/rejoin/partition schedule plus the cluster's current
    /// [`crate::fault::AliveSet`]. The engine applies due events at every
    /// round boundary ([`run`]); strategies consult `fault.alive` for their
    /// masked collective/pullback paths. With no faults configured every
    /// consumer takes its pre-fault branch, so the empty-schedule digests
    /// are bit-identical to the pre-fault engine.
    pub fault: FaultState,
    /// Compression seam state (DESIGN.md §12): per-worker error-feedback
    /// residuals, contribution buffers, launch snapshots, and the
    /// compressor itself — `None` for `--compress none`, so every
    /// uncompressed strategy path stays bit-identical to the pre-seam
    /// engine. Rejoiners are reset here (residual zeroed, warm-start basis
    /// restored) before the strategy's own `on_rejoin` runs.
    pub compress: Option<CompressState>,
    /// Population axis (DESIGN.md §14): when `cfg.population > 0` the m
    /// slots are *machines*, each bound per round to one of N registered
    /// workers by the deterministic cohort sampler; unbound worker state
    /// lives in the O(k) LRU store. `None` (axis off) leaves every path
    /// above bit-identical to the dense engine. Fault events then replay
    /// over population ids ([`crate::fault::PopulationFaults`]): a
    /// crashed id leaves the sampling pool, and each round
    /// [`bind_population_round`] *projects* the id-level down/partition
    /// state onto the cohort's slots — so [`Engine::fault`] is built with
    /// an empty plan and zero rates (id-level sources own the events),
    /// but its [`crate::fault::AliveSet`] still carries the per-round
    /// slot view the strategies' masked collectives consume.
    pub population: Option<crate::population::PopulationState>,
}

impl Engine {
    /// Fresh engine state for one run; the execution backend comes from
    /// the config's `execution` mode. Fallible because the `net` backend
    /// binds its socket and waits for the worker fleet here.
    pub fn new(ctx: &TrainContext) -> Result<Self> {
        let workers = Workers::new(ctx);
        let m = workers.m;
        // Compression state is built before the population axis so fresh
        // population workers can materialize with the compressor's shared
        // PowerSGD basis template.
        let compress =
            CompressState::build(ctx.cfg, &ctx.rt.manifest, ctx.cluster.message_bytes);
        let population = crate::population::PopulationState::build(
            ctx,
            compress.as_ref().and_then(|cs| cs.powersgd_qs_init()),
        )?;
        // In population mode every fault source — the explicit plan *and*
        // the `fault_rate`/`rejoin_rate` random process — replays over
        // population ids inside `PopulationState`; the slot-level machine
        // is built inert (empty plan, zero rates) and its alive set is
        // driven per round by the cohort projection in
        // [`bind_population_round`].
        let (slot_plan, slot_rate, slot_rejoin) = if population.is_some() {
            (FaultPlan { events: Vec::new() }, 0.0, 0.0)
        } else {
            (ctx.cfg.fault.clone(), ctx.cfg.fault_rate, ctx.cfg.rejoin_rate)
        };
        Ok(Self {
            workers,
            clocks: Clocks::new(m),
            rec: Recorder::new(ctx),
            k: 0,
            total: ctx.total_steps(),
            round: 0,
            steps_done: vec![0; m],
            exec: Executor::from_config(ctx.cfg)?,
            fault: FaultState::new(&slot_plan, slot_rate, slot_rejoin, ctx.cfg.seed, m),
            compress,
            population,
        })
    }

    /// Steps remaining on the nominal schedule.
    pub fn remaining(&self) -> usize {
        self.total - self.k
    }

    /// Virtual time the next collective effectively starts: the latest
    /// clock among this round's *stepping* workers — a crashed or parked
    /// worker's frozen clock never gates a launch (DESIGN.md §11). Equals
    /// `clocks.max_now()` bit-for-bit when the alive set is full.
    pub fn launch_clock(&self) -> f64 {
        (0..self.workers.m).fold(0.0f64, |t, w| {
            if self.fault.alive.steps(w) {
                t.max(self.clocks.now(w))
            } else {
                t
            }
        })
    }
}

/// The mixing decision — the only thing that differs between algorithms
/// (the mixing matrix W_k of Eq. 8, plus *when* the wire is used).
pub trait MixingStrategy {
    /// What the local phase computes. Defaults to fused local steps.
    fn phase(&self) -> LocalPhase {
        LocalPhase::FusedSteps
    }

    /// Called once before the first round (anchor/center initialization).
    fn on_run_start(&mut self, _eng: &mut Engine, _ctx: &TrainContext) -> Result<()> {
        Ok(())
    }

    /// Steps per worker for the coming round.
    fn plan(&mut self, eng: &Engine, ctx: &TrainContext) -> RoundPlan;

    /// Hook before the local phase (CoCoD launches its collective here).
    fn before_local(&mut self, _eng: &mut Engine, _ctx: &TrainContext) -> Result<()> {
        Ok(())
    }

    /// Whether this strategy keeps *every* alive worker training through a
    /// network partition (the decentralized gossip family) instead of
    /// parking the non-quorum components like the exact-collective
    /// strategies do — see [`crate::fault::AliveSet`] (DESIGN.md §11).
    fn decentralized(&self) -> bool {
        false
    }

    /// Re-seed worker `w`'s training state when it rejoins after a crash
    /// (or returns from a healed partition). `src` is a boundary-accurate
    /// live replica chosen by the engine; the default copies its full
    /// replica state. Anchor-bearing strategies override this with the
    /// paper's warm start — params ← the current anchor, the exact state
    /// every survivor is being pulled toward.
    fn on_rejoin(
        &mut self,
        eng: &mut Engine,
        _ctx: &TrainContext,
        w: usize,
        src: usize,
    ) -> Result<()> {
        eng.workers.reseed_from(w, src);
        Ok(())
    }

    /// The mixing decision at the round boundary.
    fn mix(&mut self, eng: &mut Engine, ctx: &TrainContext, out: RoundOutcome) -> Result<()>;
}

/// Uniform plan: every worker runs `tau` steps (capped by the remaining
/// schedule) — the classic lockstep round.
pub fn uniform_plan(eng: &Engine, tau: usize) -> RoundPlan {
    let steps = tau.max(1).min(eng.remaining());
    RoundPlan { steps: vec![steps; eng.workers.m], advance: steps }
}

/// Straggler-aware heterogeneous plan (paper §straggler mitigation, E9):
/// scale each worker's step count by its observed per-step compute rate so
/// all workers reach the round boundary at ≈ the same virtual time. Falls
/// back to the uniform plan until every worker has been measured (round 1).
pub fn hetero_plan(eng: &Engine, tau: usize) -> RoundPlan {
    let advance = tau.max(1).min(eng.remaining());
    let m = eng.workers.m;
    let mut rates = Vec::with_capacity(m);
    for w in 0..m {
        let done = eng.steps_done[w];
        if done == 0 {
            return uniform_plan(eng, tau);
        }
        rates.push(eng.clocks.worker(w).compute_s / done as f64);
    }
    let fastest = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    let steps = rates
        .iter()
        .map(|&r| ((advance as f64 * fastest / r).round() as usize).clamp(1, advance))
        .collect();
    RoundPlan { steps, advance }
}

/// The τ-family plan honoring the config's `tau_hetero` switch.
pub fn plan_tau(eng: &Engine, ctx: &TrainContext, tau: usize) -> RoundPlan {
    if ctx.cfg.tau_hetero {
        hetero_plan(eng, tau)
    } else {
        uniform_plan(eng, tau)
    }
}

/// Drive `strategy` to completion: the one round loop every algorithm
/// shares. The engine owns the *schedule* (plans, folding order, the
/// virtual timeline); the workers own their state; the executor
/// (`cfg.execution`) owns where the state's work physically runs. Every
/// cross-worker fold is worker-major (worker 0's results, then worker
/// 1's, ...) and every straggler draw comes from that worker's own RNG
/// stream, so the observables are bit-identical whether the local phase
/// ran sequentially or on one OS thread per worker (golden tests).
pub fn run(ctx: &TrainContext, strategy: &mut dyn MixingStrategy) -> Result<TrainLog> {
    let mut eng = Engine::new(ctx)?;
    eng.fault.set_decentralized(strategy.decentralized());
    eng.fault.validate()?;
    strategy.on_run_start(&mut eng, ctx)?;
    // Tracked-counter snapshot at the warm-up boundary: everything after
    // it is the steady-state window that must stay at zero spawns/allocs.
    let mut warm: Option<ExecSnapshot> = None;
    while eng.k < eng.total {
        // On the net backend, the service plane reports its round-boundary
        // weather first: worker processes that died since the last round
        // become injected `crash` events, reconnections become `rejoin`s —
        // and then they replay through exactly the same fault machinery an
        // explicit `--fault` schedule uses (which is what makes the kill
        // test's digest-equality assertion possible).
        let injected = eng.exec.poll_net_events(eng.round + 1, &eng.fault.alive)?;
        for ev in injected {
            if let Some(pop) = eng.population.as_mut() {
                // Service-plane events arrive keyed by *slot* (the net
                // backend knows processes, not population ids). A dead
                // process kills the worker currently bound to that slot,
                // so translate through the binding and replay the crash
                // over its id — which is exactly what makes a killed
                // process land on the digest of the equivalent per-id
                // `crash@round:id` schedule. Reconnections are transport
                // recovery only: they do not resurrect a downed id (ids
                // come back through `rejoin` events or `rejoin_rate`).
                match ev {
                    FaultEvent::Crash { round, worker: slot } => {
                        let id = pop.bound[slot].with_context(|| {
                            format!("net worker process {slot} died before its first binding")
                        })?;
                        pop.faults
                            .inject(FaultEvent::Crash { round, worker: id as usize })?;
                    }
                    FaultEvent::Rejoin { .. } => {}
                    other => anyhow::bail!(
                        "net backend injected unsupported event {:?} under population mode",
                        other.describe()
                    ),
                }
            } else {
                eng.fault.inject(ev)?;
            }
        }
        // Fault events fire at the round boundary, before anything of the
        // round runs (DESIGN.md §11): crashes park workers, rejoins
        // warm-start them from the strategy's anchor, partitions re-shape
        // the alive set. All of it happens on the coordinator thread, so
        // the replay is bit-deterministic on either execution backend.
        apply_round_faults(&mut eng, ctx, strategy)?;
        // Population binding happens at the same boundary: replay id-level
        // faults, sample the round's cohort, and swap each sampled
        // worker's persistent state into its slot (no-op when the axis is
        // off, and provably a no-op after round 1 when N == k).
        bind_population_round(&mut eng, ctx, strategy)?;
        strategy.before_local(&mut eng, ctx)?;
        let mut plan = strategy.plan(&eng, ctx);
        // Plan validation is a *hard* error in every profile: a ragged or
        // over-advancing plan silently corrupts the schedule (and in release
        // builds a debug_assert would wave it through) — see
        // rust/tests/engine_plan.rs.
        ensure!(
            plan.steps.len() == eng.workers.m,
            "malformed RoundPlan: {} step entries for {} workers",
            plan.steps.len(),
            eng.workers.m
        );
        // Fault mask: parked workers (crashed, or outside the quorum
        // component for exact-collective strategies) take zero local steps
        // this round — the executor skips them entirely, so they consume
        // no batches and no RNG draws and resume their own streams exactly
        // where they left off on rejoin.
        if !eng.fault.alive.is_full() {
            for w in 0..eng.workers.m {
                if !eng.fault.alive.steps(w) {
                    plan.steps[w] = 0;
                }
            }
        }
        ensure!(
            plan.advance >= 1 && plan.advance <= eng.remaining(),
            "malformed RoundPlan: advance {} outside [1, {}]",
            plan.advance,
            eng.remaining()
        );
        if let Some(w) = (0..eng.workers.m).find(|&w| {
            eng.fault.alive.steps(w) && (plan.steps[w] < 1 || plan.steps[w] > plan.advance)
        }) {
            anyhow::bail!(
                "malformed RoundPlan: worker {w} assigned {} steps outside [1, {}]",
                plan.steps[w],
                plan.advance
            );
        }
        let phase = strategy.phase();
        if phase == LocalPhase::GradOnly {
            ensure!(
                plan.advance == 1,
                "malformed RoundPlan: grad-mode rounds are single-step, got advance {}",
                plan.advance
            );
        }
        let start_step = eng.k;
        // Local phase: the executor runs each worker's burst — sequentially
        // on `sim`, on the persistent per-worker pool threads on `threads`.
        // Either way the per-worker results come back in worker order and
        // are folded here in that order, so losses, clocks, and gradients
        // are bit-identical across backends (DESIGN.md §9).
        let views = eng.workers.step_views();
        let mut rounds = eng.exec.run_phase(views, ctx, &plan, start_step, phase)?;
        let mut grads = Vec::new();
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;
        for (w, r) in rounds.iter_mut().enumerate() {
            for &loss in &r.losses {
                loss_sum += loss;
            }
            loss_n += r.losses.len();
            for &dt in &r.dts {
                eng.clocks.compute(w, dt);
            }
            eng.steps_done[w] += r.losses.len();
            if let Some(g) = r.grad.take() {
                grads.push(g);
            }
        }
        eng.exec.recycle_rounds(rounds);
        eng.k = start_step + plan.advance;
        eng.round += 1;
        if eng.round == WARMUP_ROUNDS {
            warm = Some(eng.exec.snapshot());
        }
        let mean_loss = loss_sum / loss_n.max(1) as f64;
        let outcome = RoundOutcome { start_step, steps: plan.steps, grads, mean_loss };
        strategy.mix(&mut eng, ctx, outcome)?;
        eng.rec.push_loss(eng.k - 1, mean_loss);
        eng.rec.maybe_eval_masked(eng.k, ctx, &eng.workers, &eng.clocks, &eng.fault.alive)?;
    }
    let end = eng.exec.snapshot();
    // Short runs (fewer rounds than the warm-up) have an empty steady
    // window; the deltas below are then zero by construction.
    let warm = warm.unwrap_or(end);
    eng.rec.set_hot(HotPathCounters {
        rounds: eng.round as u64,
        warmup_rounds: WARMUP_ROUNDS.min(eng.round) as u64,
        thread_spawns_total: end.thread_spawns,
        steady_thread_spawns: end.thread_spawns - warm.thread_spawns,
        buffer_allocs_total: end.buffer_allocs,
        steady_buffer_allocs: end.buffer_allocs - warm.buffer_allocs,
        buffer_alloc_bytes_total: end.buffer_alloc_bytes,
        steady_buffer_alloc_bytes: end.buffer_alloc_bytes - warm.buffer_alloc_bytes,
        buffer_hits_total: end.buffer_hits,
    });
    if let Some(pop) = &eng.population {
        eng.rec.set_population(pop.counters());
    }
    eng.rec.force_eval_masked(eng.total, ctx, &eng.workers, &eng.clocks, &eng.fault.alive)?;
    Ok(eng.rec.finish(ctx, &eng.clocks, eng.total))
}

/// Apply every fault due at the upcoming round boundary (no-op unless a
/// fault source is configured): flip the alive set, record the trace and
/// survivor series, and bring rejoining workers back — their clock jumps
/// to the cluster's current time (downtime charged as idle), they pay one
/// full-message anchor fetch on the wire (`NetworkModel::rejoin_fetch_time`),
/// and the strategy warm-starts their replica (`MixingStrategy::on_rejoin`).
fn apply_round_faults(
    eng: &mut Engine,
    ctx: &TrainContext,
    strategy: &mut dyn MixingStrategy,
) -> Result<()> {
    if !eng.fault.engaged() {
        return Ok(());
    }
    let round = eng.round + 1; // 1-based index of the round about to run
    let rf = eng.fault.begin_round(round)?;
    for ev in &rf.applied {
        eng.rec.note_fault(round, ev.describe());
    }
    if !rf.joined.is_empty() {
        // The cluster time a rejoiner syncs to: the latest clock among the
        // workers stepping this round (`Engine::launch_clock` — the
        // joiner's own frozen clock is at or behind it, so including the
        // joiner in the fold is harmless).
        let t = eng.launch_clock();
        let fetch = ctx.cluster.net.rejoin_fetch_time(ctx.cluster.message_bytes);
        for &w in &rf.joined {
            eng.clocks.wait_idle_until(w, t);
            eng.clocks.comm_blocked(w, fetch);
            // Compressor rejoin protocol first: zero the residual and
            // restore the warm-start basis, so the strategy's warm start
            // sees a clean slate (DESIGN.md §12).
            if let Some(cs) = eng.compress.as_mut() {
                cs.reset_worker(w);
            }
            strategy.on_rejoin(eng, ctx, w, rf.src)?;
        }
    }
    if rf.changed {
        eng.rec.note_survivors(round, eng.fault.alive.stepping_count());
    }
    Ok(())
}

/// Bind the upcoming round's sampled cohort to the engine's slots (no-op
/// unless the population axis is engaged). Order within the boundary:
///
/// 1. replay id-level fault events — explicit schedule, net-injected
///    crashes, then (after binding) the per-id `fault_rate` random process
///    (a crashed id leaves the sampling pool; the trace and survivor
///    series land in the same recorder fields the slot-level machinery
///    uses);
/// 2. sample k distinct eligible ids, ascending (slot order) — downed ids
///    pad the tail only when the eligible pool is squeezed below k;
/// 3. unbind every slot whose worker changed — its full state (including
///    the compressor's error-feedback residual and, under PowerSGD, the
///    per-worker warm basis) swaps out into the LRU store;
/// 4. bind the incoming worker: resident hit, bit-exact spill
///    rematerialization, or fresh materialization from init. A *rebinding*
///    slot models the new participant syncing up: its virtual clock jumps
///    to the cluster's launch clock (the off-round gap was idle time —
///    non-participants advance through virtual time without ever being
///    materialized) and it pays one full-message model fetch on the wire,
///    exactly the rejoin protocol. Round-1 binds are initial placement and
///    charge nothing.
/// 5. project the id-level down/partition state onto the slots (the alive
///    set the strategies' masked collectives consume), run the random
///    process over the bound cohort, and hard-error if nothing is left on
///    the quorum side;
/// 6. warm-start through the strategy's `on_rejoin`: never-before-seen
///    workers, ids that rejoined while unbound (deferred until they are
///    next sampled), and — exactly the dense rejoin protocol, clock jump
///    and anchor fetch included — slots that kept their binding but flip
///    parked → stepping. Rematerialized workers with an unbroken history
///    resume their own trajectory and are *not* warm-started;
/// 7. note the survivor series (stepping slots while partitioned, the
///    eligible count otherwise) and evict the store down to its reserve
///    cap (the O(k) guarantee).
///
/// When `N == k` the sampler returns `0..k` every round, so after round 1
/// nothing ever changes binding, the id→slot projection is the identity,
/// and every observable — including the fault trace and survivor series —
/// is bit-identical to the dense engine (golden-locked by
/// rust/tests/population.rs).
fn bind_population_round(
    eng: &mut Engine,
    ctx: &TrainContext,
    strategy: &mut dyn MixingStrategy,
) -> Result<()> {
    let Some(mut pop) = eng.population.take() else {
        return Ok(());
    };
    let res = bind_cohort(eng, ctx, strategy, &mut pop);
    eng.population = Some(pop);
    res
}

fn bind_cohort(
    eng: &mut Engine,
    ctx: &TrainContext,
    strategy: &mut dyn MixingStrategy,
    pop: &mut crate::population::PopulationState,
) -> Result<()> {
    let round = eng.round + 1; // 1-based index of the round about to run
    let m = eng.workers.m;
    // Dense-mirror snapshot: which slots stepped before this boundary's
    // events. Drives the joined detection and warm-start source selection
    // below, exactly like `FaultState::begin_round`'s `prev_stepping`.
    let prev_stepping: Vec<bool> = (0..m).map(|w| eng.fault.alive.steps(w)).collect();
    let prev_bound = pop.bound.clone();
    let mut applied = pop.faults.begin_round(round)?;
    let cohort = pop.sample(round)?;
    // Cluster time the incoming workers sync to — computed before any of
    // this round's clock jumps, like the rejoin path above.
    let t = eng.launch_clock();
    let fetch = ctx.cluster.net.rejoin_fetch_time(ctx.cluster.message_bytes);
    // Unbind every outgoing worker first so its state is parked (and
    // takeable) before any incoming bind — cohorts are sets, so the same
    // id may move between slots within one boundary.
    let mut incoming: Vec<(usize, u64, bool)> = Vec::new(); // (slot, id, rebind)
    for (slot, &id) in cohort.iter().enumerate() {
        let prev = pop.bound[slot];
        if prev == Some(id) {
            continue;
        }
        if let Some(old) = prev {
            let mut shell = pop.store.blank();
            eng.workers.swap_state(slot, &mut shell);
            if let Some(cs) = eng.compress.as_mut() {
                let mut r = shell.residual.take().unwrap_or_default();
                cs.swap_residual(slot, &mut r);
                shell.residual = Some(r);
                if cs.kind == CompressKind::PowerSgd {
                    let mut e = shell.psgd_error.take().unwrap_or_default();
                    let mut q = shell.psgd_qs.take().unwrap_or_default();
                    cs.swap_powersgd_state(slot, &mut e, &mut q);
                    shell.psgd_error = Some(e);
                    shell.psgd_qs = Some(q);
                }
            }
            pop.store.park(old, shell);
        }
        incoming.push((slot, id, prev.is_some()));
    }
    let mut fresh_slots: Vec<usize> = Vec::new();
    for &(slot, id, rebind) in &incoming {
        let (mut st, seen) = pop.store.take_or_materialize(id, &ctx.shards)?;
        eng.workers.swap_state(slot, &mut st);
        if let Some(cs) = eng.compress.as_mut() {
            if let Some(r) = st.residual.as_mut() {
                cs.swap_residual(slot, r);
            }
            if cs.kind == CompressKind::PowerSgd {
                if let (Some(e), Some(q)) = (st.psgd_error.as_mut(), st.psgd_qs.as_mut())
                {
                    cs.swap_powersgd_state(slot, e, q);
                }
            }
        }
        pop.store.recycle(st);
        pop.bound[slot] = Some(id);
        if rebind {
            eng.clocks.wait_idle_until(slot, t);
            eng.clocks.comm_blocked(slot, fetch);
            if !seen {
                fresh_slots.push(slot);
            }
        }
    }
    // Project the id-level fault state onto the slots: a slot is alive iff
    // its bound id is up, and an active partition carries over through
    // `component_of` (identity at N == k with full coverage, so the dense
    // mirror holds bit-for-bit; a fault-free round leaves the alive set
    // untouched and `is_full` keeps every downstream path on the dense
    // fast path).
    for (slot, &id) in cohort.iter().enumerate() {
        eng.fault.alive.set_alive(slot, !pop.faults.down().contains(&id));
    }
    if let Some(ncomp) = pop.faults.partition_components() {
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); ncomp];
        for (slot, &id) in cohort.iter().enumerate() {
            let c = pop.faults.component_of(id).expect("partition is active");
            groups[c].push(slot);
        }
        eng.fault.alive.clear_partition();
        eng.fault.alive.set_partition(&groups);
    } else {
        eng.fault.alive.clear_partition();
    }
    eng.fault.alive.refresh();
    // The seeded per-id random process draws over the bound cohort (plus
    // the downed set, so rejoin draws fire for ids outside every cohort).
    applied.extend(pop.faults.random_round(round, &pop.bound, &mut eng.fault.alive));
    for ev in &applied {
        eng.rec.note_fault(round, ev.describe());
    }
    ensure!(
        eng.fault.alive.member_count() > 0,
        "fault schedule leaves no live worker in the primary partition at round {round}"
    );
    // Who needs a warm start this boundary, beyond the fresh slots: ids
    // that rejoined while unbound warm-start the round they are next
    // sampled (their parked state predates the crash — resuming it would
    // fork the trajectory dense mode never takes), and an id that rejoined
    // *and* rebound within this same boundary warm-starts now. A rejoined
    // id whose binding is unchanged flows through the dense-mirror joined
    // path below instead.
    let mut warm_slots = fresh_slots;
    for (slot, &id) in cohort.iter().enumerate() {
        if !pop.faults.down().contains(&id)
            && pop.pending_warm.remove(&id)
            && !warm_slots.contains(&slot)
        {
            warm_slots.push(slot);
        }
    }
    for ev in &applied {
        if let FaultEvent::Rejoin { worker, .. } = ev {
            let id = *worker as u64;
            match (0..m).find(|&s| pop.bound[s] == Some(id)) {
                Some(slot) if pop.bound[slot] == prev_bound[slot] => {} // joined path
                Some(slot) => {
                    if !warm_slots.contains(&slot) {
                        warm_slots.push(slot);
                    }
                }
                None => {
                    pop.pending_warm.insert(id);
                }
            }
        }
    }
    // Dense-mirror rejoin protocol: a slot that kept its binding and flips
    // parked → stepping (its id rejoined, or a heal reunited its
    // component) gets exactly the dense treatment — clock jump to the
    // cluster's launch time, one anchor fetch on the wire, compressor
    // reset, strategy warm start. Slots that changed binding already paid
    // the rebind protocol above.
    let joined: Vec<usize> = (0..m)
        .filter(|&w| {
            pop.bound[w] == prev_bound[w] && !prev_stepping[w] && eng.fault.alive.steps(w)
        })
        .collect();
    if !joined.is_empty() {
        let src = (0..m)
            .find(|&w| prev_stepping[w] && eng.fault.alive.steps(w))
            .or_else(|| (0..m).find(|&w| prev_stepping[w]))
            .expect("a non-empty cluster always has a previous stepping worker");
        let tj = eng.launch_clock();
        for &w in &joined {
            eng.clocks.wait_idle_until(w, tj);
            eng.clocks.comm_blocked(w, fetch);
            if let Some(cs) = eng.compress.as_mut() {
                cs.reset_worker(w);
            }
            strategy.on_rejoin(eng, ctx, w, src)?;
        }
    }
    // Warm-start protocol for workers without a usable history: compressor
    // reset first, then the strategy's rejoin hook. `src` prefers a slot
    // with real training history; if the whole cohort is fresh any other
    // slot works — anchor-bearing strategies ignore `src` and pull the
    // newcomer to the anchor, which is the semantics that matter.
    if !warm_slots.is_empty() {
        let src = (0..m).find(|s| !warm_slots.contains(s));
        for &slot in &warm_slots {
            let src = match src {
                Some(s) => s,
                None if m > 1 => (slot + 1) % m,
                None => continue, // a lone fresh slot has no one to start from
            };
            if let Some(cs) = eng.compress.as_mut() {
                cs.reset_worker(slot);
            }
            strategy.on_rejoin(eng, ctx, slot, src)?;
        }
    }
    // Survivor series: the cohort-level quorum while a partition is active
    // (what the collectives actually reduce over), the id-level eligible
    // count otherwise — noted only when the value moves, which at N == k
    // reproduces the dense series exactly.
    let survivors = if pop.faults.partitioned() {
        eng.fault.alive.stepping_count()
    } else {
        pop.faults.eligible() as usize
    };
    if survivors != pop.last_survivors {
        eng.rec.note_survivors(round, survivors);
        pop.last_survivors = survivors;
    }
    // Publish the binding to the service plane (net backend only): the
    // next PhaseReq ships each slot's bound id and stream state.
    eng.exec.bind_population(&pop.bound);
    pop.store.enforce_cap()?;
    pop.note_round();
    Ok(())
}
