//! Metrics substrate: training logs, CSV/JSON emission, run summaries.
//!
//! Every algorithm driver produces a `TrainLog`; benches aggregate logs into
//! the paper's tables/figures and write both human-readable rows (stdout)
//! and machine-readable files under `results/`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{arr, arr_f64, num, obj, s, Json};

/// Tracked hot-path counters for one run (DESIGN.md §10): OS-thread spawns
/// and tracked buffer-pool allocations, split into lifetime totals and the
/// **steady-state** remainder after the warm-up rounds. On a pooled
/// backend the steady-state numbers must be exactly zero — the property
/// `rust/tests/hot_path.rs` and the wallclock bench hard-assert. The
/// counters are reporting-only observables: they never enter
/// [`TrainLog::digest`], so identical schedules stay digest-identical
/// across backends regardless of how their memory behaved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HotPathCounters {
    /// rounds the engine completed
    pub rounds: u64,
    /// rounds counted as warm-up (pool priming) before the steady window
    pub warmup_rounds: u64,
    /// OS threads spawned by the executor over the whole run
    pub thread_spawns_total: u64,
    /// OS threads spawned after warm-up (must be 0: the pool is persistent)
    pub steady_thread_spawns: u64,
    /// tracked buffer-pool allocations (free-list misses) over the run
    pub buffer_allocs_total: u64,
    /// tracked allocations after warm-up (must be 0: buffers recycle)
    pub steady_buffer_allocs: u64,
    /// bytes of tracked allocations over the run
    pub buffer_alloc_bytes_total: u64,
    /// bytes of tracked allocations after warm-up
    pub steady_buffer_alloc_bytes: u64,
    /// buffer-pool requests served without allocating
    pub buffer_hits_total: u64,
}

impl HotPathCounters {
    /// The run's hot-path counters as a JSON object (rides inside the
    /// result-file format).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("rounds", num(self.rounds as f64)),
            ("warmup_rounds", num(self.warmup_rounds as f64)),
            ("thread_spawns_total", num(self.thread_spawns_total as f64)),
            ("steady_thread_spawns", num(self.steady_thread_spawns as f64)),
            ("buffer_allocs_total", num(self.buffer_allocs_total as f64)),
            ("steady_buffer_allocs", num(self.steady_buffer_allocs as f64)),
            (
                "buffer_alloc_bytes_total",
                num(self.buffer_alloc_bytes_total as f64),
            ),
            (
                "steady_buffer_alloc_bytes",
                num(self.steady_buffer_alloc_bytes as f64),
            ),
            ("buffer_hits_total", num(self.buffer_hits_total as f64)),
        ])
    }
}

/// Population-store counters for one partial-participation run
/// (DESIGN.md §14, E17): sampler activity plus the LRU/spill behavior of
/// the per-worker state store. Reporting-only, exactly like
/// [`HotPathCounters`]: present in the JSON but never hashed into
/// [`TrainLog::digest`], so a sampled run's digest depends only on what the
/// cohort actually computed — not on how its state was cached.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PopulationCounters {
    /// registered population size N
    pub population: u64,
    /// sampled cohort size k (= the engine's slot count)
    pub sample_k: u64,
    /// LRU reserve: unbound worker states kept resident beyond the k bound
    pub reserve: u64,
    /// rounds the cohort sampler ran
    pub rounds_sampled: u64,
    /// slot binds served from the resident LRU store (no decode)
    pub store_hits: u64,
    /// slot binds rematerialized bit-exactly from the disk spill
    pub spill_reads: u64,
    /// slot binds that materialized a never-seen worker from init
    pub fresh_materializations: u64,
    /// resident states evicted (encoded and appended) to the spill
    pub evictions: u64,
    /// total bytes appended to the spill file
    pub spilled_bytes: u64,
    /// peak materialized worker states (bound + resident); the O(k) claim
    /// is `resident_workers_max <= sample_k + reserve`, gated in CI (E17)
    pub resident_workers_max: u64,
}

impl PopulationCounters {
    /// The counters as a JSON object (rides inside the result-file format).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("population", num(self.population as f64)),
            ("sample_k", num(self.sample_k as f64)),
            ("reserve", num(self.reserve as f64)),
            ("rounds_sampled", num(self.rounds_sampled as f64)),
            ("store_hits", num(self.store_hits as f64)),
            ("spill_reads", num(self.spill_reads as f64)),
            (
                "fresh_materializations",
                num(self.fresh_materializations as f64),
            ),
            ("evictions", num(self.evictions as f64)),
            ("spilled_bytes", num(self.spilled_bytes as f64)),
            ("resident_workers_max", num(self.resident_workers_max as f64)),
        ])
    }
}

/// One evaluation point (cadence = config.eval_every epochs).
#[derive(Clone, Debug)]
pub struct EvalRecord {
    /// fractional epoch of this record
    pub epoch: f64,
    /// global step of this record
    pub step: usize,
    /// virtual cluster time (seconds) at this point
    pub sim_time: f64,
    /// mean training loss since the previous record
    pub train_loss: f64,
    /// mean test-set loss of the consensus model
    pub test_loss: f64,
    /// test-set accuracy of the consensus model
    pub test_acc: f64,
}

/// Full record of one training run.
#[derive(Clone, Debug)]
pub struct TrainLog {
    /// algorithm name (`Algo::name`)
    pub algo: String,
    /// compressor name (`CompressKind::name`; "none" when off). Reported
    /// in the JSON/CSV outputs but deliberately outside the digest: the
    /// observables the digest hashes (losses, times, bytes) already see
    /// compression wherever it acts.
    pub compress: String,
    /// configured τ
    pub tau: usize,
    /// cluster size m
    pub workers: usize,
    /// evaluation records at the configured cadence
    pub records: Vec<EvalRecord>,
    /// (step, mean loss across workers) every sync round
    pub step_losses: Vec<(usize, f64)>,
    /// (step, τ) points recorded by an adaptive-τ controller; empty for
    /// fixed-τ runs
    pub tau_trace: Vec<(usize, usize)>,
    /// applied fault events as (1-based round, canonical spec) pairs
    /// (DESIGN.md §11); empty — and out of the digest — when no fault
    /// fires, so fault-free runs keep their pre-fault digests bit-for-bit
    pub fault_trace: Vec<(usize, String)>,
    /// (round, stepping-worker count) survivor series, one point per
    /// change; empty when the cluster never loses a worker
    pub survivors: Vec<(usize, usize)>,
    /// final virtual cluster time (max worker clock)
    pub total_sim_time: f64,
    /// total compute seconds across workers
    pub total_compute_s: f64,
    /// total blocked-on-communication seconds across workers
    pub total_comm_blocked_s: f64,
    /// total barrier-idle seconds across workers
    pub total_idle_s: f64,
    /// total bytes put on the wire
    pub bytes_sent: u64,
    /// per-worker transmitted bytes on the topology axis (hier leaders,
    /// tree inner nodes, and gossip neighbors send different amounts);
    /// all-zero on the seed's uniform ring accounting
    pub neighbor_bytes: Vec<u64>,
    /// total global steps of the run
    pub steps: usize,
    /// tracked hot-path counters (spawns, pooled-buffer allocations);
    /// reporting-only — excluded from [`TrainLog::digest`] so memory
    /// behavior can never masquerade as an algorithmic observable
    pub hot: HotPathCounters,
    /// population-store counters (DESIGN.md §14); `None` when the
    /// partial-participation axis is off, and — like `hot` — excluded from
    /// [`TrainLog::digest`] even when present
    pub population: Option<PopulationCounters>,
}

impl TrainLog {
    /// Test accuracy of the last evaluation record.
    pub fn final_acc(&self) -> f64 {
        self.records.last().map(|r| r.test_acc).unwrap_or(0.0)
    }

    /// Test loss of the last evaluation record.
    pub fn final_loss(&self) -> f64 {
        self.records.last().map(|r| r.test_loss).unwrap_or(f64::NAN)
    }

    /// Communication-to-computation ratio — the paper's E8 metric: time the
    /// workers spent blocked (comm wait + straggler idle) over compute time.
    pub fn comm_ratio(&self) -> f64 {
        if self.total_compute_s == 0.0 {
            0.0
        } else {
            (self.total_comm_blocked_s + self.total_idle_s) / self.total_compute_s
        }
    }

    /// Average virtual seconds per epoch.
    pub fn time_per_epoch(&self, epochs: f64) -> f64 {
        if epochs == 0.0 {
            0.0
        } else {
            self.total_sim_time / epochs
        }
    }

    /// The run as a JSON object (the result-file format).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("algo", s(&self.algo)),
            ("compress", s(&self.compress)),
            ("tau", num(self.tau as f64)),
            ("workers", num(self.workers as f64)),
            ("steps", num(self.steps as f64)),
            ("total_sim_time", num(self.total_sim_time)),
            ("total_compute_s", num(self.total_compute_s)),
            ("total_comm_blocked_s", num(self.total_comm_blocked_s)),
            ("total_idle_s", num(self.total_idle_s)),
            ("comm_ratio", num(self.comm_ratio())),
            ("bytes_sent", num(self.bytes_sent as f64)),
            ("final_acc", num(self.final_acc())),
            (
                "records",
                arr(self.records.iter().map(|r| {
                    obj(vec![
                        ("epoch", num(r.epoch)),
                        ("step", num(r.step as f64)),
                        ("sim_time", num(r.sim_time)),
                        ("train_loss", num(r.train_loss)),
                        ("test_loss", num(r.test_loss)),
                        ("test_acc", num(r.test_acc)),
                    ])
                })),
            ),
            (
                "step_losses",
                arr(self
                    .step_losses
                    .iter()
                    .map(|&(k, l)| arr_f64(&[k as f64, l]))),
            ),
            (
                "tau_trace",
                arr(self
                    .tau_trace
                    .iter()
                    .map(|&(k, t)| arr_f64(&[k as f64, t as f64]))),
            ),
            (
                "fault_trace",
                arr(self.fault_trace.iter().map(|(r, ev)| {
                    obj(vec![("round", num(*r as f64)), ("event", s(ev))])
                })),
            ),
            (
                "survivors",
                arr(self
                    .survivors
                    .iter()
                    .map(|&(r, c)| arr_f64(&[r as f64, c as f64]))),
            ),
            (
                "neighbor_bytes",
                arr(self.neighbor_bytes.iter().map(|&b| num(b as f64))),
            ),
            ("hot_path", self.hot.to_json()),
        ];
        if let Some(p) = &self.population {
            fields.push(("population", p.to_json()));
        }
        obj(fields)
    }

    /// Order-sensitive FNV-1a fingerprint over every observable of the run
    /// (floats hashed by exact bits) — the golden-regression digest. Two
    /// runs with identical schedules, numerics, and timing produce the same
    /// digest; any drift in loss traces, eval records, virtual time, byte
    /// accounting, or the τ schedule changes it.
    pub fn digest(&self) -> u64 {
        struct Fnv(u64);
        impl Fnv {
            fn bytes(&mut self, bytes: &[u8]) {
                for &b in bytes {
                    self.0 ^= b as u64;
                    self.0 = self.0.wrapping_mul(0x100000001b3);
                }
            }
            fn u64(&mut self, v: u64) {
                self.bytes(&v.to_le_bytes());
            }
            fn f64(&mut self, v: f64) {
                self.u64(v.to_bits());
            }
        }
        let mut h = Fnv(0xcbf29ce484222325);
        h.bytes(self.algo.as_bytes());
        h.u64(self.tau as u64);
        h.u64(self.workers as u64);
        h.u64(self.steps as u64);
        h.u64(self.bytes_sent);
        h.f64(self.total_sim_time);
        h.f64(self.total_compute_s);
        h.f64(self.total_comm_blocked_s);
        h.f64(self.total_idle_s);
        for r in &self.records {
            h.f64(r.epoch);
            h.u64(r.step as u64);
            h.f64(r.sim_time);
            h.f64(r.train_loss);
            h.f64(r.test_loss);
            h.f64(r.test_acc);
        }
        for &(k, l) in &self.step_losses {
            h.u64(k as u64);
            h.f64(l);
        }
        for &(k, t) in &self.tau_trace {
            h.u64(k as u64);
            h.u64(t as u64);
        }
        // Fault-axis observables. Hashed only when a fault actually fired:
        // fault-free runs (including runs whose schedule never triggers)
        // keep every pre-fault digest bit-identical.
        if !self.fault_trace.is_empty() {
            for (r, ev) in &self.fault_trace {
                h.u64(*r as u64);
                h.bytes(ev.as_bytes());
            }
        }
        if !self.survivors.is_empty() {
            for &(r, c) in &self.survivors {
                h.u64(r as u64);
                h.u64(c as u64);
            }
        }
        // Topology-axis observable. Hashed only when engaged (any nonzero):
        // the seed's ring runs keep their all-zero vector out of the digest,
        // so every pre-topology golden digest is unchanged.
        if self.neighbor_bytes.iter().any(|&b| b != 0) {
            for &b in &self.neighbor_bytes {
                h.u64(b);
            }
        }
        h.0
    }

    /// CSV of the eval records.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("epoch,step,sim_time,train_loss,test_loss,test_acc\n");
        for r in &self.records {
            let _ = writeln!(
                out,
                "{:.3},{},{:.4},{:.6},{:.6},{:.6}",
                r.epoch, r.step, r.sim_time, r.train_loss, r.test_loss, r.test_acc
            );
        }
        out
    }
}

/// Write a JSON value to `dir/name`, creating `dir`.
pub fn write_json(dir: &Path, name: &str, j: &Json) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    let path = dir.join(name);
    std::fs::write(&path, j.to_string_pretty()).with_context(|| format!("writing {path:?}"))?;
    Ok(())
}

/// Write text to `dir/name`, creating `dir`.
pub fn write_text(dir: &Path, name: &str, text: &str) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    let path = dir.join(name);
    std::fs::write(&path, text).with_context(|| format!("writing {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> TrainLog {
        TrainLog {
            algo: "overlap-m".into(),
            compress: "none".into(),
            tau: 2,
            workers: 8,
            records: vec![
                EvalRecord {
                    epoch: 1.0,
                    step: 16,
                    sim_time: 3.5,
                    train_loss: 2.0,
                    test_loss: 1.9,
                    test_acc: 0.42,
                },
                EvalRecord {
                    epoch: 2.0,
                    step: 32,
                    sim_time: 7.0,
                    train_loss: 1.2,
                    test_loss: 1.1,
                    test_acc: 0.61,
                },
            ],
            step_losses: vec![(0, 2.3), (16, 1.5)],
            tau_trace: Vec::new(),
            fault_trace: Vec::new(),
            survivors: Vec::new(),
            neighbor_bytes: vec![0; 8],
            total_sim_time: 7.0,
            total_compute_s: 50.0,
            total_comm_blocked_s: 4.0,
            total_idle_s: 1.0,
            bytes_sent: 1 << 20,
            steps: 32,
            hot: HotPathCounters::default(),
            population: None,
        }
    }

    #[test]
    fn derived_metrics() {
        let log = sample_log();
        assert_eq!(log.final_acc(), 0.61);
        assert!((log.comm_ratio() - 0.1).abs() < 1e-12);
        assert!((log.time_per_epoch(2.0) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn json_round_trips() {
        let j = sample_log().to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("algo").unwrap().as_str().unwrap(), "overlap-m");
        assert_eq!(
            parsed.get("records").unwrap().as_arr().unwrap().len(),
            2
        );
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let a = sample_log();
        let mut b = sample_log();
        assert_eq!(a.digest(), b.digest(), "identical logs must share a digest");
        b.records[1].test_loss += 1e-9;
        assert_ne!(a.digest(), b.digest(), "digest must see tiny numeric drift");
        let mut c = sample_log();
        c.tau_trace.push((8, 4));
        assert_ne!(a.digest(), c.digest(), "digest must see the τ schedule");
        // The topology axis is digest-visible once engaged, but an all-zero
        // (= ring) vector leaves the legacy digests untouched.
        let mut d = sample_log();
        d.neighbor_bytes = vec![0; 4];
        assert_eq!(a.digest(), d.digest(), "inert neighbor accounting must not drift");
        d.neighbor_bytes[2] = 1 << 10;
        assert_ne!(a.digest(), d.digest(), "digest must see neighbor bytes");
        // The fault axis is digest-visible once a fault fires, but empty
        // traces leave fault-free digests untouched.
        let mut f = sample_log();
        f.fault_trace.push((3, "crash@3:2".into()));
        assert_ne!(a.digest(), f.digest(), "digest must see the fault trace");
        let mut g = sample_log();
        g.survivors.push((3, 7));
        assert_ne!(a.digest(), g.digest(), "digest must see the survivor series");
        // Hot-path counters are reporting-only: memory behavior (spawns,
        // pool misses) must never shift a digest.
        let mut e = sample_log();
        e.hot.thread_spawns_total = 17;
        e.hot.buffer_allocs_total = 99;
        e.hot.steady_buffer_allocs = 5;
        assert_eq!(a.digest(), e.digest(), "hot counters must stay out of the digest");
        // The compress label is reporting-only: the digest sees compression
        // through the observables it changes (losses, times, bytes), never
        // through the label itself.
        let mut h = sample_log();
        h.compress = "topk".into();
        assert_eq!(a.digest(), h.digest(), "compress label must stay out of the digest");
        // Population-store counters are reporting-only for the same reason:
        // cache behavior (hits, spills, evictions) must never shift a
        // digest — only what the cohort computed may.
        let mut p = sample_log();
        p.population = Some(PopulationCounters {
            population: 1_000_000,
            sample_k: 16,
            reserve: 8,
            rounds_sampled: 40,
            store_hits: 3,
            spill_reads: 21,
            fresh_materializations: 612,
            evictions: 620,
            spilled_bytes: 9 << 20,
            resident_workers_max: 24,
        });
        assert_eq!(a.digest(), p.digest(), "population counters must stay out of the digest");
        assert!(p.to_json().to_string_pretty().contains("resident_workers_max"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = sample_log().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("epoch,"));
        assert!(lines[1].starts_with("1.000,16,"));
    }
}
