//! Hot-path buffer pool: recycled flat-`f32` storage for the per-round
//! collective snapshots (DESIGN.md §10).
//!
//! Every non-blocking collective needs an owned copy of the m participant
//! vectors (the communicator thread must outlive the borrow of
//! `Workers::params`), and before this subsystem each launch paid a fresh
//! `m × n` heap snapshot — the single largest steady-state allocation in
//! the round loop. [`BufferPool`] keeps a free list of previously used
//! buffers so that, after the first warm-up rounds, every launch reuses
//! storage returned by the previous absorb and the steady-state round loop
//! performs **zero** tracked allocations (hard-asserted by
//! `rust/tests/hot_path.rs` via the counters surfaced in
//! `TrainLog::hot`).
//!
//! Why pooling cannot change a digest: a recycled buffer is `clear()`ed and
//! rewritten (copy or zero-fill) before any arithmetic reads it, so the
//! values entering every reduce schedule are bit-identical to the
//! `to_vec()` snapshots the pool replaced. The pool moves memory, never
//! numbers.
//!
//! The pool is `Clone` (a shared handle) and thread-safe: snapshots are
//! taken on the coordinator, consumed on the communicator thread, and
//! returned from either side.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Counter snapshot of a pool's lifetime traffic (monotone totals).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// buffers (or buffer sets) the pool had to allocate — the tracked
    /// hot-path allocation count
    pub allocs: u64,
    /// bytes of backing storage those allocations created
    pub alloc_bytes: u64,
    /// requests served from the free list without allocating
    pub hits: u64,
}

struct PoolInner {
    /// free flat buffers (all runs use one length n, so any entry fits)
    free: Mutex<Vec<Vec<f32>>>,
    /// free (emptied) outer `Vec<Vec<f32>>` shells for buffer sets
    free_sets: Mutex<Vec<Vec<Vec<f32>>>>,
    allocs: AtomicU64,
    alloc_bytes: AtomicU64,
    hits: AtomicU64,
}

/// Shared recycling pool for flat `f32` buffers and `Vec<Vec<f32>>` buffer
/// sets. Cloning clones the handle, not the storage.
#[derive(Clone)]
pub struct BufferPool {
    inner: Arc<PoolInner>,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    /// Fresh, empty pool.
    pub fn new() -> Self {
        Self {
            inner: Arc::new(PoolInner {
                free: Mutex::new(Vec::new()),
                free_sets: Mutex::new(Vec::new()),
                allocs: AtomicU64::new(0),
                alloc_bytes: AtomicU64::new(0),
                hits: AtomicU64::new(0),
            }),
        }
    }

    fn count_alloc(&self, bytes: usize) {
        self.inner.allocs.fetch_add(1, Ordering::Relaxed);
        self.inner.alloc_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Pop a recycled buffer able to hold `len` elements without growing.
    /// A popped buffer whose capacity is too small still has to touch the
    /// real allocator, so it is counted as a tracked allocation (not a
    /// hit) — capacity growth must not hide from the zero-steady-state
    /// gate when differently-sized buffers ever share a pool.
    fn pop_fitting(&self, len: usize) -> Option<Vec<f32>> {
        let v = self.inner.free.lock().expect("buffer pool poisoned").pop()?;
        if v.capacity() >= len {
            self.inner.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.count_alloc(len * std::mem::size_of::<f32>());
        }
        Some(v)
    }

    /// A buffer of exactly `len` zeros: recycled when possible, counted as
    /// a tracked allocation otherwise.
    pub fn take_zeroed(&self, len: usize) -> Vec<f32> {
        match self.pop_fitting(len) {
            Some(mut v) => {
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => {
                self.count_alloc(len * std::mem::size_of::<f32>());
                vec![0.0f32; len]
            }
        }
    }

    /// A buffer of exactly `len` elements with **unspecified contents**
    /// (stale recycled data), for callers that unconditionally overwrite
    /// every element (e.g. `Executor::mean_into`): skips `take_zeroed`'s
    /// full zero-fill pass on the recycled path.
    pub fn take_for_overwrite(&self, len: usize) -> Vec<f32> {
        match self.pop_fitting(len) {
            Some(mut v) => {
                if v.len() >= len {
                    v.truncate(len);
                } else {
                    v.resize(len, 0.0);
                }
                v
            }
            None => {
                self.count_alloc(len * std::mem::size_of::<f32>());
                vec![0.0f32; len]
            }
        }
    }

    /// A buffer holding a copy of `src` (same recycling rules; the copy is
    /// bit-exact, so downstream arithmetic cannot observe the pool).
    pub fn take_copy(&self, src: &[f32]) -> Vec<f32> {
        match self.pop_fitting(src.len()) {
            Some(mut v) => {
                v.clear();
                v.extend_from_slice(src);
                v
            }
            None => {
                self.count_alloc(std::mem::size_of_val(src));
                src.to_vec()
            }
        }
    }

    /// Return one buffer to the free list (contents become garbage).
    pub fn put(&self, v: Vec<f32>) {
        self.inner.free.lock().expect("buffer pool poisoned").push(v);
    }

    fn take_outer(&self, m: usize) -> Vec<Vec<f32>> {
        let recycled = self.inner.free_sets.lock().expect("buffer pool poisoned").pop();
        match recycled {
            Some(outer) => {
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                outer
            }
            None => {
                self.count_alloc(m * std::mem::size_of::<Vec<f32>>());
                Vec::with_capacity(m)
            }
        }
    }

    /// A buffer set holding copies of `inputs` — the pooled replacement for
    /// the per-collective `inputs.iter().map(|v| v.to_vec()).collect()`
    /// snapshot.
    pub fn take_set_copy(&self, inputs: &[&[f32]]) -> Vec<Vec<f32>> {
        let mut set = self.take_outer(inputs.len());
        for src in inputs {
            set.push(self.take_copy(src));
        }
        set
    }

    /// A buffer set of `m` zeroed buffers of length `n` (gossip mix
    /// outputs).
    pub fn take_set_zeroed(&self, m: usize, n: usize) -> Vec<Vec<f32>> {
        let mut set = self.take_outer(m);
        for _ in 0..m {
            set.push(self.take_zeroed(n));
        }
        set
    }

    /// Return a whole buffer set: the inner buffers go on the buffer free
    /// list, the emptied outer shell on the set free list.
    pub fn put_set(&self, mut set: Vec<Vec<f32>>) {
        {
            let mut free = self.inner.free.lock().expect("buffer pool poisoned");
            free.extend(set.drain(..));
        }
        self.inner.free_sets.lock().expect("buffer pool poisoned").push(set);
    }

    /// Lifetime counters (monotone): tracked allocations, their bytes, and
    /// free-list hits.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            allocs: self.inner.allocs.load(Ordering::Relaxed),
            alloc_bytes: self.inner.alloc_bytes.load(Ordering::Relaxed),
            hits: self.inner.hits.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_recycles_and_counts() {
        let pool = BufferPool::new();
        let a = pool.take_zeroed(8);
        assert_eq!(a, vec![0.0f32; 8]);
        let s0 = pool.stats();
        assert_eq!(s0.allocs, 1);
        assert_eq!(s0.alloc_bytes, 32);
        assert_eq!(s0.hits, 0);
        pool.put(a);
        let b = pool.take_copy(&[1.0, 2.0, 3.0]);
        assert_eq!(b, vec![1.0, 2.0, 3.0]);
        let s1 = pool.stats();
        assert_eq!(s1.allocs, 1, "recycled take must not allocate");
        assert_eq!(s1.hits, 1);
    }

    #[test]
    fn recycled_buffers_are_fully_overwritten() {
        let pool = BufferPool::new();
        pool.put(vec![9.0f32; 16]);
        let z = pool.take_zeroed(4);
        assert_eq!(z, vec![0.0f32; 4], "stale contents must never leak");
        pool.put(z);
        let c = pool.take_copy(&[5.0, 6.0]);
        assert_eq!(c, vec![5.0, 6.0]);
    }

    #[test]
    fn take_for_overwrite_recycles_without_zeroing() {
        let pool = BufferPool::new();
        pool.put(vec![7.0f32; 8]);
        let v = pool.take_for_overwrite(4);
        assert_eq!(v.len(), 4, "length contract");
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(pool.stats().allocs, 0, "recycled overwrite-take must not allocate");
        pool.put(v);
        // Growing past the recycled length zero-fills only the new tail.
        let v = pool.take_for_overwrite(6);
        assert_eq!(v.len(), 6);
        assert_eq!(v[4], 0.0);
        assert_eq!(v[5], 0.0);
    }

    #[test]
    fn capacity_growth_on_the_recycled_path_is_a_tracked_alloc() {
        let pool = BufferPool::new();
        pool.put(Vec::with_capacity(2));
        let v = pool.take_zeroed(100); // recycled shell is too small: must grow
        assert_eq!(v.len(), 100);
        let s = pool.stats();
        assert_eq!(s.hits, 0, "a growing take is not a hit");
        assert_eq!(s.allocs, 1, "capacity growth must not hide from the E13 gate");
        assert_eq!(s.alloc_bytes, 400);
    }

    #[test]
    fn sets_balance_after_warmup() {
        let pool = BufferPool::new();
        let inputs = [[1.0f32, 2.0], [3.0, 4.0], [5.0, 6.0]];
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let s = pool.take_set_copy(&refs);
        assert_eq!(s.len(), 3);
        let warm = pool.stats();
        assert_eq!(warm.allocs, 4); // 3 buffers + 1 outer shell
        pool.put_set(s);
        for _ in 0..5 {
            let s = pool.take_set_copy(&refs);
            assert_eq!(s[1], vec![3.0, 4.0]);
            pool.put_set(s);
        }
        let steady = pool.stats();
        assert_eq!(steady.allocs, warm.allocs, "steady state must not allocate");
        assert!(steady.hits > warm.hits);
    }

    #[test]
    fn pool_is_shared_across_clones_and_threads() {
        let pool = BufferPool::new();
        let handle = pool.clone();
        std::thread::spawn(move || handle.put(vec![1.0f32; 4]))
            .join()
            .unwrap();
        let v = pool.take_zeroed(4);
        assert_eq!(pool.stats().hits, 1, "clone must share the free list");
        assert_eq!(v, vec![0.0f32; 4]);
    }
}
