//! Deterministic, seedable PRNG substrate (no `rand` in the offline crate
//! mirror — built from scratch per the substrate rule).
//!
//! * `SplitMix64` — seed expander (Steele et al.), used to key streams.
//! * `Xoshiro256pp` — the workhorse generator (Blackman & Vigna), passes
//!   BigCrush; `jump()` gives 2^128 non-overlapping substreams so every
//!   worker / data shard / straggler draw has an independent stream.
//! * Box–Muller `next_normal` for Gaussian init and synthetic data.
//!
//! Everything is reproducible from a single experiment seed: stream keys are
//! derived as `seed -> splitmix -> label hash`, so adding a consumer never
//! perturbs the draws of existing consumers.

/// SplitMix64: tiny, solid seed expander.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Expander seeded at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 expanded bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller draw
    spare_normal: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

/// Map 64 random bits to a uniform f32 in [0, 1).
///
/// The obvious `(53-bit f64 draw) as f32` narrowing is *not* half-open:
/// f64 draws within ~2⁻²⁵ of 1.0 round up to exactly `1.0f32`, violating
/// the `[0, 1)` contract (the regression pinned by
/// `next_f32_respects_half_open_contract_at_the_boundary`). Clamp those
/// draws — and only those — to the largest f32 below 1.0, so every
/// in-contract draw keeps its exact pre-fix bits (digest-safe).
#[inline]
fn unit_f32(bits: u64) -> f32 {
    // Largest f32 strictly below 1.0: 1 - 2⁻²⁴.
    const BELOW_ONE: f32 = f32::from_bits(0x3F7F_FFFF);
    let x = ((bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64)) as f32;
    if x < 1.0 { x } else { BELOW_ONE }
}

impl Rng {
    /// Seed via SplitMix64 (the reference-recommended initialization).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream for `label` (e.g. "worker-3/data").
    /// Stable across runs and across unrelated consumers.
    pub fn stream(seed: u64, label: &str) -> Self {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Self::seed_from(seed ^ h)
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        unit_f32(self.next_u64())
    }

    /// Uniform integer in [0, n). Lemire-style rejection to kill modulo bias.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to keep ln finite.
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential with mean `mean` (for shifted-exp straggler model).
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64();
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill with N(0, std^2) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = (self.next_normal() as f32) * std;
        }
    }

    /// The generator's complete internal state `(xoshiro words, cached
    /// Box–Muller draw)` — everything a spill codec must persist so a
    /// restored stream continues bit-for-bit (population store, E17).
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare_normal)
    }

    /// Rebuild a generator from [`Rng::state`]; the restored stream
    /// produces exactly the draws the saved one would have.
    pub fn from_state(s: [u64; 4], spare_normal: Option<f64>) -> Self {
        Self { s, spare_normal }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent_and_stable() {
        let mut a = Rng::stream(1, "worker-0");
        let mut b = Rng::stream(1, "worker-1");
        let (x, y) = (a.next_u64(), b.next_u64());
        assert_ne!(x, y);
        // Re-derivation reproduces the same stream.
        assert_eq!(Rng::stream(1, "worker-0").next_u64(), x);
    }

    #[test]
    fn uniform_is_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn next_f32_respects_half_open_contract_at_the_boundary() {
        // The boundary input: u64::MAX maps to the largest f64 draw,
        // 1 - 2⁻⁵³, which the raw f32 narrowing rounds up to exactly 1.0.
        let raw = ((u64::MAX >> 11) as f64 * (1.0 / (1u64 << 53) as f64)) as f32;
        assert_eq!(raw, 1.0, "the pre-fix narrowing really does escape [0, 1)");
        // The fixed mapping clamps that draw to the largest f32 below 1.0.
        let below_one = f32::from_bits(0x3F7F_FFFF);
        assert_eq!(unit_f32(u64::MAX).to_bits(), below_one.to_bits());
        assert!(unit_f32(u64::MAX) < 1.0);
        // Every in-contract draw keeps its exact pre-fix bits, and the
        // contract holds across a long stream.
        let mut r = Rng::seed_from(23);
        for _ in 0..10_000 {
            let bits = r.next_u64();
            let raw = ((bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64)) as f32;
            let fixed = unit_f32(bits);
            assert!((0.0..1.0).contains(&fixed));
            if raw < 1.0 {
                assert_eq!(raw.to_bits(), fixed.to_bits());
            }
        }
    }

    #[test]
    fn next_below_covers_all_residues() {
        let mut r = Rng::seed_from(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.next_below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.next_normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::seed_from(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.next_exp(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
