//! Hand-rolled property-testing harness (no `proptest` in the offline crate
//! mirror). Deliberately small: a deterministic case generator driven by the
//! repo PRNG, with shrink-free but *reproducible* failure reports — every
//! failing case prints the seed that regenerates it.
//!
//! Usage:
//! ```ignore
//! property("allreduce is exact mean", 200, |g| {
//!     let m = g.usize_in(1, 16);
//!     let v = g.vec_f32(g.usize_in(1, 1000), 10.0);
//!     /* ... assert ... */
//! });
//! ```

use super::rng::Rng;

/// Per-case generator handed to the property body.
pub struct Gen {
    rng: Rng,
    /// the seed that regenerates exactly this case
    pub seed: u64,
}

impl Gen {
    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of f32 uniform in [-scale, scale].
    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(-scale, scale)).collect()
    }

    /// Vector of standard normals scaled by `std`.
    pub fn vec_normal(&mut self, len: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, std);
        v
    }

    /// Random permutation of `0..n` (Fisher–Yates on the case RNG).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut idx);
        idx
    }

    /// Random subset of `items`: each kept independently with probability
    /// `keep_prob`. May be empty — callers needing non-empty subsets must
    /// handle that (e.g. the push-sum dropout rounds, where an empty active
    /// set just means "keep everything local this round").
    pub fn subset<T: Copy>(&mut self, items: &[T], keep_prob: f64) -> Vec<T> {
        items
            .iter()
            .copied()
            .filter(|_| self.rng.next_f64() < keep_prob)
            .collect()
    }

    /// Direct access to the case RNG (for bespoke draws).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `body`. Panics (with the reproducing seed)
/// on the first failing case. The master seed is fixed so CI is stable;
/// override with env `PROPTEST_SEED` to explore.
pub fn property<F: Fn(&mut Gen)>(name: &str, cases: u32, body: F) {
    let master: u64 = std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let mut seeder = Rng::stream(master, name);
    for case in 0..cases {
        let seed = seeder.next_u64();
        let mut g = Gen { rng: Rng::seed_from(seed), seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (PROPTEST_SEED={master}, case seed {seed}): {msg}"
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
#[track_caller]
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "mismatch at [{i}]: {x} vs {y} (|d|={}, tol={tol})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0u32;
        // Property bodies take &mut Gen; use a cell to count.
        let counter = std::cell::Cell::new(0u32);
        property("counting", 50, |_g| {
            counter.set(counter.get() + 1);
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn property_reports_failure_with_seed() {
        property("fails", 10, |g| {
            assert!(g.usize_in(0, 9) > 100, "always fails");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        property("ranges", 100, |g| {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 2.0);
            assert!((-1.0..=2.0).contains(&f));
            let len = g.usize_in(0, 50);
            let v = g.vec_f32(len, 2.0);
            assert!(v.iter().all(|x| x.abs() <= 2.0));
        });
    }

    #[test]
    fn permutation_and_subset_are_well_formed() {
        property("gen permutation/subset", 60, |g| {
            let n = g.usize_in(0, 40);
            let mut p = g.permutation(n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
            let items: Vec<usize> = (0..n).collect();
            let s = g.subset(&items, 0.5);
            assert!(s.len() <= n);
            let mut last = None;
            for &x in &s {
                assert!(items.contains(&x));
                assert!(last.map(|l| l < x).unwrap_or(true), "subset keeps order");
                last = Some(x);
            }
            assert!(g.subset(&items, 1.0).len() == n);
            assert!(g.subset(&items, 0.0).is_empty());
        });
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-8], 1e-6, 1e-6);
    }

    #[test]
    #[should_panic(expected = "mismatch at [1]")]
    fn assert_close_rejects_far() {
        assert_close(&[1.0, 2.0], &[1.0, 3.0], 1e-6, 1e-6);
    }
}
