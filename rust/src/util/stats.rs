//! Small statistics helpers used by metrics, benches, and the simnet.

/// Online mean/variance (Welford) plus min/max.
///
/// `Default` is implemented manually as [`Summary::new`]: a derived
/// `Default` would zero the min/max accumulators, and a `Summary` whose
/// data never contains 0.0 would then silently report `min() = 0.0` /
/// `max() = 0.0` (the regression pinned by `default_is_new`).
///
/// **NaN policy:** a NaN observation poisons *every* statistic — count
/// still advances, and mean/var/min/max all become (and stay) NaN. The
/// pre-fix code was inconsistent: NaN propagated into mean/var through
/// the arithmetic but was silently dropped by `f64::min`/`f64::max`
/// (IEEE min/max discard NaN operands), so a summary could report a
/// clean min/max over poisoned moments (the regression pinned by
/// `nan_poisons_every_statistic_uniformly`).
#[derive(Clone, Debug)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Summary {
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one observation in (see the struct-level NaN policy).
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        // IEEE min/max silently drop NaN operands, which would leave
        // min/max clean while the moments are already poisoned — and a
        // later real observation would launder a NaN min/max back to a
        // real value. Propagate explicitly, and stickily, instead.
        if x.is_nan() || self.min.is_nan() {
            self.min = f64::NAN;
            self.max = f64::NAN;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (0 below two observations).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a copy of the data, by the **nearest-rank** definition:
/// the smallest sample with at least p% of the data at or below it, i.e.
/// the 1-based rank `⌈(p/100)·n⌉` clamped to `[1, n]` (so p = 0 is the
/// minimum and p = 100 the maximum). The pre-fix code claimed nearest-rank
/// but computed a *rounded linear* rank over `n − 1`, which disagrees on
/// every even-length median (the regression pinned by
/// `percentile_nearest_rank_boundaries`).
///
/// Returns `None` on an empty slice — benches skip legs under
/// `OLSGD_SMOKE=1`, so empty sample vectors are a real input, not a
/// programming error. NaN samples are handled by the IEEE total order
/// (`f64::total_cmp`): they sort after every real value instead of
/// aborting the run.
pub fn percentile(xs: &[f64], p: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    Some(v[rank.clamp(1, n) - 1])
}

/// An ordinary-least-squares line `y = intercept + slope * x`, with the
/// coefficient of determination and an explicit degeneracy flag.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearFit {
    /// the fitted intercept a
    pub intercept: f64,
    /// the fitted slope b
    pub slope: f64,
    /// coefficient of determination (0 when the slope is undefined)
    pub r2: f64,
    /// `true` when the data cannot pin a slope (n = 1, or constant x):
    /// `slope` is 0 and `intercept` is the mean of y by convention, and
    /// `r2` is 0 — *not* the bogus "perfect fit" the pre-fix code claimed
    /// for vertical data
    pub degenerate: bool,
}

/// Ordinary least squares fit of `y = a + b*x`. Returns `None` on empty
/// input (the pre-fix code divided by `n = 0`). Constant-x data yields a
/// `degenerate` fit (slope undefined ⇒ reported as 0 with `r2 = 0`);
/// constant-y data over varying x is a genuine perfect horizontal fit
/// (`r2 = 1`).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> Option<LinearFit> {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return None;
    }
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 {
        // n = 1 or constant x: no slope is identifiable.
        return Some(LinearFit { intercept: my, slope: 0.0, r2: 0.0, degenerate: true });
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    // Constant y over varying x: zero residuals, a true perfect fit.
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    Some(LinearFit { intercept: a, slope: b, r2, degenerate: false })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn default_is_new() {
        // Regression: a derived Default once initialized min/max to 0.0,
        // so all-positive data reported min() = 0.0 and all-negative data
        // reported max() = 0.0.
        let mut pos = Summary::default();
        for x in [3.0, 5.0, 9.0] {
            pos.add(x);
        }
        assert_eq!(pos.min(), 3.0, "min must come from the data, not a zeroed sentinel");
        assert_eq!(pos.max(), 9.0);
        let mut neg = Summary::default();
        for x in [-7.0, -2.0, -4.0] {
            neg.add(x);
        }
        assert_eq!(neg.min(), -7.0);
        assert_eq!(neg.max(), -2.0, "max must come from the data, not a zeroed sentinel");
        // And the empty default keeps the ±INFINITY sentinels of new().
        let empty = Summary::default();
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.min(), f64::INFINITY);
        assert_eq!(empty.max(), f64::NEG_INFINITY);
    }

    #[test]
    fn nan_poisons_every_statistic_uniformly() {
        // Regression: IEEE f64::min/max silently drop NaN operands, so a
        // NaN observation used to poison mean/var while min/max stayed
        // clean — the summary looked half-healthy.
        let mut s = Summary::new();
        s.add(1.0);
        s.add(f64::NAN);
        assert_eq!(s.count(), 2, "count still advances on NaN");
        assert!(s.mean().is_nan());
        assert!(s.min().is_nan(), "min must be poisoned like the moments");
        assert!(s.max().is_nan(), "max must be poisoned like the moments");
        // ...and the poison is sticky: a later real observation must not
        // launder min/max back to a real value (bare IEEE min would).
        s.add(5.0);
        assert!(s.var().is_nan());
        assert!(s.min().is_nan());
        assert!(s.max().is_nan());
    }

    #[test]
    fn percentile_nearest_rank_boundaries() {
        // n = 1: every percentile is the single sample.
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(percentile(&[7.0], p), Some(7.0));
        }
        // n = 2: nearest-rank p50 is the *lower* sample (rank ⌈0.5·2⌉ = 1).
        // The pre-fix rounded-linear rank returned the upper one.
        assert_eq!(percentile(&[10.0, 20.0], 0.0), Some(10.0));
        assert_eq!(percentile(&[10.0, 20.0], 50.0), Some(10.0));
        assert_eq!(percentile(&[10.0, 20.0], 100.0), Some(20.0));
        // Even length: p50 → rank ⌈0.5·4⌉ = 2.
        let even = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&even, 0.0), Some(1.0));
        assert_eq!(percentile(&even, 50.0), Some(2.0));
        assert_eq!(percentile(&even, 100.0), Some(4.0));
        // Odd length: p50 → rank ⌈0.5·5⌉ = 3, the true median.
        let odd = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&odd, 0.0), Some(1.0));
        assert_eq!(percentile(&odd, 50.0), Some(3.0));
        assert_eq!(percentile(&odd, 100.0), Some(5.0));
        // Out-of-range p clamps to the extremes rather than indexing out.
        assert_eq!(percentile(&odd, -10.0), Some(1.0));
        assert_eq!(percentile(&odd, 250.0), Some(5.0));
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        assert_eq!(percentile(&xs, 100.0), Some(100.0));
        assert!((percentile(&xs, 50.0).unwrap() - 50.0).abs() <= 1.0);
    }

    #[test]
    fn percentile_handles_empty_and_nan() {
        // Regression: the pre-fix code assert!ed on empty slices and
        // panicked in the sort comparator on NaN.
        assert_eq!(percentile(&[], 50.0), None);
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        // total_cmp sorts NaN after every real value, so low percentiles
        // still see the real data.
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        assert!(percentile(&xs, 100.0).unwrap().is_nan());
        assert_eq!(percentile(&[42.0], 99.0), Some(42.0));
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let f = linear_fit(&xs, &ys).unwrap();
        assert!((f.intercept - 3.0).abs() < 1e-12);
        assert!((f.slope - 2.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
        assert!(!f.degenerate);
    }

    #[test]
    fn linear_fit_edges_are_explicit_not_bogus() {
        // n = 0: no fit at all (the pre-fix code divided by zero).
        assert_eq!(linear_fit(&[], &[]), None);
        // n = 1: degenerate — slope unidentifiable, not a perfect fit.
        let f1 = linear_fit(&[2.0], &[5.0]).unwrap();
        assert!(f1.degenerate);
        assert_eq!(f1.slope, 0.0);
        assert_eq!(f1.intercept, 5.0);
        assert_eq!(f1.r2, 0.0);
        // Constant x, varying y (vertical data): the pre-fix code claimed
        // r2 = 1.0; the slope is undefined, so this is degenerate with
        // r2 = 0.
        let fx = linear_fit(&[4.0, 4.0, 4.0], &[1.0, 2.0, 9.0]).unwrap();
        assert!(fx.degenerate);
        assert_eq!(fx.r2, 0.0);
        assert!((fx.intercept - 4.0).abs() < 1e-12);
        // Constant y over varying x: a genuine perfect horizontal fit.
        let fy = linear_fit(&[1.0, 2.0, 3.0], &[7.0, 7.0, 7.0]).unwrap();
        assert!(!fy.degenerate);
        assert_eq!(fy.slope, 0.0);
        assert!((fy.intercept - 7.0).abs() < 1e-12);
        assert_eq!(fy.r2, 1.0);
    }
}
