//! Small statistics helpers used by metrics, benches, and the simnet.

/// Online mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one observation in.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (0 below two observations).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a copy of the data (nearest-rank).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Ordinary least squares fit y = a + b*x; returns (a, b, r2).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if sxx == 0.0 || syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }
}
