//! Minimal JSON substrate: parser + writer (no `serde` in the offline crate
//! mirror). Parses the AOT `artifacts/manifest.json` and writes experiment
//! result files.
//!
//! Full JSON grammar except for `\u` surrogate pairs outside the BMP (not
//! needed by any producer in this repo, still parsed as a replacement char).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value (numbers are f64, objects are ordered maps).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object (key-sorted)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing characters are an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// Object field lookup; errors on missing key or non-object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    /// Optional object field lookup (None on missing key or non-object).
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    /// The value as a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// The value as an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // ---- writer ----------------------------------------------------------

    /// Serialize with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    /// Serialize without any whitespace.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Inf; null is the conventional stand-in.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (k, item) in v.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, val)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, key);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    val.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for result writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// A number value.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// A string value.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// An array from any iterator of values.
pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

/// An array of numbers.
pub fn arr_f64(items: &[f64]) -> Json {
    Json::Arr(items.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + len).min(self.b.len());
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
            "image_shape": [32, 32, 3],
            "models": {"mlp": {"param_count": 402250,
                               "tensors": [{"name": "fc1.w", "compress": true}]}},
            "train_batch": 32
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("train_batch").unwrap().as_usize().unwrap(), 32);
        let shape: Vec<usize> = j
            .get("image_shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![32, 32, 3]);
        let mlp = j.get("models").unwrap().get("mlp").unwrap();
        assert_eq!(mlp.get("param_count").unwrap().as_usize().unwrap(), 402_250);
        assert!(mlp.get("tensors").unwrap().as_arr().unwrap()[0]
            .get("compress").unwrap().as_bool().unwrap());
    }

    #[test]
    fn round_trips_through_writer() {
        let text = r#"{"a":[1,2.5,-3e2],"b":"hi\nthere","c":null,"d":true}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn escapes_round_trip() {
        let j = Json::Str("quote\" slash\\ nl\n tab\t".to_string());
        let j2 = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo é");
    }
}
