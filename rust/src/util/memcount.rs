//! Opt-in global-allocator instrumentation for benches and tests.
//!
//! The library never installs a global allocator (that is a binary's
//! decision), but it ships one that binaries *can* install to measure true
//! allocator traffic around a region of interest:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: olsgd::util::memcount::CountingAlloc =
//!     olsgd::util::memcount::CountingAlloc;
//! // ...
//! let before = olsgd::util::memcount::snapshot();
//! run_the_hot_region();
//! let spent = olsgd::util::memcount::since(before);
//! println!("{} allocations, {} bytes", spent.allocs, spent.bytes);
//! ```
//!
//! `rust/benches/wallclock.rs` uses this to report whole-process
//! allocations per timed training leg in `BENCH_wallclock.json`
//! (EXPERIMENTS.md E13) — the ground truth the tracked subsystem counters
//! in `TrainLog::hot` are sanity-checked against. Counters are process-wide
//! atomics: cheap (one relaxed add per allocation), always coherent, and
//! zero when the allocator is not installed.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// A pass-through wrapper over the system allocator that counts every
/// allocation (and reallocation) and the bytes requested. Install with
/// `#[global_allocator]` in a bench/test binary; reads come back through
/// [`snapshot`] / [`since`].
pub struct CountingAlloc;

// SAFETY: pure delegation to `System`; the only additions are relaxed
// atomic counter bumps, which allocate nothing and cannot fail.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Monotone allocator counters at one instant (or a difference of two).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemCounters {
    /// allocation + reallocation calls
    pub allocs: u64,
    /// bytes requested by those calls
    pub bytes: u64,
}

/// Current process-wide counters (all-zero unless a binary installed
/// [`CountingAlloc`]).
pub fn snapshot() -> MemCounters {
    MemCounters {
        allocs: ALLOC_CALLS.load(Ordering::Relaxed),
        bytes: ALLOC_BYTES.load(Ordering::Relaxed),
    }
}

/// Counter delta since `start` (saturating, so a stale snapshot cannot
/// underflow).
pub fn since(start: MemCounters) -> MemCounters {
    let now = snapshot();
    MemCounters {
        allocs: now.allocs.saturating_sub(start.allocs),
        bytes: now.bytes.saturating_sub(start.bytes),
    }
}
