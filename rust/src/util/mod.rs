//! Cross-cutting substrates: PRNG, JSON, statistics, property testing.

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
