//! Cross-cutting substrates: PRNG, JSON, statistics, property testing,
//! hot-path memory pooling, and allocator instrumentation.

pub mod json;
pub mod memcount;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
