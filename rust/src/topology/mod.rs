//! Communication-graph substrate: the topology axis of the simulated
//! cluster.
//!
//! Overlap-Local-SGD's anchor pullback (Eq. 8's mixing-matrix framing) does
//! not require a *global* all-reduce — the anchor can be synchronized over
//! any connected graph, which is exactly the regime the paper targets
//! (wireless/sensor networks where a full ring is the worst case; cf.
//! Stochastic Gradient Push, Assran et al. 2018, PAPERS.md). This module
//! owns both planes of that axis (DESIGN.md §8):
//!
//! * **Data plane** — exact reduce/mix schedules over neighbor buffers:
//!   chunked ring (the seed's path, `collective::ring_allreduce_mean`),
//!   two-level hierarchical ring (intra-group ring → size-weighted
//!   inter-group ring over leaders → leader broadcast), binary-tree
//!   reduce-broadcast, and k-regular push-sum gossip (one column-stochastic
//!   mixing round per call; inexact per round, exact in the limit).
//! * **Timing plane** — per-topology virtual cost formulas, delegated to
//!   [`crate::simnet::NetworkModel`]: the ring's α/β model, hierarchical =
//!   intra-ring + inter-ring (+ leader broadcast), tree = `2⌈log2 m⌉`
//!   full-message hops, and gossip = `degree·(latency + bytes/BW)` with
//!   **no global handshake** — gossip never rendezvouses the whole cluster.
//!
//! Push-sum (the SGP weight correction): every mixing round moves a scalar
//! weight alongside each value with the *same* column-stochastic matrix, and
//! estimates de-bias as `value/weight`. On a k-regular graph with uniform
//! shares the matrix is doubly stochastic and the weights stay exactly 1,
//! but the correction is what keeps the fixed point the exact global average
//! under any column-stochastic schedule — e.g. the random edge-dropout
//! rounds of [`Topology::gossip_mix_with`] (the foundation for the planned
//! partial-participation scenarios), property-tested in
//! rust/tests/topology.rs (E10).
//!
//! Both planes take the message size as an argument, so the compression
//! axis (DESIGN.md §12) composes with every graph for free: a compressed
//! strategy quotes its `wire_plan`-scaled byte count and the per-topology
//! cost formulas, `collective_time`, and the `neighbor_bytes` per-link
//! accounting all evaluate at the compressed payload — no per-topology
//! compression code exists anywhere in this module.

use anyhow::{bail, Result};

use crate::collective::{ring_allreduce_mean_with, ReduceScratch};
use crate::fault::AliveSet;
use crate::simnet::NetworkModel;
use crate::util::rng::Rng;

/// Which communication graph the cluster synchronizes over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// Chunked ring all-reduce (NCCL-style) — the seed's one topology.
    Ring,
    /// Two-level ring: intra-group, then inter-group over group leaders.
    Hier,
    /// Binary-tree reduce + broadcast (full message per hop).
    Tree,
    /// Connected k-regular gossip graph with push-sum weights (inexact per
    /// round; only `overlap-gossip` may use it).
    Gossip,
}

impl TopologyKind {
    /// Canonical config-spec name of the graph kind.
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::Hier => "hier",
            TopologyKind::Tree => "tree",
            TopologyKind::Gossip => "gossip",
        }
    }
}

/// A concrete communication graph over `m` workers. Owns the exact data
/// plane (reduce/mix schedules) and the per-collective timing formula.
#[derive(Clone, Debug)]
pub struct Topology {
    /// which graph family this is
    pub kind: TopologyKind,
    /// worker count m
    pub m: usize,
    /// contiguous `[lo, hi)` worker ranges per group (`Hier` only; empty
    /// otherwise)
    groups: Vec<(usize, usize)>,
    /// per-worker sorted neighbor lists (`Gossip` only; empty otherwise)
    adjacency: Vec<Vec<usize>>,
}

impl Topology {
    /// The seed's chunked NCCL-style ring over `m` workers.
    pub fn ring(m: usize) -> Self {
        assert!(m >= 1, "topology needs at least one worker");
        Self { kind: TopologyKind::Ring, m, groups: Vec::new(), adjacency: Vec::new() }
    }

    /// Two-level hierarchy with (up to) `groups` contiguous groups; group
    /// sizes differ by at most one. `groups` is clamped to `[1, m]`.
    pub fn hier(m: usize, groups: usize) -> Self {
        assert!(m >= 1, "topology needs at least one worker");
        let g = groups.clamp(1, m);
        let (base, rem) = (m / g, m % g);
        let mut bounds = Vec::with_capacity(g);
        let mut lo = 0;
        for i in 0..g {
            let size = base + usize::from(i < rem);
            bounds.push((lo, lo + size));
            lo += size;
        }
        Self { kind: TopologyKind::Hier, m, groups: bounds, adjacency: Vec::new() }
    }

    /// Binary-tree reduce-broadcast over `m` workers.
    pub fn tree(m: usize) -> Self {
        assert!(m >= 1, "topology needs at least one worker");
        Self { kind: TopologyKind::Tree, m, groups: Vec::new(), adjacency: Vec::new() }
    }

    /// Connected k-regular gossip graph: circulant offsets `1..=k/2` (plus
    /// the antipode `m/2` for odd k, which needs even `m`), relabeled by a
    /// seeded random permutation so the graph is not axis-aligned with the
    /// worker ids. The effective degree is clamped to `[2, m-1]` (a cycle is
    /// the sparsest connected regular graph); odd k on odd `m` rounds down.
    pub fn gossip(m: usize, degree: usize, seed: u64) -> Result<Self> {
        assert!(m >= 1, "topology needs at least one worker");
        if degree == 0 && m > 1 {
            bail!("gossip_degree must be >= 1 (got 0) for m = {m}");
        }
        let mut adjacency = vec![Vec::new(); m];
        if m >= 2 {
            let k = if m == 2 { 1 } else { degree.clamp(2, m - 1) };
            let k = if k % 2 == 1 && m % 2 == 1 { k - 1 } else { k };
            // Neighbor offsets on the base circulant.
            let mut neigh: Vec<Vec<usize>> = vec![Vec::new(); m];
            for i in 0..m {
                for o in 1..=(k / 2) {
                    neigh[i].push((i + o) % m);
                    neigh[i].push((i + m - o) % m);
                }
                if k % 2 == 1 {
                    neigh[i].push((i + m / 2) % m);
                }
                neigh[i].sort_unstable();
                neigh[i].dedup();
            }
            // Random relabeling (derived stream; perturbs no other consumer).
            let mut perm: Vec<usize> = (0..m).collect();
            Rng::stream(seed, "topology/gossip").shuffle(&mut perm);
            for i in 0..m {
                let mut ns: Vec<usize> = neigh[i].iter().map(|&j| perm[j]).collect();
                ns.sort_unstable();
                adjacency[perm[i]] = ns;
            }
        }
        Ok(Self { kind: TopologyKind::Gossip, m, groups: Vec::new(), adjacency })
    }

    /// Build from a config spec string (`--topology ring|hier|tree|gossip`).
    pub fn from_spec(
        spec: &str,
        m: usize,
        gossip_degree: usize,
        hier_groups: usize,
        seed: u64,
    ) -> Result<Self> {
        Ok(match spec {
            "ring" => Self::ring(m),
            "hier" | "hierarchical" => Self::hier(m, hier_groups),
            "tree" => Self::tree(m),
            "gossip" => Self::gossip(m, gossip_degree, seed)?,
            other => bail!("unknown topology '{other}' (want ring|hier|tree|gossip)"),
        })
    }

    /// Actual per-node degree of the gossip graph (0 unless `Gossip`).
    pub fn degree(&self) -> usize {
        self.adjacency.first().map(|n| n.len()).unwrap_or(0)
    }

    /// Gossip neighbors of worker `i` (empty unless `Gossip`).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        if self.adjacency.is_empty() {
            &[]
        } else {
            &self.adjacency[i]
        }
    }

    /// Hier group bounds `[lo, hi)` (empty unless `Hier`).
    pub fn group_bounds(&self) -> &[(usize, usize)] {
        &self.groups
    }

    // -- data plane ---------------------------------------------------------

    /// Exact in-place all-reduce (mean) over the workers' equal-length
    /// buffers using this topology's schedule. Panics for `Gossip`, whose
    /// per-round mix is inexact — use [`Topology::gossip_mix`] there.
    /// Allocates fresh scratch per call; hot paths use
    /// [`Topology::allreduce_mean_with`].
    pub fn allreduce_mean(&self, buffers: &mut [Vec<f32>]) {
        self.allreduce_mean_with(buffers, &mut ReduceScratch::default());
    }

    /// [`Topology::allreduce_mean`] with caller-provided reusable scratch
    /// (the ring's arena, the tree's broadcast root, the hierarchy's leader
    /// set). Every scratch slot is rewritten before it is read, so reuse is
    /// bit-identical to fresh allocation — the property the pooled
    /// collective path relies on (DESIGN.md §10).
    pub fn allreduce_mean_with(&self, buffers: &mut [Vec<f32>], scratch: &mut ReduceScratch) {
        match self.kind {
            TopologyKind::Ring => ring_allreduce_mean_with(buffers, &mut scratch.arena),
            TopologyKind::Tree => tree_allreduce_mean(buffers, &mut scratch.root),
            TopologyKind::Hier => {
                hier_allreduce_mean(buffers, &self.groups, &mut scratch.arena, &mut scratch.leaders)
            }
            TopologyKind::Gossip => {
                panic!("gossip topology has no exact all-reduce; use gossip_mix")
            }
        }
    }

    /// Alive-set-aware exact all-reduce (DESIGN.md §11): reduces the
    /// *members'* buffers (`alive.members()`) in place to their exact
    /// survivor mean, leaving every other buffer bit-untouched. With a full
    /// alive set this is exactly [`Topology::allreduce_mean_with`]
    /// (bit-identical — the empty-fault-schedule digest guarantee);
    /// otherwise the members' buffers are swapped into compact scratch
    /// slots (no copies, no allocations once warm) and the topology's real
    /// schedule runs over the survivor sub-graph via
    /// [`Topology::allreduce_mean_compact`].
    pub fn allreduce_mean_alive_with(
        &self,
        buffers: &mut [Vec<f32>],
        alive: &AliveSet,
        scratch: &mut ReduceScratch,
    ) {
        assert_eq!(buffers.len(), self.m, "buffer count != topology size");
        assert_eq!(alive.len(), self.m, "alive set != topology size");
        if alive.is_full() {
            return self.allreduce_mean_with(buffers, scratch);
        }
        let members = alive.members();
        assert!(!members.is_empty(), "alive-set reduce needs at least one member");
        let a = members.len();
        if scratch.active.len() < a {
            scratch.active.resize_with(a, Vec::new);
        }
        // Destructure (a reborrow of the scratch fields) so the compact
        // reduce can use the remaining scratch pieces while `active` holds
        // the swapped-in survivor buffers.
        let ReduceScratch { arena, root, leaders, active, bounds } = &mut *scratch;
        for (slot, &w) in members.iter().enumerate() {
            std::mem::swap(&mut active[slot], &mut buffers[w]);
        }
        reduce_compact(self, &mut active[..a], members, arena, root, leaders, bounds);
        for (slot, &w) in members.iter().enumerate() {
            std::mem::swap(&mut active[slot], &mut buffers[w]);
        }
    }

    /// Exact all-reduce (mean) over an already-compacted survivor buffer
    /// set: `buffers[k]` belongs to worker `members[k]` (ascending). Runs
    /// this topology's real schedule on the survivor sub-graph — the ring
    /// and tree over the `a` survivors, the hierarchy over the survivor
    /// intersection of its original groups (size-weighted, so the result is
    /// the exact survivor mean even for ragged subgroup sizes). This is the
    /// data plane of `collective::launch_collective_among`.
    pub fn allreduce_mean_compact(
        &self,
        buffers: &mut [Vec<f32>],
        members: &[usize],
        scratch: &mut ReduceScratch,
    ) {
        assert_eq!(buffers.len(), members.len(), "one buffer per member");
        let ReduceScratch { arena, root, leaders, active: _, bounds } = &mut *scratch;
        reduce_compact(self, buffers, members, arena, root, leaders, bounds);
    }

    /// One push-sum gossip round over the full neighbor sets: returns the
    /// new (biased) values and the matching push-sum weights. De-bias an
    /// estimate as `values[i] / weights[i] as f32`. Allocates its outputs;
    /// the hot path uses [`Topology::gossip_mix_into`] over pooled buffers.
    pub fn gossip_mix(&self, values: &[Vec<f32>], weights: &[f64]) -> (Vec<Vec<f32>>, Vec<f64>) {
        self.gossip_mix_with(values, weights, &self.adjacency)
    }

    /// [`Topology::gossip_mix`] writing into caller-provided storage: `out`
    /// must hold `m` buffers of the value length (they are zeroed here
    /// before accumulation, so recycled buffers are safe) and `w_out` one
    /// weight slot per worker. The accumulation order is identical to
    /// [`Topology::gossip_mix_with`], so the results are bit-identical.
    pub fn gossip_mix_into(
        &self,
        values: &[Vec<f32>],
        weights: &[f64],
        out: &mut [Vec<f32>],
        w_out: &mut [f64],
    ) {
        let m = values.len();
        assert_eq!(m, self.m, "value count != topology size");
        assert_eq!(weights.len(), m, "weight count != topology size");
        assert_eq!(out.len(), m, "output count != topology size");
        assert_eq!(w_out.len(), m, "output weight count != topology size");
        let n = values.first().map(|v| v.len()).unwrap_or(0);
        for o in out.iter_mut() {
            assert_eq!(o.len(), n, "output length mismatch in gossip mix");
            o.fill(0.0);
        }
        w_out.fill(0.0);
        for j in 0..m {
            let neighbors = self.neighbors(j);
            let share = 1.0f32 / (1 + neighbors.len()) as f32;
            for (o, &x) in out[j].iter_mut().zip(values[j].iter()) {
                *o += share * x;
            }
            w_out[j] += share as f64 * weights[j];
            for &i in neighbors {
                for (o, &x) in out[i].iter_mut().zip(values[j].iter()) {
                    *o += share * x;
                }
                w_out[i] += share as f64 * weights[j];
            }
        }
    }

    /// Alive-set-aware push-sum round into caller-provided storage
    /// (DESIGN.md §11): dead workers neither send nor receive (their output
    /// rows are zeroed and their weights land at exactly 0 — the caller
    /// keeps their old state), and every edge is filtered through
    /// [`AliveSet::edge_allowed`], so a partition localizes the mix to each
    /// component. Each live sender spreads uniformly over itself plus its
    /// *allowed* neighbors — column-stochastic over the survivors, so
    /// survivor mass (values and weights alike) is conserved per component
    /// and the de-biased fixed point stays each component's exact survivor
    /// average. With a full alive set this is bit-identical to
    /// [`Topology::gossip_mix_into`].
    pub fn gossip_mix_alive_into(
        &self,
        values: &[Vec<f32>],
        weights: &[f64],
        alive: &AliveSet,
        out: &mut [Vec<f32>],
        w_out: &mut [f64],
    ) {
        let m = values.len();
        assert_eq!(m, self.m, "value count != topology size");
        assert_eq!(alive.len(), m, "alive set != topology size");
        assert_eq!(weights.len(), m, "weight count != topology size");
        assert_eq!(out.len(), m, "output count != topology size");
        assert_eq!(w_out.len(), m, "output weight count != topology size");
        let n = values.first().map(|v| v.len()).unwrap_or(0);
        for o in out.iter_mut() {
            assert_eq!(o.len(), n, "output length mismatch in gossip mix");
            o.fill(0.0);
        }
        w_out.fill(0.0);
        for j in 0..m {
            if !alive.is_alive(j) {
                continue;
            }
            let allowed =
                self.neighbors(j).iter().filter(|&&i| alive.edge_allowed(j, i)).count();
            let share = 1.0f32 / (1 + allowed) as f32;
            for (o, &x) in out[j].iter_mut().zip(values[j].iter()) {
                *o += share * x;
            }
            w_out[j] += share as f64 * weights[j];
            for &i in self.neighbors(j) {
                if !alive.edge_allowed(j, i) {
                    continue;
                }
                for (o, &x) in out[i].iter_mut().zip(values[j].iter()) {
                    *o += share * x;
                }
                w_out[i] += share as f64 * weights[j];
            }
        }
    }

    /// Allocating form of [`Topology::gossip_mix_alive_into`] (tests and
    /// property sweeps).
    pub fn gossip_mix_alive(
        &self,
        values: &[Vec<f32>],
        weights: &[f64],
        alive: &AliveSet,
    ) -> (Vec<Vec<f32>>, Vec<f64>) {
        let n = values.first().map(|v| v.len()).unwrap_or(0);
        let mut out = vec![vec![0.0f32; n]; values.len()];
        let mut w_out = vec![0.0f64; values.len()];
        self.gossip_mix_alive_into(values, weights, alive, &mut out, &mut w_out);
        (out, w_out)
    }

    /// Push-sum round over per-sender *subsets* of the out-edges (partial
    /// participation / dropout). Column j spreads its value and weight
    /// uniformly over itself plus `active_out[j]`; mass is conserved, so the
    /// de-biased fixed point stays the exact global average even when the
    /// matrix is only column-stochastic.
    pub fn gossip_mix_with(
        &self,
        values: &[Vec<f32>],
        weights: &[f64],
        active_out: &[Vec<usize>],
    ) -> (Vec<Vec<f32>>, Vec<f64>) {
        let m = values.len();
        assert_eq!(m, self.m, "value count != topology size");
        assert_eq!(weights.len(), m, "weight count != topology size");
        assert_eq!(active_out.len(), m, "active_out count != topology size");
        let n = values.first().map(|v| v.len()).unwrap_or(0);
        let mut out = vec![vec![0.0f32; n]; m];
        let mut w_out = vec![0.0f64; m];
        for j in 0..m {
            let share = 1.0f32 / (1 + active_out[j].len()) as f32;
            for (o, &x) in out[j].iter_mut().zip(values[j].iter()) {
                *o += share * x;
            }
            w_out[j] += share as f64 * weights[j];
            for &i in &active_out[j] {
                assert!(i < m, "active_out neighbor {i} out of range");
                for (o, &x) in out[i].iter_mut().zip(values[j].iter()) {
                    *o += share * x;
                }
                w_out[i] += share as f64 * weights[j];
            }
        }
        (out, w_out)
    }

    /// The round mixing matrix W (row index = receiver, column = sender):
    /// `(1/m)·11ᵀ` for the exact topologies, the uniform push-share matrix
    /// for gossip. Doubly stochastic in every case (property-tested).
    pub fn mixing_matrix(&self) -> Vec<Vec<f64>> {
        let m = self.m;
        match self.kind {
            TopologyKind::Gossip => {
                let mut w = vec![vec![0.0f64; m]; m];
                for j in 0..m {
                    let share = 1.0 / (1 + self.adjacency[j].len()) as f64;
                    w[j][j] += share;
                    for &i in &self.adjacency[j] {
                        w[i][j] += share;
                    }
                }
                w
            }
            _ => vec![vec![1.0 / m as f64; m]; m],
        }
    }

    // -- timing plane -------------------------------------------------------

    /// Virtual duration of one collective of `bytes` on this topology.
    pub fn collective_time(&self, net: &NetworkModel, bytes: usize) -> f64 {
        match self.kind {
            TopologyKind::Ring => net.allreduce_time(bytes, self.m),
            TopologyKind::Hier => {
                // A single group degenerates to one plain ring (exactly what
                // the data plane runs) — no second phase, no broadcast.
                if self.groups.len() <= 1 {
                    return net.allreduce_time(bytes, self.m);
                }
                let largest = self
                    .groups
                    .iter()
                    .map(|&(lo, hi)| hi - lo)
                    .max()
                    .unwrap_or(self.m);
                net.hier_allreduce_time(bytes, largest, self.groups.len())
            }
            TopologyKind::Tree => net.tree_allreduce_time(bytes, self.m),
            TopologyKind::Gossip => net.gossip_time(bytes, self.degree()),
        }
    }

    /// Virtual duration of one collective of `bytes` over the alive set's
    /// *members* (DESIGN.md §11): the same per-topology formulas evaluated
    /// at the survivor sub-cluster shape — the ring and tree at the member
    /// count, the hierarchy at its largest surviving subgroup and nonempty
    /// group count (degenerating to one plain ring when only one group
    /// survives, mirroring the data plane). Equals
    /// [`Topology::collective_time`] exactly when the alive set is full.
    /// Panics for `Gossip`, whose per-neighborhood timing lives in the
    /// gossip strategy.
    pub fn collective_time_alive(
        &self,
        net: &NetworkModel,
        bytes: usize,
        alive: &AliveSet,
    ) -> f64 {
        if alive.is_full() {
            return self.collective_time(net, bytes);
        }
        let a = alive.member_count();
        match self.kind {
            TopologyKind::Ring => net.allreduce_time(bytes, a),
            TopologyKind::Tree => net.tree_allreduce_time(bytes, a),
            TopologyKind::Hier => {
                let mut largest = 0usize;
                let mut nonempty = 0usize;
                for &(lo, hi) in &self.groups {
                    let size =
                        alive.members().iter().filter(|&&w| (lo..hi).contains(&w)).count();
                    if size > 0 {
                        nonempty += 1;
                        largest = largest.max(size);
                    }
                }
                if nonempty <= 1 {
                    net.allreduce_time(bytes, a)
                } else {
                    net.hier_allreduce_time(bytes, largest, nonempty)
                }
            }
            TopologyKind::Gossip => {
                panic!("gossip timing is per-neighborhood; see coordinator::gossip")
            }
        }
    }

    /// Per-worker bytes *transmitted* during one collective of
    /// `message_bytes` — the `TrainLog::neighbor_bytes` accounting. The ring
    /// keeps the seed's NCCL convention (one full message per worker); the
    /// other topologies count true per-link traffic, which is deliberately
    /// non-uniform (hier leaders and tree inner nodes send more).
    pub fn neighbor_bytes(&self, message_bytes: usize) -> Vec<u64> {
        let msg = message_bytes as u64;
        match self.kind {
            TopologyKind::Ring => vec![msg; self.m],
            TopologyKind::Gossip => {
                (0..self.m).map(|i| self.neighbors(i).len() as u64 * msg).collect()
            }
            TopologyKind::Hier => {
                // A single group is one plain ring: keep the ring convention
                // (matches the data plane's fallback and the timing plane).
                if self.groups.len() <= 1 {
                    return vec![msg; self.m];
                }
                // Members of non-trivial groups send one message in their
                // intra-group ring; each leader additionally sends one in
                // the inter-group ring and one broadcast copy per other
                // member of its group. Size-1 groups have no intra traffic
                // and no broadcast — their leader only rides the inter ring.
                let mut per = vec![0u64; self.m];
                for &(lo, hi) in &self.groups {
                    let size = (hi - lo) as u64;
                    if size > 1 {
                        for w in per.iter_mut().take(hi).skip(lo) {
                            *w += msg; // intra-group ring
                        }
                        per[lo] += (size - 1) * msg; // leader broadcast
                    }
                    per[lo] += msg; // inter-group ring
                }
                per
            }
            TopologyKind::Tree => {
                // Reduce: at each doubling level, node `i+gap` sends its
                // partial to `i`. Broadcast: the reverse — `i` sends to
                // `i+gap` at each level.
                let m = self.m;
                let mut per = vec![0u64; m];
                let mut gap = 1;
                while gap < m {
                    let mut i = 0;
                    while i + gap < m {
                        per[i + gap] += msg; // reduce hop up
                        per[i] += msg; // broadcast hop down
                        i += 2 * gap;
                    }
                    gap *= 2;
                }
                per
            }
        }
    }

    /// [`Topology::neighbor_bytes`] over the alive set: dead (and, for the
    /// exact topologies, partitioned-away) workers transmit nothing, and
    /// every schedule counts the traffic of its survivor sub-graph — the
    /// ring keeps its one-message-per-participant convention, hier/tree
    /// mirror their compact data planes, gossip counts only the edges
    /// [`AliveSet::edge_allowed`] admits. Equal to
    /// [`Topology::neighbor_bytes`] when the alive set is full.
    pub fn neighbor_bytes_alive(&self, message_bytes: usize, alive: &AliveSet) -> Vec<u64> {
        if alive.is_full() {
            return self.neighbor_bytes(message_bytes);
        }
        let msg = message_bytes as u64;
        let mut per = vec![0u64; self.m];
        match self.kind {
            TopologyKind::Ring => {
                for &w in alive.members() {
                    per[w] = msg;
                }
            }
            TopologyKind::Gossip => {
                for i in 0..self.m {
                    if alive.is_alive(i) {
                        let deg = self
                            .neighbors(i)
                            .iter()
                            .filter(|&&j| alive.edge_allowed(i, j))
                            .count();
                        per[i] = deg as u64 * msg;
                    }
                }
            }
            TopologyKind::Tree => {
                // The compact tree over the a survivors, scattered back to
                // their original worker ids.
                let members = alive.members();
                let a = members.len();
                let mut gap = 1;
                while gap < a {
                    let mut i = 0;
                    while i + gap < a {
                        per[members[i + gap]] += msg; // reduce hop up
                        per[members[i]] += msg; // broadcast hop down
                        i += 2 * gap;
                    }
                    gap *= 2;
                }
            }
            TopologyKind::Hier => {
                // Survivor intersection of the original groups, mirroring
                // the masked data plane: one ring message per member of a
                // non-trivial subgroup, the subgroup leader broadcasts and
                // rides the inter ring (only when >= 2 subgroups survive).
                let members = alive.members();
                let nonempty = self
                    .groups
                    .iter()
                    .filter(|&&(lo, hi)| members.iter().any(|&w| (lo..hi).contains(&w)))
                    .count();
                if nonempty <= 1 {
                    for &w in members {
                        per[w] = msg; // one plain ring over the survivors
                    }
                    return per;
                }
                for &(lo, hi) in &self.groups {
                    let sub: Vec<usize> =
                        members.iter().copied().filter(|&w| (lo..hi).contains(&w)).collect();
                    if sub.is_empty() {
                        continue;
                    }
                    let size = sub.len() as u64;
                    if size > 1 {
                        for &w in &sub {
                            per[w] += msg; // intra-group ring
                        }
                        per[sub[0]] += (size - 1) * msg; // leader broadcast
                    }
                    per[sub[0]] += msg; // inter-group ring
                }
            }
        }
        per
    }
}

/// Run `topo`'s exact reduce schedule over an already-compacted survivor
/// buffer set (`bufs[k]` ↔ worker `members[k]`), using the caller's
/// persistent scratch pieces. The hierarchy reduces over the survivor
/// intersection of its original groups (contiguous in the compact index
/// space because groups and members are both ascending), computed into the
/// reusable `bounds` scratch.
fn reduce_compact(
    topo: &Topology,
    bufs: &mut [Vec<f32>],
    members: &[usize],
    arena: &mut Vec<f32>,
    root: &mut Vec<f32>,
    leaders: &mut Vec<Vec<f32>>,
    bounds: &mut Vec<(usize, usize)>,
) {
    match topo.kind {
        TopologyKind::Ring => ring_allreduce_mean_with(bufs, arena),
        TopologyKind::Tree => tree_allreduce_mean(bufs, root),
        TopologyKind::Hier => {
            bounds.clear();
            let mut start = 0usize;
            for &(lo, hi) in &topo.groups {
                let size = members.iter().filter(|&&w| (lo..hi).contains(&w)).count();
                if size > 0 {
                    bounds.push((start, start + size));
                    start += size;
                }
            }
            debug_assert_eq!(start, bufs.len(), "subgroup bounds must cover the members");
            hier_allreduce_mean(bufs, bounds, arena, leaders);
        }
        TopologyKind::Gossip => {
            panic!("gossip topology has no exact all-reduce; use gossip_mix")
        }
    }
}

/// Binary-tree all-reduce (mean): pairwise reduction at doubling gaps, scale
/// at the root, then broadcast back down. Exact global mean everywhere; no
/// chunking, so vectors shorter than the worker count are handled trivially.
/// `root` is reusable scratch for the broadcast copy (fully rewritten).
fn tree_allreduce_mean(buffers: &mut [Vec<f32>], root: &mut Vec<f32>) {
    let m = buffers.len();
    assert!(m > 0, "no buffers");
    let n = buffers[0].len();
    for b in buffers.iter() {
        assert_eq!(b.len(), n, "ragged buffers");
    }
    if m == 1 {
        return;
    }
    let mut gap = 1;
    while gap < m {
        let mut i = 0;
        while i + gap < m {
            let (head, tail) = buffers.split_at_mut(i + gap);
            let dst = &mut head[i];
            let src = &tail[0];
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d += s;
            }
            i += 2 * gap;
        }
        gap *= 2;
    }
    let inv = 1.0f32 / m as f32;
    for v in buffers[0].iter_mut() {
        *v *= inv;
    }
    root.clear();
    root.extend_from_slice(&buffers[0]);
    for b in buffers[1..].iter_mut() {
        b.copy_from_slice(root);
    }
}

/// Hierarchical two-level all-reduce (mean): ring within each contiguous
/// group, size-weighted ring across the group leaders, leader broadcast.
/// Weighting by group size keeps the result the exact *global* mean even
/// when `m % groups != 0` (or when faults leave ragged survivor
/// subgroups). Leader buffers and ring arenas are caller-provided scratch
/// (every slot rewritten before read).
fn hier_allreduce_mean(
    buffers: &mut [Vec<f32>],
    groups: &[(usize, usize)],
    arena: &mut Vec<f32>,
    leader_scratch: &mut Vec<Vec<f32>>,
) {
    let m = buffers.len();
    assert!(m > 0, "no buffers");
    if m == 1 || groups.len() <= 1 {
        ring_allreduce_mean_with(buffers, arena);
        return;
    }
    // Intra-group rings: every member of group g ends with the group mean.
    for &(lo, hi) in groups {
        ring_allreduce_mean_with(&mut buffers[lo..hi], arena);
    }
    // Inter-group ring over size-scaled leader copies:
    // mean_g(size_g * mean_g) = (Σ size_g mean_g) / G, so scaling the ring
    // output by G/m recovers the exact global mean.
    let g = groups.len();
    leader_scratch.resize_with(g.max(leader_scratch.len()), Vec::new);
    for (leader, &(lo, hi)) in leader_scratch.iter_mut().zip(groups) {
        let size = (hi - lo) as f32;
        leader.clear();
        leader.extend(buffers[lo].iter().map(|&v| v * size));
    }
    ring_allreduce_mean_with(&mut leader_scratch[..g], arena);
    let scale = g as f32 / m as f32;
    for v in leader_scratch[0].iter_mut() {
        *v *= scale;
    }
    // Leader broadcast within each group.
    let result = &leader_scratch[0];
    for &(lo, hi) in groups {
        for b in buffers[lo..hi].iter_mut() {
            b.copy_from_slice(result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::ring_allreduce_mean;
    use crate::model::vecmath;
    use crate::util::proptest::assert_close;

    #[test]
    fn scratch_reuse_is_bit_identical_across_topologies() {
        // One ReduceScratch across every exact topology and shape: reused
        // arenas/roots/leaders must never change a bit of any result.
        fn vals(m: usize, n: usize, salt: usize) -> Vec<Vec<f32>> {
            (0..m)
                .map(|w| {
                    (0..n)
                        .map(|i| ((w * 131 + i * 17 + salt) % 101) as f32 * 0.13 - 6.0)
                        .collect()
                })
                .collect()
        }
        let mut scratch = ReduceScratch::default();
        for m in [1usize, 3, 4, 7, 8] {
            for n in [1usize, 5, 64] {
                for (salt, topo) in
                    [Topology::ring(m), Topology::tree(m), Topology::hier(m, 2)]
                        .into_iter()
                        .enumerate()
                {
                    let inputs = vals(m, n, salt);
                    let mut fresh = inputs.clone();
                    topo.allreduce_mean(&mut fresh);
                    let mut reused = inputs;
                    topo.allreduce_mean_with(&mut reused, &mut scratch);
                    for (a, b) in fresh.iter().zip(&reused) {
                        for (x, y) in a.iter().zip(b) {
                            assert_eq!(x.to_bits(), y.to_bits(), "{:?} m={m} n={n}", topo.kind);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn gossip_mix_into_matches_allocating_mix_bitwise() {
        let t = Topology::gossip(6, 2, 3).unwrap();
        let values: Vec<Vec<f32>> = (0..6)
            .map(|w| (0..5).map(|i| (w * 5 + i) as f32 * 0.37 - 3.0).collect())
            .collect();
        let weights = vec![1.0f64; 6];
        let (want_v, want_w) = t.gossip_mix(&values, &weights);
        // Poisoned recycled outputs: gossip_mix_into must fully rewrite.
        let mut out: Vec<Vec<f32>> = vec![vec![f32::NAN; 5]; 6];
        let mut w_out = vec![f64::NAN; 6];
        t.gossip_mix_into(&values, &weights, &mut out, &mut w_out);
        for (a, b) in want_v.iter().zip(&out) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        for (a, b) in want_w.iter().zip(&w_out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn from_spec_round_trips_and_rejects_unknown() {
        for spec in ["ring", "hier", "tree", "gossip"] {
            let t = Topology::from_spec(spec, 8, 4, 2, 1).unwrap();
            assert_eq!(t.kind.name(), spec);
            assert_eq!(t.m, 8);
        }
        assert!(Topology::from_spec("torus", 8, 4, 2, 1).is_err());
    }

    #[test]
    fn hier_groups_partition_the_workers() {
        let t = Topology::hier(10, 4);
        let bounds = t.group_bounds();
        assert_eq!(bounds.len(), 4);
        assert_eq!(bounds[0].0, 0);
        assert_eq!(bounds.last().unwrap().1, 10);
        for pair in bounds.windows(2) {
            assert_eq!(pair[0].1, pair[1].0, "groups must be contiguous");
        }
        let sizes: Vec<usize> = bounds.iter().map(|&(lo, hi)| hi - lo).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn gossip_graph_is_regular_and_connected() {
        for (m, k) in [(2usize, 1usize), (3, 2), (8, 3), (16, 4), (16, 2), (9, 3), (12, 11)] {
            let t = Topology::gossip(m, k, 7).unwrap();
            let deg = t.degree();
            assert!(deg >= 1, "m={m} k={k}: degree 0");
            let mut seen = vec![false; m];
            let mut stack = vec![0usize];
            seen[0] = true;
            while let Some(v) = stack.pop() {
                assert_eq!(t.neighbors(v).len(), deg, "m={m} k={k}: not regular");
                for &u in t.neighbors(v) {
                    assert_ne!(u, v, "self-loop");
                    assert!(t.neighbors(u).contains(&v), "not symmetric");
                    if !seen[u] {
                        seen[u] = true;
                        stack.push(u);
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "m={m} k={k}: disconnected");
        }
    }

    #[test]
    fn gossip_single_worker_is_empty_graph() {
        let t = Topology::gossip(1, 4, 1).unwrap();
        assert_eq!(t.degree(), 0);
        let (vals, ws) = t.gossip_mix(&[vec![2.0f32, -1.0]], &[1.0]);
        assert_close(&vals[0], &[2.0, -1.0], 1e-6, 0.0);
        assert!((ws[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tree_and_hier_match_mean_small() {
        let bufs = vec![vec![1.0f32, 2.0], vec![3.0, 6.0], vec![5.0, 1.0]];
        let want = vecmath::mean(&bufs.iter().map(|b| b.as_slice()).collect::<Vec<_>>());
        let mut t = bufs.clone();
        Topology::tree(3).allreduce_mean(&mut t);
        for b in &t {
            assert_close(b, &want, 1e-6, 1e-6);
        }
        let mut h = bufs.clone();
        Topology::hier(3, 2).allreduce_mean(&mut h);
        for b in &h {
            assert_close(b, &want, 1e-5, 1e-6);
        }
    }

    #[test]
    fn ring_kind_delegates_to_the_seed_collective() {
        let mut a = vec![vec![1.0f32, 5.0], vec![3.0, 7.0]];
        let mut b = a.clone();
        Topology::ring(2).allreduce_mean(&mut a);
        ring_allreduce_mean(&mut b);
        assert_eq!(a, b, "ring topology must be the seed's exact schedule");
    }

    #[test]
    #[should_panic(expected = "gossip topology has no exact all-reduce")]
    fn gossip_allreduce_panics() {
        let t = Topology::gossip(4, 2, 1).unwrap();
        let mut bufs = vec![vec![0.0f32; 2]; 4];
        t.allreduce_mean(&mut bufs);
    }

    // (The doubly-stochastic and tree/hier-vs-mean *property* sweeps live in
    // rust/tests/topology.rs — the E10 suite — to avoid duplicate CI work;
    // the unit tests here are fast deterministic smokes for module hacking.)

    #[test]
    fn timing_formulas_are_positive_and_gossip_skips_the_handshake() {
        let net = NetworkModel::paper_40gbps();
        let bytes = 44_700_000;
        let ring = Topology::ring(16).collective_time(&net, bytes);
        let hier = Topology::hier(16, 4).collective_time(&net, bytes);
        let tree = Topology::tree(16).collective_time(&net, bytes);
        let gossip = Topology::gossip(16, 4, 1).unwrap().collective_time(&net, bytes);
        for t in [ring, hier, tree, gossip] {
            assert!(t > 0.0);
        }
        // Gossip has no rendezvous: for tiny messages its cost drops below
        // every handshake-bearing collective.
        let tiny = 1_000;
        let g_tiny = Topology::gossip(16, 4, 1).unwrap().collective_time(&net, tiny);
        assert!(g_tiny < net.handshake_s);
        assert!(Topology::ring(16).collective_time(&net, tiny) >= net.handshake_s);
    }

    #[test]
    fn neighbor_bytes_shapes() {
        let msg = 1000usize;
        let ring = Topology::ring(4).neighbor_bytes(msg);
        assert_eq!(ring, vec![1000u64; 4]);
        let gossip = Topology::gossip(6, 2, 1).unwrap();
        let gb = gossip.neighbor_bytes(msg);
        assert!(gb.iter().all(|&b| b == 2 * 1000));
        // hier leaders send strictly more than members
        let hier = Topology::hier(8, 2).neighbor_bytes(msg);
        assert!(hier[0] > hier[1]);
        assert_eq!(hier[1], 1000);
        // degenerate hier shapes match their data/timing planes: one group
        // is a plain ring; all-size-1 groups are just the inter-group ring
        let net = NetworkModel::paper_40gbps();
        assert_eq!(Topology::hier(4, 1).neighbor_bytes(msg), vec![1000u64; 4]);
        assert_eq!(
            Topology::hier(4, 1).collective_time(&net, msg),
            Topology::ring(4).collective_time(&net, msg)
        );
        assert_eq!(Topology::hier(4, 4).neighbor_bytes(msg), vec![1000u64; 4]);
        // mixed sizes: size-1 group's leader only rides the inter ring
        assert_eq!(Topology::hier(3, 2).neighbor_bytes(msg), vec![3000, 1000, 1000]);
        // tree totals: every non-root sends once up, every sender once down
        let tree = Topology::tree(8).neighbor_bytes(msg);
        let total: u64 = tree.iter().sum();
        assert_eq!(total, 2 * 7 * 1000);
    }
}
