//! Data substrate: deterministic synthetic-CIFAR + partitioners + batcher.
//!
//! The paper trains on CIFAR-10. In a sealed sandbox we substitute a
//! generator with the same tensor interface (32x32x3 f32 images, 10
//! classes) and CIFAR-like difficulty: a Gaussian mixture whose class means
//! are mildly separated, heteroscedastic per-sample contrast, a second
//! "style" direction shared across classes (so features correlate), and a
//! small label-noise floor that caps attainable accuracy below 100 % —
//! giving algorithms room to rank, exactly what Tables 1–2 need.
//!
//! Partitioners reproduce the paper's two settings:
//! * **IID** — global shuffle, equal shards;
//! * **non-IID** — each node's shard is dominated by one class (the paper:
//!   3125 samples per node, 2000 of them one class ⇒ 64 % skew).

use crate::util::rng::Rng;

/// Image height.
pub const H: usize = 32;
/// Image width.
pub const W: usize = 32;
/// Image channels.
pub const C: usize = 3;
/// Pixels per image (flat NHWC length).
pub const PX: usize = H * W * C;
/// Number of label classes.
pub const NUM_CLASSES: usize = 10;

/// Generation knobs. Defaults are calibrated so the CNN lands in the high-80s
/// / low-90s accuracy regime (CIFAR-like headroom), see data tests.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// class-signal amplitude (prototype scale)
    pub signal: f32,
    /// per-pixel Gaussian noise amplitude
    pub noise: f32,
    /// amplitude of the shared cross-class style direction
    pub style_strength: f32,
    /// probability a label is resampled uniformly (caps accuracy)
    pub label_noise: f64,
}

impl Default for GenConfig {
    fn default() -> Self {
        Self { signal: 0.28, noise: 1.0, style_strength: 0.5, label_noise: 0.06 }
    }
}

/// A dataset in NHWC f32 with i32 labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// flat NHWC image tensor (`n * PX` f32)
    pub images: Vec<f32>,
    /// (possibly noisy) training labels
    pub labels: Vec<i32>,
    /// labels before label-noise injection (for diagnostics)
    pub clean_labels: Vec<i32>,
    /// sample count
    pub n: usize,
}

impl Dataset {
    /// Flat pixels of sample `i`.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * PX..(i + 1) * PX]
    }
}

/// Deterministic synthetic-CIFAR. Train/test splits with different seeds
/// share the same class prototypes (drawn from the base seed), so train and
/// test are i.i.d. from one distribution.
/// Build one smooth spatial prototype: a sum of random low-frequency 2-D
/// cosine modes per channel. Smoothness matters: a conv net with small
/// kernels + global average pooling can only exploit *spatially structured*
/// signal, mirroring real image statistics (iid-noise prototypes would be
/// invisible to it).
fn smooth_prototype(rng: &mut Rng) -> Vec<f32> {
    const MODES: usize = 6;
    let mut proto = vec![0.0f32; PX];
    for _ in 0..MODES {
        // spatial frequency <= 4 cycles per image, random phase/orientation
        let fx = rng.next_f64() * 4.0;
        let fy = rng.next_f64() * 4.0;
        let phase = rng.next_f64() * std::f64::consts::TAU;
        let amp: [f32; C] = [
            rng.next_normal() as f32,
            rng.next_normal() as f32,
            rng.next_normal() as f32,
        ];
        for y in 0..H {
            for x in 0..W {
                let t = std::f64::consts::TAU * (fx * x as f64 + fy * y as f64) / W as f64 + phase;
                let v = t.cos() as f32;
                let base = (y * W + x) * C;
                for (c, &a) in amp.iter().enumerate() {
                    proto[base + c] += a * v;
                }
            }
        }
    }
    // Normalize to unit RMS so `signal` means the same for every class.
    let rms = (proto.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / PX as f64).sqrt();
    let inv = (1.0 / rms.max(1e-9)) as f32;
    for v in proto.iter_mut() {
        *v *= inv;
    }
    proto
}

/// Generate `n` deterministic synthetic-CIFAR samples for `split`
/// (train/test share class prototypes via the base seed but draw disjoint
/// sample streams).
pub fn generate(seed: u64, n: usize, split: &str, cfg: &GenConfig) -> Dataset {
    // Class prototypes + shared style pattern from the base seed.
    let mut proto_rng = Rng::stream(seed, "prototypes");
    let mut protos = Vec::with_capacity(NUM_CLASSES * PX);
    for _ in 0..NUM_CLASSES {
        protos.extend(smooth_prototype(&mut proto_rng));
    }
    let style = smooth_prototype(&mut proto_rng);

    let mut rng = Rng::stream(seed, &format!("samples/{split}"));
    let mut images = vec![0.0f32; n * PX];
    let mut labels = Vec::with_capacity(n);
    let mut clean = Vec::with_capacity(n);

    for i in 0..n {
        let class = rng.next_below(NUM_CLASSES as u64) as usize;
        clean.push(class as i32);
        // contrast jitter: per-sample signal scale in [0.6, 1.4] * signal
        let contrast = cfg.signal * (0.6 + 0.8 * rng.next_f32());
        let style_coef = cfg.style_strength * rng.next_normal() as f32;
        let img = &mut images[i * PX..(i + 1) * PX];
        let p = &protos[class * PX..(class + 1) * PX];
        for j in 0..PX {
            let noise = cfg.noise * rng.next_normal() as f32;
            img[j] = contrast * p[j] + style_coef * style[j] + noise;
        }
        // label noise caps the attainable accuracy
        let label = if rng.next_f64() < cfg.label_noise {
            rng.next_below(NUM_CLASSES as u64) as i32
        } else {
            class as i32
        };
        labels.push(label);
    }

    Dataset { images, labels, clean_labels: clean, n }
}

// --------------------------------------------------------------------------
// Partitioners
// --------------------------------------------------------------------------

/// Equal IID shards after a global shuffle.
pub fn partition_iid(n: usize, m: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    let mut idx: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut idx);
    let per = n / m;
    (0..m).map(|w| idx[w * per..(w + 1) * per].to_vec()).collect()
}

/// Paper-style skewed shards: a `dominant_frac` fraction of each node's
/// shard comes from class `node % 10`; the rest is drawn uniformly from the
/// remaining pool. (Paper: 2000/3125 = 64 % from one class.)
pub fn partition_noniid(
    labels: &[i32],
    m: usize,
    dominant_frac: f64,
    rng: &mut Rng,
) -> Vec<Vec<u32>> {
    let n = labels.len();
    let per = n / m;
    let want_dom = (per as f64 * dominant_frac).round() as usize;

    // Pools per class, shuffled.
    let mut pools: Vec<Vec<u32>> = vec![Vec::new(); NUM_CLASSES];
    for (i, &l) in labels.iter().enumerate() {
        pools[l as usize].push(i as u32);
    }
    for p in pools.iter_mut() {
        rng.shuffle(p);
    }

    let mut shards: Vec<Vec<u32>> = vec![Vec::with_capacity(per); m];
    // Dominant draws first (capped by pool size so small pools degrade
    // gracefully instead of panicking).
    for (w, shard) in shards.iter_mut().enumerate() {
        let class = w % NUM_CLASSES;
        let pool = &mut pools[class];
        let take = want_dom.min(pool.len());
        let split = pool.len() - take;
        shard.extend(pool.drain(split..));
    }
    // Fill the rest round-robin from the leftover pool.
    let mut leftovers: Vec<u32> = pools.into_iter().flatten().collect();
    rng.shuffle(&mut leftovers);
    let mut it = leftovers.into_iter();
    for shard in shards.iter_mut() {
        while shard.len() < per {
            shard.push(it.next().expect("leftover pool exhausted"));
        }
    }
    shards
}

// --------------------------------------------------------------------------
// Batcher
// --------------------------------------------------------------------------

/// Per-worker mini-batch sampler. Reshuffles its shard every epoch with its
/// own PRNG stream; `next_batch` fills caller-owned buffers (no allocation
/// in the training hot loop).
pub struct Batcher {
    shard: Vec<u32>,
    pos: usize,
    rng: Rng,
    /// completed passes over the shard
    pub epochs_completed: usize,
    /// if false (paper: data "not shuffled during training"), the shard
    /// order is fixed after the initial shuffle
    pub reshuffle: bool,
}

impl Batcher {
    /// Sampler over `shard` with worker-keyed shuffling.
    pub fn new(shard: Vec<u32>, seed: u64, worker: usize, reshuffle: bool) -> Self {
        let mut rng = Rng::stream(seed, &format!("batcher/{worker}"));
        let mut shard = shard;
        rng.shuffle(&mut shard);
        Self { shard, pos: 0, rng, epochs_completed: 0, reshuffle }
    }

    /// Samples in this worker's shard.
    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }

    /// Steps per epoch at batch size `b` (drop-last semantics).
    pub fn steps_per_epoch(&self, b: usize) -> usize {
        self.shard.len() / b
    }

    /// The sampler's complete state for the population spill codec
    /// (DESIGN.md §14): `(shard order, cursor, rng)`. The shard *order*
    /// must be persisted — it carries the initial shuffle and every epoch
    /// reshuffle — so a rematerialized worker draws exactly the batches the
    /// evicted one would have.
    pub fn spill_parts(&self) -> (&[u32], usize, &Rng) {
        (&self.shard, self.pos, &self.rng)
    }

    /// Rebuild a sampler from [`Batcher::spill_parts`] plus the public
    /// `epochs_completed`/`reshuffle` fields, continuing the evicted
    /// stream bit-for-bit (no re-shuffle on restore).
    pub fn from_spill_parts(
        shard: Vec<u32>,
        pos: usize,
        rng: Rng,
        epochs_completed: usize,
        reshuffle: bool,
    ) -> Self {
        Self { shard, pos, rng, epochs_completed, reshuffle }
    }

    /// Fill `images`/`labels` with the next batch of `b` samples.
    pub fn next_batch(&mut self, ds: &Dataset, b: usize, images: &mut [f32], labels: &mut [i32]) {
        assert_eq!(images.len(), b * PX);
        assert_eq!(labels.len(), b);
        for k in 0..b {
            if self.pos >= self.shard.len() {
                self.pos = 0;
                self.epochs_completed += 1;
                if self.reshuffle {
                    self.rng.shuffle(&mut self.shard);
                }
            }
            let i = self.shard[self.pos] as usize;
            self.pos += 1;
            images[k * PX..(k + 1) * PX].copy_from_slice(ds.image(i));
            labels[k] = ds.labels[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn generation_is_deterministic() {
        let cfg = GenConfig::default();
        let a = generate(1, 64, "train", &cfg);
        let b = generate(1, 64, "train", &cfg);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn train_and_test_differ_but_share_distribution() {
        let cfg = GenConfig::default();
        let tr = generate(1, 256, "train", &cfg);
        let te = generate(1, 256, "test", &cfg);
        assert_ne!(tr.images, te.images);
        // Both splits hit every class.
        for split in [&tr, &te] {
            let mut seen = [false; NUM_CLASSES];
            for &l in &split.clean_labels {
                seen[l as usize] = true;
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn nearest_prototype_classifier_works_but_not_perfectly() {
        // The generator must be learnable (signal present) yet non-trivial
        // (label noise + overlap). A nearest-class-mean classifier on clean
        // labels should score well above chance and below 100 %.
        let cfg = GenConfig::default();
        let tr = generate(3, 2000, "train", &cfg);
        let te = generate(3, 500, "test", &cfg);
        // class means from train
        let mut means = vec![0.0f64; NUM_CLASSES * PX];
        let mut counts = [0usize; NUM_CLASSES];
        for i in 0..tr.n {
            let c = tr.labels[i] as usize;
            counts[c] += 1;
            for j in 0..PX {
                means[c * PX + j] += tr.image(i)[j] as f64;
            }
        }
        for c in 0..NUM_CLASSES {
            for j in 0..PX {
                means[c * PX + j] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..te.n {
            let img = te.image(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..NUM_CLASSES {
                let d: f64 = (0..PX)
                    .map(|j| {
                        let d = img[j] as f64 - means[c * PX + j];
                        d * d
                    })
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 as i32 == te.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.n as f64;
        assert!(acc > 0.5, "generator unlearnable: acc {acc}");
        assert!(acc < 0.995, "generator trivially separable: acc {acc}");
    }

    #[test]
    fn iid_partition_covers_disjointly() {
        let mut rng = Rng::seed_from(9);
        let shards = partition_iid(1000, 8, &mut rng);
        assert_eq!(shards.len(), 8);
        let mut seen = vec![false; 1000];
        for s in &shards {
            assert_eq!(s.len(), 125);
            for &i in s {
                assert!(!seen[i as usize], "duplicate index {i}");
                seen[i as usize] = true;
            }
        }
    }

    #[test]
    fn noniid_partition_has_requested_skew() {
        let cfg = GenConfig::default();
        let ds = generate(5, 4000, "train", &cfg);
        let mut rng = Rng::seed_from(7);
        let shards = partition_noniid(&ds.labels, 8, 0.64, &mut rng);
        for (w, shard) in shards.iter().enumerate() {
            let dom = w % NUM_CLASSES;
            let count = shard.iter().filter(|&&i| ds.labels[i as usize] == dom as i32).count();
            let frac = count as f64 / shard.len() as f64;
            assert!(frac > 0.5, "worker {w}: dominant frac {frac} too low");
        }
    }

    #[test]
    fn property_noniid_is_disjoint_partition() {
        property("noniid disjoint", 40, |g| {
            let n = g.usize_in(100, 2000);
            let m = g.usize_in(1, 10);
            let labels: Vec<i32> =
                (0..n).map(|_| g.usize_in(0, NUM_CLASSES - 1) as i32).collect();
            let frac = g.f64_in(0.0, 0.9);
            let shards = partition_noniid(&labels, m, frac, g.rng());
            let mut seen = vec![false; n];
            let per = n / m;
            for s in &shards {
                assert_eq!(s.len(), per);
                for &i in s {
                    assert!(!seen[i as usize], "duplicate {i}");
                    seen[i as usize] = true;
                }
            }
        });
    }

    #[test]
    fn batcher_visits_whole_shard_each_epoch() {
        let cfg = GenConfig::default();
        let ds = generate(2, 64, "train", &cfg);
        let shard: Vec<u32> = (0..64).collect();
        let mut b = Batcher::new(shard, 0, 0, true);
        let mut imgs = vec![0.0f32; 8 * PX];
        let mut labels = vec![0i32; 8];
        let mut seen = vec![0usize; 64];
        for _ in 0..8 {
            b.next_batch(&ds, 8, &mut imgs, &mut labels);
            // find which dataset rows these came from by label+first pixel
            for k in 0..8 {
                let px0 = imgs[k * PX];
                let row = (0..64).find(|&i| ds.image(i)[0] == px0).unwrap();
                seen[row] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "epoch must visit each sample once");
        assert_eq!(b.epochs_completed, 0);
        b.next_batch(&ds, 8, &mut imgs, &mut labels);
        assert_eq!(b.epochs_completed, 1);
    }

    #[test]
    fn batcher_no_reshuffle_is_periodic() {
        let cfg = GenConfig::default();
        let ds = generate(2, 32, "train", &cfg);
        let shard: Vec<u32> = (0..32).collect();
        let mut b = Batcher::new(shard, 0, 3, false);
        let mut i1 = vec![0.0f32; 16 * PX];
        let mut l1 = vec![0i32; 16];
        let mut first_epoch = Vec::new();
        for _ in 0..2 {
            b.next_batch(&ds, 16, &mut i1, &mut l1);
            first_epoch.extend_from_slice(&l1);
        }
        let mut second_epoch = Vec::new();
        for _ in 0..2 {
            b.next_batch(&ds, 16, &mut i1, &mut l1);
            second_epoch.extend_from_slice(&l1);
        }
        assert_eq!(first_epoch, second_epoch, "no-reshuffle must repeat order");
    }
}
