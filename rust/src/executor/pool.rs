//! Persistent execution pool: m parked worker threads plus one dedicated
//! communicator thread, alive for a whole training run (DESIGN.md §10).
//!
//! The previous threads backend re-spawned `thread::scope` workers every
//! round and a detached OS thread for every collective — ~m+1 spawns per
//! round of pure fixed overhead that capped the measured overlap speedup
//! (the failure mode persistent-communication-worker designs like DaSGD
//! and Stochastic Gradient Push engineer around; PAPERS.md). This pool
//! spawns each thread **once** per run and drives it by channel dispatch:
//!
//! * **worker threads** — each parks on its own job channel. Per round the
//!   coordinator sends worker w a [`PhaseJob`] (that worker's `StepView`
//!   plus its step budget) and the thread runs the *same*
//!   `executor::drive_worker` burst as the sim backend, reporting back
//!   over a shared result channel. Worker w's jobs always run on thread w.
//!   The same threads also serve chunk jobs for the pooled bit-identical
//!   parallel mean ([`WorkerPool::mean_into`]).
//! * **the communicator thread** — parks on a job queue of reduction
//!   closures and owns a persistent [`ReduceScratch`], so the data plane
//!   of every collective reuses one arena instead of allocating per call.
//!   Results come back through one persistent reply channel tagged with a
//!   launch sequence number (an abandoned collective's result is skipped,
//!   never misdelivered).
//!
//! # Safety model
//!
//! A `StepView` borrows one worker's state from `Workers` for less than
//! `'static`, but a persistent thread can only receive `'static` data, so
//! [`PhaseJob::erase`] (unsafe) transmutes the lifetimes away — the same
//! lifetime-erasure trick scoped-thread libraries use internally. The
//! soundness contract, upheld by [`WorkerPool::run_phase`] and
//! [`WorkerPool::mean_into`]:
//!
//! 1. every dispatched job is awaited before the dispatching call returns
//!    (even on error paths the reply channel is drained first), so the
//!    erased borrows never outlive the frame that created them;
//! 2. a worker thread drops the job — and with it every erased reference —
//!    *before* signaling completion (panics are caught and reported the
//!    same way, so a panicking kernel cannot leave the coordinator waiting
//!    or a borrow dangling);
//! 3. jobs are disjoint by construction: `Workers::step_views` hands out
//!    non-overlapping `&mut` bundles, and mean chunks split the output
//!    slice with `chunks_mut`.
//!
//! Virtual time still comes exclusively from the simnet cost model, so the
//! pool changes no observable: the cross-backend golden tests
//! (`rust/tests/golden_regression.rs`) and the zero-steady-state counters
//! (`rust/tests/hot_path.rs`) pin both properties.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{anyhow, Result};

use super::{drive_worker, CommJob, CommReplyRx, ReduceHandle, WorkerRound};
use crate::collective::ReduceScratch;
use crate::coordinator::engine::{LocalPhase, RoundPlan};
use crate::coordinator::{StepView, TrainContext};
use crate::model::simd::{self, KernelTier};

/// One worker's share of a round, with the borrows of its `StepView` (and
/// of the shared `TrainContext`) erased to `'static` so the job can cross
/// into a persistent thread. See the module-level safety model.
struct PhaseJob {
    view: StepView<'static>,
    ctx: &'static TrainContext<'static>,
    steps: usize,
    start_step: usize,
    phase: LocalPhase,
    round: WorkerRound,
}

impl PhaseJob {
    /// Erase the borrows in `view`/`ctx` to `'static`.
    ///
    /// # Safety
    ///
    /// The caller must not let the erased job (or any result derived from
    /// its borrows) outlive the real lifetimes — concretely: dispatch the
    /// job to a pool thread and block until that thread reports the job
    /// complete, within the borrow's original scope.
    unsafe fn erase(
        view: StepView<'_>,
        ctx: &TrainContext<'_>,
        steps: usize,
        start_step: usize,
        phase: LocalPhase,
        round: WorkerRound,
    ) -> Self {
        // SAFETY: transmuting only changes lifetime parameters; the types
        // are otherwise identical, and the caller upholds the blocking
        // contract above.
        let view = unsafe { std::mem::transmute::<StepView<'_>, StepView<'static>>(view) };
        let ctx = unsafe {
            std::mem::transmute::<&TrainContext<'_>, &'static TrainContext<'static>>(ctx)
        };
        PhaseJob { view, ctx, steps, start_step, phase, round }
    }
}

/// One contiguous chunk of a pooled parallel mean, lifetime-erased like
/// [`PhaseJob`] (chunks borrow disjoint `chunks_mut` pieces of the output).
struct MeanChunk {
    vs: &'static [&'static [f32]],
    out: &'static mut [f32],
    lo: usize,
    tier: KernelTier,
    ack: Sender<bool>,
}

impl MeanChunk {
    /// Erase the borrows in `vs`/`out` to `'static`.
    ///
    /// # Safety
    ///
    /// Same contract as [`PhaseJob::erase`]: the dispatching call must
    /// block until the chunk's ack arrives before the real borrows end.
    unsafe fn erase(
        vs: &[&[f32]],
        out: &mut [f32],
        lo: usize,
        tier: KernelTier,
        ack: Sender<bool>,
    ) -> Self {
        let vs = unsafe { std::mem::transmute::<&[&[f32]], &'static [&'static [f32]]>(vs) };
        let out = unsafe { std::mem::transmute::<&mut [f32], &'static mut [f32]>(out) };
        MeanChunk { vs, out, lo, tier, ack }
    }
}

enum WorkerMsg {
    Phase(PhaseJob),
    Mean(MeanChunk),
}

/// The persistent pool: one parked OS thread per simulated worker plus the
/// dedicated communicator thread. Spawns exactly `m + 1` threads at
/// construction and zero afterwards (`spawns` is the counter surfaced in
/// `TrainLog::hot`).
pub(crate) struct WorkerPool {
    m: usize,
    job_txs: Vec<Sender<WorkerMsg>>,
    phase_rx: Receiver<(usize, Result<WorkerRound>)>,
    ack_tx: Sender<bool>,
    ack_rx: Receiver<bool>,
    comm_tx: Option<Sender<(u64, CommJob)>>,
    reply_rx: CommReplyRx,
    next_seq: Cell<u64>,
    spawns: u64,
    handles: Vec<thread::JoinHandle<()>>,
}

fn worker_main(w: usize, rx: Receiver<WorkerMsg>, tx: Sender<(usize, Result<WorkerRound>)>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Phase(job) => {
                let PhaseJob { mut view, ctx, steps, start_step, phase, mut round } = job;
                let res = catch_unwind(AssertUnwindSafe(|| {
                    drive_worker(&mut view, ctx, steps, start_step, phase, &mut round)
                }));
                // Erased borrows end here, before the coordinator is
                // signaled (safety contract #2).
                drop(view);
                let out = match res {
                    Ok(Ok(())) => Ok(round),
                    Ok(Err(e)) => Err(e),
                    Err(_) => Err(anyhow!("pool worker {w} panicked during the local phase")),
                };
                // A send can only fail if the coordinator already bailed;
                // the round is doomed either way, so the result may drop.
                let _ = tx.send((w, out));
            }
            WorkerMsg::Mean(chunk) => {
                let MeanChunk { vs, out, lo, tier, ack } = chunk;
                let ok = catch_unwind(AssertUnwindSafe(|| {
                    // The shared chunk kernel keeps the per-element
                    // operation sequence of the serial `vecmath::mean_into`
                    // (accumulate in input order, then scale) on either
                    // tier — the bit-identity guarantee.
                    simd::mean_chunk_into(tier, vs, lo, out);
                }))
                .is_ok();
                let _ = ack.send(ok);
            }
        }
    }
}

fn communicator_main(rx: Receiver<(u64, CommJob)>, tx: Sender<(u64, Vec<Vec<f32>>)>) {
    // The persistent per-thread scratch every reduce schedule reuses.
    let mut scratch = ReduceScratch::default();
    while let Ok((seq, job)) = rx.recv() {
        let out = job(&mut scratch);
        // The receiver outlives every handle (it is pool state); a failed
        // send means the pool is tearing down and the result may drop.
        let _ = tx.send((seq, out));
    }
}

impl WorkerPool {
    /// Spawn the pool for `m` simulated workers (`m + 1` OS threads,
    /// counted once — the steady-state spawn count is zero by
    /// construction).
    pub(crate) fn new(m: usize) -> Self {
        assert!(m > 0, "worker pool needs at least one worker");
        let (phase_tx, phase_rx) = channel();
        let (ack_tx, ack_rx) = channel();
        let mut job_txs = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m + 1);
        for w in 0..m {
            let (tx, rx) = channel();
            job_txs.push(tx);
            let phase_tx = phase_tx.clone();
            let h = thread::Builder::new()
                .name(format!("olsgd-worker-{w}"))
                .spawn(move || worker_main(w, rx, phase_tx))
                .expect("spawning a pool worker thread failed");
            handles.push(h);
        }
        let (comm_tx, comm_rx) = channel();
        let (reply_tx, reply_rx) = channel();
        let h = thread::Builder::new()
            .name("olsgd-communicator".into())
            .spawn(move || communicator_main(comm_rx, reply_tx))
            .expect("spawning the communicator thread failed");
        handles.push(h);
        Self {
            m,
            job_txs,
            phase_rx,
            ack_tx,
            ack_rx,
            comm_tx: Some(comm_tx),
            reply_rx: Arc::new(Mutex::new(reply_rx)),
            next_seq: Cell::new(0),
            spawns: (m + 1) as u64,
            handles,
        }
    }

    /// OS threads this pool has ever spawned (constant after construction).
    pub(crate) fn spawns(&self) -> u64 {
        self.spawns
    }

    /// Run one round's local phase on the parked worker threads: dispatch
    /// worker w's view to thread w, then block until all dispatched jobs
    /// report back (the lifetime-erasure soundness contract). `rounds`
    /// supplies one recycled result buffer per view.
    pub(crate) fn run_phase(
        &self,
        views: Vec<StepView<'_>>,
        ctx: &TrainContext,
        plan: &RoundPlan,
        start_step: usize,
        phase: LocalPhase,
        mut rounds: Vec<WorkerRound>,
    ) -> Result<Vec<WorkerRound>> {
        let m = views.len();
        assert_eq!(m, self.m, "local phase has {m} views but the pool serves {}", self.m);
        assert_eq!(rounds.len(), m, "one recycled round buffer per view");
        let mut slots: Vec<Option<WorkerRound>> = (0..m).map(|_| None).collect();
        let mut dispatched = 0usize;
        let mut dispatch_err = None;
        for (w, view) in views.into_iter().enumerate() {
            let round = rounds.pop().expect("checked above");
            if plan.steps[w] == 0 {
                // Parked worker (fault subsystem, DESIGN.md §11): no job is
                // dispatched — its thread stays parked, spawning nothing —
                // and the recycled (cleared) buffer is its empty result.
                slots[w] = Some(round);
                continue;
            }
            // SAFETY: this loop dispatches to parked threads and the drain
            // below blocks until every dispatched job has reported back;
            // worker threads drop the job (ending the erased borrows)
            // before reporting. On a failed send the job comes back inside
            // the error and is dropped here, un-run.
            let job =
                unsafe { PhaseJob::erase(view, ctx, plan.steps[w], start_step, phase, round) };
            match self.job_txs[w].send(WorkerMsg::Phase(job)) {
                Ok(()) => dispatched += 1,
                Err(_dropped_job) => {
                    dispatch_err = Some(anyhow!("pool worker {w} exited before the round"));
                    break;
                }
            }
        }
        // Drain every dispatched job before any early return — the erased
        // borrows must not outlive this frame even when the round failed.
        let mut job_err: Option<anyhow::Error> = None;
        for _ in 0..dispatched {
            let (w, out) = self
                .phase_rx
                .recv()
                .expect("pool result channel broken with jobs in flight");
            match out {
                Ok(r) => slots[w] = Some(r),
                Err(e) => job_err = job_err.or(Some(e)),
            }
        }
        if let Some(e) = dispatch_err {
            return Err(e);
        }
        if let Some(e) = job_err {
            return Err(e);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(w, r)| r.ok_or_else(|| anyhow!("worker {w} reported no round result")))
            .collect()
    }

    /// Dispatch a reduction job to the parked communicator thread and
    /// return immediately. Jobs complete in FIFO order; the handle's
    /// sequence number keeps an abandoned collective's result from being
    /// misdelivered to a later `wait`.
    pub(crate) fn start_reduce(&self, job: CommJob) -> ReduceHandle {
        let seq = self.next_seq.get();
        self.next_seq.set(seq + 1);
        self.comm_tx
            .as_ref()
            .expect("communicator sender lives as long as the pool")
            .send((seq, job))
            .expect("communicator thread exited with the pool alive");
        ReduceHandle::Pending { reply: Arc::clone(&self.reply_rx), seq }
    }

    /// Pooled thread-parallel mean, *bit*-identical to
    /// `vecmath::mean_into` on either kernel tier: the same contiguous
    /// chunking as `vecmath::mean_into_parallel` with one chunk per pool
    /// worker, served by the parked threads instead of fresh spawns, each
    /// chunk running the tier-dispatched `simd::mean_chunk_into`. `out` is
    /// unconditionally overwritten.
    pub(crate) fn mean_into(&self, vs: &[&[f32]], out: &mut [f32], tier: KernelTier) {
        let count = vs.len();
        assert!(count > 0, "mean of zero vectors");
        for v in vs {
            assert_eq!(v.len(), out.len(), "length mismatch in mean");
        }
        let n = out.len();
        let t = self.m.max(1).min(n.max(1));
        if t <= 1 {
            return simd::mean_into(tier, vs, out);
        }
        let chunk = n.div_ceil(t);
        let mut sent = 0usize;
        let mut dispatch_failed = false;
        for (ci, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let lo = ci * chunk;
            // SAFETY: chunks are disjoint `chunks_mut` slices; the ack
            // drain below blocks until every dispatched chunk is done (the
            // worker drops its erased borrows before acking), so no borrow
            // escapes this frame. A failed send drops the chunk un-run.
            let job = unsafe { MeanChunk::erase(vs, out_chunk, lo, tier, self.ack_tx.clone()) };
            if self.job_txs[ci].send(WorkerMsg::Mean(job)).is_err() {
                dispatch_failed = true;
                break;
            }
            sent += 1;
        }
        let mut ok = true;
        for _ in 0..sent {
            ok &= self.ack_rx.recv().expect("pool ack channel broken with chunks in flight");
        }
        assert!(!dispatch_failed, "a pool worker exited before the mean");
        assert!(ok, "a pooled mean chunk panicked");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job channels parks the threads out of their recv
        // loops; join so a finished run leaves no threads behind.
        self.job_txs.clear();
        self.comm_tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
