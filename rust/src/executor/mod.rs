//! Execution backends: how one round of scheduled work actually runs.
//!
//! The round **engine** (`coordinator::engine`) decides *what* happens —
//! the plan, the mixing decision, the virtual timeline. The execution
//! mode ([`Execution`], from the config's `execution` key) decides
//! *where* it happens; this module implements both backends on that
//! enum:
//!
//! * [`Execution::Sim`] — everything on the calling thread, in
//!   worker-major order. Concurrency is purely virtual (clock
//!   arithmetic). This is the deterministic discrete-event mode every
//!   experiment defaults to.
//! * [`Execution::Threads`] — the round's local phase runs on **one OS
//!   thread per simulated worker** (`threads.rs`), and every collective
//!   launched through [`Execution::start_reduce`] runs on a **background
//!   communicator thread**, so an overlapped schedule genuinely computes
//!   local steps while the previous round's all-reduce is in flight.
//!   This is the backend `rust/benches/wallclock.rs` measures (E12).
//!
//! **Digest identity** (asserted for every algorithm by
//! `rust/tests/golden_regression.rs`): the two backends produce
//! bit-identical `TrainLog`s because
//!
//! 1. all training numerics run on per-worker [`StepView`]s — no state is
//!    shared between workers during a local phase, and each worker's
//!    operation sequence (batch draws, RNG stream, kernel calls) is the
//!    same regardless of which thread runs it;
//! 2. cross-worker reductions (loss folding, clock charging, gradient
//!    collection) happen on the coordinator in fixed worker order, fed
//!    from the per-worker [`WorkerRound`] results;
//! 3. a background collective computes the *same* reduction code over the
//!    *same* snapshot the sim backend reduces eagerly, and its virtual
//!    completion time comes from the simnet cost model, never from wall
//!    clock.

pub mod threads;

use anyhow::Result;

use crate::config::Execution;
use crate::coordinator::engine::{LocalPhase, RoundPlan};
use crate::coordinator::{StepView, TrainContext};

/// What one worker produced during a round's local phase, in its own step
/// order. The engine folds these in worker-major order, so the fold is
/// identical no matter how the phase was scheduled.
pub struct WorkerRound {
    /// per-step mini-batch losses (length = planned steps; 1 in grad mode)
    pub losses: Vec<f64>,
    /// per-step virtual compute durations, parallel to `losses`
    pub dts: Vec<f64>,
    /// the raw gradient (grad-only phase; `None` for fused steps)
    pub grad: Option<Vec<f32>>,
}

/// Run one worker's share of a round: `steps` fused steps, or one
/// gradient. Both backends call exactly this function — the sim backend on
/// the coordinator thread, the threads backend on the worker's own thread.
pub(crate) fn drive_worker(
    view: &mut StepView<'_>,
    ctx: &TrainContext,
    steps: usize,
    start_step: usize,
    phase: LocalPhase,
) -> Result<WorkerRound> {
    match phase {
        LocalPhase::FusedSteps => {
            let mut losses = Vec::with_capacity(steps);
            let mut dts = Vec::with_capacity(steps);
            for s in 0..steps {
                let (loss, dt) = view.fused_step(ctx, start_step + s)?;
                losses.push(loss);
                dts.push(dt);
            }
            Ok(WorkerRound { losses, dts, grad: None })
        }
        LocalPhase::GradOnly => {
            let (loss, dt, g) = view.grad_only(ctx)?;
            Ok(WorkerRound { losses: vec![loss], dts: vec![dt], grad: Some(g) })
        }
    }
}

// The execution *behavior* lives here, as inherent methods on the config
// enum — one type names the axis end to end, so a future third backend is
// added in exactly one place. Worker threads are scoped to each round and
// communicator threads to each collective; no backend keeps a pool, so a
// run can never leak threads past its own lifetime.
impl Execution {
    /// Execute one round's local phase over the per-worker views (worker
    /// order in, worker order out). `plan.steps[w]` fused steps per worker,
    /// or one gradient each in grad mode. `Sim` drives the views
    /// sequentially on the calling thread; `Threads` spawns one OS thread
    /// per worker.
    pub fn run_phase(
        &self,
        views: Vec<StepView<'_>>,
        ctx: &TrainContext,
        plan: &RoundPlan,
        start_step: usize,
        phase: LocalPhase,
    ) -> Result<Vec<WorkerRound>> {
        match self {
            Execution::Sim => {
                let mut out = Vec::with_capacity(views.len());
                for (w, mut view) in views.into_iter().enumerate() {
                    out.push(drive_worker(&mut view, ctx, plan.steps[w], start_step, phase)?);
                }
                Ok(out)
            }
            Execution::Threads => threads::run_phase(views, ctx, plan, start_step, phase),
        }
    }

    /// Run a reduction job — the data plane of a collective or gossip
    /// exchange over an owned snapshot. `Sim` computes it inline (eager,
    /// the seed semantics); `Threads` spawns a background communicator
    /// thread and returns immediately, which is what lets the next round's
    /// local compute overlap the wire work for real.
    ///
    /// The `'static` bound exists for the communicator thread; on the sim
    /// backend, callers with borrowable inputs can skip the snapshot and
    /// build a [`ReduceHandle::Ready`] directly (see
    /// `coordinator::gossip`).
    pub fn start_reduce(
        &self,
        job: impl FnOnce() -> Vec<Vec<f32>> + Send + 'static,
    ) -> ReduceHandle {
        match self {
            Execution::Sim => ReduceHandle::Ready(job()),
            Execution::Threads => ReduceHandle::InFlight(threads::spawn_communicator(job)),
        }
    }
}

/// Handle to a (possibly in-flight) reduction launched via
/// [`Execution::start_reduce`]. Dropping an `InFlight` handle detaches the
/// communicator thread (it owns only its snapshot, so this is safe — it
/// happens when a run ends with a collective still pending, exactly like
/// the sim backend dropping an unabsorbed result).
pub enum ReduceHandle {
    /// the reduction already ran inline (sim backend)
    Ready(Vec<Vec<f32>>),
    /// the reduction is running on a background communicator thread
    InFlight(std::thread::JoinHandle<Vec<Vec<f32>>>),
}

impl ReduceHandle {
    /// Block until the reduction is done and take its output buffers.
    /// Instant on `Ready`; joins the communicator thread on `InFlight`.
    pub fn wait(self) -> Vec<Vec<f32>> {
        match self {
            ReduceHandle::Ready(v) => v,
            ReduceHandle::InFlight(h) => h.join().expect("communicator thread panicked"),
        }
    }

    /// Whether `wait` would return without blocking.
    pub fn is_finished(&self) -> bool {
        match self {
            ReduceHandle::Ready(_) => true,
            ReduceHandle::InFlight(h) => h.is_finished(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_job(inputs: Vec<Vec<f32>>) -> impl FnOnce() -> Vec<Vec<f32>> + Send + 'static {
        move || {
            let mut acc = vec![0.0f32; inputs[0].len()];
            for v in &inputs {
                for (a, &x) in acc.iter_mut().zip(v) {
                    *a += x;
                }
            }
            vec![acc]
        }
    }

    #[test]
    fn start_reduce_is_backend_invariant() {
        let inputs = vec![vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let a = Execution::Sim.start_reduce(sum_job(inputs.clone()));
        let b = Execution::Threads.start_reduce(sum_job(inputs));
        assert!(a.is_finished());
        let (ra, rb) = (a.wait(), b.wait());
        assert_eq!(ra, rb);
        assert_eq!(ra, vec![vec![11.0, 22.0, 33.0]]);
    }
}
