//! Execution backends: how one round of scheduled work actually runs.
//!
//! The round **engine** (`coordinator::engine`) decides *what* happens —
//! the plan, the mixing decision, the virtual timeline. The execution
//! mode (`config::Execution`) decides *where*; the engine materializes
//! that choice once per run as an [`Executor`], which this module
//! implements:
//!
//! * `sim` — everything on the calling thread, in worker-major order.
//!   Concurrency is purely virtual (clock arithmetic). This is the
//!   deterministic discrete-event mode every experiment defaults to.
//! * `threads` — a persistent worker pool (`pool.rs`: m parked worker
//!   threads, spawned once per run) runs each round's local phase, and
//!   every collective dispatched through
//!   [`Executor::start_reduce`] runs on the pool's dedicated
//!   **communicator thread**, so an overlapped schedule genuinely
//!   computes local steps while the previous round's all-reduce is in
//!   flight. Threads are spawned once per run and parked between jobs —
//!   the steady-state round loop performs **zero** thread spawns
//!   (DESIGN.md §10; previously every round paid ~m scoped spawns plus a
//!   detached thread per collective). This is the backend
//!   `rust/benches/wallclock.rs` measures (E12/E13).
//! * `net` — a real coordinator/worker split over TCP (`net.rs` here, the
//!   worker side and wire codecs in `crate::net`, DESIGN.md §13): worker
//!   *processes* run the local phases, the coordinator keeps the canonical
//!   state and replays each slot's stochastic draws, and a dead connection
//!   becomes an injected `crash@round` fault. Collectives run inline on
//!   the coordinator with sim semantics.
//!
//! Either way the `Executor` owns the run's hot-path memory: the
//! [`BufferPool`] that recycles collective snapshot storage, a free list
//! of per-round result buffers, and the coordinator-side
//! [`ReduceScratch`]. [`Executor::snapshot`] exposes the tracked
//! allocation/spawn counters the engine surfaces in `TrainLog::hot`.
//!
//! **Digest identity** (asserted for every algorithm by
//! `rust/tests/golden_regression.rs`): the two backends produce
//! bit-identical `TrainLog`s because
//!
//! 1. all training numerics run on per-worker [`StepView`]s — no state is
//!    shared between workers during a local phase, and each worker's
//!    operation sequence (batch draws, RNG stream, kernel calls) is the
//!    same regardless of which thread runs it;
//! 2. cross-worker reductions (loss folding, clock charging, gradient
//!    collection) happen on the coordinator in fixed worker order, fed
//!    from the per-worker [`WorkerRound`] results;
//! 3. a background collective computes the *same* reduction code over a
//!    bit-exact snapshot of the same inputs the sim backend reduces
//!    eagerly (pooled storage is fully overwritten before use), and its
//!    virtual completion time comes from the simnet cost model, never
//!    from wall clock.

mod net;
mod pool;

use std::cell::RefCell;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::collective::ReduceScratch;
use crate::config::{Execution, ExperimentConfig};
use crate::coordinator::engine::{LocalPhase, RoundPlan};
use crate::coordinator::{StepView, TrainContext};
use crate::fault::{AliveSet, FaultEvent};
use crate::model::simd::{self, KernelTier};
use crate::util::pool::BufferPool;

use net::NetCoordinator;
use pool::WorkerPool;

/// A reduction job: the data plane of a collective or gossip exchange over
/// owned (pooled) snapshots, given the executing thread's persistent
/// scratch.
pub(crate) type CommJob = Box<dyn FnOnce(&mut ReduceScratch) -> Vec<Vec<f32>> + Send + 'static>;

/// The pool communicator's shared reply channel: results tagged with their
/// launch sequence number, consumed by [`ReduceHandle::wait`].
pub(crate) type CommReplyRx = Arc<Mutex<Receiver<(u64, Vec<Vec<f32>>)>>>;

/// What one worker produced during a round's local phase, in its own step
/// order. The engine folds these in worker-major order, so the fold is
/// identical no matter how the phase was scheduled. Instances are recycled
/// across rounds through [`Executor::recycle_rounds`], so their vectors
/// stop allocating once warm.
#[derive(Default)]
pub struct WorkerRound {
    /// per-step mini-batch losses (length = planned steps; 1 in grad mode)
    pub losses: Vec<f64>,
    /// per-step virtual compute durations, parallel to `losses`
    pub dts: Vec<f64>,
    /// the raw gradient (grad-only phase; `None` for fused steps)
    pub grad: Option<Vec<f32>>,
}

/// Run one worker's share of a round into `out` (cleared first): `steps`
/// fused steps, or one gradient. Both backends call exactly this function —
/// the sim backend on the coordinator thread, the pool on the worker's own
/// parked thread.
pub(crate) fn drive_worker(
    view: &mut StepView<'_>,
    ctx: &TrainContext,
    steps: usize,
    start_step: usize,
    phase: LocalPhase,
    out: &mut WorkerRound,
) -> Result<()> {
    out.losses.clear();
    out.dts.clear();
    out.grad = None;
    match phase {
        LocalPhase::FusedSteps => {
            for s in 0..steps {
                let (loss, dt) = view.fused_step(ctx, start_step + s)?;
                out.losses.push(loss);
                out.dts.push(dt);
            }
        }
        LocalPhase::GradOnly => {
            let (loss, dt, g) = view.grad_only(ctx)?;
            out.losses.push(loss);
            out.dts.push(dt);
            out.grad = Some(g);
        }
    }
    Ok(())
}

enum Mode {
    Sim,
    Pool(WorkerPool),
    /// The TCP service plane (`--execution net`, DESIGN.md §13). In a
    /// `RefCell` because phase dispatch and the round-boundary poll mutate
    /// the connection ledger while the `Executor` API takes `&self`.
    Net(RefCell<NetCoordinator>),
}

/// Tracked hot-path counters at one instant (monotone totals since the
/// executor was built). The engine snapshots these at the warm-up boundary
/// and at run end to compute the steady-state deltas in `TrainLog::hot`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecSnapshot {
    /// OS threads spawned by the executor (pool startup only; 0 on `sim`)
    pub thread_spawns: u64,
    /// tracked buffer-pool allocations (free-list misses)
    pub buffer_allocs: u64,
    /// bytes those allocations created
    pub buffer_alloc_bytes: u64,
    /// buffer-pool requests served without allocating
    pub buffer_hits: u64,
}

/// The per-run execution backend object: where local phases and reduction
/// jobs physically run, plus the run's recycled hot-path storage. Built by
/// `coordinator::engine::Engine::new` from the config's `execution` mode;
/// strategies reach it as `eng.exec`.
pub struct Executor {
    mode: Mode,
    /// kernel tier for the executor-side collectives (the chunked mean);
    /// bit-identical either way, from the config's `kernels` key
    tier: KernelTier,
    buffers: BufferPool,
    scratch: RefCell<ReduceScratch>,
    rounds: RefCell<Vec<WorkerRound>>,
}

impl Executor {
    /// Build the backend for one run of `m` workers. `Execution::Threads`
    /// spawns the persistent pool (m + 1 threads) here — the run's one and
    /// only spawn site. `Execution::Net` needs the full config (listen
    /// address, fleet size, timeouts) and must be built through
    /// [`Executor::from_config`].
    pub fn new(mode: Execution, m: usize) -> Self {
        let mode = match mode {
            Execution::Sim => Mode::Sim,
            Execution::Threads => Mode::Pool(WorkerPool::new(m)),
            Execution::Net => {
                panic!("the net backend carries run config; build it via Executor::from_config")
            }
        };
        Self {
            mode,
            tier: KernelTier::default(),
            buffers: BufferPool::new(),
            scratch: RefCell::new(ReduceScratch::default()),
            rounds: RefCell::new(Vec::new()),
        }
    }

    /// Build the backend a run's config asks for. This is the engine's
    /// constructor path; it is fallible because `Execution::Net` binds a
    /// socket, spawns the worker fleet, and waits for every slot to be
    /// claimed before the first round.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        if cfg.execution != Execution::Net {
            let mut ex = Self::new(cfg.execution, cfg.workers);
            ex.tier = cfg.kernels;
            return Ok(ex);
        }
        Ok(Self {
            mode: Mode::Net(RefCell::new(NetCoordinator::new(cfg)?)),
            tier: cfg.kernels,
            buffers: BufferPool::new(),
            scratch: RefCell::new(ReduceScratch::default()),
            rounds: RefCell::new(Vec::new()),
        })
    }

    /// The config axis this executor realizes.
    pub fn execution(&self) -> Execution {
        match self.mode {
            Mode::Sim => Execution::Sim,
            Mode::Pool(_) => Execution::Threads,
            Mode::Net(_) => Execution::Net,
        }
    }

    /// The run's shared buffer pool (collective snapshots and mix outputs
    /// recycle through it; see `util::pool`).
    pub fn buffers(&self) -> &BufferPool {
        &self.buffers
    }

    /// The coordinator-side reduce scratch, for strategies that run their
    /// collective inline at the boundary (sync/local/elastic). The
    /// communicator thread keeps its own.
    pub fn reduce_scratch(&self) -> std::cell::RefMut<'_, ReduceScratch> {
        self.scratch.borrow_mut()
    }

    /// Current tracked counters (spawns + buffer-pool traffic).
    pub fn snapshot(&self) -> ExecSnapshot {
        let stats = self.buffers.stats();
        ExecSnapshot {
            thread_spawns: match &self.mode {
                // net runs collectives inline and phases in *other*
                // processes: this process spawns no threads at all.
                Mode::Sim | Mode::Net(_) => 0,
                Mode::Pool(p) => p.spawns(),
            },
            buffer_allocs: stats.allocs,
            buffer_alloc_bytes: stats.alloc_bytes,
            buffer_hits: stats.hits,
        }
    }

    /// Execute one round's local phase over the per-worker views (worker
    /// order in, worker order out). `plan.steps[w]` fused steps per worker,
    /// or one gradient each in grad mode; a worker planned at **zero**
    /// steps is parked (the fault subsystem's crashed/partitioned-away
    /// workers, DESIGN.md §11) — it is skipped entirely, consuming no
    /// batches, no RNG draws, and (on the pool) no dispatch, and its result
    /// buffer comes back empty. `Sim` drives the views sequentially on the
    /// calling thread; `Threads` dispatches each view to its parked pool
    /// thread. Result buffers come from the recycle list, so steady-state
    /// rounds reuse their capacity.
    pub fn run_phase(
        &self,
        views: Vec<StepView<'_>>,
        ctx: &TrainContext,
        plan: &RoundPlan,
        start_step: usize,
        phase: LocalPhase,
    ) -> Result<Vec<WorkerRound>> {
        let m = views.len();
        let mut bufs: Vec<WorkerRound> = {
            let mut stash = self.rounds.borrow_mut();
            (0..m).map(|_| stash.pop().unwrap_or_default()).collect()
        };
        match &self.mode {
            Mode::Sim => {
                for (w, mut view) in views.into_iter().enumerate() {
                    if plan.steps[w] == 0 {
                        continue; // parked: the cleared buffer is the result
                    }
                    drive_worker(&mut view, ctx, plan.steps[w], start_step, phase, &mut bufs[w])?;
                }
                Ok(bufs)
            }
            Mode::Pool(p) => p.run_phase(views, ctx, plan, start_step, phase, bufs),
            Mode::Net(nc) => {
                let mut views = views;
                nc.borrow_mut().run_phase(&mut views, ctx, plan, start_step, phase, &mut bufs)?;
                Ok(bufs)
            }
        }
    }

    /// Round-boundary service sweep of the `net` backend: detect worker
    /// processes that died since the last round (as `Crash` events) and
    /// admit reconnecting ones (as `Rejoin` events), for the engine to
    /// feed into the fault machinery (slot-level `FaultState::inject`, or
    /// the id-level replay under population) before it applies round
    /// `round`'s faults. A no-op returning no events on `sim`/`threads`.
    pub fn poll_net_events(&self, round: usize, alive: &AliveSet) -> Result<Vec<FaultEvent>> {
        match &self.mode {
            Mode::Net(nc) => nc.borrow_mut().poll(round, alive),
            _ => Ok(Vec::new()),
        }
    }

    /// Publish the round's slot → population-id binding to the `net`
    /// backend, which ships it (plus each bound worker's stream state) in
    /// the next `PhaseReq`. A no-op on `sim`/`threads`, where the binding
    /// already lives in the canonical per-slot state.
    pub fn bind_population(&self, bound: &[Option<u64>]) {
        if let Mode::Net(nc) = &self.mode {
            nc.borrow_mut().set_bound(bound);
        }
    }

    /// Return a round's folded result buffers for reuse by the next round.
    pub fn recycle_rounds(&self, rounds: Vec<WorkerRound>) {
        let mut stash = self.rounds.borrow_mut();
        for mut r in rounds {
            r.losses.clear();
            r.dts.clear();
            r.grad = None;
            stash.push(r);
        }
    }

    /// Run a reduction job — the data plane of a collective or gossip
    /// exchange over pooled snapshots. `Sim` computes it inline (eager, the
    /// seed semantics) using the coordinator-side scratch; `Threads` hands
    /// it to the parked communicator thread and returns immediately, which
    /// is what lets the next round's local compute overlap the wire work
    /// for real.
    ///
    /// Handles must be waited **in launch order** (or dropped): the
    /// communicator serves one FIFO queue, and a `wait` skips — and drops —
    /// the results of earlier, abandoned launches to reach its own (see
    /// [`ReduceHandle::wait`]). Every in-repo caller holds at most one
    /// in-flight handle at a time.
    pub fn start_reduce(
        &self,
        job: impl FnOnce(&mut ReduceScratch) -> Vec<Vec<f32>> + Send + 'static,
    ) -> ReduceHandle {
        match &self.mode {
            // net keeps collectives on the coordinator: the engine already
            // holds every worker's canonical state, so reductions run
            // inline with sim semantics (and bits).
            Mode::Sim | Mode::Net(_) => ReduceHandle::Ready(job(&mut *self.scratch.borrow_mut())),
            Mode::Pool(p) => p.start_reduce(Box::new(job)),
        }
    }

    /// Elementwise mean into `out`, *bit*-identical to
    /// [`vecmath::mean_into`] on every backend and kernel tier: serial on
    /// `sim`, chunked over the parked pool threads on `threads` (the same
    /// deterministic chunking as `vecmath::mean_into_parallel`, without
    /// its per-call spawns), with the per-chunk kernel dispatched on the
    /// run's `kernels` tier.
    pub fn mean_into(&self, vs: &[&[f32]], out: &mut [f32]) {
        match &self.mode {
            Mode::Sim | Mode::Net(_) => simd::mean_into(self.tier, vs, out),
            Mode::Pool(p) => p.mean_into(vs, out, self.tier),
        }
    }
}

/// Handle to a (possibly in-flight) reduction launched via
/// [`Executor::start_reduce`]. Dropping a `Pending` handle abandons the
/// job (its result is skipped by sequence number, never misdelivered) —
/// this happens when a run ends with a collective still pending, exactly
/// like the sim backend dropping an unabsorbed result.
pub enum ReduceHandle {
    /// the reduction already ran inline (sim backend)
    Ready(Vec<Vec<f32>>),
    /// the reduction is queued on the pool's communicator thread
    Pending {
        /// the pool's shared reply channel
        reply: CommReplyRx,
        /// this job's launch sequence number
        seq: u64,
    },
}

impl ReduceHandle {
    /// Block until the reduction is done and take its output buffers.
    /// Instant on `Ready`; waits on the communicator's reply on `Pending`.
    ///
    /// Replies arrive in launch order, and results bearing an earlier
    /// sequence number than this handle's are treated as abandoned and
    /// dropped — so live handles must be waited in launch order: waiting a
    /// newer handle first discards an older live handle's result, and the
    /// older `wait` would then block forever. (In-repo, strategies hold at
    /// most one in-flight collective, which satisfies this by
    /// construction.)
    pub fn wait(self) -> Vec<Vec<f32>> {
        match self {
            ReduceHandle::Ready(v) => v,
            ReduceHandle::Pending { reply, seq } => {
                let rx = reply.lock().expect("communicator reply channel poisoned");
                loop {
                    let (s, v) = rx.recv().expect("communicator thread exited mid-reduce");
                    if s == seq {
                        return v;
                    }
                    // Cold path (at most once per abandoned launch), so the
                    // FIFO invariant stays a hard check in release builds.
                    assert!(s < seq, "communicator replies out of order");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vecmath;
    use crate::util::proptest::property;

    fn sum_job(inputs: Vec<Vec<f32>>) -> CommJob {
        Box::new(move |_scratch| {
            let mut acc = vec![0.0f32; inputs[0].len()];
            for v in &inputs {
                for (a, &x) in acc.iter_mut().zip(v) {
                    *a += x;
                }
            }
            vec![acc]
        })
    }

    #[test]
    fn start_reduce_is_backend_invariant() {
        let inputs = vec![vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let sim = Executor::new(Execution::Sim, 2);
        let thr = Executor::new(Execution::Threads, 2);
        let a = sim.start_reduce(sum_job(inputs.clone()));
        let b = thr.start_reduce(sum_job(inputs));
        let (ra, rb) = (a.wait(), b.wait());
        assert_eq!(ra, rb);
        assert_eq!(ra, vec![vec![11.0, 22.0, 33.0]]);
    }

    #[test]
    fn abandoned_reduce_results_are_skipped_not_misdelivered() {
        let thr = Executor::new(Execution::Threads, 2);
        let abandoned = thr.start_reduce(sum_job(vec![vec![1.0f32]]));
        drop(abandoned);
        let kept = thr.start_reduce(sum_job(vec![vec![5.0f32], vec![7.0]]));
        assert_eq!(kept.wait(), vec![vec![12.0f32]]);
    }

    #[test]
    fn executor_counts_spawns_once() {
        let sim = Executor::new(Execution::Sim, 4);
        assert_eq!(sim.snapshot().thread_spawns, 0);
        let thr = Executor::new(Execution::Threads, 4);
        let s0 = thr.snapshot();
        assert_eq!(s0.thread_spawns, 5, "m workers + 1 communicator");
        for _ in 0..3 {
            thr.start_reduce(sum_job(vec![vec![1.0f32]])).wait();
        }
        assert_eq!(thr.snapshot().thread_spawns, 5, "no spawns after startup");
    }

    #[test]
    fn property_pooled_mean_is_bit_identical_to_serial() {
        // The elastic strategy and the wallclock micro-bench route their
        // averages through the pool; chunking across parked threads must
        // not change a single bit relative to the serial loop — on either
        // kernel tier.
        let thr = Executor::new(Execution::Threads, 5);
        let thr_simd = {
            let mut cfg = ExperimentConfig::default();
            cfg.set("execution", "threads").unwrap();
            cfg.set("workers", "5").unwrap();
            cfg.set("kernels", "simd").unwrap();
            Executor::from_config(&cfg).unwrap()
        };
        property("pooled mean == serial mean (bits)", 80, |g| {
            let n = g.usize_in(1, 2000);
            let m = g.usize_in(1, 12);
            let vs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 50.0)).collect();
            let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
            let mut serial = vec![0.0f32; n];
            vecmath::mean_into(&refs, &mut serial);
            for ex in [&thr, &thr_simd] {
                let mut pooled = vec![f32::NAN; n];
                ex.mean_into(&refs, &mut pooled);
                for i in 0..n {
                    assert_eq!(
                        serial[i].to_bits(),
                        pooled[i].to_bits(),
                        "bit drift at {i} (n={n}, m={m}, tier {:?})",
                        ex.tier
                    );
                }
            }
        });
    }
}
