//! Coordinator side of the `net` execution backend (DESIGN.md §13).
//!
//! [`NetCoordinator`] is the `--execution net` realization of the
//! `Executor` seam: it owns the listening socket, the worker-process fleet
//! (spawned children or externally launched `olsgd worker` processes), and
//! the slot ledger mapping each engine worker index to the TCP connection
//! serving it. Per round it sends **one** batched `PhaseReq` frame per
//! process (every slot's planned steps + full replica state), reads one
//! `PhaseResp` back, and replays each executed step's stochastic draws on
//! the coordinator's canonical streams (`StepView::replay_draws`) — which
//! is what keeps the observables bit-identical to the `sim` backend and
//! makes the failure path trivial: a dead connection's slots simply run
//! locally on the canonical replicas, same bits, and the death is reported
//! to the engine as an injected `crash@round` fault event
//! ([`NetCoordinator::poll`] → `FaultState::inject`).
//!
//! Determinism of the ledger itself: fleet children are spawned with a
//! stable `--proc-index`, and the handshake grants each index the same
//! contiguous slot range on every run — so the `net_kill` chaos hook
//! ("process p dies after serving r rounds") always maps to the same
//! worker slots, and the kill test can assert digest equality against the
//! explicit `--fault crash@round:worker` schedule.

use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::engine::{LocalPhase, RoundPlan};
use crate::coordinator::{StepView, TrainContext};
use crate::fault::{AliveSet, FaultEvent};
use crate::net::{self, wire};

use super::{drive_worker, WorkerRound};

/// One live worker-process connection and its per-round scratch.
struct Conn {
    stream: TcpStream,
    /// slots this process serves (engine worker indices)
    slots: Vec<usize>,
    /// slots requested from this process in the round in flight
    round_slots: Vec<usize>,
    /// reusable frame read buffer
    rbuf: Vec<u8>,
    /// reusable request payload buffer
    wbuf: Vec<u8>,
}

/// What reading one process's `PhaseResp` concluded.
enum RespOutcome {
    /// the response was decoded and applied to the canonical replicas
    Applied,
    /// the transport failed before anything was applied — the slots fall
    /// back to local execution and the process is declared dead
    Dead,
}

/// The `--execution net` backend object (see the module docs).
pub(crate) struct NetCoordinator {
    listener: TcpListener,
    /// connections by stable index; a dead process leaves a `None` hole so
    /// indices in `slot_proc` never dangle
    conns: Vec<Option<Conn>>,
    /// slot → index into `conns` of the process serving it
    slot_proc: Vec<Option<usize>>,
    /// per-slot executed local steps (== batch/straggler draws consumed) —
    /// shipped in `Welcome` so a rejoining process can fast-forward
    consumed: Vec<u64>,
    /// deterministic slot ranges per spawned process index
    planned: Vec<Vec<usize>>,
    /// the run config as ordered pairs, shipped verbatim in every `Welcome`
    cfg_kv: Vec<(String, String)>,
    /// population axis on: `PhaseReq` carries per-slot id + stream extras
    population: bool,
    /// slot → bound population id, published by the engine each round
    /// ([`crate::executor::Executor::bind_population`])
    bound_ids: Vec<Option<u64>>,
    timeout: Duration,
    children: Vec<Child>,
    /// slots whose process died mid-phase, awaiting their `crash@round`
    /// injection at the next [`NetCoordinator::poll`]
    pending_dead: Vec<usize>,
    /// round scratch: slots executing locally this round
    pending_local: Vec<usize>,
    m: usize,
}

impl NetCoordinator {
    /// Bind the service socket, optionally spawn the worker fleet, and
    /// block until every slot is claimed (or the timeout passes).
    pub(crate) fn new(cfg: &ExperimentConfig) -> Result<Self> {
        let m = cfg.workers;
        let listener = TcpListener::bind(&cfg.net_listen)
            .with_context(|| format!("binding net coordinator to {}", cfg.net_listen))?;
        listener.set_nonblocking(true).context("making the listener non-blocking")?;
        let addr = listener.local_addr().context("resolving the bound address")?;
        let timeout = Duration::from_secs_f64(cfg.net_timeout_s);

        let procs = cfg.net_procs.min(m);
        let (base, extra) = (m / procs, m % procs);
        let mut planned = Vec::with_capacity(procs);
        let mut next_slot = 0usize;
        for p in 0..procs {
            let lanes = base + usize::from(p < extra);
            planned.push((next_slot..next_slot + lanes).collect::<Vec<_>>());
            next_slot += lanes;
        }

        let kill = parse_net_kill(&cfg.net_kill)?;
        let mut children = Vec::new();
        if cfg.net_spawn {
            let bin: PathBuf = if cfg.net_worker_bin.is_empty() {
                std::env::current_exe().context("resolving the worker binary (net_worker_bin)")?
            } else {
                PathBuf::from(&cfg.net_worker_bin)
            };
            for (p, slots) in planned.iter().enumerate() {
                let mut cmd = Command::new(&bin);
                cmd.arg("worker")
                    .arg("--connect")
                    .arg(addr.to_string())
                    .arg("--lanes")
                    .arg(slots.len().to_string())
                    .arg("--proc-index")
                    .arg(p.to_string())
                    .arg("--timeout")
                    .arg(cfg.net_timeout_s.to_string())
                    .stdout(Stdio::null());
                if let Some((kp, kr)) = kill {
                    if kp == p {
                        cmd.arg("--die-after").arg(kr.to_string());
                    }
                }
                children
                    .push(cmd.spawn().with_context(|| format!("spawning worker process {p}"))?);
            }
        }

        let mut nc = Self {
            listener,
            conns: Vec::new(),
            slot_proc: vec![None; m],
            consumed: vec![0; m],
            planned,
            cfg_kv: cfg.to_kv(),
            population: cfg.population > 0,
            bound_ids: vec![None; m],
            timeout,
            children,
            pending_dead: Vec::new(),
            pending_local: Vec::new(),
            m,
        };

        // Round 0 rendezvous: every slot must have a serving process before
        // the engine's first round. Workers that fail the handshake are
        // dropped, not fatal — the fleet has until the deadline to cover m.
        let deadline = Instant::now() + timeout;
        while nc.slot_proc.iter().any(Option::is_none) {
            match nc.listener.accept() {
                Ok((stream, _)) => {
                    if let Err(e) = nc.admit(stream) {
                        eprintln!("net: rejected connection during startup: {e:#}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    for (p, child) in nc.children.iter_mut().enumerate() {
                        if let Ok(Some(status)) = child.try_wait() {
                            bail!("worker process {p} exited during startup ({status})");
                        }
                    }
                    let unclaimed = nc.slot_proc.iter().filter(|s| s.is_none()).count();
                    ensure!(
                        Instant::now() < deadline,
                        "net coordinator: {unclaimed} of {m} worker slots still unclaimed \
                         after {:.1}s (listening on {addr}; raise net_timeout_s or start \
                         more `olsgd worker --connect {addr}` processes)",
                        timeout.as_secs_f64()
                    );
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("accepting worker connections"),
            }
        }
        Ok(nc)
    }

    /// Handshake one inbound connection: read `Hello`, grant slots (the
    /// spawner-pinned range for a fleet child, else the first unclaimed
    /// slots), send `Welcome` with the config and consumed-step counts.
    /// Returns the granted slots.
    fn admit(&mut self, stream: TcpStream) -> Result<Vec<usize>> {
        let mut stream = stream;
        stream.set_nonblocking(false).context("handshake: clearing non-blocking")?;
        stream.set_nodelay(true).context("handshake: TCP_NODELAY")?;
        stream.set_read_timeout(Some(self.timeout)).context("handshake: read timeout")?;
        stream.set_write_timeout(Some(self.timeout)).context("handshake: write timeout")?;
        let mut rbuf = Vec::new();
        let kind = wire::read_frame(&mut stream, &mut rbuf)?;
        ensure!(kind == wire::KIND_HELLO, "expected Hello, got frame kind {kind}");
        let hello = net::decode_hello(&rbuf)?;
        let claimed: Vec<usize> = match hello.proc {
            // A fleet child (or a restart of one) gets its pinned range —
            // deterministic slot ownership is what keeps the `net_kill`
            // chaos hook replayable.
            Some(p)
                if p < self.planned.len()
                    && self.planned[p].iter().all(|&w| self.slot_proc[w].is_none()) =>
            {
                self.planned[p].clone()
            }
            _ => (0..self.m)
                .filter(|&w| self.slot_proc[w].is_none())
                .take(hello.lanes)
                .collect(),
        };
        let consumed: Vec<u64> = claimed.iter().map(|&w| self.consumed[w]).collect();
        wire::write_frame(
            &mut stream,
            wire::KIND_WELCOME,
            net::encode_welcome(&claimed, &consumed, &self.cfg_kv).as_bytes(),
        )?;
        let idx = self.conns.len();
        for &w in &claimed {
            self.slot_proc[w] = Some(idx);
        }
        self.conns.push(Some(Conn {
            stream,
            slots: claimed.clone(),
            round_slots: Vec::new(),
            rbuf,
            wbuf: Vec::new(),
        }));
        Ok(claimed)
    }

    /// Install the round's slot → population-id binding (engine-published
    /// via `Executor::bind_population`); the next `PhaseReq` ships it.
    pub(crate) fn set_bound(&mut self, bound: &[Option<u64>]) {
        debug_assert_eq!(bound.len(), self.bound_ids.len());
        self.bound_ids.clear();
        self.bound_ids.extend_from_slice(bound);
    }

    /// Declare process `p` dead: free its slots (queueing their
    /// `crash@round` injection) and reroute any work it still owed this
    /// round to local execution.
    fn fail_conn(&mut self, p: usize) {
        if let Some(conn) = self.conns[p].take() {
            for &w in &conn.slots {
                self.slot_proc[w] = None;
                self.pending_dead.push(w);
            }
            for &w in &conn.round_slots {
                self.pending_local.push(w);
            }
        }
    }

    /// Run one round's local phase across the fleet (see the module docs
    /// for the wire pattern and the determinism argument). `views` and
    /// `bufs` are indexed by worker slot; parked slots
    /// (`plan.steps[w] == 0`) are skipped entirely, exactly as on `sim`.
    pub(crate) fn run_phase(
        &mut self,
        views: &mut [StepView<'_>],
        ctx: &TrainContext,
        plan: &RoundPlan,
        start_step: usize,
        phase: LocalPhase,
        bufs: &mut [WorkerRound],
    ) -> Result<()> {
        debug_assert_eq!(views.len(), self.m);
        self.pending_local.clear();
        for conn in self.conns.iter_mut().flatten() {
            conn.round_slots.clear();
        }
        for w in 0..self.m {
            if plan.steps[w] == 0 {
                continue;
            }
            match self.slot_proc[w].filter(|&p| self.conns[p].is_some()) {
                Some(p) => {
                    self.conns[p].as_mut().expect("filtered Some").round_slots.push(w)
                }
                None => self.pending_local.push(w),
            }
        }

        // Send every process its batched request first, then read the
        // responses in the same order: each side fully reads before it
        // writes, and per-process sockets are drained every round, so the
        // exchange cannot deadlock.
        // Cloned out of `self` so `fail_conn` (which needs `&mut self`)
        // stays callable inside the send loop; m options per round is noise
        // next to the replica payloads.
        let pop_ids: Option<Vec<Option<u64>>> = self.population.then(|| self.bound_ids.clone());
        for p in 0..self.conns.len() {
            let sent = match self.conns[p].as_mut() {
                Some(conn) if !conn.round_slots.is_empty() => {
                    net::encode_phase_req(
                        &mut conn.wbuf,
                        phase,
                        start_step,
                        &conn.round_slots,
                        &plan.steps,
                        views,
                        pop_ids.as_deref(),
                    );
                    wire::write_frame(&mut conn.stream, wire::KIND_PHASE_REQ, &conn.wbuf)
                }
                _ => continue,
            };
            if sent.is_err() {
                self.fail_conn(p);
            }
        }
        for p in 0..self.conns.len() {
            let outcome = match (&mut self.conns[p], &mut self.consumed) {
                (Some(conn), consumed) if !conn.round_slots.is_empty() => {
                    apply_resp(conn, plan, phase, views, bufs, ctx, consumed)?
                }
                _ => continue,
            };
            if matches!(outcome, RespOutcome::Dead) {
                self.fail_conn(p);
            }
        }

        // Fallback lane: slots with no live process run on the canonical
        // replicas — the exact same per-worker streams, so the bits match
        // what the remote would have produced.
        for &w in &self.pending_local {
            drive_worker(&mut views[w], ctx, plan.steps[w], start_step, phase, &mut bufs[w])?;
            self.consumed[w] += bufs[w].losses.len() as u64;
        }
        Ok(())
    }

    /// Round-boundary service sweep, called by the engine *before* fault
    /// application: report mid-phase deaths and failed liveness probes as
    /// `Crash` events, admit reconnecting processes and report their
    /// claimed dead slots as `Rejoin` events — all stamped with the
    /// upcoming `round`, feeding `FaultState::inject` so the service plane
    /// replays through exactly the `--fault` machinery.
    pub(crate) fn poll(&mut self, round: usize, alive: &AliveSet) -> Result<Vec<FaultEvent>> {
        let mut events = Vec::new();
        let mut crashed_now: Vec<usize> = Vec::new();
        let mut crash = |w: usize, events: &mut Vec<FaultEvent>, crashed: &mut Vec<usize>| {
            // A slot the explicit schedule already crashed needs no event;
            // a slot can die at most once per boundary.
            if alive.is_alive(w) && !crashed.contains(&w) {
                events.push(FaultEvent::Crash { round, worker: w });
                crashed.push(w);
            }
        };
        for w in std::mem::take(&mut self.pending_dead) {
            crash(w, &mut events, &mut crashed_now);
        }
        for conn_opt in &mut self.conns {
            let ok = match conn_opt.as_mut() {
                Some(conn) => ping(conn).is_ok(),
                None => continue,
            };
            if ok {
                continue;
            }
            if let Some(conn) = conn_opt.take() {
                for &w in &conn.slots {
                    self.slot_proc[w] = None;
                    crash(w, &mut events, &mut crashed_now);
                }
            }
        }
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => match self.admit(stream) {
                    Ok(claimed) => {
                        for w in claimed {
                            if !alive.is_alive(w) || crashed_now.contains(&w) {
                                events.push(FaultEvent::Rejoin { round, worker: w });
                            }
                        }
                    }
                    Err(e) => eprintln!("net: rejected reconnection: {e:#}"),
                },
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e).context("accepting reconnections"),
            }
        }
        Ok(events)
    }
}

impl Drop for NetCoordinator {
    fn drop(&mut self) {
        for conn in self.conns.iter_mut().flatten() {
            let _ = wire::write_frame(&mut conn.stream, wire::KIND_SHUTDOWN, &[]);
        }
        self.conns.clear(); // closing the sockets also unblocks any reader
        for child in &mut self.children {
            let deadline = Instant::now() + Duration::from_secs(2);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
    }
}

/// Parse the validated `net_kill` config key ("proc:rounds", empty = off).
fn parse_net_kill(spec: &str) -> Result<Option<(usize, u64)>> {
    if spec.is_empty() {
        return Ok(None);
    }
    let (p, r) = spec
        .split_once(':')
        .with_context(|| format!("net_kill wants proc:rounds, got '{spec}'"))?;
    Ok(Some((
        p.parse().with_context(|| format!("bad proc in net_kill '{spec}'"))?,
        r.parse().with_context(|| format!("bad rounds in net_kill '{spec}'"))?,
    )))
}

/// One liveness round-trip on an idle connection (the socket's read
/// timeout bounds the wait).
fn ping(conn: &mut Conn) -> Result<()> {
    wire::write_frame(&mut conn.stream, wire::KIND_PING, &[])?;
    let kind = wire::read_frame(&mut conn.stream, &mut conn.rbuf)?;
    ensure!(kind == wire::KIND_PONG, "expected Pong, got frame kind {kind}");
    Ok(())
}

/// Read and apply one process's `PhaseResp`: write the stepped state back
/// into the canonical views, collect losses/gradients into `bufs`, and
/// replay each executed step's draws so the coordinator's streams advance
/// exactly as if it had run the steps itself. A *transport* failure before
/// the frame arrives returns [`RespOutcome::Dead`] (nothing was applied —
/// the fallback lane recomputes from canonical state); a *decode* failure
/// after partial application is a fatal protocol error, never a fault.
fn apply_resp(
    conn: &mut Conn,
    plan: &RoundPlan,
    phase: LocalPhase,
    views: &mut [StepView<'_>],
    bufs: &mut [WorkerRound],
    ctx: &TrainContext,
    consumed: &mut [u64],
) -> Result<RespOutcome> {
    let kind = match wire::read_frame(&mut conn.stream, &mut conn.rbuf) {
        Ok(k) => k,
        Err(_) => return Ok(RespOutcome::Dead),
    };
    ensure!(kind == wire::KIND_PHASE_RESP, "expected PhaseResp, got frame kind {kind}");
    let mut c = wire::Cursor::new(&conn.rbuf);
    let nslots = c.get_u32()? as usize;
    ensure!(
        nslots == conn.round_slots.len(),
        "PhaseResp covers {nslots} slots, requested {}",
        conn.round_slots.len()
    );
    for &w in &conn.round_slots {
        let ww = c.get_u32()? as usize;
        ensure!(ww == w, "PhaseResp slot order mismatch: got {ww}, expected {w}");
        let buf = &mut bufs[w];
        buf.losses.clear();
        c.get_f64s_into(&mut buf.losses)?;
        let expected = match phase {
            LocalPhase::FusedSteps => plan.steps[w],
            LocalPhase::GradOnly => 1,
        };
        ensure!(
            buf.losses.len() == expected,
            "slot {w} returned {} losses for {expected} planned steps",
            buf.losses.len()
        );
        let view = &mut views[w];
        {
            let (params, mom, mom2, adam_t) = view.state_mut();
            c.get_f32s_into(params)?;
            c.get_f32s_into(mom)?;
            c.get_f32s_into(mom2)?;
            *adam_t = c.get_f32()?;
        }
        buf.grad = match c.get_u8()? {
            0 => None,
            1 => Some(c.get_f32s_vec()?),
            other => bail!("bad grad marker {other} in PhaseResp"),
        };
        buf.dts.clear();
        for _ in 0..buf.losses.len() {
            let dt = view.replay_draws(ctx);
            buf.dts.push(dt);
        }
        consumed[w] += buf.losses.len() as u64;
    }
    c.finish()?;
    Ok(RespOutcome::Applied)
}
