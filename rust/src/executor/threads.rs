//! Real-thread backend: one OS thread per simulated worker, plus a
//! background communicator thread per collective.
//!
//! Generalizes the seed's `collective::spawn_background_mean` proof of
//! concept into the execution path proper. Two kinds of threads exist:
//!
//! * **worker threads** — scoped to one round's local phase. Each receives
//!   its worker's [`StepView`] (a disjoint `&mut` borrow of the shared
//!   `Workers` state, so no locks and no copies) and runs the *same*
//!   `drive_worker` burst the sim backend runs sequentially. Results come
//!   back over an mpsc channel tagged with the worker id; the coordinator
//!   reassembles them in worker order before folding, which pins the
//!   cross-worker reduction order regardless of thread completion order.
//! * **communicator threads** — detached, one per collective
//!   ([`spawn_communicator`]). They own a snapshot of the inputs and run
//!   the exact topology reduce schedule while the *next* round's worker
//!   threads compute — the paper's overlap, on real cores. The strategy
//!   joins the thread at the next boundary (`ReduceHandle::wait`).
//!
//! Wall-clock time never leaks into any observable: virtual durations
//! still come from the simnet cost model, so `TrainLog`s are bit-identical
//! to the sim backend (`rust/tests/golden_regression.rs`) while
//! `rust/benches/wallclock.rs` measures the real speedup.
//!
//! Scoped threads (`std::thread::scope`) let the worker closures borrow
//! the `TrainContext` directly; this requires the model runtime to be
//! `Sync`, which both the native backend and the vendored PJRT stub are.

use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Result};

use super::{drive_worker, WorkerRound};
use crate::coordinator::engine::{LocalPhase, RoundPlan};
use crate::coordinator::{StepView, TrainContext};

/// Run one round's local phase with one OS thread per worker. Spawns
/// `views.len()` scoped threads, collects `(worker id, result)` over a
/// channel, and returns the results in worker order.
pub(crate) fn run_phase(
    views: Vec<StepView<'_>>,
    ctx: &TrainContext,
    plan: &RoundPlan,
    start_step: usize,
    phase: LocalPhase,
) -> Result<Vec<WorkerRound>> {
    let m = views.len();
    let (tx, rx) = mpsc::channel::<(usize, Result<WorkerRound>)>();
    thread::scope(|s| {
        for (w, mut view) in views.into_iter().enumerate() {
            let tx = tx.clone();
            let steps = plan.steps[w];
            s.spawn(move || {
                let out = drive_worker(&mut view, ctx, steps, start_step, phase);
                // A send can only fail if the coordinator already bailed;
                // the round is doomed either way, so the result may drop.
                let _ = tx.send((w, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<WorkerRound>> = (0..m).map(|_| None).collect();
        for _ in 0..m {
            let (w, out) = rx
                .recv()
                .map_err(|_| anyhow!("worker thread exited without reporting its round"))?;
            slots[w] = Some(out?);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(w, r)| r.ok_or_else(|| anyhow!("worker {w} reported no round result")))
            .collect()
    })
}

/// Spawn the background communicator thread for one collective. The job
/// owns its snapshot, so the thread is detached-safe: if the run ends with
/// the collective still pending, the thread finishes into the void.
pub(crate) fn spawn_communicator(
    job: impl FnOnce() -> Vec<Vec<f32>> + Send + 'static,
) -> thread::JoinHandle<Vec<Vec<f32>>> {
    thread::Builder::new()
        .name("olsgd-communicator".into())
        .spawn(job)
        .expect("spawning the communicator thread failed")
}
