//! Optimizer policy substrate: learning-rate schedules + hyper-parameter
//! presets from the paper's §4 (base lr 0.1, 5-epoch warmup, x0.1 decay at
//! epochs 150/250 of 300 — scaled proportionally to shorter runs here).

/// Warmup + step-decay schedule over *steps*, stated in epochs.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    /// the post-warmup learning rate
    pub base_lr: f32,
    /// linear warmup from base_lr/warmup_epochs to base_lr (Goyal et al.)
    pub warmup_epochs: f64,
    /// (epoch, multiplier) milestones, applied cumulatively
    pub milestones: Vec<(f64, f32)>,
    /// steps-per-epoch used to convert step indices to epochs
    pub steps_per_epoch: usize,
}

impl LrSchedule {
    /// The paper's CIFAR-10 schedule scaled to `total_epochs`:
    /// warmup 5/300, decays at 150/300 and 250/300 of the run.
    pub fn paper_scaled(base_lr: f32, total_epochs: f64, steps_per_epoch: usize) -> Self {
        let s = total_epochs / 300.0;
        Self {
            base_lr,
            warmup_epochs: 5.0 * s,
            milestones: vec![(150.0 * s, 0.1), (250.0 * s, 0.1)],
            steps_per_epoch: steps_per_epoch.max(1),
        }
    }

    /// Constant lr (for theory-check runs where the paper's Theorem 1
    /// prescribes a fixed gamma).
    pub fn constant(lr: f32) -> Self {
        Self { base_lr: lr, warmup_epochs: 0.0, milestones: vec![], steps_per_epoch: 1 }
    }

    /// Learning rate at a global step index.
    pub fn lr_at_step(&self, step: usize) -> f32 {
        let epoch = step as f64 / self.steps_per_epoch as f64;
        self.lr_at_epoch(epoch)
    }

    /// Learning rate at a (fractional) epoch.
    pub fn lr_at_epoch(&self, epoch: f64) -> f32 {
        if self.warmup_epochs > 0.0 && epoch < self.warmup_epochs {
            // Goyal et al. warmup: linear ramp from a small fraction of the
            // base lr up to the base lr over the warmup window.
            const WARMUP_START_FRAC: f32 = 0.1;
            let frac = (epoch / self.warmup_epochs) as f32;
            let start = self.base_lr * WARMUP_START_FRAC;
            return start + (self.base_lr - start) * frac;
        }
        let mut lr = self.base_lr;
        for &(at, mult) in &self.milestones {
            if epoch >= at {
                lr *= mult;
            }
        }
        lr
    }
}

/// Hyper-parameters shared by all Local-SGD-family algorithms.
#[derive(Clone, Debug)]
pub struct HyperParams {
    /// local updates between synchronizations
    pub tau: usize,
    /// pullback strength (paper: 0.6 for tau >= 2, 0.5 for tau = 1)
    pub alpha: f32,
    /// anchor momentum (paper: 0.7, following SlowMo)
    pub beta: f32,
    /// local Nesterov momentum (paper recipe: 0.9)
    pub mu: f32,
    /// weight decay
    pub wd: f32,
}

impl HyperParams {
    /// The paper's tuned settings for a given tau (§4).
    pub fn paper(tau: usize) -> Self {
        Self {
            tau,
            alpha: if tau <= 1 { 0.5 } else { 0.6 },
            beta: 0.7,
            mu: 0.9,
            wd: 1e-4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_to_base() {
        let s = LrSchedule::paper_scaled(0.1, 300.0, 10);
        assert!(s.lr_at_epoch(0.0) < 0.1);
        assert!((s.lr_at_epoch(5.0) - 0.1).abs() < 1e-6);
        assert!((s.lr_at_epoch(100.0) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn milestones_decay_cumulatively() {
        let s = LrSchedule::paper_scaled(0.1, 300.0, 10);
        assert!((s.lr_at_epoch(200.0) - 0.01).abs() < 1e-7);
        assert!((s.lr_at_epoch(299.0) - 0.001).abs() < 1e-8);
    }

    #[test]
    fn scaling_moves_milestones() {
        let s = LrSchedule::paper_scaled(0.1, 30.0, 10);
        assert!((s.lr_at_epoch(20.0) - 0.01).abs() < 1e-7); // 150/300 * 30 = 15
        assert!(s.lr_at_epoch(14.0) > 0.05);
    }

    #[test]
    fn lr_at_step_uses_steps_per_epoch() {
        let s = LrSchedule::paper_scaled(0.1, 300.0, 100);
        assert_eq!(s.lr_at_step(50_000), s.lr_at_epoch(500.0));
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.02);
        assert_eq!(s.lr_at_step(0), 0.02);
        assert_eq!(s.lr_at_step(10_000), 0.02);
    }

    #[test]
    fn paper_hyperparams_follow_alpha_rule() {
        assert_eq!(HyperParams::paper(1).alpha, 0.5);
        assert_eq!(HyperParams::paper(2).alpha, 0.6);
        assert_eq!(HyperParams::paper(24).alpha, 0.6);
        assert_eq!(HyperParams::paper(2).beta, 0.7);
    }
}
