//! TOML-subset parser (no `serde`/`toml` in the offline crate mirror).
//!
//! Supported grammar — everything experiment files use:
//! * `# comments` and blank lines
//! * `[section]` headers (flattened into dotted key prefixes)
//! * `key = "string"`, `key = 123`, `key = 1.5e-3`, `key = true`
//! * flat arrays `key = [1, 2, 3]` (flattened to a comma-joined value)
//!
//! Values are returned as raw strings; typing happens in
//! `ExperimentConfig::set`, so the parser stays schema-free.

use anyhow::{bail, Result};

/// Parse into ordered `(dotted.key, value)` pairs.
pub fn parse_flat(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut section = String::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            if section.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            continue;
        }
        let Some(eq) = find_unquoted(line, '=') else {
            bail!("line {}: expected 'key = value', got '{line}'", lineno + 1);
        };
        let key = line[..eq].trim();
        let value = line[eq + 1..].trim();
        if key.is_empty() || value.is_empty() {
            bail!("line {}: empty key or value", lineno + 1);
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.push((full_key, parse_value(value, lineno + 1)?));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    match find_unquoted(line, '#') {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Index of `target` outside double quotes.
fn find_unquoted(s: &str, target: char) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            c if c == target && !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_value(v: &str, lineno: usize) -> Result<String> {
    let v = v.trim();
    if let Some(inner) = v.strip_prefix('"') {
        let Some(s) = inner.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string");
        };
        return Ok(s.to_string());
    }
    if let Some(inner) = v.strip_prefix('[') {
        let Some(body) = inner.strip_suffix(']') else {
            bail!("line {lineno}: unterminated array");
        };
        let items: Vec<String> = body
            .split(',')
            .map(|x| x.trim().trim_matches('"').to_string())
            .filter(|x| !x.is_empty())
            .collect();
        return Ok(items.join(","));
    }
    // bare scalar: number or bool — validated downstream
    Ok(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_scalars_and_strings() {
        let text = r#"
            # an experiment
            name = "fig4"
            tau = 2
            alpha = 0.6   # tuned

            [data]
            train_n = 4096
            noniid = true

            [net]
            preset = "paper40g"
        "#;
        let kv = parse_flat(text).unwrap();
        let get = |k: &str| kv.iter().find(|(key, _)| key == k).map(|(_, v)| v.clone());
        assert_eq!(get("name").unwrap(), "fig4");
        assert_eq!(get("tau").unwrap(), "2");
        assert_eq!(get("alpha").unwrap(), "0.6");
        assert_eq!(get("data.train_n").unwrap(), "4096");
        assert_eq!(get("data.noniid").unwrap(), "true");
        assert_eq!(get("net.preset").unwrap(), "paper40g");
    }

    #[test]
    fn arrays_flatten_to_commas() {
        let kv = parse_flat("taus = [1, 2, 8, 24]").unwrap();
        assert_eq!(kv[0].1, "1,2,8,24");
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let kv = parse_flat(r#"name = "exp #7""#).unwrap();
        assert_eq!(kv[0].1, "exp #7");
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse_flat("[unterminated").is_err());
        assert!(parse_flat("novalue =").is_err());
        assert!(parse_flat("just a line").is_err());
        assert!(parse_flat("s = \"open").is_err());
    }
}
