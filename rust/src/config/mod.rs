//! Experiment configuration: typed config struct + TOML-subset parser +
//! `key=value` override layer (shared by config files and the CLI).
//!
//! The TOML subset covers what experiment files need: `[sections]`,
//! `key = value` with strings, integers, floats, booleans, and flat arrays,
//! plus `#` comments. Section names become dotted key prefixes, so
//! `[data]\ntrain_n = 4000` is the override `data.train_n=4000`.

pub mod toml;

use anyhow::{bail, Context, Result};

use crate::compress::CompressKind;
use crate::fault::FaultPlan;
use crate::model::simd::KernelTier;
use crate::simnet::{ClusterModel, ComputeModel, NetworkModel, StragglerModel};
use crate::topology::{Topology, TopologyKind};

/// Which execution backend drives the round loop (DESIGN.md §9, §13).
///
/// All backends produce bit-identical `TrainLog`s (the cross-backend
/// golden tests in `rust/tests/golden_regression.rs` and
/// `rust/tests/net_backend.rs` assert digest equality); they differ only
/// in what runs on real OS threads or processes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Execution {
    /// Single-threaded discrete-event simulation — the default. All
    /// concurrency is virtual (clock arithmetic); nothing overlaps on
    /// real cores.
    Sim,
    /// Real-thread backend: one OS thread per simulated worker for the
    /// local phase, plus a background communicator thread per collective,
    /// so overlapped schedules genuinely hide the reduction behind local
    /// compute (measured by `rust/benches/wallclock.rs`, E12).
    Threads,
    /// Real service plane: the coordinator runs the engine and worker
    /// *processes* run the local phases, connected over TCP with the
    /// hand-rolled wire protocol of DESIGN.md §13. Dropped or timed-out
    /// connections map to `crash@round` events in the fault subsystem;
    /// fresh connections claim dead slots as `rejoin@round` events.
    Net,
}

impl Execution {
    /// Parse a CLI/config spelling (`sim` | `threads` | `net`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sim" => Execution::Sim,
            "threads" | "thread" => Execution::Threads,
            "net" | "tcp" => Execution::Net,
            _ => bail!("unknown execution backend '{s}' (want sim|threads|net)"),
        })
    }

    /// Canonical name (round-trips through [`Execution::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Execution::Sim => "sim",
            Execution::Threads => "threads",
            Execution::Net => "net",
        }
    }
}

/// Which algorithm drives the run (see coordinator/).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Fully synchronous SGD: blocking gradient all-reduce every step.
    Sync,
    /// Local SGD: blocking parameter averaging every τ steps.
    Local,
    /// Overlap-Local-SGD, vanilla anchor (Eq. 5, β = 0).
    Overlap,
    /// Overlap-Local-SGD with anchor momentum (Eqs. 10–11) — the paper's
    /// headline algorithm.
    OverlapM,
    /// Overlap-m with the AdaComm-style adaptive-τ controller.
    OverlapAda,
    /// Decentralized overlap: per-worker anchors pulled toward push-sum
    /// neighbor averages on the gossip topology (DESIGN.md §8, E10).
    OverlapGossip,
    /// EASGD: blocking symmetric elastic x↔z exchange every τ steps.
    Easgd,
    /// EAMSGD: EASGD with local Nesterov momentum.
    Eamsgd,
    /// CoCoD-SGD: local deltas applied onto a τ-stale average, overlapped.
    Cocod,
    /// Sync SGD with rank-r PowerSGD gradient compression.
    PowerSgd,
}

impl Algo {
    /// Parse a CLI/config spelling (accepts `-`/`_`/collapsed variants).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sync" => Algo::Sync,
            "local" => Algo::Local,
            "overlap" => Algo::Overlap,
            "overlap-m" | "overlap_m" | "overlapm" => Algo::OverlapM,
            "overlap-ada" | "overlap_ada" | "overlapada" => Algo::OverlapAda,
            "overlap-gossip" | "overlap_gossip" | "overlapgossip" => Algo::OverlapGossip,
            "easgd" => Algo::Easgd,
            "eamsgd" => Algo::Eamsgd,
            "cocod" => Algo::Cocod,
            "powersgd" => Algo::PowerSgd,
            _ => bail!(
                "unknown algorithm '{s}' (want sync|local|overlap|overlap-m|overlap-ada|overlap-gossip|easgd|eamsgd|cocod|powersgd)"
            ),
        })
    }

    /// Canonical name (round-trips through [`Algo::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Sync => "sync",
            Algo::Local => "local",
            Algo::Overlap => "overlap",
            Algo::OverlapM => "overlap-m",
            Algo::OverlapAda => "overlap-ada",
            Algo::OverlapGossip => "overlap-gossip",
            Algo::Easgd => "easgd",
            Algo::Eamsgd => "eamsgd",
            Algo::Cocod => "cocod",
            Algo::PowerSgd => "powersgd",
        }
    }

    /// Every algorithm, in the canonical sweep order.
    pub fn all() -> &'static [Algo] {
        &[
            Algo::Sync,
            Algo::Local,
            Algo::Overlap,
            Algo::OverlapM,
            Algo::OverlapAda,
            Algo::OverlapGossip,
            Algo::Easgd,
            Algo::Eamsgd,
            Algo::Cocod,
            Algo::PowerSgd,
        ]
    }
}

/// Full experiment description. Every field is settable via
/// `set("dotted.key", "value")` so config files and CLI share one path.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// free-form experiment name (used in logs and output paths)
    pub name: String,
    /// which mixing schedule drives the run
    pub algo: Algo,
    /// model name handed to `runtime::load_for` ("cnn", "linear", "mlp");
    /// "mlp" selects the native one-hidden-layer ReLU model
    pub model: String,
    /// hidden width of the MLP model (`model = mlp`); ignored otherwise
    pub hidden: usize,
    /// kernel tier for the native hot kernels (`scalar` | `simd`,
    /// DESIGN.md §15). The tiers are bit-identical, so this never moves a
    /// digest — it only changes wall-clock speed
    pub kernels: KernelTier,
    /// cluster size m (simulated workers)
    pub workers: usize,
    /// training length in epochs (fractional allowed)
    pub epochs: f64,
    /// the one experiment seed every PRNG stream is derived from
    pub seed: u64,
    /// evaluate every this many epochs (also the loss-record cadence)
    pub eval_every: f64,
    /// execution backend: discrete-event `sim` or real-thread `threads`
    /// (bit-identical observables either way; DESIGN.md §9)
    pub execution: Execution,

    // optimizer
    /// base learning rate before the paper's warmup/decay scaling
    pub base_lr: f32,
    /// local steps per synchronization round (the paper's τ)
    pub tau: usize,
    /// adaptive-τ floor (overlap-ada never shrinks τ below this)
    pub tau_min: usize,
    /// per-worker heterogeneous τ: stragglers run fewer local steps per
    /// round so every worker hits the boundary at ≈ the same virtual time
    pub tau_hetero: bool,
    /// adaptive-τ: rounds without relative improvement before τ halves
    pub ada_patience: usize,
    /// adaptive-τ: relative round-loss improvement that counts as progress
    pub ada_threshold: f64,
    /// pullback / elastic strength α (Eq. 4)
    pub alpha: f32,
    /// anchor momentum β (Eqs. 10–11); 0 gives the vanilla anchor
    pub beta: f32,
    /// local Nesterov momentum μ
    pub mu: f32,
    /// weight decay
    pub wd: f32,
    /// PowerSGD rank (`compress_rank` is an alias for this key)
    pub rank: usize,
    /// collective-payload compressor (`--compress`, DESIGN.md §12);
    /// orthogonal to the algorithm and topology axes
    pub compress: CompressKind,
    /// top-k kept entries per message (`--set compress_k=`); 0 = auto
    /// (1% of the message, at least one entry)
    pub compress_k: usize,
    /// QSGD quantization bits per entry (`--set compress_bits=`, 2..=32;
    /// 32 is the bit-exact lossless limit)
    pub compress_bits: u32,
    /// local optimizer: "nesterov" (paper recipe) or "adam" (§6 extension,
    /// Overlap-Local-Adam — local steps use fused Adam)
    pub local_opt: String,

    // data
    /// training-set size (synthetic-CIFAR samples)
    pub train_n: usize,
    /// test-set size (must be a multiple of the eval batch)
    pub test_n: usize,
    /// non-IID sharding: each worker's shard dominated by one class
    pub noniid: bool,
    /// dominant-class fraction of each non-IID shard (paper: 0.64)
    pub dominant_frac: f64,
    /// reshuffle each worker's shard every epoch
    pub reshuffle: bool,

    // cluster timing + communication graph
    /// network cost preset: paper40g | slow10g | fast
    pub net_preset: String,
    /// communication topology: ring | hier | tree | gossip (DESIGN.md §8)
    pub topology: String,
    /// gossip graph degree (k-regular; clamped to a connected range)
    pub gossip_degree: usize,
    /// number of groups in the hierarchical two-level ring
    pub hier_groups: usize,
    /// per-worker compute-time variability model
    pub straggler: StragglerModel,
    /// explicit fault schedule (DESIGN.md §11): `;`-separated
    /// `crash@round:worker` / `rejoin@round:worker` /
    /// `partition@round:set|set` / `heal@round` events (the `fault` key
    /// *appends*, so repeated `--fault` flags accumulate; `fault=none`
    /// clears). Empty by default — and bit-inert when empty.
    pub fault: FaultPlan,
    /// random fault process: per-worker per-round crash probability
    /// (0 disables; drawn from seeded per-worker `"fault/{w}"` streams —
    /// per-id `"fault/{id}"` streams when a population is registered)
    pub fault_rate: f64,
    /// random fault process: per-worker per-round rejoin probability for
    /// downed workers (0 = crashed workers stay down unless an explicit
    /// `rejoin@` event revives them)
    pub rejoin_rate: f64,
    // population-scale partial participation (DESIGN.md §14, E17)
    /// registered population size N (0 = axis off: every worker
    /// participates every round, the dense pre-population behavior). When
    /// set, each round trains a deterministically sampled cohort of
    /// `sample_k` workers; per-worker state is materialized lazily and
    /// evicted LRU, so resident memory is O(k), not O(N)
    pub population: u64,
    /// sampled cohort size k (0 = use `workers`); [`ExperimentConfig::resolved`]
    /// normalizes `workers` to this value, since the engine's slot count
    /// *is* the cohort size
    pub sample_k: usize,
    /// seed of the per-round cohort sampler streams (0 = derive from `seed`)
    pub sample_seed: u64,
    /// LRU reserve: unbound worker states kept resident beyond the k bound
    /// ones before eviction to the disk spill (0 = evict immediately —
    /// every cohort change round-trips through the spill codec)
    pub sample_reserve: usize,

    /// seconds per local mini-batch step on an unperturbed node
    pub base_step_s: f64,
    /// None -> paper ResNet-18 message size (44.7 MB); Some(0) -> actual
    /// model size; Some(b) -> explicit bytes
    pub message_bytes: Option<usize>,

    // net execution backend (`--execution net`, DESIGN.md §13)
    /// coordinator listen address (`host:port`; port 0 = OS-assigned)
    pub net_listen: String,
    /// worker processes the self-hosting coordinator forks (slots are
    /// split as evenly as possible across them)
    pub net_procs: usize,
    /// fork local worker processes (`olsgd train --execution net`); the
    /// `olsgd coordinator` subcommand sets this false and waits for
    /// external `olsgd worker` clients instead
    pub net_spawn: bool,
    /// per-connection read/write timeout in seconds; a worker that stays
    /// silent longer is declared dead and crashed into the fault model
    pub net_timeout_s: f64,
    /// worker binary for self-hosted spawning (empty = this executable);
    /// integration tests point it at the `olsgd` binary explicitly
    pub net_worker_bin: String,
    /// chaos hook `proc:rounds`: the self-hosted worker process `proc`
    /// exits after serving `rounds` rounds — the deterministic
    /// kill-a-worker leg of the E16 suite (empty = off)
    pub net_kill: String,

    /// directory holding the AOT PJRT artifacts (feature `pjrt`)
    pub artifacts_dir: String,
    /// default output directory for result JSON/CSV
    pub out_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "experiment".into(),
            algo: Algo::OverlapM,
            model: "cnn".into(),
            hidden: crate::runtime::DEFAULT_HIDDEN,
            kernels: KernelTier::Scalar,
            workers: 8,
            epochs: 20.0,
            seed: 1,
            eval_every: 1.0,
            execution: Execution::Sim,
            // paper recipe is 0.1 on BN-equipped ResNet-18; our scaled CNN
            // has no normalization layers, so 0.05 is its stable analogue
            base_lr: 0.05,
            tau: 2,
            tau_min: 1,
            tau_hetero: false,
            ada_patience: 2,
            ada_threshold: 0.02,
            alpha: 0.6,
            beta: 0.7,
            mu: 0.9,
            wd: 1e-4,
            rank: 4,
            compress: CompressKind::None,
            compress_k: 0,
            compress_bits: 8,
            local_opt: "nesterov".into(),
            train_n: 4096,
            test_n: 1000,
            noniid: false,
            dominant_frac: 0.64,
            reshuffle: true,
            net_preset: "paper40g".into(),
            topology: "ring".into(),
            gossip_degree: 4,
            hier_groups: 4,
            straggler: StragglerModel::None,
            fault: FaultPlan::default(),
            fault_rate: 0.0,
            rejoin_rate: 0.0,
            population: 0,
            sample_k: 0,
            sample_seed: 0,
            sample_reserve: 8,
            base_step_s: 0.188,
            message_bytes: None,
            net_listen: "127.0.0.1:0".into(),
            net_procs: 2,
            net_spawn: true,
            net_timeout_s: 30.0,
            net_worker_bin: String::new(),
            net_kill: String::new(),
            artifacts_dir: "artifacts".into(),
            out_dir: "results".into(),
        }
    }
}

impl ExperimentConfig {
    /// Apply one dotted-key override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim().trim_matches('"');
        let parse_f64 = || -> Result<f64> {
            v.parse::<f64>().with_context(|| format!("bad number for {key}: '{v}'"))
        };
        let parse_usize = || -> Result<usize> {
            v.parse::<usize>().with_context(|| format!("bad integer for {key}: '{v}'"))
        };
        let parse_bool = || -> Result<bool> {
            v.parse::<bool>().with_context(|| format!("bad bool for {key}: '{v}'"))
        };
        match key {
            "name" => self.name = v.to_string(),
            "algo" | "algorithm" => self.algo = Algo::parse(v)?,
            "model" => self.model = v.to_string(),
            "hidden" => {
                let h = parse_usize()?;
                anyhow::ensure!(h >= 1, "hidden must be >= 1");
                self.hidden = h;
            }
            "kernels" | "kernel_tier" => self.kernels = KernelTier::parse(v)?,
            "workers" | "m" => self.workers = parse_usize()?,
            "epochs" => self.epochs = parse_f64()?,
            "seed" => self.seed = v.parse().context("bad seed")?,
            "eval_every" => self.eval_every = parse_f64()?,
            "execution" | "exec" => self.execution = Execution::parse(v)?,
            "base_lr" | "lr" => self.base_lr = parse_f64()? as f32,
            "tau" => self.tau = parse_usize()?,
            "tau_min" => self.tau_min = parse_usize()?,
            "tau_hetero" | "hetero_tau" => self.tau_hetero = parse_bool()?,
            "ada_patience" => self.ada_patience = parse_usize()?,
            "ada_threshold" => self.ada_threshold = parse_f64()?,
            "alpha" => self.alpha = parse_f64()? as f32,
            "beta" => self.beta = parse_f64()? as f32,
            "mu" | "momentum" => self.mu = parse_f64()? as f32,
            "wd" | "weight_decay" => self.wd = parse_f64()? as f32,
            "rank" | "compress_rank" => self.rank = parse_usize()?,
            "compress" => self.compress = CompressKind::parse(v)?,
            "compress_k" => self.compress_k = parse_usize()?,
            "compress_bits" => {
                let bits = v.parse::<u32>()
                    .with_context(|| format!("bad integer for {key}: '{v}'"))?;
                anyhow::ensure!(
                    (2..=32).contains(&bits),
                    "compress_bits must be in 2..=32, got {bits}"
                );
                self.compress_bits = bits;
            }
            "local_opt" | "optimizer" => {
                anyhow::ensure!(
                    v == "nesterov" || v == "adam",
                    "local_opt must be 'nesterov' or 'adam', got '{v}'"
                );
                self.local_opt = v.to_string();
            }
            "data.train_n" | "train_n" => self.train_n = parse_usize()?,
            "data.test_n" | "test_n" => self.test_n = parse_usize()?,
            "data.noniid" | "noniid" => self.noniid = parse_bool()?,
            "data.dominant_frac" | "dominant_frac" => self.dominant_frac = parse_f64()?,
            "data.reshuffle" | "reshuffle" => self.reshuffle = parse_bool()?,
            "net.preset" | "net" => self.net_preset = v.to_string(),
            "topology" | "net.topology" | "topo" => self.topology = v.to_string(),
            "gossip_degree" | "net.gossip_degree" => self.gossip_degree = parse_usize()?,
            "hier_groups" | "net.hier_groups" => self.hier_groups = parse_usize()?,
            "net.base_step_s" | "base_step_s" => self.base_step_s = parse_f64()?,
            "net.message_bytes" | "message_bytes" => {
                self.message_bytes = Some(parse_usize()?)
            }
            "straggler" => {
                // none | exp:<scale> | slow:<node>:<factor> | jitter:<j>
                let parts: Vec<&str> = v.split(':').collect();
                self.straggler = match parts[0] {
                    "none" => StragglerModel::None,
                    "exp" => StragglerModel::ShiftedExp {
                        scale: parts.get(1).unwrap_or(&"0.2").parse()?,
                    },
                    "slow" => StragglerModel::SlowNode {
                        node: parts.get(1).unwrap_or(&"0").parse()?,
                        factor: parts.get(2).unwrap_or(&"3.0").parse()?,
                    },
                    "jitter" => StragglerModel::UniformJitter {
                        jitter: parts.get(1).unwrap_or(&"0.1").parse()?,
                    },
                    other => bail!("unknown straggler model '{other}'"),
                };
            }
            "fault" | "faults" => self.fault.push(v)?,
            "fault_rate" => {
                let r = parse_f64()?;
                anyhow::ensure!((0.0..1.0).contains(&r), "fault_rate must be in [0, 1)");
                self.fault_rate = r;
            }
            "rejoin_rate" => {
                let r = parse_f64()?;
                anyhow::ensure!((0.0..1.0).contains(&r), "rejoin_rate must be in [0, 1)");
                self.rejoin_rate = r;
            }
            "population" | "n_pop" => {
                self.population = v
                    .parse()
                    .with_context(|| format!("bad integer for {key}: '{v}'"))?
            }
            "sample_k" => self.sample_k = parse_usize()?,
            "sample_seed" => {
                self.sample_seed = v
                    .parse()
                    .with_context(|| format!("bad integer for {key}: '{v}'"))?
            }
            "sample_reserve" => self.sample_reserve = parse_usize()?,
            "net_listen" => self.net_listen = v.to_string(),
            "net_procs" => {
                let p = parse_usize()?;
                anyhow::ensure!(p >= 1, "net_procs must be >= 1");
                self.net_procs = p;
            }
            "net_spawn" => self.net_spawn = parse_bool()?,
            "net_timeout_s" => {
                let t = parse_f64()?;
                anyhow::ensure!(t > 0.0, "net_timeout_s must be positive");
                self.net_timeout_s = t;
            }
            "net_worker_bin" => self.net_worker_bin = v.to_string(),
            "net_kill" => {
                if !v.is_empty() {
                    let (p, r) = v
                        .split_once(':')
                        .with_context(|| format!("net_kill wants proc:rounds, got '{v}'"))?;
                    p.parse::<usize>().with_context(|| format!("bad proc in net_kill '{v}'"))?;
                    r.parse::<u64>().with_context(|| format!("bad rounds in net_kill '{v}'"))?;
                }
                self.net_kill = v.to_string();
            }
            "artifacts_dir" => self.artifacts_dir = v.to_string(),
            "out_dir" => self.out_dir = v.to_string(),
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    /// Serialize the full config as canonical `(key, value)` pairs: applying
    /// them to a default config via [`ExperimentConfig::set`] reconstructs
    /// this config exactly (the net backend's handshake ships these to every
    /// worker process, which must rebuild bit-identical data, shards, and
    /// schedules — DESIGN.md §13). `message_bytes = None` is expressed by
    /// omitting the key.
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let kv = |k: &str, v: String| (k.to_string(), v);
        let mut out = vec![
            kv("name", self.name.clone()),
            kv("algo", self.algo.name().to_string()),
            kv("model", self.model.clone()),
            kv("hidden", self.hidden.to_string()),
            kv("kernels", self.kernels.name().to_string()),
            kv("workers", self.workers.to_string()),
            kv("epochs", self.epochs.to_string()),
            kv("seed", self.seed.to_string()),
            kv("eval_every", self.eval_every.to_string()),
            kv("execution", self.execution.name().to_string()),
            kv("base_lr", self.base_lr.to_string()),
            kv("tau", self.tau.to_string()),
            kv("tau_min", self.tau_min.to_string()),
            kv("tau_hetero", self.tau_hetero.to_string()),
            kv("ada_patience", self.ada_patience.to_string()),
            kv("ada_threshold", self.ada_threshold.to_string()),
            kv("alpha", self.alpha.to_string()),
            kv("beta", self.beta.to_string()),
            kv("mu", self.mu.to_string()),
            kv("wd", self.wd.to_string()),
            kv("rank", self.rank.to_string()),
            kv("compress", self.compress.name().to_string()),
            kv("compress_k", self.compress_k.to_string()),
            kv("compress_bits", self.compress_bits.to_string()),
            kv("local_opt", self.local_opt.clone()),
            kv("train_n", self.train_n.to_string()),
            kv("test_n", self.test_n.to_string()),
            kv("noniid", self.noniid.to_string()),
            kv("dominant_frac", self.dominant_frac.to_string()),
            kv("reshuffle", self.reshuffle.to_string()),
            kv("net", self.net_preset.clone()),
            kv("topology", self.topology.clone()),
            kv("gossip_degree", self.gossip_degree.to_string()),
            kv("hier_groups", self.hier_groups.to_string()),
            kv("straggler", self.straggler.spec()),
            // `fault` appends; "none" clears, so an empty plan round-trips.
            kv(
                "fault",
                if self.fault.is_empty() { "none".to_string() } else { self.fault.describe() },
            ),
            kv("fault_rate", self.fault_rate.to_string()),
            kv("rejoin_rate", self.rejoin_rate.to_string()),
            kv("population", self.population.to_string()),
            kv("sample_k", self.sample_k.to_string()),
            kv("sample_seed", self.sample_seed.to_string()),
            kv("sample_reserve", self.sample_reserve.to_string()),
            kv("base_step_s", self.base_step_s.to_string()),
            kv("net_listen", self.net_listen.clone()),
            kv("net_procs", self.net_procs.to_string()),
            kv("net_spawn", self.net_spawn.to_string()),
            kv("net_timeout_s", self.net_timeout_s.to_string()),
            kv("net_worker_bin", self.net_worker_bin.clone()),
            kv("net_kill", self.net_kill.clone()),
            kv("artifacts_dir", self.artifacts_dir.clone()),
            kv("out_dir", self.out_dir.clone()),
        ];
        if let Some(b) = self.message_bytes {
            out.push(kv("message_bytes", b.to_string()));
        }
        out
    }

    /// Load a TOML-subset file, then apply `overrides` in order.
    pub fn from_file(path: &str, overrides: &[(String, String)]) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let mut cfg = Self::default();
        for (k, v) in toml::parse_flat(&text)? {
            cfg.set(&k, &v)?;
        }
        for (k, v) in overrides {
            cfg.set(k, v)?;
        }
        Ok(cfg)
    }

    /// Resolve the population axis into an executable config (DESIGN.md
    /// §14) and validate its compositions. With `population == 0` this is
    /// an identity clone. With `population > 0` the engine's slot count
    /// *is* the cohort size, so `workers` is normalized to `sample_k`
    /// (which itself defaults to `workers`).
    ///
    /// Every scenario axis now composes with population sampling — the
    /// `net` backend (`PhaseReq` ships the slot → id binding and the
    /// bound worker's streams), the `fault_rate`/`rejoin_rate` random
    /// process (lazy per-id `"fault/{id}"` streams, O(k) per round),
    /// partitions over id sets, and PowerSGD (warm bases + gradient
    /// residual ride the spill codec). The checks left below are
    /// *consistency* errors — a cohort the population cannot fill, or the
    /// axis half-engaged — each stating the reason and the fix.
    ///
    /// `run_experiment` calls this; tests that assemble a `TrainContext`
    /// by hand must call it themselves before engaging the axis.
    pub fn resolved(&self) -> Result<ExperimentConfig> {
        let mut out = self.clone();
        if self.population == 0 {
            anyhow::ensure!(
                self.sample_k == 0,
                "sample_k = {} engages cohort sampling, which needs a registered \
                 population; set population=N (N >= sample_k) or drop sample_k",
                self.sample_k
            );
            return Ok(out);
        }
        let k = if self.sample_k == 0 { self.workers } else { self.sample_k };
        anyhow::ensure!(k >= 1, "sample_k must be >= 1");
        anyhow::ensure!(
            self.population >= k as u64,
            "population {} cannot fill a cohort of sample_k = {k}; register at \
             least k workers (population >= sample_k) or shrink the cohort",
            self.population
        );
        crate::fault::validate_population_plan(&self.fault, self.population)?;
        out.workers = k;
        out.sample_k = k;
        Ok(out)
    }

    /// The wire cost model selected by `net_preset`.
    pub fn network(&self) -> Result<NetworkModel> {
        Ok(match self.net_preset.as_str() {
            "paper40g" => NetworkModel::paper_40gbps(),
            "slow10g" => NetworkModel::slow_10gbps(),
            "fast" => NetworkModel::fast_fabric(),
            other => bail!("unknown net preset '{other}' (paper40g|slow10g|fast)"),
        })
    }

    /// The configured communication graph (validated here so bad specs fail
    /// before any training state exists). An *explicitly* requested gossip
    /// topology must be feasible as asked — a silently altered degree would
    /// skew every byte/time observable against the recorded config. (The
    /// auto-derived graph of `--algo overlap-gossip` on the default ring
    /// clamps instead; see `coordinator::gossip`.)
    pub fn topology(&self) -> Result<Topology> {
        let t = Topology::from_spec(
            &self.topology,
            self.workers,
            self.gossip_degree,
            self.hier_groups,
            self.seed,
        )?;
        if t.kind == TopologyKind::Gossip && t.degree() != self.gossip_degree {
            bail!(
                "gossip_degree {} is infeasible for {} workers (m = 2 admits only k = 1; \
                 otherwise a connected k-regular graph needs 2 <= k <= m-1, with odd k \
                 requiring even m; nearest feasible here: {}) — set a feasible \
                 gossip_degree, or use the default ring topology with --algo \
                 overlap-gossip to derive one automatically",
                self.gossip_degree,
                self.workers,
                t.degree()
            );
        }
        if t.kind == TopologyKind::Hier && t.group_bounds().len() != self.hier_groups {
            bail!(
                "hier_groups {} is infeasible for {} workers (need 1 <= groups <= m)",
                self.hier_groups,
                self.workers
            );
        }
        Ok(t)
    }

    /// Assemble the cluster timing model; `actual_model_bytes` is used when
    /// `message_bytes = 0` is requested.
    pub fn cluster(&self, actual_model_bytes: usize) -> Result<ClusterModel> {
        let message_bytes = match self.message_bytes {
            None => 11_173_962 * 4, // paper's ResNet-18
            Some(0) => actual_model_bytes,
            Some(b) => b,
        };
        Ok(ClusterModel {
            workers: self.workers,
            net: self.network()?,
            compute: ComputeModel {
                base_step_s: self.base_step_s,
                straggler: self.straggler.clone(),
            },
            message_bytes,
            topology: self.topology()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_coherent() {
        let c = ExperimentConfig::default();
        assert_eq!(c.algo, Algo::OverlapM);
        assert!(c.cluster(1000).is_ok());
    }

    #[test]
    fn set_overrides_every_group() {
        let mut c = ExperimentConfig::default();
        c.set("algo", "cocod").unwrap();
        c.set("tau", "24").unwrap();
        c.set("data.noniid", "true").unwrap();
        c.set("straggler", "slow:2:3.5").unwrap();
        c.set("net.message_bytes", "0").unwrap();
        assert_eq!(c.algo, Algo::Cocod);
        assert_eq!(c.tau, 24);
        assert!(c.noniid);
        match c.straggler {
            StragglerModel::SlowNode { node, factor } => {
                assert_eq!(node, 2);
                assert_eq!(factor, 3.5);
            }
            _ => panic!("wrong straggler"),
        }
        assert_eq!(c.cluster(1234).unwrap().message_bytes, 1234);
    }

    #[test]
    fn model_and_kernel_keys_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert_eq!(d.kernels, KernelTier::Scalar);
        assert_eq!(d.hidden, crate::runtime::DEFAULT_HIDDEN);
        let mut c = ExperimentConfig::default();
        c.set("model", "mlp").unwrap();
        c.set("hidden", "256").unwrap();
        c.set("kernels", "simd").unwrap();
        assert_eq!(c.model, "mlp");
        assert_eq!(c.hidden, 256);
        assert_eq!(c.kernels, KernelTier::Simd);
        c.set("kernel_tier", "scalar").unwrap(); // alias
        assert_eq!(c.kernels, KernelTier::Scalar);
        assert!(c.set("kernels", "avx512").is_err());
        assert!(c.set("hidden", "0").is_err());
        assert!(c.set("hidden", "wide").is_err());
    }

    #[test]
    fn unknown_key_is_error() {
        let mut c = ExperimentConfig::default();
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn algo_round_trips() {
        for a in Algo::all() {
            assert_eq!(Algo::parse(a.name()).unwrap(), *a);
        }
        assert_eq!(Algo::all().len(), 10);
    }

    #[test]
    fn topology_keys_parse_and_validate() {
        use crate::topology::TopologyKind;
        let mut c = ExperimentConfig::default();
        assert_eq!(c.topology().unwrap().kind, TopologyKind::Ring);
        c.set("topology", "gossip").unwrap();
        c.set("gossip_degree", "6").unwrap();
        c.set("hier_groups", "2").unwrap();
        assert_eq!(c.gossip_degree, 6);
        assert_eq!(c.hier_groups, 2);
        let t = c.topology().unwrap();
        assert_eq!(t.kind, TopologyKind::Gossip);
        assert_eq!(t.degree(), 6);
        assert_eq!(c.cluster(100).unwrap().topology.kind, TopologyKind::Gossip);
        c.set("topology", "hier").unwrap();
        assert_eq!(c.topology().unwrap().group_bounds().len(), 2);
        // Infeasible explicit shapes are hard errors, not silent clamps.
        c.set("topology", "gossip").unwrap();
        c.set("gossip_degree", "1").unwrap(); // m=8 needs k >= 2
        assert!(c.topology().is_err());
        c.set("topology", "hier").unwrap();
        c.set("hier_groups", "16").unwrap(); // > m=8 workers
        assert!(c.topology().is_err());
        c.set("hier_groups", "0").unwrap();
        assert!(c.topology().is_err());
        c.set("topology", "moebius").unwrap(); // stored...
        assert!(c.topology().is_err()); // ...but rejected at use
        assert!(c.set("gossip_degree", "many").is_err());
    }

    #[test]
    fn adaptive_and_hetero_keys_parse() {
        let mut c = ExperimentConfig::default();
        c.set("algo", "overlap-ada").unwrap();
        c.set("tau", "16").unwrap();
        c.set("tau_min", "2").unwrap();
        c.set("tau_hetero", "true").unwrap();
        c.set("ada_patience", "3").unwrap();
        c.set("ada_threshold", "0.05").unwrap();
        assert_eq!(c.algo, Algo::OverlapAda);
        assert_eq!(c.tau_min, 2);
        assert!(c.tau_hetero);
        assert_eq!(c.ada_patience, 3);
        assert!((c.ada_threshold - 0.05).abs() < 1e-12);
        // defaults stay benign for every other algorithm
        let d = ExperimentConfig::default();
        assert_eq!(d.tau_min, 1);
        assert!(!d.tau_hetero);
        assert!(c.set("ada_threshold", "much").is_err());
    }

    #[test]
    fn compress_keys_parse_validate_and_default_off() {
        let d = ExperimentConfig::default();
        assert_eq!(d.compress, CompressKind::None);
        assert_eq!(d.compress_k, 0);
        assert_eq!(d.compress_bits, 8);
        let mut c = ExperimentConfig::default();
        c.set("compress", "topk").unwrap();
        c.set("compress_k", "500").unwrap();
        assert_eq!(c.compress, CompressKind::TopK);
        assert_eq!(c.compress_k, 500);
        c.set("compress", "qsgd").unwrap();
        c.set("compress_bits", "4").unwrap();
        assert_eq!(c.compress_bits, 4);
        c.set("compress", "powersgd").unwrap();
        c.set("compress_rank", "2").unwrap(); // alias for rank
        assert_eq!(c.rank, 2);
        c.set("compress", "none").unwrap();
        assert_eq!(c.compress, CompressKind::None);
        assert!(c.set("compress", "gzip").is_err());
        assert!(c.set("compress_bits", "1").is_err());
        assert!(c.set("compress_bits", "33").is_err());
        assert!(c.set("compress_k", "few").is_err());
    }

    #[test]
    fn execution_backend_parses_and_defaults_to_sim() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.execution, Execution::Sim);
        c.set("execution", "threads").unwrap();
        assert_eq!(c.execution, Execution::Threads);
        c.set("execution", "net").unwrap();
        assert_eq!(c.execution, Execution::Net);
        c.set("exec", "sim").unwrap();
        assert_eq!(c.execution, Execution::Sim);
        assert!(c.set("execution", "fibers").is_err());
        for e in [Execution::Sim, Execution::Threads, Execution::Net] {
            assert_eq!(Execution::parse(e.name()).unwrap(), e);
        }
    }

    #[test]
    fn net_keys_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert_eq!(d.net_listen, "127.0.0.1:0");
        assert!(d.net_spawn);
        assert!(d.net_kill.is_empty());
        let mut c = ExperimentConfig::default();
        c.set("net_listen", "0.0.0.0:7070").unwrap();
        c.set("net_procs", "4").unwrap();
        c.set("net_spawn", "false").unwrap();
        c.set("net_timeout_s", "2.5").unwrap();
        c.set("net_worker_bin", "/bin/olsgd").unwrap();
        c.set("net_kill", "1:3").unwrap();
        assert_eq!(c.net_listen, "0.0.0.0:7070");
        assert_eq!(c.net_procs, 4);
        assert!(!c.net_spawn);
        assert!((c.net_timeout_s - 2.5).abs() < 1e-12);
        assert_eq!(c.net_worker_bin, "/bin/olsgd");
        assert_eq!(c.net_kill, "1:3");
        // `net` (the preset key) must not collide with the new net_* keys.
        c.set("net", "slow10g").unwrap();
        assert_eq!(c.net_preset, "slow10g");
        assert_eq!(c.net_listen, "0.0.0.0:7070");
        assert!(c.set("net_procs", "0").is_err());
        assert!(c.set("net_timeout_s", "0").is_err());
        assert!(c.set("net_kill", "3").is_err());
        assert!(c.set("net_kill", "a:b").is_err());
    }

    #[test]
    fn to_kv_round_trips_through_set() {
        let replay = |cfg: &ExperimentConfig| {
            let mut c = ExperimentConfig::default();
            for (k, v) in cfg.to_kv() {
                c.set(&k, &v).unwrap_or_else(|e| panic!("set({k}, {v}): {e}"));
            }
            c
        };
        // Default config round-trips.
        let d = ExperimentConfig::default();
        assert_eq!(replay(&d).to_kv(), d.to_kv());
        // A config exercising every group — including fractional floats,
        // a straggler model, a multi-event fault plan, and the net keys —
        // round-trips exactly (the handshake-correctness requirement).
        let mut c = ExperimentConfig::default();
        for (k, v) in [
            ("algo", "easgd"),
            ("model", "mlp"),
            ("hidden", "64"),
            ("kernels", "simd"),
            ("workers", "16"),
            ("epochs", "2.5"),
            ("seed", "99"),
            ("execution", "net"),
            ("base_lr", "0.037"),
            ("tau", "8"),
            ("tau_hetero", "true"),
            ("alpha", "0.55"),
            ("mu", "0.93"),
            ("compress", "topk"),
            ("compress_k", "17"),
            ("local_opt", "adam"),
            ("noniid", "true"),
            ("dominant_frac", "0.61"),
            ("straggler", "slow:3:2.5"),
            ("fault", "crash@3:2;rejoin@6:2"),
            ("fault_rate", "0.01"),
            ("topology", "tree"),
            ("message_bytes", "4096"),
            ("net_procs", "3"),
            ("net_timeout_s", "1.25"),
            ("net_kill", "0:5"),
        ] {
            c.set(k, v).unwrap();
        }
        let r = replay(&c);
        assert_eq!(r.to_kv(), c.to_kv());
        assert_eq!(r.fault.describe(), "crash@3:2;rejoin@6:2");
        assert_eq!(r.straggler.spec(), "slow:3:2.5");
        assert_eq!(r.message_bytes, Some(4096));
    }

    #[test]
    fn fault_keys_parse_append_and_validate() {
        use crate::fault::FaultEvent;
        let mut c = ExperimentConfig::default();
        assert!(c.fault.is_empty());
        assert_eq!(c.fault_rate, 0.0);
        assert_eq!(c.rejoin_rate, 0.0);
        // The `fault` key appends, so repeated --fault flags accumulate.
        c.set("fault", "crash@3:2").unwrap();
        c.set("fault", "rejoin@6:2;partition@8:0,1|2,3").unwrap();
        assert_eq!(c.fault.events.len(), 3);
        assert_eq!(c.fault.events[0], FaultEvent::Crash { round: 3, worker: 2 });
        c.set("fault", "none").unwrap();
        assert!(c.fault.is_empty());
        c.set("fault_rate", "0.05").unwrap();
        c.set("rejoin_rate", "0.5").unwrap();
        assert!((c.fault_rate - 0.05).abs() < 1e-12);
        assert!((c.rejoin_rate - 0.5).abs() < 1e-12);
        // Garbage and out-of-range values are loud errors.
        assert!(c.set("fault", "crash@x:1").is_err());
        assert!(c.set("fault_rate", "1.5").is_err());
        assert!(c.set("rejoin_rate", "-0.1").is_err());
        assert!(c.set("fault_rate", "often").is_err());
    }

    #[test]
    fn population_keys_parse_resolve_and_validate() {
        let d = ExperimentConfig::default();
        assert_eq!(d.population, 0);
        assert_eq!(d.sample_k, 0);
        assert_eq!(d.sample_seed, 0);
        assert_eq!(d.sample_reserve, 8);
        // Off axis: resolved() is the identity.
        assert_eq!(d.resolved().unwrap().workers, d.workers);
        // sample_k without a population is a contradiction.
        let mut c = ExperimentConfig::default();
        c.set("sample_k", "4").unwrap();
        assert!(c.resolved().is_err());
        // Engaged: workers normalizes to the cohort size.
        let mut c = ExperimentConfig::default();
        c.set("population", "1000000").unwrap();
        c.set("sample_k", "16").unwrap();
        c.set("sample_seed", "7").unwrap();
        c.set("sample_reserve", "0").unwrap();
        let r = c.resolved().unwrap();
        assert_eq!(r.workers, 16);
        assert_eq!(r.sample_k, 16);
        assert_eq!(r.sample_reserve, 0);
        // sample_k defaults to workers.
        let mut c = ExperimentConfig::default();
        c.set("population", "64").unwrap();
        assert_eq!(c.resolved().unwrap().sample_k, c.workers);
        // The only remaining refusals are consistency errors: a cohort the
        // population cannot fill.
        let mut c = ExperimentConfig::default();
        c.set("population", "4").unwrap(); // < default workers = 8
        assert!(c.resolved().is_err());
        // The PR-8 composition refusals are lifted: net execution, the
        // random fault process, powersgd, and partitions over ids all
        // resolve under sampling now.
        c.set("population", "100").unwrap();
        c.set("execution", "net").unwrap();
        assert!(c.resolved().is_ok());
        c.set("execution", "sim").unwrap();
        c.set("fault_rate", "0.1").unwrap();
        c.set("rejoin_rate", "0.2").unwrap();
        assert!(c.resolved().is_ok());
        c.set("compress", "powersgd").unwrap();
        assert!(c.resolved().is_ok());
        c.set("fault", "partition@3:0-49|50-99;heal@6").unwrap();
        assert!(c.resolved().is_ok());
        c.set("fault", "none").unwrap();
        c.set("fault", "crash@3:200").unwrap(); // id outside N = 100
        assert!(c.resolved().is_err());
        c.set("fault", "none").unwrap();
        c.set("fault", "crash@3:42;rejoin@5:42").unwrap();
        assert!(c.resolved().is_ok());
        assert!(c.set("population", "many").is_err());
        assert!(c.set("sample_reserve", "-1").is_err());
    }

    #[test]
    fn population_keys_round_trip_through_kv() {
        let mut c = ExperimentConfig::default();
        c.set("population", "100000").unwrap();
        c.set("sample_k", "16").unwrap();
        c.set("sample_seed", "99").unwrap();
        c.set("sample_reserve", "32").unwrap();
        let mut r = ExperimentConfig::default();
        for (k, v) in c.to_kv() {
            r.set(&k, &v).unwrap_or_else(|e| panic!("set({k}, {v}): {e}"));
        }
        assert_eq!(r.to_kv(), c.to_kv());
        assert_eq!(r.population, 100_000);
        assert_eq!(r.sample_k, 16);
        assert_eq!(r.sample_seed, 99);
        assert_eq!(r.sample_reserve, 32);
    }

    #[test]
    fn message_bytes_default_is_paper_scale() {
        let c = ExperimentConfig::default();
        let cl = c.cluster(40).unwrap();
        assert_eq!(cl.message_bytes, 11_173_962 * 4);
    }
}
