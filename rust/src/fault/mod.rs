//! Fault-injection subsystem: crashes, rejoins, and network partitions as
//! first-class, bit-deterministic round-plan events (DESIGN.md §11,
//! EXPERIMENTS.md E14).
//!
//! The paper's anchor model decouples local progress from synchronization,
//! which should make Overlap-Local-SGD robust not just to *slow* nodes but
//! to nodes that *disappear* — Stochastic Gradient Push (Assran et al.,
//! PAPERS.md) shows column-stochastic de-biasing stays exactly
//! mean-preserving on time-varying participation graphs. This module owns
//! the failure model; the collective layer owns the alive-set-aware reduce
//! schedules that consume it.
//!
//! Three pieces:
//!
//! * [`FaultEvent`] / [`FaultPlan`] — the *configured* schedule, parsed
//!   from `--fault crash@round:worker`, `rejoin@round:worker`,
//!   `partition@round:set|set`, `heal@round` specs (rounds are 1-based;
//!   events apply at the *start* of their round, before any local step of
//!   that round runs).
//! * [`AliveSet`] — the cluster's current participation state: which
//!   workers are up, how the graph is partitioned, which partition
//!   component holds the quorum. Exact-collective strategies park every
//!   worker outside the primary (largest) component — no quorum, no
//!   progress — while the decentralized gossip strategy keeps *every*
//!   component training on its own sub-graph (`AliveSet::steps` vs
//!   [`AliveSet::edge_allowed`]).
//! * [`FaultState`] — the per-run replay machine the engine drives once per
//!   round: explicit events first, then (when `fault_rate`/`rejoin_rate`
//!   are set) a seeded random process drawing one decision per worker per
//!   round from that worker's own derived stream (`"fault/{w}"`).
//!   Everything runs on the coordinator thread, so a fixed schedule yields
//!   bit-identical observables on the `sim` and `threads` backends
//!   (asserted by rust/tests/failure_injection.rs).
//!
//! Population mode (DESIGN.md §14) replays the same model over stable
//! population ids via [`PopulationFaults`]: the random process keys its
//! streams on the *id* (`"fault/{id}"`, lazily advanced only for sampled
//! and downed ids — O(touched), never O(N)), partitions split the id
//! space into ranged sets, and under `population == sample_k` every path
//! collapses bit-for-bit onto the dense machine because id == slot.
//!
//! Per-worker *compressor* state (error-feedback residuals, PowerSGD
//! bases — DESIGN.md §12) obeys the same park/freeze discipline as the
//! replica it belongs to: a parked worker's residual is frozen bit-for-bit
//! and never averaged in, and a rejoiner's compressor state is reset
//! (residual zeroed, bases re-seeded) *before* the strategy's anchor warm
//! start. That protocol is what deleted the old "powersgd does not support
//! fault injection" refusal.

use anyhow::{bail, ensure, Context, Result};

use crate::util::rng::Rng;

/// One scheduled fault event. Rounds are 1-based; an event fires at the
/// start of its round, before that round's local phase.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultEvent {
    /// Worker `worker` drops out of the cluster at the start of `round`:
    /// its clock freezes, it takes no local steps, and every collective
    /// reduces over the survivors only.
    Crash {
        /// 1-based round the crash fires at
        round: usize,
        /// worker index
        worker: usize,
    },
    /// Worker `worker` comes back at the start of `round`, warm-started
    /// from the current anchor (the paper's pullback target) and charged
    /// one full-message state fetch on the wire.
    Rejoin {
        /// 1-based round the rejoin fires at
        round: usize,
        /// worker index
        worker: usize,
    },
    /// The network splits into the given disjoint components at the start
    /// of `round`. Sets accept single ids and inclusive `a-b` ranges. In
    /// dense mode the groups must cover every worker exactly once; in
    /// population mode they may name any subset of the id space — unlisted
    /// ids share one implicit trailing component. A later `Partition`
    /// replaces the split; `Heal` removes it.
    Partition {
        /// 1-based round the partition fires at
        round: usize,
        /// disjoint worker groups
        groups: Vec<Vec<usize>>,
    },
    /// The partition heals at the start of `round`: full connectivity is
    /// restored and parked minority workers rejoin from the anchor.
    Heal {
        /// 1-based round the heal fires at
        round: usize,
    },
}

impl FaultEvent {
    /// The 1-based round this event fires at.
    pub fn round(&self) -> usize {
        match self {
            FaultEvent::Crash { round, .. }
            | FaultEvent::Rejoin { round, .. }
            | FaultEvent::Partition { round, .. }
            | FaultEvent::Heal { round } => *round,
        }
    }

    /// Parse one spec: `crash@R:W`, `rejoin@R:W`, `partition@R:a,b|c,d`,
    /// `heal@R`.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        let (kind, rest) = spec
            .split_once('@')
            .with_context(|| format!("bad fault event '{spec}' (want kind@round[:args])"))?;
        let parse_round = |s: &str| -> Result<usize> {
            let r: usize =
                s.trim().parse().with_context(|| format!("bad round in fault event '{spec}'"))?;
            ensure!(r >= 1, "fault event '{spec}': rounds are 1-based");
            Ok(r)
        };
        let parse_worker = |s: &str| -> Result<usize> {
            s.trim().parse().with_context(|| format!("bad worker in fault event '{spec}'"))
        };
        Ok(match kind.trim() {
            "crash" | "rejoin" => {
                let (r, w) = rest.split_once(':').with_context(|| {
                    format!("fault event '{spec}' needs a worker (kind@round:worker)")
                })?;
                let (round, worker) = (parse_round(r)?, parse_worker(w)?);
                if kind.trim() == "crash" {
                    FaultEvent::Crash { round, worker }
                } else {
                    FaultEvent::Rejoin { round, worker }
                }
            }
            "partition" => {
                let (r, sets) = rest.split_once(':').with_context(|| {
                    format!("fault event '{spec}' needs worker sets (partition@round:a,b|c,d)")
                })?;
                let round = parse_round(r)?;
                let mut groups = Vec::new();
                for set in sets.split('|') {
                    let mut group = Vec::new();
                    for id in set.split(',') {
                        let id = id.trim();
                        if id.is_empty() {
                            continue;
                        }
                        // Inclusive range syntax (`a-b`) — how a population
                        // partition names 10^5 ids without 10^5 commas.
                        if let Some((a, b)) = id.split_once('-') {
                            let (a, b) = (parse_worker(a)?, parse_worker(b)?);
                            ensure!(
                                a <= b,
                                "fault event '{spec}': bad id range {a}-{b} (want lo-hi)"
                            );
                            group.extend(a..=b);
                        } else {
                            group.push(parse_worker(id)?);
                        }
                    }
                    ensure!(!group.is_empty(), "fault event '{spec}': empty partition set");
                    groups.push(group);
                }
                ensure!(
                    groups.len() >= 2,
                    "fault event '{spec}': a partition needs at least two sets"
                );
                FaultEvent::Partition { round, groups }
            }
            "heal" => FaultEvent::Heal { round: parse_round(rest)? },
            other => bail!(
                "unknown fault kind '{other}' in '{spec}' (want crash|rejoin|partition|heal)"
            ),
        })
    }

    /// Canonical spec string (round-trips through [`FaultEvent::parse`]).
    pub fn describe(&self) -> String {
        match self {
            FaultEvent::Crash { round, worker } => format!("crash@{round}:{worker}"),
            FaultEvent::Rejoin { round, worker } => format!("rejoin@{round}:{worker}"),
            FaultEvent::Partition { round, groups } => {
                // Ascending runs of >= 3 compress to `a-b` (round-trips
                // through `parse`; keeps population traces readable).
                let format_ids = |g: &[usize]| -> String {
                    let mut parts = Vec::new();
                    let mut i = 0;
                    while i < g.len() {
                        let mut j = i;
                        while j + 1 < g.len() && g[j + 1] == g[j] + 1 {
                            j += 1;
                        }
                        if j - i >= 2 {
                            parts.push(format!("{}-{}", g[i], g[j]));
                        } else {
                            for k in i..=j {
                                parts.push(g[k].to_string());
                            }
                        }
                        i = j + 1;
                    }
                    parts.join(",")
                };
                let sets: Vec<String> = groups.iter().map(|g| format_ids(g)).collect();
                format!("partition@{round}:{}", sets.join("|"))
            }
            FaultEvent::Heal { round } => format!("heal@{round}"),
        }
    }
}

/// The configured explicit fault schedule (the `fault` config key /
/// repeated `--fault` flags). The random-process knobs (`fault_rate`,
/// `rejoin_rate`) live beside it in `ExperimentConfig`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// the scheduled events, in spec order
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse a `;`-separated event list (empty or `none` → empty plan).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::default();
        plan.push(spec)?;
        Ok(plan)
    }

    /// Append the events of a `;`-separated spec; `none` clears the plan.
    pub fn push(&mut self, spec: &str) -> Result<()> {
        let spec = spec.trim();
        if spec.eq_ignore_ascii_case("none") {
            self.events.clear();
            return Ok(());
        }
        for ev in spec.split(';') {
            if !ev.trim().is_empty() {
                self.events.push(FaultEvent::parse(ev)?);
            }
        }
        Ok(())
    }

    /// Whether the plan schedules no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Canonical `;`-separated spec (round-trips through
    /// [`FaultPlan::parse`]).
    pub fn describe(&self) -> String {
        self.events.iter().map(FaultEvent::describe).collect::<Vec<_>>().join(";")
    }
}

/// The cluster's current participation state: which workers are alive,
/// how the communication graph is partitioned, and — derived from both —
/// who participates in exact collectives and who takes local steps.
///
/// Terminology used throughout the crate:
///
/// * a worker is **alive** if it has not crashed;
/// * the **primary** component is the partition component with the most
///   alive workers (ties break toward the component listed first in the
///   partition spec) — the quorum side;
/// * the **members** are the alive workers of the primary component: the
///   participant set of every *exact* collective (ring/hier/tree);
/// * a worker is **stepping** if it runs local steps this round: members
///   for the exact-collective strategies, *every alive worker* for the
///   decentralized gossip strategy (minority components keep training on
///   their sub-graph — no quorum needed, the decisive decentralized
///   advantage E14 measures).
#[derive(Clone, Debug)]
pub struct AliveSet {
    decentralized: bool,
    alive: Vec<bool>,
    /// partition component id per worker (all 0 when unpartitioned)
    component: Vec<usize>,
    partitioned: bool,
    primary: usize,
    members: Vec<usize>,
    stepping: Vec<bool>,
    stepping_count: usize,
}

impl AliveSet {
    /// Fully-connected, all-alive cluster of `m` workers.
    pub fn full(m: usize) -> Self {
        assert!(m >= 1, "alive set needs at least one worker");
        let mut s = Self {
            decentralized: false,
            alive: vec![true; m],
            component: vec![0; m],
            partitioned: false,
            primary: 0,
            members: Vec::with_capacity(m),
            stepping: vec![true; m],
            stepping_count: m,
        };
        s.refresh();
        s
    }

    /// An unpartitioned set with the given per-worker alive flags (at
    /// least one must be alive). Intended for tests and property sweeps.
    pub fn with_alive(alive: Vec<bool>) -> Self {
        assert!(alive.iter().any(|&a| a), "alive set needs at least one live worker");
        let m = alive.len();
        let mut s = Self::full(m);
        s.alive = alive;
        s.refresh();
        s
    }

    /// A set with the given alive flags *and* partition components
    /// (`component[w]` = component id of worker `w`). Intended for tests.
    pub fn with_partition(alive: Vec<bool>, component: Vec<usize>) -> Self {
        assert_eq!(alive.len(), component.len(), "alive/component length mismatch");
        let mut s = Self::with_alive(alive);
        s.component = component;
        s.partitioned = true;
        s.refresh();
        s
    }

    /// Worker count m (alive or not).
    pub fn len(&self) -> usize {
        self.alive.len()
    }

    /// Whether the set covers zero workers (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.alive.is_empty()
    }

    /// `true` when every worker is alive and the graph is unpartitioned —
    /// the state in which every fault-aware code path must be bit-identical
    /// to its pre-fault form.
    pub fn is_full(&self) -> bool {
        !self.partitioned && self.alive.iter().all(|&a| a)
    }

    /// Whether worker `w` is alive (has not crashed).
    pub fn is_alive(&self, w: usize) -> bool {
        self.alive[w]
    }

    /// The exact-collective participants: alive workers of the primary
    /// component, in ascending worker order.
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// Number of exact-collective participants.
    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    /// Whether worker `w` participates in exact collectives this round.
    pub fn is_member(&self, w: usize) -> bool {
        self.alive[w] && self.component[w] == self.primary
    }

    /// Whether worker `w` runs local steps this round (see the type docs
    /// for the exact-vs-decentralized distinction).
    pub fn steps(&self, w: usize) -> bool {
        self.stepping[w]
    }

    /// Number of stepping workers — the survivor-count series in
    /// `TrainLog::survivors`.
    pub fn stepping_count(&self) -> usize {
        self.stepping_count
    }

    /// Whether a message can move between workers `i` and `j`: both alive
    /// and in the same partition component. The gossip data plane filters
    /// its edges with exactly this predicate.
    pub fn edge_allowed(&self, i: usize, j: usize) -> bool {
        self.alive[i] && self.alive[j] && self.component[i] == self.component[j]
    }

    /// Install the decentralized stepping rule (gossip: every alive worker
    /// steps, partitioned or not).
    pub(crate) fn set_decentralized(&mut self, decentralized: bool) {
        self.decentralized = decentralized;
        self.refresh();
    }

    pub(crate) fn set_alive(&mut self, w: usize, alive: bool) {
        self.alive[w] = alive;
    }

    pub(crate) fn set_partition(&mut self, groups: &[Vec<usize>]) {
        for (id, group) in groups.iter().enumerate() {
            for &w in group {
                self.component[w] = id;
            }
        }
        self.partitioned = true;
    }

    pub(crate) fn clear_partition(&mut self) {
        self.component.fill(0);
        self.partitioned = false;
    }

    /// Recompute the derived state (primary component, members, stepping).
    pub(crate) fn refresh(&mut self) {
        let m = self.alive.len();
        self.primary = if self.partitioned {
            // Most alive members wins; ties break toward the lowest
            // component id (the set listed first in the spec).
            let max_id = self.component.iter().copied().max().unwrap_or(0);
            let mut best = (0usize, 0usize);
            for id in 0..=max_id {
                let count = (0..m).filter(|&w| self.alive[w] && self.component[w] == id).count();
                if count > best.1 {
                    best = (id, count);
                }
            }
            best.0
        } else {
            0
        };
        self.members.clear();
        self.members.extend(
            (0..m).filter(|&w| self.alive[w] && self.component[w] == self.primary),
        );
        self.stepping_count = 0;
        for w in 0..m {
            self.stepping[w] = self.alive[w]
                && (self.decentralized || self.component[w] == self.primary);
            self.stepping_count += usize::from(self.stepping[w]);
        }
    }
}

/// What one round's fault application produced, handed back to the engine.
pub struct RoundFaults {
    /// events applied this round (explicit + synthesized random), in
    /// application order — the `TrainLog::fault_trace` entries
    pub applied: Vec<FaultEvent>,
    /// workers that transitioned parked → stepping (crash rejoins and
    /// partition returns): the engine warm-starts these from the anchor
    pub joined: Vec<usize>,
    /// re-seed source: the lowest-id worker that was stepping before this
    /// round's events (preferring one still stepping) — a boundary-accurate
    /// replica for the default warm-start
    pub src: usize,
    /// whether the stepping count changed (drives the survivor series)
    pub changed: bool,
}

/// The per-run fault replay machine, owned by the engine. Applies the
/// explicit schedule and the seeded random process at each round boundary,
/// entirely on the coordinator thread — bit-deterministic by construction
/// on either execution backend.
pub struct FaultState {
    /// the cluster's current participation state
    pub alive: AliveSet,
    /// events sorted stably by round (spec order within a round)
    events: Vec<FaultEvent>,
    cursor: usize,
    /// events synthesized at run time (the net backend's dropped-connection
    /// crashes and reconnect rejoins, [`FaultState::inject`]) — applied
    /// after the explicit schedule of their round
    injected: Vec<FaultEvent>,
    rate: f64,
    rejoin_rate: f64,
    /// one private stream per worker (`"fault/{w}"`): the draw a worker
    /// sees depends only on its identity, round, and the seed — the same
    /// keying [`PopulationFaults`] uses per population id, which is what
    /// makes the `N == k` random-process digests collapse onto this one
    streams: Vec<Rng>,
    engaged: bool,
}

impl FaultState {
    /// Build the replay machine for one run of `m` workers. `seed` derives
    /// the per-worker random process streams (`"fault/{w}"` — perturbs no
    /// other consumer).
    pub fn new(plan: &FaultPlan, rate: f64, rejoin_rate: f64, seed: u64, m: usize) -> Self {
        let mut events = plan.events.clone();
        events.sort_by_key(FaultEvent::round); // stable: spec order within a round
        let engaged = !events.is_empty() || rate > 0.0;
        Self {
            alive: AliveSet::full(m),
            events,
            cursor: 0,
            injected: Vec::new(),
            rate,
            rejoin_rate,
            streams: (0..m).map(|w| Rng::stream(seed, &format!("fault/{w}"))).collect(),
            engaged,
        }
    }

    /// Queue an event synthesized by the service plane for the *upcoming*
    /// round — the net backend maps a dead TCP connection to a `Crash` and
    /// a reconnect claiming dead slots to a `Rejoin` (DESIGN.md §13).
    /// Injected events run through exactly the same application, trace, and
    /// warm-start machinery as a `--fault` schedule, which is why killing a
    /// worker process replays bit-identically to the equivalent explicit
    /// `crash@round:worker` spec. Injection engages the fault machinery if
    /// it wasn't already.
    pub fn inject(&mut self, ev: FaultEvent) -> Result<()> {
        let m = self.alive.len();
        if let FaultEvent::Crash { worker, .. } | FaultEvent::Rejoin { worker, .. } = &ev {
            ensure!(
                *worker < m,
                "injected fault event '{}' names worker {} but the cluster has {} workers",
                ev.describe(),
                worker,
                m
            );
        }
        self.injected.push(ev);
        self.engaged = true;
        Ok(())
    }

    /// Whether any fault source is configured. When `false`, the engine
    /// never calls [`FaultState::begin_round`] and every fault-aware code
    /// path takes its pre-fault branch — the empty-schedule digest
    /// guarantee.
    pub fn engaged(&self) -> bool {
        self.engaged
    }

    /// Install the strategy's stepping rule (see [`AliveSet`]).
    pub fn set_decentralized(&mut self, decentralized: bool) {
        self.alive.set_decentralized(decentralized);
    }

    /// Validate the schedule against the cluster size (run start): worker
    /// indices in range, partitions disjoint and covering, rates sane.
    pub fn validate(&self) -> Result<()> {
        let m = self.alive.len();
        ensure!(
            (0.0..1.0).contains(&self.rate),
            "fault_rate must be in [0, 1), got {}",
            self.rate
        );
        ensure!(
            (0.0..1.0).contains(&self.rejoin_rate),
            "rejoin_rate must be in [0, 1), got {}",
            self.rejoin_rate
        );
        for ev in &self.events {
            match ev {
                FaultEvent::Crash { worker, .. } | FaultEvent::Rejoin { worker, .. } => {
                    ensure!(
                        *worker < m,
                        "fault event '{}' names worker {} but the cluster has {} workers",
                        ev.describe(),
                        worker,
                        m
                    );
                }
                FaultEvent::Partition { groups, .. } => {
                    let mut seen = vec![false; m];
                    for g in groups {
                        for &w in g {
                            ensure!(
                                w < m,
                                "fault event '{}' names worker {w} but the cluster has {m} workers",
                                ev.describe()
                            );
                            ensure!(
                                !seen[w],
                                "fault event '{}' lists worker {w} twice",
                                ev.describe()
                            );
                            seen[w] = true;
                        }
                    }
                    ensure!(
                        seen.iter().all(|&s| s),
                        "fault event '{}' must cover every worker exactly once",
                        ev.describe()
                    );
                }
                FaultEvent::Heal { .. } => {}
            }
        }
        Ok(())
    }

    /// Apply one event's alive-set transition (shared by the explicit
    /// schedule and the injected service-plane events). The caller
    /// refreshes the derived state afterwards.
    fn apply_event(&mut self, ev: &FaultEvent) -> Result<()> {
        match ev {
            FaultEvent::Crash { worker, .. } => {
                ensure!(
                    self.alive.is_alive(*worker),
                    "fault event '{}': worker {} is already down",
                    ev.describe(),
                    worker
                );
                self.alive.set_alive(*worker, false);
            }
            FaultEvent::Rejoin { worker, .. } => {
                ensure!(
                    !self.alive.is_alive(*worker),
                    "fault event '{}': worker {} is not down",
                    ev.describe(),
                    worker
                );
                self.alive.set_alive(*worker, true);
            }
            FaultEvent::Partition { groups, .. } => {
                self.alive.set_partition(groups);
            }
            FaultEvent::Heal { .. } => {
                ensure!(
                    self.alive.partitioned,
                    "fault event '{}': the graph is not partitioned",
                    ev.describe()
                );
                self.alive.clear_partition();
            }
        }
        Ok(())
    }

    /// Apply every fault due at the start of 1-based `round`: the explicit
    /// events in spec order, then the injected service-plane events
    /// ([`FaultState::inject`]), then one random draw per worker when the
    /// random process is configured. Errors on inconsistent schedules
    /// (crashing a dead worker, rejoining a live one, healing an
    /// unpartitioned graph) and on schedules that leave the quorum side
    /// empty.
    pub fn begin_round(&mut self, round: usize) -> Result<RoundFaults> {
        let m = self.alive.len();
        let prev_stepping: Vec<bool> = self.alive.stepping.clone();
        let prev_count = self.alive.stepping_count;
        let mut applied = Vec::new();

        while self.cursor < self.events.len() && self.events[self.cursor].round() == round {
            let ev = self.events[self.cursor].clone();
            self.cursor += 1;
            self.apply_event(&ev)?;
            applied.push(ev);
        }
        // Service-plane events injected for this round run after the
        // explicit schedule; future injections stay queued, and a stale one
        // is a caller bug, not a silently dropped event.
        let mut future = Vec::new();
        for ev in std::mem::take(&mut self.injected) {
            ensure!(
                ev.round() >= round,
                "injected fault event '{}' is due at round {}, but round {round} already started",
                ev.describe(),
                ev.round()
            );
            if ev.round() == round {
                self.apply_event(&ev)?;
                applied.push(ev);
            } else {
                future.push(ev);
            }
        }
        self.injected = future;
        self.alive.refresh();

        // Random process: exactly one draw per worker per round from the
        // worker's own stream (state-independent consumption), crash with
        // `rate` when alive, rejoin with `rejoin_rate` when down. A draw
        // that would empty the quorum side is skipped, never fatal.
        if self.rate > 0.0 || self.rejoin_rate > 0.0 {
            for w in 0..m {
                let u = self.streams[w].next_f64();
                if self.alive.is_alive(w) {
                    if self.rate > 0.0 && u < self.rate {
                        self.alive.set_alive(w, false);
                        self.alive.refresh();
                        if self.alive.member_count() == 0 {
                            self.alive.set_alive(w, true); // would kill the quorum
                            self.alive.refresh();
                        } else {
                            applied.push(FaultEvent::Crash { round, worker: w });
                        }
                    }
                } else if self.rejoin_rate > 0.0 && u < self.rejoin_rate {
                    self.alive.set_alive(w, true);
                    self.alive.refresh();
                    applied.push(FaultEvent::Rejoin { round, worker: w });
                }
            }
        }

        ensure!(
            self.alive.member_count() > 0,
            "fault schedule leaves no live worker in the primary partition at round {round}"
        );

        let joined: Vec<usize> =
            (0..m).filter(|&w| !prev_stepping[w] && self.alive.steps(w)).collect();
        let src = (0..m)
            .find(|&w| prev_stepping[w] && self.alive.steps(w))
            .or_else(|| (0..m).find(|&w| prev_stepping[w]))
            .expect("a non-empty cluster always has a previous stepping worker");
        Ok(RoundFaults {
            applied,
            joined,
            src,
            changed: self.alive.stepping_count != prev_count,
        })
    }
}

/// Validate a fault plan against population mode (DESIGN.md §14): every
/// worker id — `crash@R:W` / `rejoin@R:W` targets and partition set
/// members alike — must name a member of the registered population, and a
/// partition must not list an id twice. Unlike the dense
/// [`FaultState::validate`], a population partition need *not* cover every
/// id: unlisted ids share one implicit trailing component (the usual shape
/// at N = 10^5 — you name the split-off ranges, the rest of the world
/// stays connected).
pub fn validate_population_plan(plan: &FaultPlan, population: u64) -> Result<()> {
    for ev in &plan.events {
        match ev {
            FaultEvent::Crash { worker, .. } | FaultEvent::Rejoin { worker, .. } => {
                ensure!(
                    (*worker as u64) < population,
                    "fault event '{}' names worker {} outside the population (N = {})",
                    ev.describe(),
                    worker,
                    population
                );
            }
            FaultEvent::Partition { groups, .. } => {
                let mut seen = std::collections::HashSet::new();
                for g in groups {
                    for &w in g {
                        ensure!(
                            (w as u64) < population,
                            "fault event '{}' names worker {w} outside the population (N = {})",
                            ev.describe(),
                            population
                        );
                        ensure!(
                            seen.insert(w),
                            "fault event '{}' lists worker {w} twice",
                            ev.describe()
                        );
                    }
                }
            }
            FaultEvent::Heal { .. } => {}
        }
    }
    Ok(())
}

/// Population-mode fault replay (DESIGN.md §14): the same 1-based
/// round-boundary event semantics as [`FaultState`], applied to an
/// *eligibility pool* over stable population ids instead of the dense
/// per-slot [`AliveSet`]. A crashed id stays out of every cohort the
/// sampler draws until it rejoins (explicit event, random draw, or net
/// reconnect); a partition assigns listed id sets to components the
/// engine projects onto the cohort's slots each round. State is
/// O(downed + touched + partition spec), never O(N). Built only from
/// plans that passed [`validate_population_plan`].
#[derive(Debug)]
pub struct PopulationFaults {
    /// events sorted stably by round (spec order breaks ties, matching
    /// [`FaultState`])
    events: Vec<FaultEvent>,
    cursor: usize,
    /// events synthesized at run time (the net backend maps a dead worker
    /// connection to a `Crash` on the *population id* bound to the slot)
    injected: Vec<FaultEvent>,
    /// currently-downed population ids (sorted; deterministic iteration)
    down: std::collections::BTreeSet<u64>,
    n_pop: u64,
    rate: f64,
    rejoin_rate: f64,
    seed: u64,
    /// lazily-built per-id random-process streams: id -> (stream, rounds
    /// drawn so far). Only sampled and downed ids ever appear here, so the
    /// random process costs O(touched) per run, not O(N).
    draws: std::collections::HashMap<u64, (Rng, usize)>,
    /// active partition: per listed group (spec order), sorted disjoint
    /// inclusive id intervals; unlisted ids share the implicit trailing
    /// component `groups.len()`
    partition: Option<Vec<Vec<(u64, u64)>>>,
    engaged: bool,
}

impl PopulationFaults {
    /// Replay machine for `plan` plus the seeded random process
    /// (`rate`/`rejoin_rate`, streams `"fault/{id}"`) over a population of
    /// `n_pop` ids.
    pub fn new(
        plan: &FaultPlan,
        n_pop: u64,
        rate: f64,
        rejoin_rate: f64,
        seed: u64,
    ) -> Result<Self> {
        validate_population_plan(plan, n_pop)?;
        ensure!((0.0..1.0).contains(&rate), "fault_rate must be in [0, 1), got {rate}");
        ensure!(
            (0.0..1.0).contains(&rejoin_rate),
            "rejoin_rate must be in [0, 1), got {rejoin_rate}"
        );
        let mut events = plan.events.clone();
        events.sort_by_key(FaultEvent::round);
        let engaged = !events.is_empty() || rate > 0.0;
        Ok(Self {
            events,
            cursor: 0,
            injected: Vec::new(),
            down: std::collections::BTreeSet::new(),
            n_pop,
            rate,
            rejoin_rate,
            seed,
            draws: std::collections::HashMap::new(),
            partition: None,
            engaged,
        })
    }

    /// Queue a service-plane event for an upcoming round, keyed on the
    /// population id — the net backend's dead-connection mapping under
    /// sampling ([`FaultState::inject`] is the dense twin). Injection
    /// engages the fault machinery if it wasn't already.
    pub fn inject(&mut self, ev: FaultEvent) -> Result<()> {
        match &ev {
            FaultEvent::Crash { worker, .. } | FaultEvent::Rejoin { worker, .. } => {
                ensure!(
                    (*worker as u64) < self.n_pop,
                    "injected fault event '{}' names worker {} outside the population (N = {})",
                    ev.describe(),
                    worker,
                    self.n_pop
                );
            }
            other => bail!(
                "population mode injects crash/rejoin events only; got '{}'",
                other.describe()
            ),
        }
        self.injected.push(ev);
        self.engaged = true;
        Ok(())
    }

    /// Apply every event due at the start of 1-based `round` — the explicit
    /// schedule first, then injected service-plane events — returning them
    /// in applied order. Inconsistent schedules (crash a downed id, rejoin
    /// an up id, heal a whole graph) are hard errors, mirroring
    /// [`FaultState`].
    pub fn begin_round(&mut self, round: usize) -> Result<Vec<FaultEvent>> {
        let mut applied = Vec::new();
        while self.cursor < self.events.len() && self.events[self.cursor].round() <= round {
            let ev = self.events[self.cursor].clone();
            self.cursor += 1;
            self.apply_event(&ev)?;
            applied.push(ev);
        }
        let mut future = Vec::new();
        for ev in std::mem::take(&mut self.injected) {
            ensure!(
                ev.round() >= round,
                "injected fault event '{}' is due at round {}, but round {round} already started",
                ev.describe(),
                ev.round()
            );
            if ev.round() == round {
                self.apply_event(&ev)?;
                applied.push(ev);
            } else {
                future.push(ev);
            }
        }
        self.injected = future;
        Ok(applied)
    }

    fn apply_event(&mut self, ev: &FaultEvent) -> Result<()> {
        match ev {
            FaultEvent::Crash { worker, .. } => ensure!(
                self.down.insert(*worker as u64),
                "fault event '{}' crashes a worker that is already down",
                ev.describe()
            ),
            FaultEvent::Rejoin { worker, .. } => ensure!(
                self.down.remove(&(*worker as u64)),
                "fault event '{}' rejoins a worker that is not down",
                ev.describe()
            ),
            FaultEvent::Partition { groups, .. } => {
                // Compress each listed group to sorted disjoint inclusive
                // intervals — component lookups stay cheap even when a
                // range names 10^5 ids.
                let compressed: Vec<Vec<(u64, u64)>> = groups
                    .iter()
                    .map(|g| {
                        let mut ids: Vec<u64> = g.iter().map(|&w| w as u64).collect();
                        ids.sort_unstable();
                        let mut ivs: Vec<(u64, u64)> = Vec::new();
                        for id in ids {
                            match ivs.last_mut() {
                                Some(last) if id <= last.1 => {}
                                Some(last) if id == last.1 + 1 => last.1 = id,
                                _ => ivs.push((id, id)),
                            }
                        }
                        ivs
                    })
                    .collect();
                self.partition = Some(compressed);
            }
            FaultEvent::Heal { .. } => ensure!(
                self.partition.take().is_some(),
                "fault event '{}': the graph is not partitioned",
                ev.describe()
            ),
        }
        Ok(())
    }

    /// The seeded random fault process over the current cohort: one draw
    /// per id in (bound ∪ down), ids ascending, from the id's own
    /// `"fault/{id}"` stream — the exact per-id mirror of the dense
    /// [`FaultState`] process, so `N == k` replays bit-identically.
    /// `bound` maps engine slot → bound population id and `alive` is the
    /// slot alive-set the engine is about to train with: a crash draw for
    /// a bound id downs its slot (with the dense quorum-preserving undo),
    /// while a rejoin draw for an *unbound* id only returns it to the
    /// eligibility pool (the engine warm-starts it when next sampled).
    /// Returns the synthesized events in application order.
    pub fn random_round(
        &mut self,
        round: usize,
        bound: &[Option<u64>],
        alive: &mut AliveSet,
    ) -> Vec<FaultEvent> {
        let mut applied = Vec::new();
        if self.rate <= 0.0 && self.rejoin_rate <= 0.0 {
            return applied;
        }
        let mut slot_of = std::collections::HashMap::new();
        let mut ids = std::collections::BTreeSet::new();
        for (slot, id) in bound.iter().enumerate() {
            if let Some(id) = *id {
                ids.insert(id);
                slot_of.insert(id, slot);
            }
        }
        ids.extend(self.down.iter().copied());
        for id in ids {
            let u = self.draw(id, round);
            if !self.down.contains(&id) {
                if self.rate > 0.0 && u < self.rate {
                    let slot = slot_of[&id];
                    alive.set_alive(slot, false);
                    alive.refresh();
                    if alive.member_count() == 0 {
                        alive.set_alive(slot, true); // would kill the quorum
                        alive.refresh();
                    } else {
                        self.down.insert(id);
                        applied.push(FaultEvent::Crash { round, worker: id as usize });
                    }
                }
            } else if self.rejoin_rate > 0.0 && u < self.rejoin_rate {
                self.down.remove(&id);
                if let Some(&slot) = slot_of.get(&id) {
                    alive.set_alive(slot, true);
                    alive.refresh();
                }
                applied.push(FaultEvent::Rejoin { round, worker: id as usize });
            }
        }
        applied
    }

    /// One `fault_rate`/`rejoin_rate` draw for `id` at 1-based `round`,
    /// first catching the id's private stream up to one draw per elapsed
    /// round — an id outside every cohort consumes nothing until touched.
    fn draw(&mut self, id: u64, round: usize) -> f64 {
        let seed = self.seed;
        let (rng, drawn) = self
            .draws
            .entry(id)
            .or_insert_with(|| (Rng::stream(seed, &format!("fault/{id}")), 0));
        debug_assert!(*drawn < round, "double draw for id {id} at round {round}");
        while *drawn + 1 < round {
            rng.next_f64();
            *drawn += 1;
        }
        *drawn = round;
        rng.next_f64()
    }

    /// The partition component of `id` under the active split: listed
    /// groups take components `0..g` in spec order (so primary-selection
    /// ties break toward the first-listed set, exactly as in the dense
    /// [`AliveSet`]); unlisted ids share the implicit trailing component
    /// `g`. `None` when the graph is whole.
    pub fn component_of(&self, id: u64) -> Option<usize> {
        let groups = self.partition.as_ref()?;
        for (gi, ivs) in groups.iter().enumerate() {
            if ivs.iter().any(|&(a, b)| a <= id && id <= b) {
                return Some(gi);
            }
        }
        Some(groups.len())
    }

    /// Number of partition components (listed groups + the implicit rest
    /// component), or `None` when the graph is whole.
    pub fn partition_components(&self) -> Option<usize> {
        self.partition.as_ref().map(|g| g.len() + 1)
    }

    /// Whether a partition is active.
    pub fn partitioned(&self) -> bool {
        self.partition.is_some()
    }

    /// The currently-downed ids (ascending) — the sampler's rejection set.
    pub fn down(&self) -> &std::collections::BTreeSet<u64> {
        &self.down
    }

    /// Population ids currently eligible for sampling.
    pub fn eligible(&self) -> u64 {
        self.n_pop - self.down.len() as u64
    }

    /// Whether any fault source is configured (an empty plan with zero
    /// rates is bit-inert). Mirrors [`FaultState::engaged`]: a bare
    /// `rejoin_rate` with nothing down never fires, so it alone does not
    /// engage.
    pub fn engaged(&self) -> bool {
        self.engaged
    }

    /// Whether the seeded random process is configured.
    pub fn random_engaged(&self) -> bool {
        self.rate > 0.0 || self.rejoin_rate > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_parse_and_round_trip() {
        let specs = [
            "crash@3:2",
            "rejoin@6:2",
            "partition@4:0,1|2,3",
            "heal@8",
        ];
        for spec in specs {
            let ev = FaultEvent::parse(spec).unwrap();
            assert_eq!(ev.describe(), spec);
            assert_eq!(FaultEvent::parse(&ev.describe()).unwrap(), ev);
        }
        let plan = FaultPlan::parse("crash@3:2; rejoin@6:2").unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(FaultPlan::parse(&plan.describe()).unwrap(), plan);
        assert!(FaultPlan::parse("none").unwrap().is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn malformed_events_are_rejected() {
        for bad in [
            "crash@3",          // no worker
            "crash@x:1",        // bad round
            "crash@0:1",        // rounds are 1-based
            "rejoin@2:abc",     // bad worker
            "partition@2:0,1",  // single set
            "partition@2:|",    // empty sets
            "reboot@2:1",       // unknown kind
            "crash",            // no @
        ] {
            assert!(FaultEvent::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn alive_set_tracks_members_and_stepping() {
        let mut s = AliveSet::full(6);
        assert!(s.is_full());
        assert_eq!(s.members(), &[0, 1, 2, 3, 4, 5]);
        assert_eq!(s.stepping_count(), 6);

        s.set_alive(2, false);
        s.refresh();
        assert!(!s.is_full());
        assert_eq!(s.members(), &[0, 1, 3, 4, 5]);
        assert!(!s.steps(2));
        assert!(!s.edge_allowed(1, 2));

        // Partition {0,1,2} | {3,4,5} with 2 dead: primary = {3,4,5}.
        s.set_partition(&[vec![0, 1, 2], vec![3, 4, 5]]);
        s.refresh();
        assert_eq!(s.members(), &[3, 4, 5]);
        assert!(s.steps(4));
        assert!(!s.steps(0), "exact strategies park the minority");
        assert!(s.edge_allowed(0, 1), "minority edges stay usable (gossip)");
        assert!(!s.edge_allowed(1, 3), "cross-partition edges are cut");

        // The decentralized rule keeps every alive worker stepping.
        s.set_decentralized(true);
        assert!(s.steps(0));
        assert!(!s.steps(2), "dead stays dead");
        assert_eq!(s.stepping_count(), 5);
        assert_eq!(s.members(), &[3, 4, 5], "members are unchanged");

        s.set_decentralized(false);
        s.clear_partition();
        s.refresh();
        assert_eq!(s.members(), &[0, 1, 3, 4, 5]);
    }

    #[test]
    fn alive_set_primary_tie_breaks_to_first_listed_set() {
        let mut s = AliveSet::full(4);
        s.set_partition(&[vec![2, 3], vec![0, 1]]);
        s.refresh();
        assert_eq!(s.members(), &[2, 3], "equal sizes: the first-listed set wins");
    }

    #[test]
    fn replay_applies_events_and_validates_consistency() {
        let plan = FaultPlan::parse("crash@2:1;rejoin@4:1").unwrap();
        let mut fs = FaultState::new(&plan, 0.0, 0.0, 7, 4);
        assert!(fs.engaged());
        fs.validate().unwrap();

        let r1 = fs.begin_round(1).unwrap();
        assert!(r1.applied.is_empty() && r1.joined.is_empty() && !r1.changed);

        let r2 = fs.begin_round(2).unwrap();
        assert_eq!(r2.applied.len(), 1);
        assert!(r2.changed);
        assert!(!fs.alive.is_alive(1));
        assert_eq!(fs.alive.members(), &[0, 2, 3]);

        let r3 = fs.begin_round(3).unwrap();
        assert!(!r3.changed);

        let r4 = fs.begin_round(4).unwrap();
        assert_eq!(r4.joined, vec![1]);
        assert_eq!(r4.src, 0);
        assert!(fs.alive.is_full());
    }

    #[test]
    fn replay_rejects_inconsistent_schedules() {
        // Crashing a dead worker.
        let plan = FaultPlan::parse("crash@1:0;crash@2:0").unwrap();
        let mut fs = FaultState::new(&plan, 0.0, 0.0, 1, 3);
        fs.begin_round(1).unwrap();
        assert!(fs.begin_round(2).is_err());

        // Rejoining a live worker.
        let plan = FaultPlan::parse("rejoin@1:0").unwrap();
        assert!(FaultState::new(&plan, 0.0, 0.0, 1, 3).begin_round(1).is_err());

        // Healing an unpartitioned graph.
        let plan = FaultPlan::parse("heal@1").unwrap();
        assert!(FaultState::new(&plan, 0.0, 0.0, 1, 3).begin_round(1).is_err());

        // Killing every worker.
        let plan = FaultPlan::parse("crash@1:0;crash@1:1").unwrap();
        assert!(FaultState::new(&plan, 0.0, 0.0, 1, 2).begin_round(1).is_err());

        // Out-of-range worker / non-covering partition fail validation.
        let plan = FaultPlan::parse("crash@1:9").unwrap();
        assert!(FaultState::new(&plan, 0.0, 0.0, 1, 4).validate().is_err());
        let plan = FaultPlan::parse("partition@1:0,1|2").unwrap();
        assert!(FaultState::new(&plan, 0.0, 0.0, 1, 4).validate().is_err());
        let plan = FaultPlan::parse("partition@1:0,1|1,2,3").unwrap();
        assert!(FaultState::new(&plan, 0.0, 0.0, 1, 4).validate().is_err());
    }

    #[test]
    fn random_process_is_deterministic_and_never_empties_the_quorum() {
        let run = |seed: u64| {
            let plan = FaultPlan::default();
            let mut fs = FaultState::new(&plan, 0.4, 0.3, seed, 5);
            let mut trace = Vec::new();
            for round in 1..=40 {
                let rf = fs.begin_round(round).unwrap();
                assert!(fs.alive.member_count() >= 1, "quorum must survive");
                for ev in rf.applied {
                    trace.push(ev.describe());
                }
            }
            trace
        };
        let a = run(11);
        assert_eq!(a, run(11), "same seed must replay identically");
        assert_ne!(a, run(12), "the process must actually depend on the seed");
        assert!(!a.is_empty(), "a 40% rate over 40 rounds must fire");
    }

    #[test]
    fn injected_events_replay_like_the_explicit_schedule() {
        // The net backend's dropped-connection mapping: injecting crash@3:1
        // must produce the same per-round transitions as --fault crash@3:1.
        let mut explicit = FaultState::new(&FaultPlan::parse("crash@3:1").unwrap(), 0.0, 0.0, 5, 4);
        let mut injected = FaultState::new(&FaultPlan::default(), 0.0, 0.0, 5, 4);
        assert!(!injected.engaged(), "no schedule, no engagement — until injection");
        injected.inject(FaultEvent::Crash { round: 3, worker: 1 }).unwrap();
        assert!(injected.engaged());
        for round in 1..=4 {
            let a = explicit.begin_round(round).unwrap();
            let b = injected.begin_round(round).unwrap();
            assert_eq!(
                a.applied.iter().map(FaultEvent::describe).collect::<Vec<_>>(),
                b.applied.iter().map(FaultEvent::describe).collect::<Vec<_>>(),
                "round {round} traces diverge"
            );
            assert_eq!(explicit.alive.members(), injected.alive.members());
        }
        // Crash + same-round rejoin (a reconnect claiming the slot within
        // one boundary) applies in order and nets out to a live worker.
        let mut fs = FaultState::new(&FaultPlan::default(), 0.0, 0.0, 5, 4);
        fs.inject(FaultEvent::Crash { round: 2, worker: 0 }).unwrap();
        fs.inject(FaultEvent::Rejoin { round: 2, worker: 0 }).unwrap();
        fs.begin_round(1).unwrap();
        let r2 = fs.begin_round(2).unwrap();
        assert_eq!(r2.applied.len(), 2);
        assert!(fs.alive.is_alive(0));
        // Stale injections and out-of-range workers are loud errors.
        let mut fs = FaultState::new(&FaultPlan::default(), 0.0, 0.0, 5, 4);
        assert!(fs.inject(FaultEvent::Crash { round: 1, worker: 9 }).is_err());
        fs.inject(FaultEvent::Crash { round: 1, worker: 2 }).unwrap();
        assert!(fs.begin_round(2).is_err(), "round-1 injection applied at round 2");
    }

    #[test]
    fn partition_ranges_parse_and_compress() {
        let ev = FaultEvent::parse("partition@2:0-3|4,5,6,9").unwrap();
        match &ev {
            FaultEvent::Partition { groups, .. } => {
                assert_eq!(groups[0], vec![0, 1, 2, 3]);
                assert_eq!(groups[1], vec![4, 5, 6, 9]);
            }
            other => panic!("parsed {other:?}, not a partition"),
        }
        // Ascending runs of >= 3 compress; pairs and singletons stay
        // literal, so legacy trace strings are untouched.
        assert_eq!(ev.describe(), "partition@2:0-3|4-6,9");
        assert_eq!(FaultEvent::parse(&ev.describe()).unwrap(), ev);
        let ev = FaultEvent::parse("partition@4:0,1|2,3").unwrap();
        assert_eq!(ev.describe(), "partition@4:0,1|2,3");
        for bad in ["partition@2:5-3|0", "partition@2:0-x|1"] {
            assert!(FaultEvent::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn population_plan_accepts_ranged_partitions() {
        let plan = FaultPlan::parse("partition@2:0-9|100-199;heal@4").unwrap();
        validate_population_plan(&plan, 1_000).unwrap();
        // Out-of-population / duplicate ids are loud errors.
        assert!(validate_population_plan(&plan, 150).is_err());
        let dup = FaultPlan::parse("partition@2:0-5|3-9").unwrap();
        assert!(validate_population_plan(&dup, 100).is_err());
    }

    #[test]
    fn population_partition_components_project_over_ids() {
        let plan = FaultPlan::parse("partition@2:10-19|30,31;heal@5").unwrap();
        let mut pf = PopulationFaults::new(&plan, 1_000, 0.0, 0.0, 7).unwrap();
        assert!(pf.engaged());
        assert!(pf.begin_round(1).unwrap().is_empty());
        assert!(!pf.partitioned());
        assert_eq!(pf.begin_round(2).unwrap().len(), 1);
        assert!(pf.partitioned());
        assert_eq!(pf.partition_components(), Some(3));
        assert_eq!(pf.component_of(12), Some(0));
        assert_eq!(pf.component_of(30), Some(1));
        assert_eq!(pf.component_of(999), Some(2), "unlisted ids share the rest component");
        pf.begin_round(3).unwrap();
        pf.begin_round(4).unwrap();
        assert_eq!(pf.begin_round(5).unwrap().len(), 1, "heal applies");
        assert!(!pf.partitioned());
        assert_eq!(pf.component_of(12), None);
        // Healing a whole graph is a loud error.
        let plan = FaultPlan::parse("heal@1").unwrap();
        let mut pf = PopulationFaults::new(&plan, 10, 0.0, 0.0, 7).unwrap();
        assert!(pf.begin_round(1).is_err());
    }

    #[test]
    fn population_random_process_mirrors_the_dense_machine_at_n_equals_k() {
        // Same seed, same rates: the per-id streams must reproduce the
        // dense per-worker process event-for-event when every id is bound
        // to its own slot (the N == k embedding).
        let m = 5;
        let mut dense = FaultState::new(&FaultPlan::default(), 0.4, 0.3, 11, m);
        let mut pop =
            PopulationFaults::new(&FaultPlan::default(), m as u64, 0.4, 0.3, 11).unwrap();
        assert!(pop.engaged() && pop.random_engaged());
        let bound: Vec<Option<u64>> = (0..m as u64).map(Some).collect();
        let mut alive = AliveSet::full(m);
        for round in 1..=40 {
            let d = dense.begin_round(round).unwrap();
            assert!(pop.begin_round(round).unwrap().is_empty());
            let p = pop.random_round(round, &bound, &mut alive);
            assert_eq!(
                d.applied.iter().map(FaultEvent::describe).collect::<Vec<_>>(),
                p.iter().map(FaultEvent::describe).collect::<Vec<_>>(),
                "round {round} diverged"
            );
            assert_eq!(dense.alive.members(), alive.members(), "round {round} alive drift");
        }
        let dense_down: Vec<u64> =
            (0..m).filter(|&w| !dense.alive.is_alive(w)).map(|w| w as u64).collect();
        let pop_down: Vec<u64> = pop.down().iter().copied().collect();
        assert_eq!(dense_down, pop_down, "down set must mirror the dense dead set");
    }

    #[test]
    fn population_draws_are_lazy_and_position_aligned() {
        // Stream position depends only on (id, round): an id untouched for
        // nine rounds catches up to the same draw a round-by-round id sees.
        let mk = || PopulationFaults::new(&FaultPlan::default(), 100, 0.2, 0.1, 9).unwrap();
        let mut eager = mk();
        let mut lazy = mk();
        let seq: Vec<f64> = (1..=10).map(|r| eager.draw(5, r)).collect();
        assert_eq!(lazy.draw(5, 10), seq[9], "lazy catch-up must land on the same draw");
        // Different ids draw from genuinely different streams.
        let mut other = mk();
        assert_ne!(other.draw(6, 10), seq[9]);
    }

    #[test]
    fn population_injection_validates_and_engages() {
        let mut pf = PopulationFaults::new(&FaultPlan::default(), 50, 0.0, 0.0, 3).unwrap();
        assert!(!pf.engaged());
        assert!(pf.inject(FaultEvent::Crash { round: 2, worker: 99 }).is_err());
        assert!(pf
            .inject(FaultEvent::Partition { round: 2, groups: vec![vec![0], vec![1]] })
            .is_err());
        pf.inject(FaultEvent::Crash { round: 2, worker: 7 }).unwrap();
        assert!(pf.engaged());
        assert!(pf.begin_round(1).unwrap().is_empty());
        let r2 = pf.begin_round(2).unwrap();
        assert_eq!(r2.len(), 1);
        assert!(pf.down().contains(&7));
        // Stale injections are loud errors, as in the dense machine.
        let mut pf = PopulationFaults::new(&FaultPlan::default(), 50, 0.0, 0.0, 3).unwrap();
        pf.inject(FaultEvent::Crash { round: 1, worker: 7 }).unwrap();
        assert!(pf.begin_round(2).is_err());
    }

    #[test]
    fn partition_then_heal_reports_returning_workers_as_joined() {
        let plan = FaultPlan::parse("partition@2:0,1|2,3,4;heal@4").unwrap();
        let mut fs = FaultState::new(&plan, 0.0, 0.0, 3, 5);
        fs.begin_round(1).unwrap();
        let r2 = fs.begin_round(2).unwrap();
        assert!(r2.joined.is_empty());
        assert_eq!(fs.alive.members(), &[2, 3, 4]);
        fs.begin_round(3).unwrap();
        let r4 = fs.begin_round(4).unwrap();
        assert_eq!(r4.joined, vec![0, 1], "minority workers rejoin on heal");
        assert_eq!(r4.src, 2, "re-seed source is a quorum-side worker");
    }
}
