//! Flat f32 vector math — the Layer-3 hot path outside PJRT.
//!
//! These loops sit inside the collective (averaging), the mixing updates,
//! and PowerSGD. They are written as simple slice iterators, which LLVM
//! auto-vectorizes on x86 (verified via the perf pass, EXPERIMENTS.md §Perf);
//! no allocation happens inside any of them when an `_into` variant is used.

/// out[i] = mean over vs of vs[j][i]. `out` is unconditionally
/// overwritten (its prior contents are irrelevant); all vectors must share
/// a length.
pub fn mean_into(vs: &[&[f32]], out: &mut [f32]) {
    let m = vs.len();
    assert!(m > 0, "mean of zero vectors");
    for v in vs {
        assert_eq!(v.len(), out.len(), "length mismatch in mean");
    }
    let inv = 1.0f32 / m as f32;
    out.copy_from_slice(vs[0]);
    for v in &vs[1..] {
        for (o, &x) in out.iter_mut().zip(v.iter()) {
            *o += x;
        }
    }
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Convenience allocating mean.
pub fn mean(vs: &[&[f32]]) -> Vec<f32> {
    let mut out = vec![0.0; vs[0].len()];
    mean_into(vs, &mut out);
    out
}

/// Thread-parallel [`mean_into`] with a deterministic chunked reduction:
/// the output index range is split into `threads` contiguous chunks, each
/// reduced on its own scoped OS thread. Every output element is computed
/// by the *same* per-element operation sequence as the serial version
/// (accumulate `vs[0][i], vs[1][i], ...` then scale), so the result is
/// **bit-identical** to [`mean_into`] — property-tested below. `out` is
/// unconditionally overwritten.
///
/// This standalone form spawns fresh scoped threads per call; the training
/// hot path uses the same chunked reduction served by the persistent
/// worker pool instead (`executor::Executor::mean_into`, DESIGN.md §10),
/// which is bit-identical to both.
pub fn mean_into_parallel(vs: &[&[f32]], out: &mut [f32], threads: usize) {
    let m = vs.len();
    assert!(m > 0, "mean of zero vectors");
    for v in vs {
        assert_eq!(v.len(), out.len(), "length mismatch in mean");
    }
    let n = out.len();
    let t = threads.max(1).min(n.max(1));
    if t <= 1 {
        return mean_into(vs, out);
    }
    let chunk = n.div_ceil(t);
    let inv = 1.0f32 / m as f32;
    std::thread::scope(|s| {
        for (ci, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let lo = ci * chunk;
            s.spawn(move || {
                let len = out_chunk.len();
                out_chunk.copy_from_slice(&vs[0][lo..lo + len]);
                for v in &vs[1..] {
                    for (o, &x) in out_chunk.iter_mut().zip(&v[lo..lo + len]) {
                        *o += x;
                    }
                }
                for o in out_chunk.iter_mut() {
                    *o *= inv;
                }
            });
        }
    });
}

/// y += a * x
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = a * x + b * y  (general mixing step)
pub fn axpby(a: f32, x: &[f32], b: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = a * xi + b * *yi;
    }
}

/// Eq. (4) in place: x <- x - alpha * (x - z).
pub fn pullback_inplace(x: &mut [f32], z: &[f32], alpha: f32) {
    assert_eq!(x.len(), z.len());
    for (xi, &zi) in x.iter_mut().zip(z) {
        *xi -= alpha * (*xi - zi);
    }
}

/// Delay-corrected Eq. (4) for compressed overlap rounds (LOSCAR-style):
/// x <- x - alpha * (x_stale - z), where `x_stale` is the snapshot of `x`
/// taken when the (now absorbed) collective was launched. Contracting by
/// the *measured* gap instead of the current one keeps the pullback
/// consistent with the staleness a sparse/quantized mask introduces,
/// without discarding the local steps taken since launch.
pub fn pullback_stale_inplace(x: &mut [f32], x_stale: &[f32], z: &[f32], alpha: f32) {
    assert_eq!(x.len(), x_stale.len());
    assert_eq!(x.len(), z.len());
    for i in 0..x.len() {
        x[i] -= alpha * (x_stale[i] - z[i]);
    }
}

/// Eqs. (10)-(11) in place: v <- beta*v + (avg - z); z <- z + v.
pub fn anchor_update_inplace(z: &mut [f32], v: &mut [f32], avg: &[f32], beta: f32) {
    assert_eq!(z.len(), v.len());
    assert_eq!(z.len(), avg.len());
    for i in 0..z.len() {
        v[i] = beta * v[i] + (avg[i] - z[i]);
        z[i] += v[i];
    }
}

/// Euclidean norm, accumulated in f64.
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Dot product, accumulated in f64.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// max_i |a[i] - b[i]|
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, property};

    #[test]
    fn mean_of_identical_is_identity() {
        let v = vec![1.0f32, -2.0, 3.5];
        let out = mean(&[&v, &v, &v]);
        assert_close(&out, &v, 1e-6, 1e-7);
    }

    #[test]
    fn mean_matches_manual() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        assert_close(&mean(&[&a, &b]), &[2.0, 4.0], 1e-6, 0.0);
    }

    #[test]
    fn pullback_endpoints() {
        let z = vec![5.0f32; 4];
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        let x0 = x.clone();
        pullback_inplace(&mut x, &z, 0.0);
        assert_close(&x, &x0, 0.0, 0.0);
        pullback_inplace(&mut x, &z, 1.0);
        assert_close(&x, &z, 1e-6, 1e-7);
    }

    #[test]
    fn anchor_beta_zero_assigns_avg() {
        let mut z = vec![1.0f32, 2.0];
        let mut v = vec![9.0f32, 9.0];
        let avg = vec![3.0f32, 5.0];
        anchor_update_inplace(&mut z, &mut v, &avg, 0.0);
        assert_close(&z, &avg, 1e-6, 0.0);
        assert_close(&v, &[2.0, 3.0], 1e-6, 0.0);
    }

    #[test]
    fn property_mean_bounds_and_linearity() {
        property("mean within min/max and linear", 200, |g| {
            let n = g.usize_in(1, 400);
            let m = g.usize_in(1, 12);
            let vs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 5.0)).collect();
            let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
            let out = mean(&refs);
            for i in 0..n {
                let lo = vs.iter().map(|v| v[i]).fold(f32::INFINITY, f32::min);
                let hi = vs.iter().map(|v| v[i]).fold(f32::NEG_INFINITY, f32::max);
                assert!(out[i] >= lo - 1e-4 && out[i] <= hi + 1e-4);
                let manual: f32 = vs.iter().map(|v| v[i]).sum::<f32>() / m as f32;
                assert!((out[i] - manual).abs() <= 1e-4);
            }
        });
    }

    #[test]
    fn property_parallel_mean_is_bit_identical_to_serial() {
        // The threads execution backend leans on exactly this guarantee:
        // chunking an elementwise reduction across threads must not change
        // a single bit relative to the serial loop.
        property("parallel mean == serial mean (bits)", 150, |g| {
            let n = g.usize_in(1, 2000);
            let m = g.usize_in(1, 12);
            let threads = g.usize_in(1, 9); // including > n and 1
            let vs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 50.0)).collect();
            let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
            let mut serial = vec![0.0f32; n];
            mean_into(&refs, &mut serial);
            // Pre-poison the parallel output: "unconditionally overwritten"
            // must hold for any prior contents.
            let mut parallel = vec![f32::NAN; n];
            mean_into_parallel(&refs, &mut parallel, threads);
            for i in 0..n {
                assert_eq!(
                    serial[i].to_bits(),
                    parallel[i].to_bits(),
                    "bit drift at {i} with {threads} threads"
                );
            }
        });
    }

    #[test]
    fn property_pullback_is_convex_combination() {
        property("pullback convexity", 200, |g| {
            let n = g.usize_in(1, 300);
            let mut x = g.vec_f32(n, 3.0);
            let z = g.vec_f32(n, 3.0);
            let alpha = g.f32_in(0.0, 1.0);
            let x0 = x.clone();
            pullback_inplace(&mut x, &z, alpha);
            for i in 0..n {
                let lo = x0[i].min(z[i]) - 1e-5;
                let hi = x0[i].max(z[i]) + 1e-5;
                assert!(x[i] >= lo && x[i] <= hi, "not convex at {i}");
            }
        });
    }

    #[test]
    fn stale_pullback_reduces_to_plain_when_snapshot_is_current() {
        // With x_stale == x the delay-corrected form is exactly Eq. (4).
        let z = vec![5.0f32; 4];
        let mut a = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut b = a.clone();
        let snap = a.clone();
        pullback_inplace(&mut a, &z, 0.3);
        pullback_stale_inplace(&mut b, &snap, &z, 0.3);
        assert_eq!(a, b);
        // With a stale snapshot, local progress since launch survives:
        // x - x' is invariant under the correction.
        let mut x = vec![2.0f32; 3];
        pullback_stale_inplace(&mut x, &[1.0; 3], &[0.0; 3], 0.5);
        assert_close(&x, &[1.5; 3], 1e-6, 0.0);
    }

    #[test]
    fn property_axpby_matches_scalar_loop() {
        property("axpby", 100, |g| {
            let n = g.usize_in(1, 256);
            let x = g.vec_f32(n, 2.0);
            let mut y = g.vec_f32(n, 2.0);
            let y0 = y.clone();
            let (a, b) = (g.f32_in(-2.0, 2.0), g.f32_in(-2.0, 2.0));
            axpby(a, &x, b, &mut y);
            for i in 0..n {
                assert!((y[i] - (a * x[i] + b * y0[i])).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn norms_and_dot() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 5.0]), 0.5);
    }
}
