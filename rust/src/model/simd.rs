//! The opt-in SIMD kernel tier (DESIGN.md §15): hand-unrolled f32 lanes
//! for the flat-vector hot kernels, **bit-identical** to their scalar
//! references by construction.
//!
//! Every golden digest in this repo depends on deterministic f32
//! arithmetic with a fixed accumulation order, so a faster kernel tier is
//! only admissible if it reproduces the scalar tier bit for bit. These
//! kernels do, by design rather than by luck:
//!
//! * **Elementwise kernels** (fused Nesterov/Adam step, pullback, anchor,
//!   axpy, scale) compute one output element from the same-index inputs
//!   only. Processing [`LANES`] elements per block never reassociates
//!   anything — each lane evaluates the *identical* scalar expression.
//! * **Reductions** ([`mean_into_simd`]) keep the per-element operation
//!   sequence of the serial loop (accumulate `vs[0][i], vs[1][i], …`,
//!   then scale): the lane blocks run across the output index, not across
//!   the reduction axis.
//!
//! What the tier buys is *guaranteed* fixed-width vectorization: the
//! lane blocks are fixed-size arrays (`[f32; LANES]`, obtained via
//! infallible slice→array conversions), so the compiler sees a constant
//! trip count with no aliasing or bounds checks in the inner loop —
//! multi-slice update kernels like the fused optimizer steps otherwise
//! vectorize at LLVM's discretion, not by contract.
//!
//! Selection is per run: [`KernelTier`] comes from the config
//! (`kernels = scalar | simd`, default scalar), flows into the model
//! runtime and the executor, and every kernel here carries a `to_bits`
//! identity test against its scalar reference (including remainder-lane
//! shapes, n ≢ 0 mod [`LANES`]). The register-blocked matmul tier lives
//! in [`crate::model::matmul`] under the same discipline.

use crate::model::vecmath;

/// Lane width of the unrolled blocks. Eight f32s = one 256-bit vector
/// register (AVX) or two 128-bit ones (SSE/NEON) — wide enough to saturate
/// either, small enough that remainder loops stay trivial.
pub const LANES: usize = 8;

/// Which kernel implementation a run uses for the flat-vector hot path.
///
/// `Scalar` is the reference tier — the exact loops the golden digests
/// were recorded with. `Simd` is the hand-unrolled tier in this module;
/// it is bit-identical (property-locked), so digests do not move either
/// way, but only `Scalar` is the *definition* of the numbers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelTier {
    /// Plain scalar loops (`vecmath`, `runtime::native`) — the bit-identity
    /// reference and the default.
    #[default]
    Scalar,
    /// Hand-unrolled fixed-width lanes (this module) plus the
    /// register-blocked matmul ([`crate::model::matmul`]).
    Simd,
}

impl KernelTier {
    /// Parse a config/CLI value (`scalar` | `simd`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "scalar" => Ok(Self::Scalar),
            "simd" => Ok(Self::Simd),
            other => anyhow::bail!("unknown kernel tier '{other}' (expected scalar|simd)"),
        }
    }

    /// Canonical config value, inverse of [`KernelTier::parse`].
    pub fn name(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Simd => "simd",
        }
    }
}

/// Exact-width view of a lane block. Infallible for `LANES`-length slices;
/// the conversion is how the inner loops get a constant trip count with no
/// bounds checks.
#[inline]
fn lanes(x: &[f32]) -> &[f32; LANES] {
    x.try_into().expect("exact lane-width slice")
}

/// Mutable [`lanes`].
#[inline]
fn lanes_mut(x: &mut [f32]) -> &mut [f32; LANES] {
    x.try_into().expect("exact lane-width slice")
}

/// `y += a * x`, unrolled — bit-identical to [`vecmath::axpy`].
pub fn axpy_simd(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    let n = y.len();
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let yb = lanes_mut(&mut y[i..i + LANES]);
        let xb = lanes(&x[i..i + LANES]);
        for l in 0..LANES {
            yb[l] += a * xb[l];
        }
        i += LANES;
    }
    for j in main..n {
        y[j] += a * x[j];
    }
}

/// `y += x`, unrolled (the accumulation step of the pooled mean).
fn add_assign_simd(y: &mut [f32], x: &[f32]) {
    assert_eq!(x.len(), y.len());
    let n = y.len();
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let yb = lanes_mut(&mut y[i..i + LANES]);
        let xb = lanes(&x[i..i + LANES]);
        for l in 0..LANES {
            yb[l] += xb[l];
        }
        i += LANES;
    }
    for j in main..n {
        y[j] += x[j];
    }
}

/// `y *= a`, unrolled (the scale step of the pooled mean).
fn scale_simd(y: &mut [f32], a: f32) {
    let n = y.len();
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        for v in lanes_mut(&mut y[i..i + LANES]) {
            *v *= a;
        }
        i += LANES;
    }
    for v in &mut y[main..] {
        *v *= a;
    }
}

/// One contiguous chunk (`lo..lo + out.len()` of the output index range) of
/// the deterministic mean, on either tier — the shared kernel behind
/// [`mean_into`], [`mean_into_simd`], and the worker pool's chunked
/// reduction (`executor::pool`). Per output element the operation sequence
/// is exactly the serial [`vecmath::mean_into`] (copy `vs[0]`, add
/// `vs[1..]` in order, scale by `1/m`), so any chunking of the index range
/// composes into a bit-identical whole.
pub fn mean_chunk_into(tier: KernelTier, vs: &[&[f32]], lo: usize, out: &mut [f32]) {
    let len = out.len();
    let inv = 1.0f32 / vs.len() as f32;
    out.copy_from_slice(&vs[0][lo..lo + len]);
    match tier {
        KernelTier::Scalar => {
            for v in &vs[1..] {
                for (o, &x) in out.iter_mut().zip(&v[lo..lo + len]) {
                    *o += x;
                }
            }
            for o in out.iter_mut() {
                *o *= inv;
            }
        }
        KernelTier::Simd => {
            for v in &vs[1..] {
                add_assign_simd(out, &v[lo..lo + len]);
            }
            scale_simd(out, inv);
        }
    }
}

/// Unrolled [`vecmath::mean_into`] — same contract, bit-identical output.
pub fn mean_into_simd(vs: &[&[f32]], out: &mut [f32]) {
    assert!(!vs.is_empty(), "mean of zero vectors");
    for v in vs {
        assert_eq!(v.len(), out.len(), "length mismatch in mean");
    }
    mean_chunk_into(KernelTier::Simd, vs, 0, out);
}

/// Tier-dispatched [`vecmath::mean_into`].
pub fn mean_into(tier: KernelTier, vs: &[&[f32]], out: &mut [f32]) {
    match tier {
        KernelTier::Scalar => vecmath::mean_into(vs, out),
        KernelTier::Simd => mean_into_simd(vs, out),
    }
}

/// Unrolled Eq. (4) pullback `x -= alpha * (x - z)` — bit-identical to
/// [`vecmath::pullback_inplace`].
pub fn pullback_inplace_simd(x: &mut [f32], z: &[f32], alpha: f32) {
    assert_eq!(x.len(), z.len());
    let n = x.len();
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let xb = lanes_mut(&mut x[i..i + LANES]);
        let zb = lanes(&z[i..i + LANES]);
        for l in 0..LANES {
            xb[l] -= alpha * (xb[l] - zb[l]);
        }
        i += LANES;
    }
    for j in main..n {
        x[j] -= alpha * (x[j] - z[j]);
    }
}

/// Tier-dispatched [`vecmath::pullback_inplace`].
pub fn pullback_inplace(tier: KernelTier, x: &mut [f32], z: &[f32], alpha: f32) {
    match tier {
        KernelTier::Scalar => vecmath::pullback_inplace(x, z, alpha),
        KernelTier::Simd => pullback_inplace_simd(x, z, alpha),
    }
}

/// Unrolled Eqs. (10)–(11) anchor update `v = beta*v + (avg - z); z += v`
/// — bit-identical to [`vecmath::anchor_update_inplace`].
pub fn anchor_update_inplace_simd(z: &mut [f32], v: &mut [f32], avg: &[f32], beta: f32) {
    assert_eq!(z.len(), v.len());
    assert_eq!(z.len(), avg.len());
    let n = z.len();
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let zb = lanes_mut(&mut z[i..i + LANES]);
        let vb = lanes_mut(&mut v[i..i + LANES]);
        let ab = lanes(&avg[i..i + LANES]);
        for l in 0..LANES {
            vb[l] = beta * vb[l] + (ab[l] - zb[l]);
            zb[l] += vb[l];
        }
        i += LANES;
    }
    for j in main..n {
        v[j] = beta * v[j] + (avg[j] - z[j]);
        z[j] += v[j];
    }
}

/// Tier-dispatched [`vecmath::anchor_update_inplace`].
pub fn anchor_update_inplace(tier: KernelTier, z: &mut [f32], v: &mut [f32], avg: &[f32], beta: f32) {
    match tier {
        KernelTier::Scalar => vecmath::anchor_update_inplace(z, v, avg, beta),
        KernelTier::Simd => anchor_update_inplace_simd(z, v, avg, beta),
    }
}

/// Unrolled fused Nesterov step — bit-identical to the scalar
/// `runtime::native::NativeModel::sgd_update_inplace` (identical
/// per-element expression order: `g = grad + wd*x; v' = mu*v + g;
/// x -= lr*(g + mu*v')`).
pub fn sgd_update_inplace_simd(
    params: &mut [f32],
    mom: &mut [f32],
    grad: &[f32],
    lr: f32,
    mu: f32,
    wd: f32,
) {
    let n = params.len();
    assert_eq!(mom.len(), n);
    assert_eq!(grad.len(), n);
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let pb = lanes_mut(&mut params[i..i + LANES]);
        let vb = lanes_mut(&mut mom[i..i + LANES]);
        let gb = lanes(&grad[i..i + LANES]);
        for l in 0..LANES {
            let g = gb[l] + wd * pb[l];
            let vn = mu * vb[l] + g;
            pb[l] -= lr * (g + mu * vn);
            vb[l] = vn;
        }
        i += LANES;
    }
    for j in main..n {
        let g = grad[j] + wd * params[j];
        let vn = mu * mom[j] + g;
        params[j] -= lr * (g + mu * vn);
        mom[j] = vn;
    }
}

/// Unrolled fused Adam step — bit-identical to the scalar
/// `runtime::native::NativeModel::adam_update_inplace` (same constants
/// b1=0.9, b2=0.999, eps=1e-8, same per-element expression order).
pub fn adam_update_inplace_simd(
    params: &mut [f32],
    m1: &mut [f32],
    m2: &mut [f32],
    grad: &[f32],
    lr: f32,
    t: f32,
) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let n = params.len();
    assert_eq!(m1.len(), n);
    assert_eq!(m2.len(), n);
    assert_eq!(grad.len(), n);
    let bc1 = 1.0 - B1.powf(t);
    let bc2 = 1.0 - B2.powf(t);
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let pb = lanes_mut(&mut params[i..i + LANES]);
        let mb = lanes_mut(&mut m1[i..i + LANES]);
        let vb = lanes_mut(&mut m2[i..i + LANES]);
        let gb = lanes(&grad[i..i + LANES]);
        for l in 0..LANES {
            let g = gb[l];
            let mn = B1 * mb[l] + (1.0 - B1) * g;
            let vn = B2 * vb[l] + (1.0 - B2) * g * g;
            let mhat = mn / bc1;
            let vhat = vn / bc2;
            pb[l] -= lr * mhat / (vhat.sqrt() + EPS);
            mb[l] = mn;
            vb[l] = vn;
        }
        i += LANES;
    }
    for j in main..n {
        let g = grad[j];
        let mn = B1 * m1[j] + (1.0 - B1) * g;
        let vn = B2 * m2[j] + (1.0 - B2) * g * g;
        let mhat = mn / bc1;
        let vhat = vn / bc2;
        params[j] -= lr * mhat / (vhat.sqrt() + EPS);
        m1[j] = mn;
        m2[j] = vn;
    }
}

/// Tier-dispatched fused Nesterov step. The `Scalar` arm is the canonical
/// in-place loop (the golden-digest definition; the allocating
/// `NativeModel::sgd_update` keeps an independent copy as the reference
/// the identity tests compare against).
pub fn sgd_update_inplace(
    tier: KernelTier,
    params: &mut [f32],
    mom: &mut [f32],
    grad: &[f32],
    lr: f32,
    mu: f32,
    wd: f32,
) {
    match tier {
        KernelTier::Scalar => {
            for i in 0..params.len() {
                let g = grad[i] + wd * params[i];
                let vn = mu * mom[i] + g;
                params[i] -= lr * (g + mu * vn);
                mom[i] = vn;
            }
        }
        KernelTier::Simd => sgd_update_inplace_simd(params, mom, grad, lr, mu, wd),
    }
}

/// Tier-dispatched fused Adam step (constants b1=0.9, b2=0.999, eps=1e-8,
/// matching `NativeModel::adam_update`).
pub fn adam_update_inplace(
    tier: KernelTier,
    params: &mut [f32],
    m1: &mut [f32],
    m2: &mut [f32],
    grad: &[f32],
    lr: f32,
    t: f32,
) {
    match tier {
        KernelTier::Scalar => {
            const B1: f32 = 0.9;
            const B2: f32 = 0.999;
            const EPS: f32 = 1e-8;
            let bc1 = 1.0 - B1.powf(t);
            let bc2 = 1.0 - B2.powf(t);
            for i in 0..params.len() {
                let g = grad[i];
                let mn = B1 * m1[i] + (1.0 - B1) * g;
                let vn = B2 * m2[i] + (1.0 - B2) * g * g;
                let mhat = mn / bc1;
                let vhat = vn / bc2;
                params[i] -= lr * mhat / (vhat.sqrt() + EPS);
                m1[i] = mn;
                m2[i] = vn;
            }
        }
        KernelTier::Simd => adam_update_inplace_simd(params, m1, m2, grad, lr, t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeModel;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit drift at {i}");
        }
    }

    #[test]
    fn tier_parse_round_trips() {
        for tier in [KernelTier::Scalar, KernelTier::Simd] {
            assert_eq!(KernelTier::parse(tier.name()).unwrap(), tier);
        }
        assert_eq!(KernelTier::default(), KernelTier::Scalar);
        assert!(KernelTier::parse("avx512").is_err());
    }

    #[test]
    fn property_axpy_simd_is_bit_identical() {
        property("axpy simd == scalar (bits)", 120, |g| {
            let n = g.usize_in(1, 600);
            let a = g.f32_in(-3.0, 3.0);
            let x = g.vec_f32(n, 5.0);
            let mut ys = g.vec_f32(n, 5.0);
            let mut yv = ys.clone();
            vecmath::axpy(a, &x, &mut ys);
            axpy_simd(a, &x, &mut yv);
            assert_bits_eq(&ys, &yv, "axpy");
        });
    }

    #[test]
    fn property_mean_simd_is_bit_identical() {
        property("mean simd == scalar (bits)", 100, |g| {
            let n = g.usize_in(1, 2000);
            let m = g.usize_in(1, 12);
            let vs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 50.0)).collect();
            let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
            let mut serial = vec![0.0f32; n];
            vecmath::mean_into(&refs, &mut serial);
            // Pre-poisoned: "unconditionally overwritten" must hold here too.
            let mut unrolled = vec![f32::NAN; n];
            mean_into_simd(&refs, &mut unrolled);
            assert_bits_eq(&serial, &unrolled, "mean");
        });
    }

    #[test]
    fn property_mean_chunks_compose_bit_identically_on_both_tiers() {
        // The pool splits the output range into arbitrary contiguous
        // chunks; on either tier the reassembled whole must equal the
        // serial mean bit for bit.
        property("chunked mean == serial mean (bits)", 80, |g| {
            let n = g.usize_in(1, 1500);
            let m = g.usize_in(1, 8);
            let chunk = g.usize_in(1, n.max(1));
            let vs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 20.0)).collect();
            let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
            let mut serial = vec![0.0f32; n];
            vecmath::mean_into(&refs, &mut serial);
            for tier in [KernelTier::Scalar, KernelTier::Simd] {
                let mut out = vec![f32::NAN; n];
                for (ci, out_chunk) in out.chunks_mut(chunk).enumerate() {
                    mean_chunk_into(tier, &refs, ci * chunk, out_chunk);
                }
                assert_bits_eq(&serial, &out, tier.name());
            }
        });
    }

    #[test]
    fn property_pullback_and_anchor_simd_are_bit_identical() {
        property("pullback/anchor simd == scalar (bits)", 120, |g| {
            let n = g.usize_in(1, 700);
            let alpha = g.f32_in(0.0, 1.0);
            let beta = g.f32_in(0.0, 1.0);
            let z = g.vec_f32(n, 3.0);
            let mut xs = g.vec_f32(n, 3.0);
            let mut xv = xs.clone();
            vecmath::pullback_inplace(&mut xs, &z, alpha);
            pullback_inplace_simd(&mut xv, &z, alpha);
            assert_bits_eq(&xs, &xv, "pullback");

            let avg = g.vec_f32(n, 3.0);
            let (mut zs, mut vs) = (g.vec_f32(n, 3.0), g.vec_f32(n, 1.0));
            let (mut zv, mut vv) = (zs.clone(), vs.clone());
            vecmath::anchor_update_inplace(&mut zs, &mut vs, &avg, beta);
            anchor_update_inplace_simd(&mut zv, &mut vv, &avg, beta);
            assert_bits_eq(&zs, &zv, "anchor z");
            assert_bits_eq(&vs, &vv, "anchor v");
        });
    }

    #[test]
    fn property_fused_optimizer_simd_is_bit_identical() {
        // Scalar reference: the *allocating* NativeModel kernels. Their
        // loops live in `runtime::native`, independent of the dispatchers
        // in this module — so both arms of the dispatch (the canonical
        // scalar loop and the unrolled tier) are compared against the
        // original golden-digest definition, not against each other.
        let model = NativeModel::new(4, 3);
        property("sgd/adam simd == scalar (bits)", 100, |g| {
            let n = g.usize_in(1, 500);
            let grad = g.vec_f32(n, 0.5);
            let (lr, mu, wd) = (g.f32_in(0.0, 0.5), g.f32_in(0.0, 0.99), g.f32_in(0.0, 1e-2));

            let (ps, vs) = (g.vec_f32(n, 1.0), g.vec_f32(n, 0.3));
            let (p_ref, v_ref) = model.sgd_update(&ps, &vs, &grad, lr, mu, wd);
            for tier in [KernelTier::Scalar, KernelTier::Simd] {
                let (mut p, mut v) = (ps.clone(), vs.clone());
                sgd_update_inplace(tier, &mut p, &mut v, &grad, lr, mu, wd);
                assert_bits_eq(&p_ref, &p, "sgd params");
                assert_bits_eq(&v_ref, &v, "sgd momentum");
            }

            let t = g.usize_in(1, 50) as f32;
            let (ps, ms) = (g.vec_f32(n, 1.0), g.vec_f32(n, 0.3));
            let m2s: Vec<f32> = g.vec_f32(n, 0.2).iter().map(|v| v.abs()).collect();
            let (p_ref, m_ref, v_ref) = model.adam_update(&ps, &ms, &m2s, &grad, lr, t);
            for tier in [KernelTier::Scalar, KernelTier::Simd] {
                let (mut p, mut m, mut v) = (ps.clone(), ms.clone(), m2s.clone());
                adam_update_inplace(tier, &mut p, &mut m, &mut v, &grad, lr, t);
                assert_bits_eq(&p_ref, &p, "adam params");
                assert_bits_eq(&m_ref, &m, "adam m1");
                assert_bits_eq(&v_ref, &v, "adam m2");
            }
        });
    }

    #[test]
    fn paper_and_mlp_shapes_cover_remainder_lanes() {
        // The two deployed flat-vector lengths: the paper's linear model
        // (3072·10 + 10) and the default MLP (3072·128 + 128 + 128·10 + 10).
        // Both leave a remainder of 2 mod LANES, so this exercises the
        // lane blocks *and* the scalar tails at full production size.
        for n in [3072 * 10 + 10, 3072 * 128 + 128 + 128 * 10 + 10] {
            assert_eq!(n % LANES, 2, "shape no longer covers the tail");
            let model = NativeModel::new(4, 3);
            let mut rng = Rng::seed_from(97);
            let mut grad = vec![0.0f32; n];
            rng.fill_normal(&mut grad, 0.1);
            let mut ps = vec![0.0f32; n];
            rng.fill_normal(&mut ps, 0.5);
            let mut vs = vec![0.0f32; n];
            rng.fill_normal(&mut vs, 0.2);
            let (p_ref, v_ref) = model.sgd_update(&ps, &vs, &grad, 0.05, 0.9, 1e-4);
            let (mut pv, mut vv) = (ps.clone(), vs.clone());
            sgd_update_inplace_simd(&mut pv, &mut vv, &grad, 0.05, 0.9, 1e-4);
            assert_bits_eq(&p_ref, &pv, "sgd params @ paper shape");
            assert_bits_eq(&v_ref, &vv, "sgd momentum @ paper shape");

            let refs = [ps.as_slice(), grad.as_slice(), vs.as_slice()];
            let mut serial = vec![0.0f32; n];
            vecmath::mean_into(&refs, &mut serial);
            let mut unrolled = vec![f32::NAN; n];
            mean_into_simd(&refs, &mut unrolled);
            assert_bits_eq(&serial, &unrolled, "mean @ paper shape");
        }
    }
}
