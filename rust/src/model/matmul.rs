//! Register-blocked dense matmul for the MLP runtime — a Rust port of the
//! Pallas blocking scheme in `python/compile/kernels/matmul.py`, under the
//! same bit-identity discipline as [`crate::model::simd`] (DESIGN.md §15).
//!
//! The Pallas kernel tiles the output into `(bm, bn)` blocks, keeps one
//! output block resident across the whole contraction (grid iterates k
//! innermost), and fuses the bias + ReLU epilogue into the final k-step so
//! the activation never takes an extra memory pass. The port keeps exactly
//! that structure at register scale: a `[[f32; BN]; BM]` accumulator block
//! lives in registers, the contraction loop runs **serially ascending in
//! k** for every output element, and the epilogue is applied to the
//! resident block right before the single store.
//!
//! Serial-k is the load-bearing choice: the scalar reference loops
//! (ikj order, `compress::linalg` style) also accumulate every output
//! element in ascending-k order from `+0.0`, so the blocked kernels
//! reassociate **nothing** — they reorder only *which element* is advanced
//! next, never the sum within an element — and are therefore bit-identical
//! to the scalar tier (property-locked below, including shapes that don't
//! tile). The speedup comes from `BM × BN` independent FMA chains per
//! k-step (instruction-level parallelism the single-element scalar loop
//! can't expose) and from each loaded `x`/`w` value being reused across a
//! whole block row instead of once.
//!
//! Edge blocks (m ≢ 0 mod [`BM`], n ≢ 0 mod [`BN`]) fall back to
//! per-element serial dots — the same accumulation order, so identity
//! holds there too. The Pallas version zero-pads instead; explicit edges
//! avoid the copy.

/// Output-block rows held in registers (the Pallas `bm`, at register scale:
/// 4 independent accumulator rows per k-step).
pub const BM: usize = 4;

/// Output-block columns held in registers (the Pallas `bn`: two 256-bit
/// vectors' worth of f32 lanes per row).
pub const BN: usize = 16;

/// The fused epilogue both tiers share: add bias happened already; apply
/// the optional ReLU. Written as a strict `> 0.0` select so the backward
/// mask (`out > 0.0`) is exactly the set of pass-through units, `-0.0`
/// normalizes to `+0.0`, and a NaN (diverged run) gates to `0.0` the same
/// way on every tier.
#[inline]
fn epilogue(v: f32, relu: bool) -> f32 {
    if relu {
        if v > 0.0 { v } else { 0.0 }
    } else {
        v
    }
}

/// `out (m×n) = act(X (m×k) @ W (k×n) + bias)`, row-major, scalar
/// reference tier. `n = bias.len()`, `m` inferred from `x`; `out` is
/// unconditionally overwritten. ikj loop order: every output element
/// accumulates in ascending-k order from `+0.0`, then takes the bias +
/// optional-ReLU epilogue — the order the blocked tier reproduces exactly.
pub fn matmul_bias_act_into(x: &[f32], k: usize, w: &[f32], bias: &[f32], relu: bool, out: &mut [f32]) {
    let n = bias.len();
    assert!(k > 0 && n > 0, "degenerate matmul shape");
    assert_eq!(x.len() % k, 0, "x not a whole number of rows");
    let m = x.len() / k;
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &xv) in xrow.iter().enumerate() {
            let wrow = &w[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += xv * wrow[j];
            }
        }
        for j in 0..n {
            orow[j] = epilogue(orow[j] + bias[j], relu);
        }
    }
}

/// One edge-cell of [`matmul_bias_act_blocked_into`]: a serial ascending-k
/// dot from `+0.0` plus the fused epilogue — the scalar reference's exact
/// per-element sequence.
#[inline]
fn bias_act_cell(x: &[f32], k: usize, w: &[f32], bias: &[f32], relu: bool, i: usize, j: usize) -> f32 {
    let n = bias.len();
    let xrow = &x[i * k..(i + 1) * k];
    let mut acc = 0.0f32;
    for (kk, &xv) in xrow.iter().enumerate() {
        acc += xv * w[kk * n + j];
    }
    epilogue(acc + bias[j], relu)
}

/// [`matmul_bias_act_into`] on the blocked tier — bit-identical output.
///
/// The Pallas scheme at register scale: for each `BM × BN` output block,
/// the accumulator block stays resident while k runs serially ascending
/// (`o_ref` across the k-innermost grid), every `w` row segment feeds all
/// `BM` accumulator rows, and the bias/ReLU epilogue hits the resident
/// block once, fused before the store. Remainder rows/columns take
/// [`bias_act_cell`].
pub fn matmul_bias_act_blocked_into(
    x: &[f32],
    k: usize,
    w: &[f32],
    bias: &[f32],
    relu: bool,
    out: &mut [f32],
) {
    let n = bias.len();
    assert!(k > 0 && n > 0, "degenerate matmul shape");
    assert_eq!(x.len() % k, 0, "x not a whole number of rows");
    let m = x.len() / k;
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);
    let mb = m - m % BM;
    let nb = n - n % BN;
    for i0 in (0..mb).step_by(BM) {
        for j0 in (0..nb).step_by(BN) {
            let mut acc = [[0.0f32; BN]; BM];
            for kk in 0..k {
                let wrow: &[f32; BN] =
                    (&w[kk * n + j0..kk * n + j0 + BN]).try_into().expect("exact block row");
                for (ii, accrow) in acc.iter_mut().enumerate() {
                    let xv = x[(i0 + ii) * k + kk];
                    for jj in 0..BN {
                        accrow[jj] += xv * wrow[jj];
                    }
                }
            }
            let brow = &bias[j0..j0 + BN];
            for (ii, accrow) in acc.iter().enumerate() {
                let at = (i0 + ii) * n + j0;
                let orow = &mut out[at..at + BN];
                for jj in 0..BN {
                    orow[jj] = epilogue(accrow[jj] + brow[jj], relu);
                }
            }
        }
        for i in i0..i0 + BM {
            for j in nb..n {
                out[i * n + j] = bias_act_cell(x, k, w, bias, relu, i, j);
            }
        }
    }
    for i in mb..m {
        for j in 0..n {
            out[i * n + j] = bias_act_cell(x, k, w, bias, relu, i, j);
        }
    }
}

/// `C (k×n) = Aᵀ @ B` where `A` is `(m×k)`, `B` is `(m×n)`, row-major,
/// scalar reference tier (`compress::linalg::matmul_tn_into` order: every
/// output element accumulates over the shared `m` axis in ascending-i
/// order from `+0.0`). `C` is unconditionally overwritten. This is the
/// weight-gradient kernel (`dW = Xᵀ @ dY`).
pub fn matmul_tn_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = arow[kk];
            let crow = &mut c[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// One edge-cell of [`matmul_tn_blocked_into`]: serial ascending-i dot
/// from `+0.0` — the scalar reference's exact per-element sequence.
#[inline]
fn tn_cell(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, kk: usize, j: usize) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..m {
        acc += a[i * k + kk] * b[i * n + j];
    }
    acc
}

/// [`matmul_tn_into`] on the blocked tier — bit-identical output. Same
/// Pallas structure with the contraction running over the shared `m` axis:
/// a resident `BM × BN` block of `C` (BM columns of `A`ᵀ × BN columns of
/// `B`) accumulates serially ascending in `i`.
pub fn matmul_tn_blocked_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    let kb = k - k % BM;
    let nb = n - n % BN;
    for k0 in (0..kb).step_by(BM) {
        for j0 in (0..nb).step_by(BN) {
            let mut acc = [[0.0f32; BN]; BM];
            for i in 0..m {
                let brow: &[f32; BN] =
                    (&b[i * n + j0..i * n + j0 + BN]).try_into().expect("exact block row");
                let arow = &a[i * k + k0..i * k + k0 + BM];
                for (ii, accrow) in acc.iter_mut().enumerate() {
                    let av = arow[ii];
                    for jj in 0..BN {
                        accrow[jj] += av * brow[jj];
                    }
                }
            }
            for (ii, accrow) in acc.iter().enumerate() {
                let at = (k0 + ii) * n + j0;
                c[at..at + BN].copy_from_slice(accrow);
            }
        }
        for kk in k0..k0 + BM {
            for j in nb..n {
                c[kk * n + j] = tn_cell(a, m, k, b, n, kk, j);
            }
        }
    }
    for kk in kb..k {
        for j in 0..n {
            c[kk * n + j] = tn_cell(a, m, k, b, n, kk, j);
        }
    }
}

/// `C (m×n) = A (m×k) @ Bᵀ` where `B` is `(n×k)`, row-major — the
/// activation-gradient kernel (`dX = dY @ Wᵀ`). Both rows being contracted
/// are contiguous, so this stays one serial ascending-k dot per output
/// element on **both** tiers (identity is trivial); in the MLP it only
/// ever runs at the small hidden×classes shape, ~`classes/px` of the
/// layer-1 work, so a blocked variant would buy nothing measurable.
pub fn matmul_nt_into(a: &[f32], k: usize, b: &[f32], n: usize, c: &mut [f32]) {
    assert!(k > 0, "degenerate matmul shape");
    assert_eq!(a.len() % k, 0, "a not a whole number of rows");
    let m = a.len() / k;
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            crow[j] = acc;
        }
    }
}

/// `out[j] = Σ_i d[i·n + j]` (column sums, ascending-i from `+0.0`) — the
/// bias-gradient kernel, shared verbatim by both tiers. `n = out.len()`;
/// `out` is unconditionally overwritten.
pub fn colsum_into(d: &[f32], out: &mut [f32]) {
    let n = out.len();
    assert!(n > 0, "degenerate colsum shape");
    assert_eq!(d.len() % n, 0, "d not a whole number of rows");
    out.fill(0.0);
    for row in d.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, property};

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit drift at {i}");
        }
    }

    #[test]
    fn bias_act_matches_manual() {
        // X = [[1,2],[3,4]], W = [[5,6],[7,8]], b = [0.5, -100].
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let w = vec![5.0f32, 6.0, 7.0, 8.0];
        let bias = vec![0.5f32, -100.0];
        let mut out = vec![f32::NAN; 4];
        matmul_bias_act_into(&x, 2, &w, &bias, false, &mut out);
        assert_close(&out, &[19.5, -78.0, 43.5, -50.0], 1e-6, 0.0);
        // ReLU gates the negative column; -0.0 normalizes to +0.0.
        matmul_bias_act_into(&x, 2, &w, &bias, true, &mut out);
        assert_close(&out, &[19.5, 0.0, 43.5, 0.0], 1e-6, 0.0);
        assert_eq!(epilogue(-0.0, true).to_bits(), 0.0f32.to_bits());
        assert_eq!(epilogue(f32::NAN, true).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn property_blocked_bias_act_is_bit_identical() {
        // Shapes straddle the block sizes on purpose: m in [1, 3·BM],
        // n in [1, 3·BN], so full blocks, partial rows, partial columns,
        // and sub-block shapes all occur.
        property("blocked bias_act == scalar (bits)", 80, |g| {
            let m = g.usize_in(1, 3 * BM);
            let k = g.usize_in(1, 48);
            let n = g.usize_in(1, 3 * BN);
            let relu = g.bool();
            let x = g.vec_f32(m * k, 2.0);
            let w = g.vec_f32(k * n, 2.0);
            let bias = g.vec_f32(n, 1.0);
            let mut scalar = vec![f32::NAN; m * n];
            let mut blocked = vec![f32::NAN; m * n];
            matmul_bias_act_into(&x, k, &w, &bias, relu, &mut scalar);
            matmul_bias_act_blocked_into(&x, k, &w, &bias, relu, &mut blocked);
            assert_bits_eq(&scalar, &blocked, "bias_act");
        });
    }

    #[test]
    fn property_blocked_tn_is_bit_identical() {
        property("blocked tn == scalar (bits)", 80, |g| {
            let m = g.usize_in(1, 20);
            let k = g.usize_in(1, 3 * BM);
            let n = g.usize_in(1, 3 * BN);
            let a = g.vec_f32(m * k, 2.0);
            let b = g.vec_f32(m * n, 2.0);
            let mut scalar = vec![f32::NAN; k * n];
            let mut blocked = vec![f32::NAN; k * n];
            matmul_tn_into(&a, m, k, &b, n, &mut scalar);
            matmul_tn_blocked_into(&a, m, k, &b, n, &mut blocked);
            assert_bits_eq(&scalar, &blocked, "tn");
        });
    }

    #[test]
    fn blocked_is_bit_identical_at_the_mlp_layer_shapes() {
        // The deployed shapes: layer 1 (batch 32 × px 3072 → hidden 128)
        // and layer 2 (batch 32 × hidden 128 → classes 10, a sub-block
        // column count). Run once at full size, both directions.
        let mut rng = crate::util::rng::Rng::seed_from(1234);
        let mut fill = |len: usize, std: f32| {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, std);
            v
        };
        for (m, k, n) in [(32usize, 3072usize, 128usize), (32, 128, 10)] {
            let x = fill(m * k, 1.0);
            let w = fill(k * n, 0.05);
            let bias = fill(n, 0.1);
            let mut scalar = vec![f32::NAN; m * n];
            let mut blocked = vec![f32::NAN; m * n];
            matmul_bias_act_into(&x, k, &w, &bias, true, &mut scalar);
            matmul_bias_act_blocked_into(&x, k, &w, &bias, true, &mut blocked);
            assert_bits_eq(&scalar, &blocked, "fwd @ mlp shape");

            let dy = fill(m * n, 0.05);
            let mut gs = vec![f32::NAN; k * n];
            let mut gb = vec![f32::NAN; k * n];
            matmul_tn_into(&x, m, k, &dy, n, &mut gs);
            matmul_tn_blocked_into(&x, m, k, &dy, n, &mut gb);
            assert_bits_eq(&gs, &gb, "dW @ mlp shape");
        }
    }

    #[test]
    fn property_nt_matches_explicit_transpose() {
        // nt's per-element dot runs ascending-k from +0.0 — the same
        // sequence the nn reference produces — so transposing B and
        // multiplying normally must agree bit for bit.
        property("nt == nn(Bᵀ) (bits)", 60, |g| {
            let m = g.usize_in(1, 12);
            let k = g.usize_in(1, 12);
            let n = g.usize_in(1, 10);
            let a = g.vec_f32(m * k, 2.0);
            let b = g.vec_f32(n * k, 2.0);
            let mut bt = vec![0.0f32; k * n];
            for j in 0..n {
                for kk in 0..k {
                    bt[kk * n + j] = b[j * k + kk];
                }
            }
            let zero_bias = vec![0.0f32; n];
            let mut want = vec![f32::NAN; m * n];
            matmul_bias_act_into(&a, k, &bt, &zero_bias, false, &mut want);
            let mut got = vec![f32::NAN; m * n];
            matmul_nt_into(&a, k, &b, n, &mut got);
            assert_bits_eq(&want, &got, "nt");
        });
    }

    #[test]
    fn colsum_matches_manual() {
        let d = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows × 2 cols
        let mut out = vec![f32::NAN; 2];
        colsum_into(&d, &mut out);
        assert_close(&out, &[9.0, 12.0], 1e-6, 0.0);
        // Zero rows: overwritten to exact zero, not left dirty.
        let mut out = vec![f32::NAN; 3];
        colsum_into(&[], &mut out);
        assert_eq!(out, vec![0.0f32; 3]);
    }
}
