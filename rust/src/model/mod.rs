//! Flat parameter-vector substrate.
//!
//! Every algorithm in the paper is stated over flat vectors (the matrix-form
//! update rule, Eq. 8, stacks them as columns of X_k). This module owns the
//! vector math the Layer-3 coordinator performs outside the AOT artifacts:
//! averaging (the content of the all-reduce), axpy-style mixing, norms —
//! plus parameter initialization from the AOT manifest so Rust, not Python,
//! owns the experiment seed.
//!
//! Two kernel tiers implement that math (DESIGN.md §15): the scalar
//! reference loops ([`vecmath`], the golden-digest definition) and the
//! opt-in unrolled tier ([`simd`] lanes + the register-blocked [`matmul`]),
//! bit-identical by construction and selected per run via the `kernels`
//! config key.

pub mod matmul;
pub mod simd;
pub mod vecmath;

use crate::runtime::manifest::ModelManifest;
use crate::util::rng::Rng;

/// Initialize a flat parameter vector per the manifest's tensor table
/// (he_normal for weights, zeros for biases) with a dedicated PRNG stream.
pub fn init_params(manifest: &ModelManifest, seed: u64) -> Vec<f32> {
    let mut flat = vec![0.0f32; manifest.param_count];
    for t in &manifest.tensors {
        if t.init == "he_normal" {
            let mut rng = Rng::stream(seed, &format!("init/{}", t.name));
            rng.fill_normal(&mut flat[t.offset..t.offset + t.size], t.std);
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorManifest;

    fn toy_manifest() -> ModelManifest {
        ModelManifest {
            param_count: 10,
            tensors: vec![
                TensorManifest {
                    name: "w".into(),
                    offset: 0,
                    size: 6,
                    shape: vec![2, 3],
                    init: "he_normal".into(),
                    std: 1.0,
                    rows: 2,
                    cols: 3,
                    compress: true,
                },
                TensorManifest {
                    name: "b".into(),
                    offset: 6,
                    size: 4,
                    shape: vec![4],
                    init: "zeros".into(),
                    std: 0.0,
                    rows: 1,
                    cols: 4,
                    compress: false,
                },
            ],
            modules: Default::default(),
        }
    }

    #[test]
    fn init_weights_nonzero_biases_zero() {
        let m = toy_manifest();
        let p = init_params(&m, 1);
        assert!(p[..6].iter().any(|&x| x != 0.0));
        assert!(p[6..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let m = toy_manifest();
        assert_eq!(init_params(&m, 7), init_params(&m, 7));
        assert_ne!(init_params(&m, 7), init_params(&m, 8));
    }
}
