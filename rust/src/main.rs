//! `olsgd` — leader entrypoint for the Overlap-Local-SGD reproduction.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline mirror):
//!
//! ```text
//! olsgd info                              runtime + artifact inventory
//! olsgd train   [--config F] [--set k=v]* [--execution sim|threads|net]
//!               [--fault EVENT]* [--out DIR] [--quiet]
//! olsgd sweep   --algos a,b --taus 1,2,8 [--set k=v]* [--out DIR]
//! olsgd report  --dir DIR                 summarize result JSONs
//! olsgd coordinator [--listen H:P] [train flags]   serve a run to workers
//! olsgd worker  --connect H:P [--lanes N]          serve local phases
//! ```
//!
//! Every `--set` key is a dotted config key (see config/mod.rs), e.g.
//! `--set algo=overlap-m --set tau=2 --set data.noniid=true`.
//! `--execution threads` runs the real-thread backend (one OS thread per
//! worker + background communicator threads, DESIGN.md §9) — identical
//! results, real wall-clock overlap. `--execution net` runs the TCP
//! service plane (DESIGN.md §13): the coordinator spawns (or waits for)
//! worker *processes* that execute the local phases, with the same bits;
//! `olsgd coordinator` / `olsgd worker` are its standalone halves.

use std::path::Path;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use olsgd::config::{Algo, ExperimentConfig};
use olsgd::coordinator;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::{write_json, write_text};
use olsgd::runtime::{self, ModelRuntime};
use olsgd::util::json::Json;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    match cmd.as_str() {
        "info" => cmd_info(&args[1..]),
        "train" => cmd_train(&args[1..]),
        "sweep" => cmd_sweep(&args[1..]),
        "report" => cmd_report(&args[1..]),
        "coordinator" => cmd_coordinator(&args[1..]),
        "worker" => cmd_worker(&args[1..]),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: olsgd help)"),
    }
}

fn print_usage() {
    println!(
        "olsgd — Overlap-Local-SGD (Wang, Liang, Joshi 2020) reproduction\n\
         \n\
         USAGE:\n  olsgd info\n  olsgd train  [--config FILE] [--set key=value]... [--execution sim|threads|net]\n               \
         [--out DIR] [--quiet]\n  \
         olsgd sweep  --algos sync,local,overlap-m --taus 1,2,8,24 [--set key=value]... [--out DIR]\n  \
         olsgd report --dir DIR\n  \
         olsgd coordinator [--listen HOST:PORT] [train flags]   (net plane, external workers)\n  \
         olsgd worker --connect HOST:PORT [--lanes N] [--proc-index P] [--die-after R] [--timeout S]\n\
         \n\
         Algorithms: sync local overlap overlap-m overlap-ada overlap-gossip easgd eamsgd\n\
                     cocod powersgd\n\
         Topologies: --set topology=ring|hier|tree|gossip (gossip_degree, hier_groups)\n\
         Execution:  --execution sim|threads|net (threads = persistent pool: one parked\n\
                     OS thread per worker + a communicator thread; bit-identical\n\
                     results, real overlap, zero steady-state spawns/allocs.\n\
                     net = TCP service plane, DESIGN.md §13: worker processes run the\n\
                     local phases — self-hosting by default (net_procs spawned children),\n\
                     or serve external `olsgd worker`s via `olsgd coordinator`; dropped\n\
                     connections replay through the fault machinery as crash@round)\n\
         Faults:     --fault crash@round:worker | rejoin@round:worker\n\
                     | partition@round:set|set | heal@round   (repeatable; rounds are\n\
                     1-based; also --set fault_rate=p / rejoin_rate=p for the seeded\n\
                     random process; deterministic replay, survivors stay exact)\n\
         Compression: --compress none|powersgd|topk|qsgd (per-collective axis, composes\n\
                     with every algorithm, topology, and fault schedule; knobs:\n\
                     --set compress_k=N compress_rank=R compress_bits=B; error-feedback\n\
                     residuals are per-worker engine state, DESIGN.md §12)\n\
         Population: --set population=N sample_k=k (register N workers, each round\n\
                     deterministically samples k participants; per-worker state is\n\
                     materialized lazily and evicted LRU so resident memory is O(k),\n\
                     not O(N) — N up to 10^6, DESIGN.md §14; sample_seed reseeds the\n\
                     cohort streams, sample_reserve sizes the resident cache;\n\
                     --fault crash/rejoin compose at the population-id level)\n\
         Model:      --set model=linear|mlp (mlp = PX x hidden ReLU layer + readout,\n\
                     --set hidden=H width, the compute-bound model; DESIGN.md §15)\n\
         Kernels:    --set kernels=scalar|simd (simd = lane-unrolled loops + register-\n\
                     blocked matmul, bit-identical to the scalar reference by\n\
                     construction — the tier never moves a digest)\n\
         Config keys: algo model hidden kernels workers epochs seed eval_every execution\n\
                      lr tau tau_min\n\
                      tau_hetero ada_patience ada_threshold alpha beta mu wd rank\n\
                      compress compress_k compress_rank compress_bits\n\
                      population sample_k sample_seed sample_reserve\n\
                      train_n test_n noniid dominant_frac reshuffle net base_step_s\n\
                      topology gossip_degree hier_groups fault fault_rate rejoin_rate\n\
                      message_bytes straggler artifacts_dir out_dir\n\
                      net_listen net_procs net_spawn net_timeout_s net_worker_bin net_kill"
    );
}

/// Shared flag parsing for train/sweep/info.
struct CommonArgs {
    cfg: ExperimentConfig,
    out: String,
    quiet: bool,
    algos: Vec<Algo>,
    taus: Vec<usize>,
}

fn parse_common(args: &[String]) -> Result<CommonArgs> {
    let mut config_file: Option<String> = None;
    let mut overrides: Vec<(String, String)> = Vec::new();
    let mut out = "results".to_string();
    let mut quiet = false;
    let mut algos = Vec::new();
    let mut taus = Vec::new();

    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                config_file = Some(next(args, &mut i, "--config")?);
            }
            "--set" => {
                let kv = next(args, &mut i, "--set")?;
                let (k, v) = kv
                    .split_once('=')
                    .with_context(|| format!("--set expects key=value, got '{kv}'"))?;
                overrides.push((k.to_string(), v.to_string()));
            }
            "--execution" => {
                let v = next(args, &mut i, "--execution")?;
                overrides.push(("execution".to_string(), v));
            }
            "--fault" => {
                // The `fault` config key appends, so repeated --fault flags
                // accumulate into one schedule (DESIGN.md §11).
                let v = next(args, &mut i, "--fault")?;
                overrides.push(("fault".to_string(), v));
            }
            "--compress" => {
                let v = next(args, &mut i, "--compress")?;
                overrides.push(("compress".to_string(), v));
            }
            "--out" | "-o" => {
                out = next(args, &mut i, "--out")?;
            }
            "--quiet" | "-q" => quiet = true,
            "--algos" => {
                for a in next(args, &mut i, "--algos")?.split(',') {
                    algos.push(Algo::parse(a.trim())?);
                }
            }
            "--taus" => {
                for t in next(args, &mut i, "--taus")?.split(',') {
                    taus.push(t.trim().parse().with_context(|| format!("bad tau '{t}'"))?);
                }
            }
            other => bail!("unknown flag '{other}'"),
        }
        i += 1;
    }

    let cfg = match config_file {
        Some(f) => ExperimentConfig::from_file(&f, &overrides)?,
        None => {
            let mut c = ExperimentConfig::default();
            for (k, v) in &overrides {
                c.set(k, v)?;
            }
            c
        }
    };
    Ok(CommonArgs { cfg, out, quiet, algos, taus })
}

fn next(args: &[String], i: &mut usize, flag: &str) -> Result<String> {
    *i += 1;
    args.get(*i)
        .cloned()
        .with_context(|| format!("{flag} requires a value"))
}

fn cmd_info(args: &[String]) -> Result<()> {
    let common = parse_common(args)?;
    let dir = Path::new(&common.cfg.artifacts_dir);
    #[cfg(feature = "pjrt")]
    if dir.join("manifest.json").exists() {
        let rt = runtime::Runtime::new(dir)?;
        println!("platform: {}", rt.platform());
        println!(
            "artifacts: train_batch={} eval_batch={} image={:?}",
            rt.manifest.train_batch, rt.manifest.eval_batch, rt.manifest.image_shape
        );
        for (name, m) in &rt.manifest.models {
            println!(
                "  model {name:<10} params={:<8} tensors={:<3} modules={:?}",
                m.param_count,
                m.tensors.len(),
                m.modules.keys().collect::<Vec<_>>()
            );
        }
        return Ok(());
    }
    let rt = runtime::load_for(dir, &common.cfg)?;
    println!("platform: native (pure-Rust reference backend; no PJRT artifacts)");
    println!(
        "model {:<10} params={:<8} train_batch={} eval_batch={} image={:?} kernels={}",
        rt.name,
        rt.n,
        rt.train_batch,
        rt.eval_batch,
        rt.image_shape,
        rt.tier.name()
    );
    Ok(())
}

/// Cache of (runtime cache key, loaded ModelRuntime) across sweep legs.
type RtCache = Option<(String, ModelRuntime)>;

/// The fields a loaded runtime depends on — legs differing in any of them
/// must not share a cached runtime.
fn rt_cache_key(cfg: &ExperimentConfig) -> String {
    format!("{}:{}:{}", cfg.model, cfg.hidden, cfg.kernels.name())
}

/// Load runtime + data and run one configured experiment.
fn run_one(
    cfg: &ExperimentConfig,
    rt_cache: &mut RtCache,
    quiet: bool,
) -> Result<olsgd::metrics::TrainLog> {
    let key = rt_cache_key(cfg);
    let reload = match rt_cache {
        Some((cached, _)) => cached != &key,
        None => true,
    };
    if reload {
        let model = runtime::load_for(Path::new(&cfg.artifacts_dir), cfg)?;
        *rt_cache = Some((key, model));
    }
    let (_, model_rt) = rt_cache.as_ref().unwrap();

    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);

    if !quiet {
        println!(
            "run: algo={} model={} m={} tau={} alpha={} beta={} epochs={} exec={} {}",
            cfg.algo.name(),
            cfg.model,
            cfg.workers,
            cfg.tau,
            cfg.alpha,
            cfg.beta,
            cfg.epochs,
            cfg.execution.name(),
            if cfg.noniid { "non-IID" } else { "IID" }
        );
    }
    let log = coordinator::run_experiment(model_rt, cfg, &train, &test)?;
    if !quiet {
        println!(
            "  -> final acc {:.2}%  test loss {:.4}  sim time {:.1}s  comm ratio {:.1}%",
            100.0 * log.final_acc(),
            log.final_loss(),
            log.total_sim_time,
            100.0 * log.comm_ratio()
        );
    }
    Ok(log)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let common = parse_common(args)?;
    let mut cache = None;
    let log = run_one(&common.cfg, &mut cache, common.quiet)?;
    let out = Path::new(&common.out);
    let tag = format!("{}_tau{}", common.cfg.algo.name(), common.cfg.tau);
    write_json(out, &format!("{tag}.json"), &log.to_json())?;
    write_text(out, &format!("{tag}.csv"), &log.to_csv())?;
    println!("wrote {}/{tag}.{{json,csv}}", common.out);
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let common = parse_common(args)?;
    if common.algos.is_empty() || common.taus.is_empty() {
        bail!("sweep requires --algos and --taus");
    }
    let out = Path::new(&common.out);
    let mut cache = None;
    let mut summary_rows = Vec::new();
    for &algo in &common.algos {
        for &tau in &common.taus {
            let mut cfg = common.cfg.clone();
            cfg.algo = algo;
            cfg.tau = tau;
            let log = run_one(&cfg, &mut cache, common.quiet)?;
            let tag = format!("{}_tau{tau}", algo.name());
            write_json(out, &format!("{tag}.json"), &log.to_json())?;
            summary_rows.push(format!(
                "{:<10} tau={:<3} acc={:.2}% time/epoch={:.2}s comm_ratio={:.1}%",
                algo.name(),
                tau,
                100.0 * log.final_acc(),
                log.time_per_epoch(cfg.epochs),
                100.0 * log.comm_ratio()
            ));
        }
    }
    println!("\n== sweep summary ==");
    for r in &summary_rows {
        println!("{r}");
    }
    write_text(out, "sweep_summary.txt", &summary_rows.join("\n"))?;
    Ok(())
}

/// `olsgd coordinator`: a `train` run on the net service plane that serves
/// externally launched `olsgd worker` processes instead of spawning its
/// own fleet (DESIGN.md §13).
fn cmd_coordinator(args: &[String]) -> Result<()> {
    let mut listen: Option<String> = None;
    let mut rest: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--listen" {
            listen = Some(next(args, &mut i, "--listen")?);
        } else {
            rest.push(args[i].clone());
        }
        i += 1;
    }
    let mut common = parse_common(&rest)?;
    // Default to a fixed port: external workers need a knowable address
    // (the self-hosting `train --execution net` path keeps port 0, since it
    // tells its spawned children the bound port itself).
    let addr = listen.unwrap_or_else(|| "127.0.0.1:7700".to_string());
    common.cfg.set("execution", "net")?;
    common.cfg.set("net_spawn", "false")?;
    common.cfg.set("net_listen", &addr)?;
    if !common.quiet {
        println!(
            "coordinator: listening on {addr}; waiting up to {}s for workers covering {} slots\n\
             (start them with: olsgd worker --connect {addr} --lanes N)",
            common.cfg.net_timeout_s, common.cfg.workers
        );
    }
    let mut cache = None;
    let log = run_one(&common.cfg, &mut cache, common.quiet)?;
    let out = Path::new(&common.out);
    let tag = format!("{}_tau{}_net", common.cfg.algo.name(), common.cfg.tau);
    write_json(out, &format!("{tag}.json"), &log.to_json())?;
    write_text(out, &format!("{tag}.csv"), &log.to_csv())?;
    println!("wrote {}/{tag}.{{json,csv}}", common.out);
    Ok(())
}

/// `olsgd worker`: one worker process of the net service plane. Connects,
/// receives its slot grant and the full run config in the `Welcome`, and
/// serves batched phase requests until the coordinator shuts it down.
fn cmd_worker(args: &[String]) -> Result<()> {
    let mut connect: Option<String> = None;
    let mut lanes = 1usize;
    let mut proc_index: Option<usize> = None;
    let mut die_after: Option<u64> = None;
    let mut timeout_s = 10.0f64;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--connect" => connect = Some(next(args, &mut i, "--connect")?),
            "--lanes" => {
                lanes = next(args, &mut i, "--lanes")?.parse().context("bad --lanes")?;
            }
            "--proc-index" => {
                proc_index =
                    Some(next(args, &mut i, "--proc-index")?.parse().context("bad --proc-index")?);
            }
            "--die-after" => {
                die_after =
                    Some(next(args, &mut i, "--die-after")?.parse().context("bad --die-after")?);
            }
            "--timeout" => {
                timeout_s = next(args, &mut i, "--timeout")?.parse().context("bad --timeout")?;
            }
            other => bail!("unknown flag '{other}'"),
        }
        i += 1;
    }
    let addr = connect.context("worker requires --connect HOST:PORT")?;
    olsgd::net::run_worker(&addr, lanes, proc_index, die_after, timeout_s)
}

fn cmd_report(args: &[String]) -> Result<()> {
    let mut dir = "results".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--dir" => dir = next(args, &mut i, "--dir")?,
            other => bail!("unknown flag '{other}'"),
        }
        i += 1;
    }
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .with_context(|| format!("reading {dir}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort();
    println!(
        "{:<24} {:>8} {:>10} {:>12} {:>12}",
        "run", "acc%", "test_loss", "sim_time_s", "comm%"
    );
    for path in entries {
        let j = Json::parse(&std::fs::read_to_string(&path)?)?;
        let name = path.file_stem().unwrap().to_string_lossy().to_string();
        let acc = j.get("final_acc")?.as_f64().unwrap_or(f64::NAN);
        let time = j.get("total_sim_time")?.as_f64().unwrap_or(f64::NAN);
        let ratio = j.get("comm_ratio")?.as_f64().unwrap_or(f64::NAN);
        let tl = j
            .get("records")?
            .as_arr()?
            .last()
            .and_then(|r| r.get("test_loss").ok())
            .and_then(|x| x.as_f64().ok())
            .unwrap_or(f64::NAN);
        println!(
            "{name:<24} {:>8.2} {tl:>10.4} {time:>12.1} {:>12.1}",
            acc * 100.0,
            ratio * 100.0
        );
    }
    Ok(())
}
