//! Loader for `artifacts/manifest.json` — the contract between the AOT
//! Python compile path and the Rust runtime.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One parameter tensor inside the flat vector (mirrors Python TensorSpec).
#[derive(Clone, Debug)]
pub struct TensorManifest {
    /// tensor name (e.g. "conv1.w")
    pub name: String,
    /// start offset in the flat parameter vector
    pub offset: usize,
    /// flat element count
    pub size: usize,
    /// original tensor shape
    pub shape: Vec<usize>,
    /// initializer name ("he_normal" | "zeros")
    pub init: String,
    /// he_normal standard deviation
    pub std: f32,
    /// PowerSGD matricization: the tensor viewed as rows x cols.
    pub rows: usize,
    /// matricization columns (see `rows`)
    pub cols: usize,
    /// false for biases — PowerSGD sends those uncompressed.
    pub compress: bool,
}

/// Per-model artifact table.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    /// total flat parameter count
    pub param_count: usize,
    /// tensor table, in flat-vector order
    pub tensors: Vec<TensorManifest>,
    /// tag ("train_step", "grad_step", "eval", "pullback", "anchor") -> file
    pub modules: BTreeMap<String, String>,
}

/// The whole artifact directory's manifest (all models + batch geometry).
#[derive(Clone, Debug)]
pub struct Manifest {
    /// input image shape (H, W, C)
    pub image_shape: [usize; 3],
    /// label class count
    pub num_classes: usize,
    /// training batch size the artifacts were compiled for
    pub train_batch: usize,
    /// evaluation batch size the artifacts were compiled for
    pub eval_batch: usize,
    /// per-model artifact tables, by model name
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let shape_arr = j.get("image_shape")?.as_arr()?;
        anyhow::ensure!(shape_arr.len() == 3, "image_shape must have 3 dims");
        let image_shape = [
            shape_arr[0].as_usize()?,
            shape_arr[1].as_usize()?,
            shape_arr[2].as_usize()?,
        ];

        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.as_obj()? {
            let mut tensors = Vec::new();
            for t in mj.get("tensors")?.as_arr()? {
                tensors.push(TensorManifest {
                    name: t.get("name")?.as_str()?.to_string(),
                    offset: t.get("offset")?.as_usize()?,
                    size: t.get("size")?.as_usize()?,
                    shape: t
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                    init: t.get("init")?.as_str()?.to_string(),
                    std: t.get("std")?.as_f64()? as f32,
                    rows: t.get("rows")?.as_usize()?,
                    cols: t.get("cols")?.as_usize()?,
                    compress: t.get("compress")?.as_bool()?,
                });
            }
            let mut modules = BTreeMap::new();
            for (tag, file) in mj.get("modules")?.as_obj()? {
                modules.insert(tag.clone(), file.as_str()?.to_string());
            }
            models.insert(
                name.clone(),
                ModelManifest {
                    param_count: mj.get("param_count")?.as_usize()?,
                    tensors,
                    modules,
                },
            );
        }

        Ok(Self {
            image_shape,
            num_classes: j.get("num_classes")?.as_usize()?,
            train_batch: j.get("train_batch")?.as_usize()?,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            models,
        })
    }

    /// Look up one model's table by name.
    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest (have: {:?})",
                                     self.models.keys().collect::<Vec<_>>()))
    }
}

impl ModelManifest {
    /// Bytes on the wire for a full-model (or full-gradient) exchange.
    pub fn message_bytes(&self) -> usize {
        self.param_count * 4
    }

    /// Sanity invariant: tensors tile the flat vector exactly.
    pub fn check_layout(&self) -> Result<()> {
        let mut off = 0;
        for t in &self.tensors {
            anyhow::ensure!(t.offset == off, "gap before {}", t.name);
            anyhow::ensure!(
                t.size == t.shape.iter().product::<usize>(),
                "size/shape mismatch on {}",
                t.name
            );
            anyhow::ensure!(t.rows * t.cols == t.size, "rows*cols != size on {}", t.name);
            off += t.size;
        }
        anyhow::ensure!(off == self.param_count, "layout does not cover param vector");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "image_shape": [32, 32, 3],
      "num_classes": 10,
      "train_batch": 32,
      "eval_batch": 100,
      "models": {
        "toy": {
          "param_count": 10,
          "tensors": [
            {"name": "w", "shape": [2, 3], "offset": 0, "size": 6,
             "init": "he_normal", "fan_in": 2, "std": 1.0,
             "rows": 2, "cols": 3, "compress": true},
            {"name": "b", "shape": [4], "offset": 6, "size": 4,
             "init": "zeros", "fan_in": 2, "std": 0.0,
             "rows": 1, "cols": 4, "compress": false}
          ],
          "modules": {"train_step": "train_step_toy.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.image_shape, [32, 32, 3]);
        assert_eq!(m.train_batch, 32);
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.param_count, 10);
        assert_eq!(toy.tensors.len(), 2);
        assert_eq!(toy.tensors[0].rows, 2);
        assert!(toy.check_layout().is_ok());
        assert_eq!(toy.message_bytes(), 40);
        assert_eq!(toy.modules["train_step"], "train_step_toy.hlo.txt");
    }

    #[test]
    fn unknown_model_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn layout_check_catches_gaps() {
        let mut m = Manifest::parse(SAMPLE).unwrap();
        let toy = m.models.get_mut("toy").unwrap();
        toy.tensors[1].offset = 7;
        assert!(toy.check_layout().is_err());
    }
}
