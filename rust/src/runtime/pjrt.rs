//! PJRT backend: load the AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`). This is the only place Rust touches XLA; the
//! coordinator above sees plain `&[f32]` in / `Vec<f32>` out via
//! [`super::ModelRuntime`].
//!
//! Interchange is HLO **text** (xla_extension 0.5.1 rejects jax≥0.5 64-bit-id
//! protos; the text parser reassigns ids — see /opt/xla-example/README.md).
//! All modules were lowered with `return_tuple=True`, so every result is a
//! tuple literal.
//!
//! Compiled only with the `pjrt` feature (which additionally needs the `xla`
//! dependency from the offline mirror — see Cargo.toml).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::manifest::Manifest;
use super::{Backend, ModelRuntime};

/// Process-wide PJRT client + parsed manifest.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    /// the parsed artifact manifest
    pub manifest: Manifest,
}

impl Runtime {
    /// `dir` is the artifacts directory produced by `make artifacts`.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir: dir.to_path_buf(), manifest })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, file: &str) -> Result<PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {file}"))
    }

    /// Compile all modules of `model` into a ready-to-run bundle.
    pub fn load_model(&self, model: &str) -> Result<ModelRuntime> {
        let mm = self.manifest.model(model)?.clone();
        mm.check_layout()?;
        let get = |tag: &str| -> Result<PjRtLoadedExecutable> {
            let file = mm
                .modules
                .get(tag)
                .with_context(|| format!("module '{tag}' missing for model '{model}'"))?;
            self.compile(file)
        };
        let exes = PjrtModel {
            image_shape: self.manifest.image_shape,
            train_batch: self.manifest.train_batch,
            eval_batch: self.manifest.eval_batch,
            train_step: get("train_step")?,
            grad_step: get("grad_step")?,
            eval: get("eval")?,
            pullback: get("pullback")?,
            anchor: get("anchor")?,
            update: get("update")?,
            adam: get("adam")?,
        };
        Ok(ModelRuntime {
            name: model.to_string(),
            n: mm.param_count,
            train_batch: self.manifest.train_batch,
            eval_batch: self.manifest.eval_batch,
            image_shape: self.manifest.image_shape,
            manifest: mm,
            backend: Backend::Pjrt(Box::new(exes)),
        })
    }
}

/// One model's compiled executables. All methods take/return host `f32`
/// slices; shape validation happens in the `ModelRuntime` wrapper.
pub struct PjrtModel {
    image_shape: [usize; 3],
    train_batch: usize,
    eval_batch: usize,
    train_step: PjRtLoadedExecutable,
    grad_step: PjRtLoadedExecutable,
    eval: PjRtLoadedExecutable,
    pullback: PjRtLoadedExecutable,
    anchor: PjRtLoadedExecutable,
    update: PjRtLoadedExecutable,
    adam: PjRtLoadedExecutable,
}

fn vec_lit(v: &[f32]) -> Literal {
    Literal::vec1(v)
}

fn scalar_lit(v: f32) -> Literal {
    Literal::vec1(&[v])
}

fn images_lit(images: &[f32], batch: usize, shape: [usize; 3]) -> Result<Literal> {
    let [h, w, c] = shape;
    Ok(Literal::vec1(images).reshape(&[batch as i64, h as i64, w as i64, c as i64])?)
}

fn labels_lit(labels: &[i32]) -> Literal {
    Literal::vec1(labels)
}

fn run(exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Vec<Literal>> {
    let result = exe.execute::<Literal>(args)?;
    let lit = result[0][0].to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

fn f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

fn f32_scalar(lit: &Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}

impl PjrtModel {
    /// Fused train step via the `train_step` artifact.
    pub fn train_step(
        &self,
        params: &[f32],
        mom: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
        mu: f32,
        wd: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let out = run(
            &self.train_step,
            &[
                vec_lit(params),
                vec_lit(mom),
                images_lit(images, self.train_batch, self.image_shape)?,
                labels_lit(labels),
                scalar_lit(lr),
                scalar_lit(mu),
                scalar_lit(wd),
            ],
        )?;
        anyhow::ensure!(out.len() == 3, "train_step returned {} outputs", out.len());
        Ok((f32_vec(&out[0])?, f32_vec(&out[1])?, f32_scalar(&out[2])?))
    }

    /// Loss + raw gradient via the `grad_step` artifact.
    pub fn grad_step(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        let out = run(
            &self.grad_step,
            &[
                vec_lit(params),
                images_lit(images, self.train_batch, self.image_shape)?,
                labels_lit(labels),
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "grad_step returned {} outputs", out.len());
        Ok((f32_scalar(&out[0])?, f32_vec(&out[1])?))
    }

    /// `(sum_loss, correct_count)` via the `eval` artifact.
    pub fn evaluate(&self, params: &[f32], images: &[f32], labels: &[i32]) -> Result<(f32, f32)> {
        let out = run(
            &self.eval,
            &[
                vec_lit(params),
                images_lit(images, self.eval_batch, self.image_shape)?,
                labels_lit(labels),
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        Ok((f32_scalar(&out[0])?, f32_scalar(&out[1])?))
    }

    /// Eq. (4) pullback via the `pullback` artifact.
    pub fn pullback(&self, x: &[f32], z: &[f32], alpha: f32) -> Result<Vec<f32>> {
        let out = run(&self.pullback, &[vec_lit(x), vec_lit(z), scalar_lit(alpha)])?;
        anyhow::ensure!(out.len() == 1, "pullback returned {} outputs", out.len());
        f32_vec(&out[0])
    }

    /// Eqs. (10)-(11) anchor update via the `anchor` artifact.
    pub fn anchor_update(
        &self,
        z: &[f32],
        v: &[f32],
        avg: &[f32],
        beta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = run(
            &self.anchor,
            &[vec_lit(z), vec_lit(v), vec_lit(avg), scalar_lit(beta)],
        )?;
        anyhow::ensure!(out.len() == 2, "anchor returned {} outputs", out.len());
        Ok((f32_vec(&out[0])?, f32_vec(&out[1])?))
    }

    /// Fused Nesterov update via the `sgd_update` artifact.
    pub fn sgd_update(
        &self,
        params: &[f32],
        mom: &[f32],
        grad: &[f32],
        lr: f32,
        mu: f32,
        wd: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = run(
            &self.update,
            &[
                vec_lit(params),
                vec_lit(mom),
                vec_lit(grad),
                scalar_lit(lr),
                scalar_lit(mu),
                scalar_lit(wd),
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "update returned {} outputs", out.len());
        Ok((f32_vec(&out[0])?, f32_vec(&out[1])?))
    }

    /// Fused Adam update via the `adam_update` artifact.
    pub fn adam_update(
        &self,
        params: &[f32],
        m1: &[f32],
        m2: &[f32],
        grad: &[f32],
        lr: f32,
        t: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let out = run(
            &self.adam,
            &[
                vec_lit(params),
                vec_lit(m1),
                vec_lit(m2),
                vec_lit(grad),
                scalar_lit(lr),
                scalar_lit(t),
            ],
        )?;
        anyhow::ensure!(out.len() == 3, "adam returned {} outputs", out.len());
        Ok((f32_vec(&out[0])?, f32_vec(&out[1])?, f32_vec(&out[2])?))
    }
}
