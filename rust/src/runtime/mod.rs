//! Model runtime: the kernel contract between the coordinator and the
//! numerics, with two interchangeable backends.
//!
//! * **pjrt** (feature `pjrt`) — loads the AOT HLO-text artifacts produced by
//!   `python/compile/` and executes them through PJRT; Python is never on
//!   the training path. See `pjrt.rs`.
//! * **native** (always available) — pure-Rust reference models
//!   implementing the identical kernel algebra
//!   (`python/compile/kernels/ref.py`), so every algorithm, test, and
//!   bench runs end-to-end on a sealed machine with no XLA and no
//!   artifacts: the linear model (`native.rs`, config `model = linear`)
//!   and a one-hidden-layer ReLU MLP (`mlp.rs`, `model = mlp`,
//!   `hidden = …`) for realistic per-step compute.
//!
//! The native backends run their hot kernels on a per-run
//! [`KernelTier`] (`kernels = scalar | simd`, DESIGN.md §15); the tiers
//! are bit-identical, so the choice affects speed, never digests.
//!
//! The coordinator sees one type either way: [`ModelRuntime`], plain
//! `&[f32]` in / `Vec<f32>` out, with all shape validation centralized here
//! (the system must fail loudly on malformed inputs regardless of backend).

pub mod manifest;
pub mod mlp;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use manifest::{ModelManifest, TensorManifest};
use mlp::NativeMlp;
use native::NativeModel;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

use crate::config::ExperimentConfig;
use crate::data::{C, H, NUM_CLASSES, PX, W};
use crate::model::simd::{self, KernelTier};

/// Hidden width of the MLP model when the config does not say otherwise.
pub const DEFAULT_HIDDEN: usize = 128;

/// Which engine executes the kernels.
enum Backend {
    Native(NativeModel),
    Mlp(NativeMlp),
    #[cfg(feature = "pjrt")]
    Pjrt(Box<pjrt::PjrtModel>),
}

/// One model, ready to run. All methods take/return host `f32` slices;
/// shapes are validated against the manifest before touching any backend.
pub struct ModelRuntime {
    /// model name (for logs)
    pub name: String,
    /// flat parameter count
    pub n: usize,
    /// training batch size
    pub train_batch: usize,
    /// evaluation batch size
    pub eval_batch: usize,
    /// input image shape (H, W, C)
    pub image_shape: [usize; 3],
    /// tensor layout table (initialization, PowerSGD matricization)
    pub manifest: ModelManifest,
    /// kernel tier the native backends dispatch to (bit-identical either
    /// way; `Scalar` unless the config opts into `kernels = simd`)
    pub tier: KernelTier,
    backend: Backend,
}

/// Manifest for the native linear model: one he-initialized weight matrix
/// (PowerSGD-compressible) plus a raw bias, tiling the flat vector.
fn native_manifest() -> ModelManifest {
    let w_size = PX * NUM_CLASSES;
    ModelManifest {
        param_count: w_size + NUM_CLASSES,
        tensors: vec![
            TensorManifest {
                name: "w".into(),
                offset: 0,
                size: w_size,
                shape: vec![PX, NUM_CLASSES],
                init: "he_normal".into(),
                std: (2.0f32 / PX as f32).sqrt(),
                rows: PX,
                cols: NUM_CLASSES,
                compress: true,
            },
            TensorManifest {
                name: "b".into(),
                offset: w_size,
                size: NUM_CLASSES,
                shape: vec![NUM_CLASSES],
                init: "zeros".into(),
                std: 0.0,
                rows: 1,
                cols: NUM_CLASSES,
                compress: false,
            },
        ],
        modules: BTreeMap::new(),
    }
}

/// Manifest for the native MLP: two he-initialized weight matrices (both
/// PowerSGD-matricizable) with their biases, tiling the flat vector as
/// `W1 | b1 | W2 | b2`.
fn mlp_manifest(hidden: usize) -> ModelManifest {
    let w1 = PX * hidden;
    let w2 = hidden * NUM_CLASSES;
    ModelManifest {
        param_count: w1 + hidden + w2 + NUM_CLASSES,
        tensors: vec![
            TensorManifest {
                name: "w1".into(),
                offset: 0,
                size: w1,
                shape: vec![PX, hidden],
                init: "he_normal".into(),
                std: (2.0f32 / PX as f32).sqrt(),
                rows: PX,
                cols: hidden,
                compress: true,
            },
            TensorManifest {
                name: "b1".into(),
                offset: w1,
                size: hidden,
                shape: vec![hidden],
                init: "zeros".into(),
                std: 0.0,
                rows: 1,
                cols: hidden,
                compress: false,
            },
            TensorManifest {
                name: "w2".into(),
                offset: w1 + hidden,
                size: w2,
                shape: vec![hidden, NUM_CLASSES],
                init: "he_normal".into(),
                std: (2.0f32 / hidden as f32).sqrt(),
                rows: hidden,
                cols: NUM_CLASSES,
                compress: true,
            },
            TensorManifest {
                name: "b2".into(),
                offset: w1 + hidden + w2,
                size: NUM_CLASSES,
                shape: vec![NUM_CLASSES],
                init: "zeros".into(),
                std: 0.0,
                rows: 1,
                cols: NUM_CLASSES,
                compress: false,
            },
        ],
        modules: BTreeMap::new(),
    }
}

impl ModelRuntime {
    /// Build the native (pure-Rust) runtime on the scalar (reference)
    /// kernel tier. `model = "mlp"` selects the MLP backend at
    /// [`DEFAULT_HIDDEN`]; any other name is recorded for logs and runs
    /// the reference linear model.
    pub fn native(name: &str) -> Result<Self> {
        Self::native_with(name, DEFAULT_HIDDEN, KernelTier::Scalar)
    }

    /// Build the native runtime with an explicit architecture and kernel
    /// tier — the constructor behind [`load_for`]. Tiers are
    /// bit-identical, so `tier` changes speed, never results.
    pub fn native_with(model: &str, hidden: usize, tier: KernelTier) -> Result<Self> {
        let (manifest, backend) = if model == "mlp" {
            anyhow::ensure!(hidden > 0, "mlp model needs hidden > 0");
            (
                mlp_manifest(hidden),
                Backend::Mlp(NativeMlp::new(PX, hidden, NUM_CLASSES, tier)),
            )
        } else {
            (
                native_manifest(),
                Backend::Native(NativeModel::with_tier(PX, NUM_CLASSES, tier)),
            )
        };
        manifest.check_layout()?;
        Ok(Self {
            name: model.to_string(),
            n: manifest.param_count,
            train_batch: 32,
            eval_batch: 100,
            image_shape: [H, W, C],
            manifest,
            tier,
            backend,
        })
    }

    /// Per-training-step floating-point work (multiply-adds × 2) of the
    /// backend's forward + backward pass — the FLOPs model behind the
    /// GFLOP/s column in the wall-clock bench. Linear: `4·B·px·nc`
    /// (forward + scatter, counting dense work). MLP: `4·B·px·h` for the
    /// layer-1 matmuls (forward + dW1) plus `6·B·h·nc` for layer 2
    /// (forward + dW2 + dh1).
    pub fn train_step_flops(&self) -> f64 {
        let b = self.train_batch as f64;
        match &self.backend {
            Backend::Native(m) => 4.0 * b * (m.px * m.classes) as f64,
            Backend::Mlp(m) => {
                b * (4.0 * (m.px * m.hidden) as f64 + 6.0 * (m.hidden * m.classes) as f64)
            }
            // No per-artifact FLOP table; approximate with the flat size.
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => 4.0 * b * self.n as f64,
        }
    }

    fn check_batch(&self, images: &[f32], labels: &[i32], batch: usize) -> Result<()> {
        let [h, w, c] = self.image_shape;
        anyhow::ensure!(
            images.len() == batch * h * w * c,
            "image buffer len {} != {}x{}x{}x{}",
            images.len(),
            batch,
            h,
            w,
            c
        );
        anyhow::ensure!(labels.len() == batch, "label len {} != batch {batch}", labels.len());
        Ok(())
    }

    /// One local SGD/Nesterov step: `(params, mom, batch, lr, mu, wd)` →
    /// `(params', mom', loss)`. mu = 0 gives plain SGD.
    pub fn train_step(
        &self,
        params: &[f32],
        mom: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
        mu: f32,
        wd: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        anyhow::ensure!(params.len() == self.n && mom.len() == self.n, "param len mismatch");
        self.check_batch(images, labels, self.train_batch)?;
        match &self.backend {
            Backend::Native(m) => {
                let (loss, g) = m.grad_step(params, images, labels, self.train_batch);
                let (p, v) = m.sgd_update(params, mom, &g, lr, mu, wd);
                Ok((p, v, loss))
            }
            Backend::Mlp(m) => {
                let (loss, g) = m.grad_step(params, images, labels, self.train_batch);
                let (mut p, mut v) = (params.to_vec(), mom.to_vec());
                simd::sgd_update_inplace(self.tier, &mut p, &mut v, &g, lr, mu, wd);
                Ok((p, v, loss))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.train_step(params, mom, images, labels, lr, mu, wd),
        }
    }

    /// One local SGD/Nesterov step **in place** with a caller-provided
    /// gradient scratch buffer: `params`/`mom` are updated directly and the
    /// mini-batch loss returned. Bit-identical to
    /// [`ModelRuntime::train_step`] (the native kernels read each element
    /// before writing it, in the same expression order); on the PJRT
    /// backend the artifact outputs are copied back into the buffers. This
    /// is the training hot path: zero allocations per step once `grad` is
    /// sized (DESIGN.md §10).
    pub fn train_step_inplace(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
        mu: f32,
        wd: f32,
        grad: &mut Vec<f32>,
    ) -> Result<f32> {
        anyhow::ensure!(params.len() == self.n && mom.len() == self.n, "param len mismatch");
        self.check_batch(images, labels, self.train_batch)?;
        match &self.backend {
            Backend::Native(m) => {
                grad.resize(self.n, 0.0);
                let loss = m.grad_step_into(params, images, labels, self.train_batch, grad);
                m.sgd_update_inplace(params, mom, grad, lr, mu, wd);
                Ok(loss)
            }
            Backend::Mlp(m) => {
                grad.resize(self.n, 0.0);
                let loss = m.grad_step_into(params, images, labels, self.train_batch, grad);
                simd::sgd_update_inplace(self.tier, params, mom, grad, lr, mu, wd);
                Ok(loss)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => {
                let (p, v, loss) = e.train_step(params, mom, images, labels, lr, mu, wd)?;
                params.copy_from_slice(&p);
                mom.copy_from_slice(&v);
                Ok(loss)
            }
        }
    }

    /// Loss + raw gradient (for sync-SGD gradient averaging and PowerSGD).
    pub fn grad_step(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        anyhow::ensure!(params.len() == self.n, "param len mismatch");
        self.check_batch(images, labels, self.train_batch)?;
        match &self.backend {
            Backend::Native(m) => Ok(m.grad_step(params, images, labels, self.train_batch)),
            Backend::Mlp(m) => Ok(m.grad_step(params, images, labels, self.train_batch)),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.grad_step(params, images, labels),
        }
    }

    /// [`ModelRuntime::grad_step`] into a reusable scratch buffer (resized
    /// to the parameter count; bit-identical contents).
    pub fn grad_step_into(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        grad: &mut Vec<f32>,
    ) -> Result<f32> {
        anyhow::ensure!(params.len() == self.n, "param len mismatch");
        self.check_batch(images, labels, self.train_batch)?;
        match &self.backend {
            Backend::Native(m) => {
                grad.resize(self.n, 0.0);
                Ok(m.grad_step_into(params, images, labels, self.train_batch, grad))
            }
            Backend::Mlp(m) => {
                grad.resize(self.n, 0.0);
                Ok(m.grad_step_into(params, images, labels, self.train_batch, grad))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => {
                let (loss, g) = e.grad_step(params, images, labels)?;
                grad.clear();
                grad.extend_from_slice(&g);
                Ok(loss)
            }
        }
    }

    /// `(sum_loss, correct_count)` over one eval batch.
    pub fn evaluate(&self, params: &[f32], images: &[f32], labels: &[i32]) -> Result<(f32, f32)> {
        anyhow::ensure!(params.len() == self.n, "param len mismatch");
        self.check_batch(images, labels, self.eval_batch)?;
        match &self.backend {
            Backend::Native(m) => Ok(m.evaluate(params, images, labels, self.eval_batch)),
            Backend::Mlp(m) => Ok(m.evaluate(params, images, labels, self.eval_batch)),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.evaluate(params, images, labels),
        }
    }

    /// Eq. (4): `x - alpha * (x - z)`.
    pub fn pullback(&self, x: &[f32], z: &[f32], alpha: f32) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == self.n && z.len() == self.n, "length mismatch");
        match &self.backend {
            Backend::Native(m) => Ok(m.pullback(x, z, alpha)),
            Backend::Mlp(_) => {
                let mut out = x.to_vec();
                simd::pullback_inplace(self.tier, &mut out, z, alpha);
                Ok(out)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.pullback(x, z, alpha),
        }
    }

    /// Eq. (4) in place: `x ← x - alpha * (x - z)`. Bit-identical to
    /// [`ModelRuntime::pullback`] (the native kernel *is* the same
    /// elementwise loop); the PJRT artifact's output is copied back.
    pub fn pullback_inplace(&self, x: &mut [f32], z: &[f32], alpha: f32) -> Result<()> {
        anyhow::ensure!(x.len() == self.n && z.len() == self.n, "length mismatch");
        match &self.backend {
            Backend::Native(_) | Backend::Mlp(_) => {
                simd::pullback_inplace(self.tier, x, z, alpha);
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => {
                let out = e.pullback(x, z, alpha)?;
                x.copy_from_slice(&out);
                Ok(())
            }
        }
    }

    /// Eqs. (10)-(11): returns `(z', v')`.
    pub fn anchor_update(
        &self,
        z: &[f32],
        v: &[f32],
        avg: &[f32],
        beta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(
            z.len() == self.n && v.len() == self.n && avg.len() == self.n,
            "length mismatch"
        );
        match &self.backend {
            Backend::Native(m) => Ok(m.anchor_update(z, v, avg, beta)),
            Backend::Mlp(_) => {
                let (mut zn, mut vn) = (z.to_vec(), v.to_vec());
                simd::anchor_update_inplace(self.tier, &mut zn, &mut vn, avg, beta);
                Ok((zn, vn))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.anchor_update(z, v, avg, beta),
        }
    }

    /// Eqs. (10)-(11) in place: `v ← beta·v + (avg - z); z ← z + v`.
    /// Bit-identical to [`ModelRuntime::anchor_update`].
    pub fn anchor_update_inplace(
        &self,
        z: &mut [f32],
        v: &mut [f32],
        avg: &[f32],
        beta: f32,
    ) -> Result<()> {
        anyhow::ensure!(
            z.len() == self.n && v.len() == self.n && avg.len() == self.n,
            "length mismatch"
        );
        match &self.backend {
            Backend::Native(_) | Backend::Mlp(_) => {
                simd::anchor_update_inplace(self.tier, z, v, avg, beta);
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => {
                let (zn, vn) = e.anchor_update(z, v, avg, beta)?;
                z.copy_from_slice(&zn);
                v.copy_from_slice(&vn);
                Ok(())
            }
        }
    }

    /// Fused Nesterov step with an externally averaged gradient (sync-SGD /
    /// PowerSGD path). Returns `(params', mom')`. mu = 0 gives plain SGD.
    pub fn sgd_update(
        &self,
        params: &[f32],
        mom: &[f32],
        grad: &[f32],
        lr: f32,
        mu: f32,
        wd: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(
            params.len() == self.n && mom.len() == self.n && grad.len() == self.n,
            "length mismatch"
        );
        match &self.backend {
            Backend::Native(m) => Ok(m.sgd_update(params, mom, grad, lr, mu, wd)),
            Backend::Mlp(_) => {
                let (mut p, mut v) = (params.to_vec(), mom.to_vec());
                simd::sgd_update_inplace(self.tier, &mut p, &mut v, grad, lr, mu, wd);
                Ok((p, v))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.sgd_update(params, mom, grad, lr, mu, wd),
        }
    }

    /// Fused Adam step (paper §6 extension). `t` is the 1-based step count
    /// for bias correction. Returns `(params', m1', m2')`.
    pub fn adam_update(
        &self,
        params: &[f32],
        m1: &[f32],
        m2: &[f32],
        grad: &[f32],
        lr: f32,
        t: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(
            params.len() == self.n
                && m1.len() == self.n
                && m2.len() == self.n
                && grad.len() == self.n,
            "length mismatch"
        );
        match &self.backend {
            Backend::Native(m) => Ok(m.adam_update(params, m1, m2, grad, lr, t)),
            Backend::Mlp(_) => {
                let (mut p, mut ma, mut va) = (params.to_vec(), m1.to_vec(), m2.to_vec());
                simd::adam_update_inplace(self.tier, &mut p, &mut ma, &mut va, grad, lr, t);
                Ok((p, ma, va))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.adam_update(params, m1, m2, grad, lr, t),
        }
    }

    /// Fused Adam step in place (paper §6 extension) — bit-identical to
    /// [`ModelRuntime::adam_update`]; the hot-path form the Adam local
    /// optimizer uses.
    pub fn adam_update_inplace(
        &self,
        params: &mut [f32],
        m1: &mut [f32],
        m2: &mut [f32],
        grad: &[f32],
        lr: f32,
        t: f32,
    ) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.n
                && m1.len() == self.n
                && m2.len() == self.n
                && grad.len() == self.n,
            "length mismatch"
        );
        match &self.backend {
            Backend::Native(m) => {
                m.adam_update_inplace(params, m1, m2, grad, lr, t);
                Ok(())
            }
            Backend::Mlp(_) => {
                simd::adam_update_inplace(self.tier, params, m1, m2, grad, lr, t);
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => {
                let (p, mn, vn) = e.adam_update(params, m1, m2, grad, lr, t)?;
                params.copy_from_slice(&p);
                m1.copy_from_slice(&mn);
                m2.copy_from_slice(&vn);
                Ok(())
            }
        }
    }

    /// Evaluate a whole test set (len must be a multiple of eval_batch).
    /// Returns (mean_loss, accuracy).
    pub fn evaluate_set(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<(f64, f64)> {
        let [h, w, c] = self.image_shape;
        let px = h * w * c;
        let n = labels.len();
        anyhow::ensure!(
            n % self.eval_batch == 0,
            "test set {n} not a multiple of eval batch {}",
            self.eval_batch
        );
        let mut sum_loss = 0.0f64;
        let mut correct = 0.0f64;
        for b in 0..(n / self.eval_batch) {
            let i0 = b * self.eval_batch;
            let (l, cnt) = self.evaluate(
                params,
                &images[i0 * px..(i0 + self.eval_batch) * px],
                &labels[i0..i0 + self.eval_batch],
            )?;
            sum_loss += l as f64;
            correct += cnt as f64;
        }
        Ok((sum_loss / n as f64, correct / n as f64))
    }
}

/// Load `model` for an experiment run: the PJRT artifacts when compiled with
/// the `pjrt` feature and `dir` holds them, otherwise the native backend.
/// This is the one loader the CLI, examples, and benches share.
pub fn load_auto(dir: &Path, model: &str) -> Result<ModelRuntime> {
    #[cfg(feature = "pjrt")]
    if dir.join("manifest.json").exists() {
        let runtime = Runtime::new(dir)?;
        let rt = runtime.load_model(model)?;
        // The executables hold their own references to the PJRT client;
        // leak the Runtime so callers need not keep it alive explicitly.
        std::mem::forget(runtime);
        return Ok(rt);
    }
    let _ = dir;
    ModelRuntime::native(model)
}

/// [`load_auto`] driven by the full experiment config: the PJRT artifacts
/// when available, otherwise the native backend selected by `cfg.model`
/// (`mlp` vs linear) with `cfg.hidden` and `cfg.kernels` applied. The CLI,
/// the net worker, and the benches all load through here so a shipped
/// config reproduces the same runtime everywhere.
pub fn load_for(dir: &Path, cfg: &ExperimentConfig) -> Result<ModelRuntime> {
    #[cfg(feature = "pjrt")]
    if dir.join("manifest.json").exists() {
        return load_auto(dir, &cfg.model);
    }
    let _ = dir;
    ModelRuntime::native_with(&cfg.model, cfg.hidden, cfg.kernels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_manifest_layout_is_consistent() {
        let m = native_manifest();
        assert!(m.check_layout().is_ok());
        assert_eq!(m.param_count, PX * NUM_CLASSES + NUM_CLASSES);
        assert_eq!(m.message_bytes(), m.param_count * 4);
    }

    #[test]
    fn native_runtime_composes_train_step_from_parts() {
        let rt = ModelRuntime::native("linear").unwrap();
        let params = crate::model::init_params(&rt.manifest, 3);
        let mom = vec![0.01f32; rt.n];
        let gen = crate::data::GenConfig::default();
        let ds = crate::data::generate(9, 64, "train", &gen);
        let images = ds.images[..rt.train_batch * PX].to_vec();
        let labels = ds.labels[..rt.train_batch].to_vec();

        let (p1, m1, loss1) = rt
            .train_step(&params, &mom, &images, &labels, 0.05, 0.9, 1e-4)
            .unwrap();
        let (loss2, g) = rt.grad_step(&params, &images, &labels).unwrap();
        let (p2, m2) = rt.sgd_update(&params, &mom, &g, 0.05, 0.9, 1e-4).unwrap();
        assert_eq!(loss1, loss2);
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn inplace_wrappers_match_allocating_wrappers_bitwise() {
        let rt = ModelRuntime::native("linear").unwrap();
        let params = crate::model::init_params(&rt.manifest, 5);
        let mom = vec![0.01f32; rt.n];
        let gen = crate::data::GenConfig::default();
        let ds = crate::data::generate(6, 64, "train", &gen);
        let images = ds.images[..rt.train_batch * PX].to_vec();
        let labels = ds.labels[..rt.train_batch].to_vec();

        let (p_a, m_a, loss_a) =
            rt.train_step(&params, &mom, &images, &labels, 0.05, 0.9, 1e-4).unwrap();
        let mut p_b = params.clone();
        let mut m_b = mom.clone();
        let mut scratch = Vec::new();
        let loss_b = rt
            .train_step_inplace(&mut p_b, &mut m_b, &images, &labels, 0.05, 0.9, 1e-4, &mut scratch)
            .unwrap();
        assert_eq!(loss_a.to_bits(), loss_b.to_bits());
        assert_eq!(p_a, p_b);
        assert_eq!(m_a, m_b);

        let (loss_c, g_c) = rt.grad_step(&params, &images, &labels).unwrap();
        let mut g_d = vec![f32::NAN; 3]; // wrong size + poisoned: must be fixed up
        let loss_d = rt.grad_step_into(&params, &images, &labels, &mut g_d).unwrap();
        assert_eq!(loss_c.to_bits(), loss_d.to_bits());
        assert_eq!(g_c, g_d);

        let z = params.clone();
        let pulled = rt.pullback(&p_a, &z, 0.6).unwrap();
        let mut x = p_a.clone();
        rt.pullback_inplace(&mut x, &z, 0.6).unwrap();
        assert_eq!(pulled, x);

        let v0 = vec![0.02f32; rt.n];
        let (z_a, v_a) = rt.anchor_update(&z, &v0, &p_a, 0.7).unwrap();
        let mut z_b = z.clone();
        let mut v_b = v0.clone();
        rt.anchor_update_inplace(&mut z_b, &mut v_b, &p_a, 0.7).unwrap();
        assert_eq!(z_a, z_b);
        assert_eq!(v_a, v_b);
    }

    #[test]
    fn mlp_manifest_layout_is_consistent() {
        let m = mlp_manifest(DEFAULT_HIDDEN);
        assert!(m.check_layout().is_ok());
        assert_eq!(
            m.param_count,
            PX * DEFAULT_HIDDEN + DEFAULT_HIDDEN + DEFAULT_HIDDEN * NUM_CLASSES + NUM_CLASSES
        );
        // Both weight matrices matricize for PowerSGD; biases stay raw.
        let compressed: Vec<&str> = m
            .tensors
            .iter()
            .filter(|t| t.compress)
            .map(|t| t.name.as_str())
            .collect();
        assert_eq!(compressed, ["w1", "w2"]);
    }

    #[test]
    fn mlp_runtime_composes_and_matches_inplace_bitwise() {
        let rt = ModelRuntime::native_with("mlp", 16, crate::model::simd::KernelTier::Scalar)
            .unwrap();
        assert_eq!(rt.n, PX * 16 + 16 + 16 * NUM_CLASSES + NUM_CLASSES);
        let params = crate::model::init_params(&rt.manifest, 3);
        assert!(params[..PX * 16].iter().any(|&x| x != 0.0), "w1 must initialize");
        let mom = vec![0.01f32; rt.n];
        let gen = crate::data::GenConfig::default();
        let ds = crate::data::generate(9, 64, "train", &gen);
        let images = ds.images[..rt.train_batch * PX].to_vec();
        let labels = ds.labels[..rt.train_batch].to_vec();

        let (p_a, m_a, loss_a) =
            rt.train_step(&params, &mom, &images, &labels, 0.05, 0.9, 1e-4).unwrap();
        let mut p_b = params.clone();
        let mut m_b = mom.clone();
        let mut scratch = Vec::new();
        let loss_b = rt
            .train_step_inplace(&mut p_b, &mut m_b, &images, &labels, 0.05, 0.9, 1e-4, &mut scratch)
            .unwrap();
        assert_eq!(loss_a.to_bits(), loss_b.to_bits());
        assert_eq!(p_a, p_b);
        assert_eq!(m_a, m_b);
        assert!(loss_a.is_finite());
    }

    #[test]
    fn mlp_step_flops_dominate_linear() {
        let lin = ModelRuntime::native("linear").unwrap();
        let mlp = ModelRuntime::native("mlp").unwrap();
        assert!(lin.train_step_flops() > 0.0);
        // Acceptance floor: the MLP must carry ≥5× the linear per-step
        // compute so overlap has something real to hide.
        assert!(
            mlp.train_step_flops() >= 5.0 * lin.train_step_flops(),
            "mlp {} vs linear {}",
            mlp.train_step_flops(),
            lin.train_step_flops()
        );
    }

    #[test]
    fn load_for_selects_model_and_tier_from_config() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("model", "mlp").unwrap();
        cfg.set("hidden", "32").unwrap();
        cfg.set("kernels", "simd").unwrap();
        let rt = load_for(Path::new("/nonexistent/artifacts"), &cfg).unwrap();
        assert_eq!(rt.name, "mlp");
        assert_eq!(rt.tier, crate::model::simd::KernelTier::Simd);
        assert_eq!(rt.n, PX * 32 + 32 + 32 * NUM_CLASSES + NUM_CLASSES);
    }

    #[test]
    fn load_auto_falls_back_to_native() {
        let rt = load_auto(Path::new("/nonexistent/artifacts"), "cnn").unwrap();
        assert_eq!(rt.name, "cnn");
        assert!(rt.n > 0);
    }

    #[test]
    fn wrapper_validates_shapes_for_native_backend() {
        let rt = ModelRuntime::native("linear").unwrap();
        let short = vec![0.0f32; rt.n - 1];
        let ok = vec![0.0f32; rt.n];
        let images = vec![0.0f32; rt.train_batch * PX];
        let labels = vec![0i32; rt.train_batch];
        assert!(rt.train_step(&short, &ok, &images, &labels, 0.1, 0.9, 0.0).is_err());
        assert!(rt.grad_step(&short, &images, &labels).is_err());
        let bad_imgs = vec![0.0f32; (rt.train_batch - 1) * PX];
        assert!(rt.grad_step(&ok, &bad_imgs, &labels).is_err());
        let imgs7 = vec![0.0f32; 7 * PX];
        let lbl7 = vec![0i32; 7];
        assert!(rt.evaluate_set(&ok, &imgs7, &lbl7).is_err());
    }
}
