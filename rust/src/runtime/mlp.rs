//! Native MLP backend: a one-hidden-layer ReLU perceptron over the same
//! flat-vector kernel contract as [`crate::runtime::native::NativeModel`],
//! built on the two-tier matmul kernels (`model::matmul`, DESIGN.md §15).
//!
//! Purpose: realistic compute intensity. The linear reference model costs
//! ~`4·B·px·nc` FLOPs per training step — so little local compute that the
//! wall-clock benches (E12–E14) mostly measure orchestration, and the
//! compute/communication ratio the paper's overlap argument depends on
//! sits at one unrealistically tiny point. The MLP's `4·B·px·hidden +
//! 6·B·hidden·nc` per-step FLOPs (~13× the linear model at the default
//! `hidden = 128`) puts a real local phase under every algorithm ×
//! topology × compressor × fault × population axis, while the flat
//! parameter vector keeps every collective, compressor, and spill codec
//! working unchanged.
//!
//! Layout of the flat vector: `W1` (px × hidden, row-major), `b1`
//! (hidden), `W2` (hidden × classes), `b2` (classes). Forward:
//! `h1 = relu(X·W1 + b1)`, `logits = h1·W2 + b2`, stable softmax
//! cross-entropy, last-max-wins argmax — per-sample semantics identical to
//! the linear model. Backward: `Δ = (softmax - onehot)/B`, `dW2 = h1ᵀΔ`,
//! `db2 = colsumΔ`, `dh1 = ΔW2ᵀ ⊙ [h1 > 0]`, `dW1 = Xᵀdh1`,
//! `db1 = colsum dh1`.
//!
//! **Kernel tiers:** layer-scale matmuls dispatch on the run's
//! [`KernelTier`] (scalar ikj reference vs the register-blocked Pallas
//! port), which are bit-identical by construction — so the two tiers
//! produce bit-identical losses, gradients, and predictions (locked by the
//! tests below).
//!
//! **Hot-path memory:** the activations live in per-OS-thread scratch
//! (`thread_local`, grow-once) — the per-step kernels allocate nothing
//! once a thread is warm, keeping the zero-steady-alloc discipline of
//! DESIGN.md §10 (each pool worker warms its own scratch during the
//! engine's warm-up rounds).

use std::cell::RefCell;

use crate::model::matmul;
use crate::model::simd::KernelTier;

/// Per-thread activation scratch: layer-1 activations, logits, the softmax
/// delta, and the hidden-layer gradient. Grow-once (`resize` never shrinks
/// capacity), so steady-state steps allocate nothing.
#[derive(Default)]
struct Scratch {
    h1: Vec<f32>,
    logits: Vec<f32>,
    delta: Vec<f32>,
    dh1: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

/// One-hidden-layer ReLU MLP over flat `[px]` inputs (config
/// `model = mlp`, `hidden = …`, `kernels = scalar|simd`).
#[derive(Clone, Debug)]
pub struct NativeMlp {
    /// flat input pixel count
    pub px: usize,
    /// hidden-layer width
    pub hidden: usize,
    /// output class count
    pub classes: usize,
    tier: KernelTier,
}

impl NativeMlp {
    /// Model over `px`-pixel inputs with `hidden` ReLU units and `classes`
    /// outputs, running its layer kernels on `tier`.
    pub fn new(px: usize, hidden: usize, classes: usize, tier: KernelTier) -> Self {
        assert!(px > 0 && hidden > 0 && classes > 0, "degenerate mlp shape");
        Self { px, hidden, classes, tier }
    }

    /// Flat parameter count (`W1 + b1 + W2 + b2`).
    pub fn param_count(&self) -> usize {
        self.px * self.hidden + self.hidden + self.hidden * self.classes + self.classes
    }

    /// The kernel tier this instance dispatches to.
    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// `out = act(X·W + bias)` on the instance's tier (both tiers are
    /// bit-identical; `model::matmul` locks it).
    fn mm_bias_act(&self, x: &[f32], k: usize, w: &[f32], bias: &[f32], relu: bool, out: &mut [f32]) {
        match self.tier {
            KernelTier::Scalar => matmul::matmul_bias_act_into(x, k, w, bias, relu, out),
            KernelTier::Simd => matmul::matmul_bias_act_blocked_into(x, k, w, bias, relu, out),
        }
    }

    /// `c = aᵀ·b` on the instance's tier (the weight-gradient kernel).
    fn mm_tn(&self, a: &[f32], m: usize, k: usize, b: &[f32], n: usize, c: &mut [f32]) {
        match self.tier {
            KernelTier::Scalar => matmul::matmul_tn_into(a, m, k, b, n, c),
            KernelTier::Simd => matmul::matmul_tn_blocked_into(a, m, k, b, n, c),
        }
    }

    /// Forward one batch; accumulate mean-loss pieces and (optionally) the
    /// gradient of the mean cross-entropy loss — the same contract as
    /// `NativeModel::forward`. Returns `(sum_loss, correct_count)`; `grad`,
    /// when given, receives the *mean* gradient over the batch (every
    /// region is fully overwritten, so prior contents are irrelevant).
    fn forward(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
        mut grad: Option<&mut [f32]>,
    ) -> (f64, usize) {
        let (px, nh, nc) = (self.px, self.hidden, self.classes);
        let w1 = &params[..px * nh];
        let b1 = &params[px * nh..px * nh + nh];
        let w2 = &params[px * nh + nh..px * nh + nh + nh * nc];
        let b2 = &params[px * nh + nh + nh * nc..];
        let inv_b = 1.0f32 / batch as f32;
        let mut sum_loss = 0.0f64;
        let mut correct = 0usize;
        SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.h1.resize(batch * nh, 0.0);
            s.logits.resize(batch * nc, 0.0);
            self.mm_bias_act(&images[..batch * px], px, w1, b1, true, &mut s.h1);
            self.mm_bias_act(&s.h1, nh, w2, b2, false, &mut s.logits);
            if grad.is_some() {
                s.delta.resize(batch * nc, 0.0);
            }
            for i in 0..batch {
                let logits = &s.logits[i * nc..(i + 1) * nc];
                // Stable softmax cross-entropy + last-max-wins argmax —
                // verbatim the linear model's per-sample semantics.
                let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum_exp = 0.0f32;
                for &l in logits.iter() {
                    sum_exp += (l - max).exp();
                }
                let y = labels[i] as usize;
                debug_assert!(y < nc, "label out of range");
                let log_z = max + sum_exp.ln();
                sum_loss += (log_z - logits[y]) as f64;
                let mut argmax = 0usize;
                let mut best = logits[0];
                for (c, &l) in logits.iter().enumerate().skip(1) {
                    if l >= best {
                        best = l;
                        argmax = c;
                    }
                }
                if argmax == y {
                    correct += 1;
                }
                if grad.is_some() {
                    let drow = &mut s.delta[i * nc..(i + 1) * nc];
                    for (c, &l) in logits.iter().enumerate() {
                        let p = (l - max).exp() / sum_exp;
                        drow[c] = (p - if c == y { 1.0 } else { 0.0 }) * inv_b;
                    }
                }
            }
            if let Some(g) = grad.as_deref_mut() {
                s.dh1.resize(batch * nh, 0.0);
                let (gw1, rest) = g.split_at_mut(px * nh);
                let (gb1, rest) = rest.split_at_mut(nh);
                let (gw2, gb2) = rest.split_at_mut(nh * nc);
                // Layer 2: dW2 = h1ᵀ·Δ, db2 = colsum Δ.
                self.mm_tn(&s.h1, batch, nh, &s.delta, nc, gw2);
                matmul::colsum_into(&s.delta, gb2);
                // dh1 = Δ·W2ᵀ, gated by the ReLU mask. The epilogue's
                // strict `> 0.0` makes `h1 == 0.0` exactly the gated set.
                matmul::matmul_nt_into(&s.delta, nc, w2, nh, &mut s.dh1);
                for (d, &a) in s.dh1.iter_mut().zip(s.h1.iter()) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
                // Layer 1: dW1 = Xᵀ·dh1, db1 = colsum dh1.
                self.mm_tn(&images[..batch * px], batch, px, &s.dh1, nh, gw1);
                matmul::colsum_into(&s.dh1, gb1);
            }
        });
        (sum_loss, correct)
    }

    /// Loss + mean gradient over one training batch.
    pub fn grad_step(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
    ) -> (f32, Vec<f32>) {
        let mut grad = vec![0.0f32; self.param_count()];
        let (sum_loss, _) = self.forward(params, images, labels, batch, Some(&mut grad));
        ((sum_loss / batch as f64) as f32, grad)
    }

    /// [`NativeMlp::grad_step`] into a caller-provided scratch buffer
    /// (fully overwritten — bit-identical to the allocating form).
    pub fn grad_step_into(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
        grad: &mut [f32],
    ) -> f32 {
        assert_eq!(grad.len(), self.param_count(), "gradient buffer length");
        let (sum_loss, _) = self.forward(params, images, labels, batch, Some(grad));
        (sum_loss / batch as f64) as f32
    }

    /// `(sum_loss, correct_count)` over one eval batch.
    pub fn evaluate(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
    ) -> (f32, f32) {
        let (sum_loss, correct) = self.forward(params, images, labels, batch, None);
        (sum_loss as f32, correct as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn toy(tier: KernelTier) -> NativeMlp {
        NativeMlp::new(6, 5, 3, tier)
    }

    fn rand_params(m: &NativeMlp, seed: u64) -> Vec<f32> {
        let mut p = vec![0.0f32; m.param_count()];
        Rng::seed_from(seed).fill_normal(&mut p, 0.4);
        p
    }

    #[test]
    fn param_count_matches_layout() {
        let m = NativeMlp::new(3072, 128, 10, KernelTier::Scalar);
        assert_eq!(m.param_count(), 3072 * 128 + 128 + 128 * 10 + 10);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let m = toy(KernelTier::Scalar);
        let params = rand_params(&m, 1);
        let b = 4;
        let mut images = vec![0.0f32; b * m.px];
        Rng::seed_from(2).fill_normal(&mut images, 1.0);
        let labels = vec![0i32, 2, 1, 1];
        let (_, grad) = m.grad_step(&params, &images, &labels, b);
        let eps = 1e-3f32;
        for idx in [0usize, 7, m.px * m.hidden, m.param_count() - 1] {
            let mut pp = params.clone();
            pp[idx] += eps;
            let (lp, _) = m.grad_step(&pp, &images, &labels, b);
            pp[idx] -= 2.0 * eps;
            let (lm, _) = m.grad_step(&pp, &images, &labels, b);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "grad[{idx}]: fd {fd} vs analytic {}",
                grad[idx]
            );
        }
    }

    #[test]
    fn training_one_batch_reduces_loss() {
        let m = NativeMlp::new(8, 6, 4, KernelTier::Scalar);
        let lin = crate::runtime::native::NativeModel::new(1, 1); // kernel host
        let mut params = rand_params(&m, 5);
        let mut mom = vec![0.0f32; m.param_count()];
        let b = 16;
        let mut images = vec![0.0f32; b * m.px];
        Rng::seed_from(6).fill_normal(&mut images, 1.0);
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 4).collect();
        let (first, _) = m.grad_step(&params, &images, &labels, b);
        let mut last = first;
        for _ in 0..60 {
            let mut grad = vec![0.0f32; m.param_count()];
            last = m.grad_step_into(&params, &images, &labels, b, &mut grad);
            lin.sgd_update_inplace(&mut params, &mut mom, &grad, 0.3, 0.9, 0.0);
        }
        assert!(last < first * 0.5, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn property_simd_tier_is_bit_identical_to_scalar() {
        // The end-to-end forward/backward lock at MLP shapes: random
        // (px, hidden, classes, batch) straddling the matmul block sizes,
        // loss + gradient + eval counts compared bit for bit across tiers.
        property("mlp simd tier == scalar tier (bits)", 40, |g| {
            let px = g.usize_in(1, 24);
            let nh = g.usize_in(1, 40);
            let nc = g.usize_in(1, 8);
            let batch = g.usize_in(1, 10);
            let scalar = NativeMlp::new(px, nh, nc, KernelTier::Scalar);
            let simd = NativeMlp::new(px, nh, nc, KernelTier::Simd);
            let params = {
                let mut p = vec![0.0f32; scalar.param_count()];
                Rng::seed_from(g.seed).fill_normal(&mut p, 0.4);
                p
            };
            let images = g.vec_f32(batch * px, 1.0);
            let labels: Vec<i32> = (0..batch).map(|i| (i % nc) as i32).collect();

            let (loss_s, grad_s) = scalar.grad_step(&params, &images, &labels, batch);
            let (loss_v, grad_v) = simd.grad_step(&params, &images, &labels, batch);
            assert_eq!(loss_s.to_bits(), loss_v.to_bits(), "loss drift");
            for (i, (a, b)) in grad_s.iter().zip(&grad_v).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "grad bit drift at {i}");
            }

            let (el_s, ec_s) = scalar.evaluate(&params, &images, &labels, batch);
            let (el_v, ec_v) = simd.evaluate(&params, &images, &labels, batch);
            assert_eq!(el_s.to_bits(), el_v.to_bits());
            assert_eq!(ec_s, ec_v);
        });
    }

    #[test]
    fn tiers_are_bit_identical_at_the_paper_shape() {
        // Full production shape (px 3072, hidden 128, classes 10, batch
        // 32), once: the deployed dimensions, covering full blocks plus
        // the classes sub-block and remainder lanes.
        let scalar = NativeMlp::new(3072, 128, 10, KernelTier::Scalar);
        let simd = NativeMlp::new(3072, 128, 10, KernelTier::Simd);
        let mut params = vec![0.0f32; scalar.param_count()];
        Rng::seed_from(41).fill_normal(&mut params, 0.02);
        let batch = 32;
        let mut images = vec![0.0f32; batch * 3072];
        Rng::seed_from(42).fill_normal(&mut images, 1.0);
        let labels: Vec<i32> = (0..batch as i32).map(|i| i % 10).collect();
        let (loss_s, grad_s) = scalar.grad_step(&params, &images, &labels, batch);
        let (loss_v, grad_v) = simd.grad_step(&params, &images, &labels, batch);
        assert_eq!(loss_s.to_bits(), loss_v.to_bits());
        for (i, (a, b)) in grad_s.iter().zip(&grad_v).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "grad bit drift at {i}");
        }
    }

    #[test]
    fn grad_step_into_matches_allocating_form_bitwise() {
        let m = toy(KernelTier::Simd);
        let params = rand_params(&m, 11);
        let b = 5;
        let mut images = vec![0.0f32; b * m.px];
        Rng::seed_from(12).fill_normal(&mut images, 1.0);
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 3).collect();
        let (loss_a, grad_a) = m.grad_step(&params, &images, &labels, b);
        let mut grad_b = vec![f32::NAN; m.param_count()]; // poisoned scratch
        let loss_b = m.grad_step_into(&params, &images, &labels, b, &mut grad_b);
        assert_eq!(loss_a.to_bits(), loss_b.to_bits());
        for (a, bb) in grad_a.iter().zip(&grad_b) {
            assert_eq!(a.to_bits(), bb.to_bits());
        }
    }
}
