//! Native reference backend: a pure-Rust multinomial logistic-regression
//! model implementing the full `ModelRuntime` kernel contract (forward,
//! gradient, fused Nesterov/Adam updates, pullback, anchor).
//!
//! Purpose: the coordinator, the round engine, and every algorithm test can
//! run end-to-end on a sealed machine with no XLA/PJRT and no AOT artifacts.
//! The algebra of the *updates* (Nesterov, Adam, pullback, anchor) matches
//! `python/compile/kernels/ref.py` exactly, so algorithm-level identities
//! (e.g. sync == local@τ=1) hold on this backend just as on the artifacts;
//! only the model architecture differs (linear instead of the scaled CNN).
//!
//! Everything is deterministic f32 arithmetic with a fixed accumulation
//! order — the property the golden-regression digests rely on.

use crate::model::vecmath;

/// Softmax-regression model over flat `[px]` inputs and `classes` outputs.
/// Parameter layout in the flat vector: `W` (px × classes, row-major) at
/// offset 0, then the bias `b` (classes).
#[derive(Clone, Debug)]
pub struct NativeModel {
    /// flat input pixel count
    pub px: usize,
    /// output class count
    pub classes: usize,
}

impl NativeModel {
    /// Model over `px`-pixel inputs and `classes` outputs.
    pub fn new(px: usize, classes: usize) -> Self {
        Self { px, classes }
    }

    /// Flat parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        self.px * self.classes + self.classes
    }

    /// Forward one batch; accumulate mean-loss pieces and (optionally) the
    /// gradient of the mean cross-entropy loss.
    ///
    /// Returns `(sum_loss, correct_count)`; `grad`, when given, must be
    /// zeroed by the caller and receives the *mean* gradient over the batch.
    fn forward(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
        mut grad: Option<&mut [f32]>,
    ) -> (f64, usize) {
        let (px, nc) = (self.px, self.classes);
        let w = &params[..px * nc];
        let b = &params[px * nc..];
        let inv_b = 1.0f32 / batch as f32;
        let mut sum_loss = 0.0f64;
        let mut correct = 0usize;
        let mut logits = vec![0.0f32; nc];
        for i in 0..batch {
            let x = &images[i * px..(i + 1) * px];
            logits.copy_from_slice(b);
            for (j, &xj) in x.iter().enumerate() {
                if xj != 0.0 {
                    let row = &w[j * nc..(j + 1) * nc];
                    for (l, &wv) in logits.iter_mut().zip(row) {
                        *l += xj * wv;
                    }
                }
            }
            // stable softmax cross-entropy
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum_exp = 0.0f32;
            for &l in logits.iter() {
                sum_exp += (l - max).exp();
            }
            let y = labels[i] as usize;
            debug_assert!(y < nc, "label out of range");
            let log_z = max + sum_exp.ln();
            sum_loss += (log_z - logits[y]) as f64;
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(c, _)| c)
                .unwrap_or(0);
            if argmax == y {
                correct += 1;
            }
            if let Some(g) = grad.as_deref_mut() {
                let (gw, gb) = g.split_at_mut(px * nc);
                for (c, &l) in logits.iter().enumerate() {
                    let p = (l - max).exp() / sum_exp;
                    let d = (p - if c == y { 1.0 } else { 0.0 }) * inv_b;
                    gb[c] += d;
                    for (j, &xj) in x.iter().enumerate() {
                        gw[j * nc + c] += xj * d;
                    }
                }
            }
        }
        (sum_loss, correct)
    }

    /// Loss + mean gradient over one training batch.
    pub fn grad_step(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
    ) -> (f32, Vec<f32>) {
        let mut grad = vec![0.0f32; self.param_count()];
        let (sum_loss, _) = self.forward(params, images, labels, batch, Some(&mut grad));
        ((sum_loss / batch as f64) as f32, grad)
    }

    /// `(sum_loss, correct_count)` over one eval batch — the same contract
    /// as the PJRT `eval` artifact.
    pub fn evaluate(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
    ) -> (f32, f32) {
        let (sum_loss, correct) = self.forward(params, images, labels, batch, None);
        (sum_loss as f32, correct as f32)
    }

    /// Fused Nesterov step (ref.py `nesterov_update`):
    /// `g += wd*x; v' = mu*v + g; x' = x - lr*(g + mu*v')`.
    pub fn sgd_update(
        &self,
        params: &[f32],
        mom: &[f32],
        grad: &[f32],
        lr: f32,
        mu: f32,
        wd: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let n = params.len();
        let mut p = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for i in 0..n {
            let g = grad[i] + wd * params[i];
            let vn = mu * mom[i] + g;
            p[i] = params[i] - lr * (g + mu * vn);
            v[i] = vn;
        }
        (p, v)
    }

    /// Fused Adam step (ref.py `adam_update`, b1=0.9, b2=0.999, eps=1e-8).
    pub fn adam_update(
        &self,
        params: &[f32],
        m1: &[f32],
        m2: &[f32],
        grad: &[f32],
        lr: f32,
        t: f32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        let n = params.len();
        let bc1 = 1.0 - B1.powf(t);
        let bc2 = 1.0 - B2.powf(t);
        let mut p = vec![0.0f32; n];
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for i in 0..n {
            let g = grad[i];
            let mn = B1 * m1[i] + (1.0 - B1) * g;
            let vn = B2 * m2[i] + (1.0 - B2) * g * g;
            let mhat = mn / bc1;
            let vhat = vn / bc2;
            p[i] = params[i] - lr * mhat / (vhat.sqrt() + EPS);
            m[i] = mn;
            v[i] = vn;
        }
        (p, m, v)
    }

    /// Eq. (4): `x - alpha * (x - z)`.
    pub fn pullback(&self, x: &[f32], z: &[f32], alpha: f32) -> Vec<f32> {
        let mut out = x.to_vec();
        vecmath::pullback_inplace(&mut out, z, alpha);
        out
    }

    /// Eqs. (10)-(11): `v' = beta*v + (avg - z); z' = z + v'`.
    pub fn anchor_update(
        &self,
        z: &[f32],
        v: &[f32],
        avg: &[f32],
        beta: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut zn = z.to_vec();
        let mut vn = v.to_vec();
        vecmath::anchor_update_inplace(&mut zn, &mut vn, avg, beta);
        (zn, vn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::assert_close;
    use crate::util::rng::Rng;

    fn toy() -> NativeModel {
        NativeModel::new(4, 3)
    }

    fn rand_params(m: &NativeModel, seed: u64) -> Vec<f32> {
        let mut p = vec![0.0f32; m.param_count()];
        Rng::seed_from(seed).fill_normal(&mut p, 0.5);
        p
    }

    #[test]
    fn grad_matches_finite_differences() {
        let m = toy();
        let params = rand_params(&m, 1);
        let images = {
            let mut v = vec![0.0f32; 2 * m.px];
            Rng::seed_from(2).fill_normal(&mut v, 1.0);
            v
        };
        let labels = vec![0i32, 2];
        let (_, grad) = m.grad_step(&params, &images, &labels, 2);
        let eps = 1e-3f32;
        for idx in [0usize, 3, 7, m.param_count() - 1] {
            let mut pp = params.clone();
            pp[idx] += eps;
            let (lp, _) = m.grad_step(&pp, &images, &labels, 2);
            pp[idx] -= 2.0 * eps;
            let (lm, _) = m.grad_step(&pp, &images, &labels, 2);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[idx]).abs() < 1e-2 * (1.0 + fd.abs()),
                "grad[{idx}]: fd {fd} vs analytic {}",
                grad[idx]
            );
        }
    }

    #[test]
    fn nesterov_mu_zero_is_plain_sgd() {
        let m = toy();
        let params = rand_params(&m, 3);
        let mom = vec![0.5f32; m.param_count()];
        let mut g = vec![0.0f32; m.param_count()];
        Rng::seed_from(4).fill_normal(&mut g, 0.1);
        let (p, v) = m.sgd_update(&params, &mom, &g, 0.1, 0.0, 0.0);
        assert_close(&v, &g, 1e-6, 1e-7);
        let want: Vec<f32> = params.iter().zip(&g).map(|(&p, &gi)| p - 0.1 * gi).collect();
        assert_close(&p, &want, 1e-5, 1e-7);
        // lr = 0 is a no-op on params
        let (p0, _) = m.sgd_update(&params, &mom, &g, 0.0, 0.9, 0.0);
        assert_close(&p0, &params, 0.0, 0.0);
    }

    #[test]
    fn training_one_batch_reduces_loss() {
        let m = NativeModel::new(8, 4);
        let mut params = vec![0.0f32; m.param_count()];
        let mut mom = vec![0.0f32; m.param_count()];
        let b = 16;
        let mut images = vec![0.0f32; b * m.px];
        Rng::seed_from(5).fill_normal(&mut images, 1.0);
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 4).collect();
        let (first, _) = m.grad_step(&params, &images, &labels, b);
        let mut last = first;
        for _ in 0..50 {
            let (loss, g) = m.grad_step(&params, &images, &labels, b);
            let (p, v) = m.sgd_update(&params, &mom, &g, 0.5, 0.9, 0.0);
            params = p;
            mom = v;
            last = loss;
        }
        assert!(last < first * 0.5, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn evaluate_counts_are_sane() {
        let m = toy();
        let params = rand_params(&m, 7);
        let b = 10;
        let mut images = vec![0.0f32; b * m.px];
        Rng::seed_from(8).fill_normal(&mut images, 1.0);
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 3).collect();
        let (sum_loss, correct) = m.evaluate(&params, &images, &labels, b);
        assert!(sum_loss.is_finite() && sum_loss > 0.0);
        assert!((0.0..=b as f32).contains(&correct));
    }

    #[test]
    fn adam_moves_against_gradient() {
        let m = toy();
        let params = vec![1.0f32; m.param_count()];
        let m1 = vec![0.0f32; m.param_count()];
        let m2 = vec![0.0f32; m.param_count()];
        let g = vec![0.5f32; m.param_count()];
        let (p, mm, vv) = m.adam_update(&params, &m1, &m2, &g, 0.01, 1.0);
        for &x in &p {
            assert!(x < 1.0);
        }
        assert!(mm[0] > 0.0 && vv[0] > 0.0);
    }
}
