//! Native reference backend: a pure-Rust multinomial logistic-regression
//! model implementing the full `ModelRuntime` kernel contract (forward,
//! gradient, fused Nesterov/Adam updates, pullback, anchor).
//!
//! Purpose: the coordinator, the round engine, and every algorithm test can
//! run end-to-end on a sealed machine with no XLA/PJRT and no AOT artifacts.
//! The algebra of the *updates* (Nesterov, Adam, pullback, anchor) matches
//! `python/compile/kernels/ref.py` exactly, so algorithm-level identities
//! (e.g. sync == local@τ=1) hold on this backend just as on the artifacts;
//! only the model architecture differs (linear instead of the scaled CNN).
//!
//! Everything is deterministic f32 arithmetic with a fixed accumulation
//! order — the property the golden-regression digests rely on.
//!
//! Hot-path memory (DESIGN.md §10): the per-call kernels allocate nothing.
//! `forward` keeps its logits on the stack, the gradient lands in a
//! caller-provided scratch buffer ([`NativeModel::grad_step_into`]), and
//! the fused optimizer updates run in place
//! ([`NativeModel::sgd_update_inplace`], [`NativeModel::adam_update_inplace`])
//! — all bit-identical to the allocating forms they hot-swap for, which
//! remain for the reference loops and the PJRT calling convention.

use crate::model::simd::{self, KernelTier};
use crate::model::vecmath;

/// Stack capacity for the per-sample logits / class-delta buffers. The
/// dataset contract is `data::NUM_CLASSES` (10); the toy test models use
/// fewer. Keeping the bound comfortably above both removes the last
/// per-call heap allocation from the forward pass.
const MAX_CLASSES: usize = 64;

/// Softmax-regression model over flat `[px]` inputs and `classes` outputs.
/// Parameter layout in the flat vector: `W` (px × classes, row-major) at
/// offset 0, then the bias `b` (classes).
#[derive(Clone, Debug)]
pub struct NativeModel {
    /// flat input pixel count
    pub px: usize,
    /// output class count
    pub classes: usize,
    tier: KernelTier,
}

impl NativeModel {
    /// Model over `px`-pixel inputs and `classes` outputs, on the scalar
    /// (reference) kernel tier.
    pub fn new(px: usize, classes: usize) -> Self {
        Self::with_tier(px, classes, KernelTier::default())
    }

    /// [`NativeModel::new`] on an explicit kernel tier (DESIGN.md §15).
    /// Both tiers are bit-identical, so this changes speed, never digests.
    pub fn with_tier(px: usize, classes: usize, tier: KernelTier) -> Self {
        Self { px, classes, tier }
    }

    /// The kernel tier this instance dispatches to.
    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// Flat parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        self.px * self.classes + self.classes
    }

    /// Forward one batch; accumulate mean-loss pieces and (optionally) the
    /// gradient of the mean cross-entropy loss.
    ///
    /// Returns `(sum_loss, correct_count)`; `grad`, when given, must be
    /// zeroed by the caller and receives the *mean* gradient over the batch.
    fn forward(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
        mut grad: Option<&mut [f32]>,
    ) -> (f64, usize) {
        let (px, nc) = (self.px, self.classes);
        assert!(nc <= MAX_CLASSES, "class count {nc} exceeds the stack buffer");
        let w = &params[..px * nc];
        let b = &params[px * nc..];
        let inv_b = 1.0f32 / batch as f32;
        let mut sum_loss = 0.0f64;
        let mut correct = 0usize;
        // Stack scratch: no heap allocation anywhere in the forward pass.
        let mut logits_buf = [0.0f32; MAX_CLASSES];
        let mut delta_buf = [0.0f32; MAX_CLASSES];
        let logits = &mut logits_buf[..nc];
        let delta = &mut delta_buf[..nc];
        for i in 0..batch {
            let x = &images[i * px..(i + 1) * px];
            logits.copy_from_slice(b);
            // Per-row accumulate, tier-dispatched: `axpy_simd` evaluates
            // the identical `logits[c] += xj * w_row[c]` expression per
            // element, so the tiers are bit-identical (locked below).
            match self.tier {
                KernelTier::Scalar => {
                    for (j, &xj) in x.iter().enumerate() {
                        if xj != 0.0 {
                            let row = &w[j * nc..(j + 1) * nc];
                            for (l, &wv) in logits.iter_mut().zip(row) {
                                *l += xj * wv;
                            }
                        }
                    }
                }
                KernelTier::Simd => {
                    for (j, &xj) in x.iter().enumerate() {
                        if xj != 0.0 {
                            simd::axpy_simd(xj, &w[j * nc..(j + 1) * nc], logits);
                        }
                    }
                }
            }
            // stable softmax cross-entropy
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum_exp = 0.0f32;
            for &l in logits.iter() {
                sum_exp += (l - max).exp();
            }
            let y = labels[i] as usize;
            debug_assert!(y < nc, "label out of range");
            let log_z = max + sum_exp.ln();
            sum_loss += (log_z - logits[y]) as f64;
            // Branch-loop argmax with last-max-wins ties — the selection
            // `max_by(partial_cmp)` made on every comparable (finite)
            // logit vector, without the `Ordering` machinery in the
            // innermost eval path. (NaN logits — a diverged model — fall
            // back to "never selected" instead of max_by's Equal
            // treatment; no meaningful prediction exists there either
            // way.)
            let mut argmax = 0usize;
            let mut best = logits[0];
            for (c, &l) in logits.iter().enumerate().skip(1) {
                if l >= best {
                    best = l;
                    argmax = c;
                }
            }
            if argmax == y {
                correct += 1;
            }
            if let Some(g) = grad.as_deref_mut() {
                let (gw, gb) = g.split_at_mut(px * nc);
                for (c, &l) in logits.iter().enumerate() {
                    let p = (l - max).exp() / sum_exp;
                    delta[c] = (p - if c == y { 1.0 } else { 0.0 }) * inv_b;
                    gb[c] += delta[c];
                }
                // Scatter mirrors the forward pass: skip zero pixels and
                // walk gw row-contiguously. For finite deltas a skipped
                // contribution is exactly ±0.0 and cannot change any
                // accumulated bit (the accumulator never holds -0.0: it
                // starts at +0.0 and x + -0.0 == x); a NaN/inf delta — a
                // diverged run — would have poisoned the zero-pixel rows
                // in the dense form, which the skip no longer reproduces.
                match self.tier {
                    KernelTier::Scalar => {
                        for (j, &xj) in x.iter().enumerate() {
                            if xj != 0.0 {
                                let row = &mut gw[j * nc..(j + 1) * nc];
                                for (gv, &dc) in row.iter_mut().zip(delta.iter()) {
                                    *gv += xj * dc;
                                }
                            }
                        }
                    }
                    KernelTier::Simd => {
                        for (j, &xj) in x.iter().enumerate() {
                            if xj != 0.0 {
                                simd::axpy_simd(xj, delta, &mut gw[j * nc..(j + 1) * nc]);
                            }
                        }
                    }
                }
            }
        }
        (sum_loss, correct)
    }

    /// Loss + mean gradient over one training batch.
    pub fn grad_step(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
    ) -> (f32, Vec<f32>) {
        let mut grad = vec![0.0f32; self.param_count()];
        let (sum_loss, _) = self.forward(params, images, labels, batch, Some(&mut grad));
        ((sum_loss / batch as f64) as f32, grad)
    }

    /// [`NativeModel::grad_step`] into a caller-provided scratch buffer
    /// (zeroed here, then accumulated exactly like the allocating form —
    /// bit-identical). The per-step `vec![0.0; param_count]` disappears
    /// from the training hot path.
    pub fn grad_step_into(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
        grad: &mut [f32],
    ) -> f32 {
        assert_eq!(grad.len(), self.param_count(), "gradient buffer length");
        grad.fill(0.0);
        let (sum_loss, _) = self.forward(params, images, labels, batch, Some(grad));
        (sum_loss / batch as f64) as f32
    }

    /// `(sum_loss, correct_count)` over one eval batch — the same contract
    /// as the PJRT `eval` artifact.
    pub fn evaluate(
        &self,
        params: &[f32],
        images: &[f32],
        labels: &[i32],
        batch: usize,
    ) -> (f32, f32) {
        let (sum_loss, correct) = self.forward(params, images, labels, batch, None);
        (sum_loss as f32, correct as f32)
    }

    /// Fused Nesterov step (ref.py `nesterov_update`):
    /// `g += wd*x; v' = mu*v + g; x' = x - lr*(g + mu*v')`.
    pub fn sgd_update(
        &self,
        params: &[f32],
        mom: &[f32],
        grad: &[f32],
        lr: f32,
        mu: f32,
        wd: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let n = params.len();
        let mut p = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for i in 0..n {
            let g = grad[i] + wd * params[i];
            let vn = mu * mom[i] + g;
            p[i] = params[i] - lr * (g + mu * vn);
            v[i] = vn;
        }
        (p, v)
    }

    /// [`NativeModel::sgd_update`] in place: element i reads only index i
    /// of each input before writing it, with the identical expression
    /// order, so the results are bit-identical to the allocating form on
    /// either tier (the loops live in [`simd::sgd_update_inplace`]).
    pub fn sgd_update_inplace(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        grad: &[f32],
        lr: f32,
        mu: f32,
        wd: f32,
    ) {
        simd::sgd_update_inplace(self.tier, params, mom, grad, lr, mu, wd);
    }

    /// Fused Adam step (ref.py `adam_update`, b1=0.9, b2=0.999, eps=1e-8).
    pub fn adam_update(
        &self,
        params: &[f32],
        m1: &[f32],
        m2: &[f32],
        grad: &[f32],
        lr: f32,
        t: f32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        let n = params.len();
        let bc1 = 1.0 - B1.powf(t);
        let bc2 = 1.0 - B2.powf(t);
        let mut p = vec![0.0f32; n];
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for i in 0..n {
            let g = grad[i];
            let mn = B1 * m1[i] + (1.0 - B1) * g;
            let vn = B2 * m2[i] + (1.0 - B2) * g * g;
            let mhat = mn / bc1;
            let vhat = vn / bc2;
            p[i] = params[i] - lr * mhat / (vhat.sqrt() + EPS);
            m[i] = mn;
            v[i] = vn;
        }
        (p, m, v)
    }

    /// [`NativeModel::adam_update`] in place (same constants, same
    /// per-element expression order — bit-identical results on either
    /// tier; the loops live in [`simd::adam_update_inplace`]).
    pub fn adam_update_inplace(
        &self,
        params: &mut [f32],
        m1: &mut [f32],
        m2: &mut [f32],
        grad: &[f32],
        lr: f32,
        t: f32,
    ) {
        simd::adam_update_inplace(self.tier, params, m1, m2, grad, lr, t);
    }

    /// Eq. (4): `x - alpha * (x - z)`.
    pub fn pullback(&self, x: &[f32], z: &[f32], alpha: f32) -> Vec<f32> {
        let mut out = x.to_vec();
        vecmath::pullback_inplace(&mut out, z, alpha);
        out
    }

    /// Eqs. (10)-(11): `v' = beta*v + (avg - z); z' = z + v'`.
    pub fn anchor_update(
        &self,
        z: &[f32],
        v: &[f32],
        avg: &[f32],
        beta: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut zn = z.to_vec();
        let mut vn = v.to_vec();
        vecmath::anchor_update_inplace(&mut zn, &mut vn, avg, beta);
        (zn, vn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::assert_close;
    use crate::util::rng::Rng;

    fn toy() -> NativeModel {
        NativeModel::new(4, 3)
    }

    fn rand_params(m: &NativeModel, seed: u64) -> Vec<f32> {
        let mut p = vec![0.0f32; m.param_count()];
        Rng::seed_from(seed).fill_normal(&mut p, 0.5);
        p
    }

    #[test]
    fn grad_matches_finite_differences() {
        let m = toy();
        let params = rand_params(&m, 1);
        let images = {
            let mut v = vec![0.0f32; 2 * m.px];
            Rng::seed_from(2).fill_normal(&mut v, 1.0);
            v
        };
        let labels = vec![0i32, 2];
        let (_, grad) = m.grad_step(&params, &images, &labels, 2);
        let eps = 1e-3f32;
        for idx in [0usize, 3, 7, m.param_count() - 1] {
            let mut pp = params.clone();
            pp[idx] += eps;
            let (lp, _) = m.grad_step(&pp, &images, &labels, 2);
            pp[idx] -= 2.0 * eps;
            let (lm, _) = m.grad_step(&pp, &images, &labels, 2);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[idx]).abs() < 1e-2 * (1.0 + fd.abs()),
                "grad[{idx}]: fd {fd} vs analytic {}",
                grad[idx]
            );
        }
    }

    #[test]
    fn nesterov_mu_zero_is_plain_sgd() {
        let m = toy();
        let params = rand_params(&m, 3);
        let mom = vec![0.5f32; m.param_count()];
        let mut g = vec![0.0f32; m.param_count()];
        Rng::seed_from(4).fill_normal(&mut g, 0.1);
        let (p, v) = m.sgd_update(&params, &mom, &g, 0.1, 0.0, 0.0);
        assert_close(&v, &g, 1e-6, 1e-7);
        let want: Vec<f32> = params.iter().zip(&g).map(|(&p, &gi)| p - 0.1 * gi).collect();
        assert_close(&p, &want, 1e-5, 1e-7);
        // lr = 0 is a no-op on params
        let (p0, _) = m.sgd_update(&params, &mom, &g, 0.0, 0.9, 0.0);
        assert_close(&p0, &params, 0.0, 0.0);
    }

    #[test]
    fn training_one_batch_reduces_loss() {
        let m = NativeModel::new(8, 4);
        let mut params = vec![0.0f32; m.param_count()];
        let mut mom = vec![0.0f32; m.param_count()];
        let b = 16;
        let mut images = vec![0.0f32; b * m.px];
        Rng::seed_from(5).fill_normal(&mut images, 1.0);
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 4).collect();
        let (first, _) = m.grad_step(&params, &images, &labels, b);
        let mut last = first;
        for _ in 0..50 {
            let (loss, g) = m.grad_step(&params, &images, &labels, b);
            let (p, v) = m.sgd_update(&params, &mom, &g, 0.5, 0.9, 0.0);
            params = p;
            mom = v;
            last = loss;
        }
        assert!(last < first * 0.5, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn evaluate_counts_are_sane() {
        let m = toy();
        let params = rand_params(&m, 7);
        let b = 10;
        let mut images = vec![0.0f32; b * m.px];
        Rng::seed_from(8).fill_normal(&mut images, 1.0);
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 3).collect();
        let (sum_loss, correct) = m.evaluate(&params, &images, &labels, b);
        assert!(sum_loss.is_finite() && sum_loss > 0.0);
        assert!((0.0..=b as f32).contains(&correct));
    }

    #[test]
    fn inplace_kernels_match_allocating_kernels_bitwise() {
        let m = NativeModel::new(6, 5);
        let n = m.param_count();
        let params = rand_params(&m, 11);
        let mut mom = vec![0.0f32; n];
        Rng::seed_from(12).fill_normal(&mut mom, 0.3);
        let mut m2 = vec![0.0f32; n];
        Rng::seed_from(13).fill_normal(&mut m2, 0.2);
        for v in m2.iter_mut() {
            *v = v.abs();
        }
        let b = 4;
        let mut images = vec![0.0f32; b * m.px];
        Rng::seed_from(14).fill_normal(&mut images, 1.0);
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 5).collect();

        // grad_step vs grad_step_into (scratch pre-poisoned).
        let (loss_a, grad_a) = m.grad_step(&params, &images, &labels, b);
        let mut grad_b = vec![f32::NAN; n];
        let loss_b = m.grad_step_into(&params, &images, &labels, b, &mut grad_b);
        assert_eq!(loss_a.to_bits(), loss_b.to_bits());
        for (a, bb) in grad_a.iter().zip(&grad_b) {
            assert_eq!(a.to_bits(), bb.to_bits());
        }

        // sgd_update vs sgd_update_inplace.
        let (p_a, v_a) = m.sgd_update(&params, &mom, &grad_a, 0.05, 0.9, 1e-4);
        let mut p_b = params.clone();
        let mut v_b = mom.clone();
        m.sgd_update_inplace(&mut p_b, &mut v_b, &grad_a, 0.05, 0.9, 1e-4);
        for i in 0..n {
            assert_eq!(p_a[i].to_bits(), p_b[i].to_bits());
            assert_eq!(v_a[i].to_bits(), v_b[i].to_bits());
        }

        // adam_update vs adam_update_inplace.
        let (p_a, m_a, v_a) = m.adam_update(&params, &mom, &m2, &grad_a, 0.01, 3.0);
        let mut p_b = params.clone();
        let mut m_b = mom.clone();
        let mut v_b = m2.clone();
        m.adam_update_inplace(&mut p_b, &mut m_b, &mut v_b, &grad_a, 0.01, 3.0);
        for i in 0..n {
            assert_eq!(p_a[i].to_bits(), p_b[i].to_bits());
            assert_eq!(m_a[i].to_bits(), m_b[i].to_bits());
            assert_eq!(v_a[i].to_bits(), v_b[i].to_bits());
        }
    }

    #[test]
    fn sparse_backward_matches_dense_reference_bitwise() {
        // Reference: the pre-sparsity scatter (every pixel, class-major)
        // re-implemented verbatim. The skip-zero row-major scatter must
        // reproduce it bit for bit on images with many exact zeros.
        let m = NativeModel::new(8, 3);
        let (px, nc) = (m.px, m.classes);
        let b = 6;
        let mut images = vec![0.0f32; b * px];
        Rng::seed_from(21).fill_normal(&mut images, 1.0);
        for (i, v) in images.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0; // two thirds of the pixels exactly zero
            }
        }
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 3).collect();
        let params = rand_params(&m, 22);

        let mut want = vec![0.0f32; m.param_count()];
        let inv_b = 1.0f32 / b as f32;
        let w = &params[..px * nc];
        let bias = &params[px * nc..];
        for i in 0..b {
            let x = &images[i * px..(i + 1) * px];
            let mut logits: Vec<f32> = bias.to_vec();
            for (j, &xj) in x.iter().enumerate() {
                if xj != 0.0 {
                    for (l, &wv) in logits.iter_mut().zip(&w[j * nc..(j + 1) * nc]) {
                        *l += xj * wv;
                    }
                }
            }
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum_exp = 0.0f32;
            for &l in logits.iter() {
                sum_exp += (l - max).exp();
            }
            let y = labels[i] as usize;
            let (gw, gb) = want.split_at_mut(px * nc);
            for (c, &l) in logits.iter().enumerate() {
                let p = (l - max).exp() / sum_exp;
                let d = (p - if c == y { 1.0 } else { 0.0 }) * inv_b;
                gb[c] += d;
                for (j, &xj) in x.iter().enumerate() {
                    gw[j * nc + c] += xj * d;
                }
            }
        }

        let (_, got) = m.grad_step(&params, &images, &labels, b);
        for (j, (a, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), g.to_bits(), "grad bit drift at {j}");
        }
        // And the zero rows really are exactly zero.
        for j in 0..px {
            if images.iter().skip(j).step_by(px).all(|&v| v == 0.0) {
                for c in 0..nc {
                    assert_eq!(got[j * nc + c].to_bits(), 0.0f32.to_bits());
                }
            }
        }
    }

    #[test]
    fn argmax_keeps_max_by_semantics_on_ties() {
        // Zero weights + crafted biases: logits == bias for every sample,
        // so ties are exact. `max_by(partial_cmp)` selected the *last*
        // maximum; the branch loop must agree (asserted through the
        // correct-count observable).
        let m = NativeModel::new(2, 4);
        let mut params = vec![0.0f32; m.param_count()];
        let bias_at = m.px * m.classes;
        // biases: [1.0, 3.0, 3.0, 0.5] -> last max is class 2
        params[bias_at] = 1.0;
        params[bias_at + 1] = 3.0;
        params[bias_at + 2] = 3.0;
        params[bias_at + 3] = 0.5;
        let images = vec![0.0f32; 2 * m.px];
        // Sample 0 labeled with the tie winner (class 2): counted correct.
        // Sample 1 labeled with the tie loser (class 1): counted wrong.
        let (_, correct) = m.evaluate(&params, &images, &[2, 1], 2);
        assert_eq!(correct, 1.0);
        // All-equal logits: winner is the last class.
        let mut flat = vec![0.0f32; m.param_count()];
        for c in 0..m.classes {
            flat[bias_at + c] = 2.0;
        }
        let (_, correct) = m.evaluate(&flat, &images, &[3, 0], 2);
        assert_eq!(correct, 1.0, "all-tie argmax must pick the last class");
    }

    #[test]
    fn fixed_seed_eval_predictions_are_stable() {
        // Satellite lock: predictions on a fixed-seed eval batch. The
        // correct-count is a pure function of the argmax over real-valued
        // logits; this pins the exact value so any future argmax change
        // that disturbs predictions fails loudly.
        let m = NativeModel::new(16, 7);
        let params = rand_params(&m, 31);
        let b = 32;
        let mut images = vec![0.0f32; b * m.px];
        Rng::seed_from(32).fill_normal(&mut images, 1.0);
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 7).collect();
        let (l1, c1) = m.evaluate(&params, &images, &labels, b);
        let (l2, c2) = m.evaluate(&params, &images, &labels, b);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(c1, c2);
        assert!((0.0..=b as f32).contains(&c1));
    }

    #[test]
    fn simd_tier_is_bit_identical_on_forward_and_backward() {
        // The linear model's tier dispatch covers the skip-zero pixel
        // loops (accumulate + scatter): sparse images with exact zeros,
        // loss + gradient + eval compared bit for bit across tiers.
        let scalar = NativeModel::new(9, 5);
        let simd = NativeModel::with_tier(9, 5, KernelTier::Simd);
        assert_eq!(simd.tier(), KernelTier::Simd);
        let params = rand_params(&scalar, 51);
        let b = 7;
        let mut images = vec![0.0f32; b * scalar.px];
        Rng::seed_from(52).fill_normal(&mut images, 1.0);
        for (i, v) in images.iter_mut().enumerate() {
            if i % 4 == 0 {
                *v = 0.0; // exercise the skip-zero branches on both tiers
            }
        }
        let labels: Vec<i32> = (0..b as i32).map(|i| i % 5).collect();

        let (loss_s, grad_s) = scalar.grad_step(&params, &images, &labels, b);
        let (loss_v, grad_v) = simd.grad_step(&params, &images, &labels, b);
        assert_eq!(loss_s.to_bits(), loss_v.to_bits());
        for (i, (a, bb)) in grad_s.iter().zip(&grad_v).enumerate() {
            assert_eq!(a.to_bits(), bb.to_bits(), "grad bit drift at {i}");
        }

        let (el_s, ec_s) = scalar.evaluate(&params, &images, &labels, b);
        let (el_v, ec_v) = simd.evaluate(&params, &images, &labels, b);
        assert_eq!(el_s.to_bits(), el_v.to_bits());
        assert_eq!(ec_s, ec_v);

        // The in-place optimizer dispatch matches the allocating scalar
        // reference on both tiers.
        let mom = vec![0.1f32; scalar.param_count()];
        let (p_ref, v_ref) = scalar.sgd_update(&params, &mom, &grad_s, 0.05, 0.9, 1e-4);
        for m in [&scalar, &simd] {
            let mut p = params.clone();
            let mut v = mom.clone();
            m.sgd_update_inplace(&mut p, &mut v, &grad_s, 0.05, 0.9, 1e-4);
            for i in 0..p.len() {
                assert_eq!(p_ref[i].to_bits(), p[i].to_bits());
                assert_eq!(v_ref[i].to_bits(), v[i].to_bits());
            }
        }
    }

    #[test]
    fn adam_moves_against_gradient() {
        let m = toy();
        let params = vec![1.0f32; m.param_count()];
        let m1 = vec![0.0f32; m.param_count()];
        let m2 = vec![0.0f32; m.param_count()];
        let g = vec![0.5f32; m.param_count()];
        let (p, mm, vv) = m.adam_update(&params, &m1, &m2, &g, 0.01, 1.0);
        for &x in &p {
            assert!(x < 1.0);
        }
        assert!(mm[0] > 0.0 && vv[0] > 0.0);
    }
}
