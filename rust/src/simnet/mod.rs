//! Simulated cluster substrate: network cost model + straggler model.
//!
//! The paper ran on 16 nodes with Titan X GPUs over 40 Gbps Ethernet (NCCL).
//! We do not have that testbed; its *observable behaviour* for every claim in
//! the paper is (a) how long a collective takes as a function of message
//! size and node count, and (b) how per-step compute time varies across
//! nodes (stragglers). Both are classic parametric models:
//!
//! * **Network** — α/β model per ring all-reduce: a fixed `handshake` per
//!   collective (the term the paper blames for PowerSGD's latency floor),
//!   plus `2(m-1)` hops each costing `latency + chunk/bandwidth` with
//!   `chunk = bytes/m` (standard ring reduce-scatter + all-gather).
//! * **Compute** — a base step time (calibrated from the paper: 4.6 s per
//!   epoch / 24.4 steps ≈ 188 ms) perturbed by a straggler model: none,
//!   shifted-exponential (the classic straggler distribution, cf. Dutta et
//!   al. 2018 [6]), or a deterministic slow node.
//!
//! `NetworkModel::paper_40gbps()` is calibrated so fully-sync SGD shows the
//! paper's measured 34.6 % communication-to-computation ratio at the
//! ResNet-18 message size (44.7 MB) — see EXPERIMENTS.md E8.
//!
//! All times are f64 seconds of *virtual* time.

use crate::topology::Topology;
use crate::util::rng::Rng;

/// α/β-model network with a per-collective handshake.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// fixed cost per collective call (rendezvous / NCCL channel setup)
    pub handshake_s: f64,
    /// per-hop latency (one neighbour exchange in the ring)
    pub latency_s: f64,
    /// link bandwidth in bytes/second
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    /// Calibrated to the paper's testbed (40 Gbps Ethernet, NCCL ring).
    /// With m=16 and 44.68 MB messages this yields ≈ 65 ms per all-reduce,
    /// i.e. 34.6 % of the 188 ms compute step — the paper's sync-SGD ratio.
    pub fn paper_40gbps() -> Self {
        Self {
            handshake_s: 30e-3,
            latency_s: 0.5e-3,
            bandwidth_bps: 5.0e9, // 40 Gbps
        }
    }

    /// The "slow interconnect" the paper predicts would magnify the win.
    pub fn slow_10gbps() -> Self {
        Self {
            handshake_s: 45e-3,
            latency_s: 1.0e-3,
            bandwidth_bps: 1.25e9, // 10 Gbps
        }
    }

    /// An idealized fast fabric (for ablations).
    pub fn fast_fabric() -> Self {
        Self { handshake_s: 2e-3, latency_s: 0.05e-3, bandwidth_bps: 25.0e9 }
    }

    /// Ring all-reduce of `bytes` over `m` nodes:
    /// handshake + 2(m-1) * (latency + bytes/(m * BW)).
    pub fn allreduce_time(&self, bytes: usize, m: usize) -> f64 {
        assert!(m >= 1);
        if m == 1 {
            return 0.0;
        }
        let hops = 2 * (m - 1);
        let chunk = bytes as f64 / m as f64;
        self.handshake_s + hops as f64 * (self.latency_s + chunk / self.bandwidth_bps)
    }

    /// Parameter-server exchange (up + down) — used by the PS ablation.
    pub fn ps_exchange_time(&self, bytes: usize, m: usize) -> f64 {
        // m clients share the server's ingress: serialized on the bottleneck
        // link, one handshake per round.
        self.handshake_s + 2.0 * (self.latency_s + (bytes as f64 * m as f64) / self.bandwidth_bps)
    }

    /// Hierarchical two-level all-reduce (topology axis, DESIGN.md §8):
    /// ring within a group of `group_size`, ring across the `groups` leader
    /// nodes, plus the leader→members broadcast — `group_size - 1` full
    /// messages serialized on the leader's NIC, matching the per-link byte
    /// accounting (`Topology::neighbor_bytes`) and the same serialization
    /// model `gossip_time` uses. Each ring phase pays its own handshake
    /// (two rendezvous groups).
    pub fn hier_allreduce_time(&self, bytes: usize, group_size: usize, groups: usize) -> f64 {
        let mut t = self.allreduce_time(bytes, group_size.max(1))
            + self.allreduce_time(bytes, groups.max(1));
        if group_size > 1 {
            t += (group_size - 1) as f64 * (self.latency_s + bytes as f64 / self.bandwidth_bps);
        }
        t
    }

    /// Binary-tree reduce + broadcast: `2·⌈log2 m⌉` *full-message* hops
    /// after one handshake. No chunking, so the tree is latency-optimal but
    /// bandwidth-suboptimal — the opposite trade to the ring.
    pub fn tree_allreduce_time(&self, bytes: usize, m: usize) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        let levels = usize::BITS - (m - 1).leading_zeros(); // ceil(log2 m)
        self.handshake_s
            + 2.0 * levels as f64 * (self.latency_s + bytes as f64 / self.bandwidth_bps)
    }

    /// k-regular gossip exchange: each node sends its full message to
    /// `degree` neighbors, serialized on its own NIC — and crucially with
    /// **no global handshake**: neighbors rendezvous pairwise, the cluster
    /// never does. This is the term the paper blames for PowerSGD's latency
    /// floor, removed entirely.
    pub fn gossip_time(&self, bytes: usize, degree: usize) -> f64 {
        degree as f64 * (self.latency_s + bytes as f64 / self.bandwidth_bps)
    }

    /// State fetch for a worker rejoining after a crash (DESIGN.md §11):
    /// one full anchor message from a live peer, serialized on that peer's
    /// NIC — a point-to-point transfer, so no collective handshake. Charged
    /// as blocked-communication time to the rejoiner only.
    pub fn rejoin_fetch_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// All-gather of per-node `bytes` (PowerSGD's second phase uses this
    /// shape; cost equals a ring all-gather = (m-1) hops).
    pub fn allgather_time(&self, bytes: usize, m: usize) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        let hops = m - 1;
        let chunk = bytes as f64 / m as f64;
        self.handshake_s + hops as f64 * (self.latency_s + chunk / self.bandwidth_bps)
    }
}

/// Per-worker compute-time variability.
#[derive(Clone, Debug)]
pub enum StragglerModel {
    /// all workers identical
    None,
    /// step time = base * (1 + Exp(mean = scale)) — shifted exponential
    ShiftedExp { scale: f64 },
    /// worker `node` runs `factor`x slower, deterministically
    SlowNode { node: usize, factor: f64 },
    /// uniform jitter in [1-jitter, 1+jitter]
    UniformJitter { jitter: f64 },
}

impl StragglerModel {
    /// Canonical config spelling (round-trips through the `straggler`
    /// config key): `none` | `exp:scale` | `slow:node:factor` | `jitter:j`.
    pub fn spec(&self) -> String {
        match self {
            StragglerModel::None => "none".to_string(),
            StragglerModel::ShiftedExp { scale } => format!("exp:{scale}"),
            StragglerModel::SlowNode { node, factor } => format!("slow:{node}:{factor}"),
            StragglerModel::UniformJitter { jitter } => format!("jitter:{jitter}"),
        }
    }

    /// Multiplier applied to the base step time for `worker` at this draw.
    pub fn factor(&self, worker: usize, rng: &mut Rng) -> f64 {
        match self {
            StragglerModel::None => 1.0,
            StragglerModel::ShiftedExp { scale } => 1.0 + rng.next_exp(*scale),
            StragglerModel::SlowNode { node, factor } => {
                if worker == *node {
                    *factor
                } else {
                    1.0
                }
            }
            StragglerModel::UniformJitter { jitter } => {
                1.0 + jitter * (2.0 * rng.next_f64() - 1.0)
            }
        }
    }
}

/// Compute-time model: base seconds per local step, modulated by stragglers.
#[derive(Clone, Debug)]
pub struct ComputeModel {
    /// seconds per local mini-batch step on an unperturbed node
    pub base_step_s: f64,
    /// per-worker variability applied on top of the base time
    pub straggler: StragglerModel,
}

impl ComputeModel {
    /// Paper calibration: 4.6 s/epoch ÷ (50 000 / (128·16)) steps ≈ 188 ms.
    pub fn paper_resnet18() -> Self {
        Self { base_step_s: 0.188, straggler: StragglerModel::None }
    }

    /// One local step's virtual duration for `worker` (consumes a draw
    /// from `rng` only for the stochastic straggler models).
    pub fn step_time(&self, worker: usize, rng: &mut Rng) -> f64 {
        self.base_step_s * self.straggler.factor(worker, rng)
    }
}

/// Everything the timing side of an experiment needs.
#[derive(Clone, Debug)]
pub struct ClusterModel {
    /// cluster size m
    pub workers: usize,
    /// wire cost model
    pub net: NetworkModel,
    /// per-step compute cost model
    pub compute: ComputeModel,
    /// bytes per full-model/full-gradient message. Decoupled from the local
    /// numeric model so runtime figures keep the paper's ResNet-18 scale
    /// (44.68 MB) while numerics run on the scaled-down CNN — see DESIGN.md §3.
    pub message_bytes: usize,
    /// the communication graph both planes run over (DESIGN.md §8)
    pub topology: Topology,
}

impl ClusterModel {
    /// The paper's testbed: 16 nodes, 40 Gbps, ResNet-18 messages.
    pub fn paper_16node() -> Self {
        Self {
            workers: 16,
            net: NetworkModel::paper_40gbps(),
            compute: ComputeModel::paper_resnet18(),
            message_bytes: 11_173_962 * 4, // ResNet-18 params * f32
            topology: Topology::ring(16),
        }
    }

    /// Ring all-reduce cost at the full message size (the seed's formula,
    /// kept verbatim for the golden reference loops).
    pub fn allreduce_time(&self) -> f64 {
        self.net.allreduce_time(self.message_bytes, self.workers)
    }

    /// Cost of one full-message collective on the configured topology
    /// (equals [`ClusterModel::allreduce_time`] on the ring).
    pub fn collective_time(&self) -> f64 {
        self.topology.collective_time(&self.net, self.message_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn paper_calibration_hits_34_6_percent() {
        let c = ClusterModel::paper_16node();
        let ratio = c.allreduce_time() / c.compute.base_step_s;
        // Paper: communication-to-computation ratio 34.6 % for sync SGD.
        assert!((ratio - 0.346).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn allreduce_monotonic_in_bytes_and_includes_handshake() {
        let net = NetworkModel::paper_40gbps();
        let t1 = net.allreduce_time(1_000_000, 16);
        let t2 = net.allreduce_time(10_000_000, 16);
        assert!(t2 > t1);
        assert!(t1 >= net.handshake_s);
    }

    #[test]
    fn allreduce_single_node_is_free() {
        let net = NetworkModel::paper_40gbps();
        assert_eq!(net.allreduce_time(1 << 20, 1), 0.0);
    }

    #[test]
    fn handshake_dominates_small_messages() {
        // The paper's PowerSGD observation: even 243x compression cannot
        // beat the handshake floor.
        let net = NetworkModel::paper_40gbps();
        let full = net.allreduce_time(44_700_000, 16);
        let tiny = net.allreduce_time(44_700_000 / 243, 16);
        assert!(tiny > 0.4 * full, "compression wins too much: {tiny} vs {full}");
        assert!(tiny >= net.handshake_s);
    }

    #[test]
    fn slow_node_factor() {
        let s = StragglerModel::SlowNode { node: 2, factor: 3.0 };
        let mut rng = Rng::seed_from(1);
        assert_eq!(s.factor(2, &mut rng), 3.0);
        assert_eq!(s.factor(0, &mut rng), 1.0);
    }

    #[test]
    fn shifted_exp_is_always_slower_than_base() {
        property("shifted exp >= 1", 200, |g| {
            let s = StragglerModel::ShiftedExp { scale: g.f64_in(0.01, 2.0) };
            let f = s.factor(g.usize_in(0, 15), g.rng());
            assert!(f >= 1.0);
        });
    }

    #[test]
    fn uniform_jitter_bounded() {
        property("jitter in band", 200, |g| {
            let j = g.f64_in(0.0, 0.5);
            let s = StragglerModel::UniformJitter { jitter: j };
            let f = s.factor(0, g.rng());
            assert!(f >= 1.0 - j - 1e-12 && f <= 1.0 + j + 1e-12);
        });
    }

    #[test]
    fn topology_costs_rank_as_designed() {
        // At the paper's message size the chunked ring beats the unchunked
        // tree and the two-handshake hierarchy, while a low-degree gossip
        // exchange (no handshake, few hops) undercuts them all.
        let net = NetworkModel::paper_40gbps();
        let bytes = 44_700_000;
        let ring = net.allreduce_time(bytes, 16);
        let hier = net.hier_allreduce_time(bytes, 4, 4);
        let tree = net.tree_allreduce_time(bytes, 16);
        let gossip = net.gossip_time(bytes, 4);
        assert!(gossip < ring, "gossip {gossip} vs ring {ring}");
        assert!(ring < hier, "ring {ring} vs hier {hier}");
        assert!(ring < tree, "ring {ring} vs tree {tree}");
        // degenerate sizes are free
        assert_eq!(net.tree_allreduce_time(bytes, 1), 0.0);
        assert_eq!(net.gossip_time(bytes, 0), 0.0);
        // the topology-aware cluster cost equals the seed formula on a ring
        let c = ClusterModel::paper_16node();
        assert_eq!(c.collective_time(), c.allreduce_time());
    }

    #[test]
    fn ring_beats_ps_at_scale() {
        let net = NetworkModel::paper_40gbps();
        let bytes = 44_700_000;
        assert!(net.allreduce_time(bytes, 16) < net.ps_exchange_time(bytes, 16));
    }
}
