//! End-to-end integration tests.
//!
//! Two tiers:
//!
//! * **CLI smoke** (`cli` module, always built): drives the compiled
//!   `olsgd` binary end to end on the native backend — config parsing
//!   (including the `--fault` schedule round-trip, DESIGN.md §11), a real
//!   training run, and the result-file format. This is the tier-1 path a
//!   sealed machine exercises on every `cargo test`.
//! * **PJRT artifacts** (`pjrt_artifacts` module): python-lowered HLO →
//!   PJRT execution → Rust coordinator substrates. Requires the `pjrt`
//!   feature and `make artifacts`; compiled out otherwise (the
//!   artifact-free kernel equivalents live in runtime::tests and
//!   tests/algorithms.rs).

/// End-to-end runs of the compiled binary (native backend; no artifacts).
mod cli {
    use std::path::PathBuf;
    use std::process::Command;

    use olsgd::util::json::Json;

    /// A fresh scratch directory under the system temp dir.
    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("olsgd_it_{}_{}", tag, std::process::id()));
        // Stale leftovers from a crashed prior run are fine to clobber.
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("creating scratch dir");
        dir
    }

    fn olsgd() -> Command {
        Command::new(env!("CARGO_BIN_EXE_olsgd"))
    }

    /// The headline smoke: `olsgd train` with a `--fault` schedule must
    /// parse, run on the native backend, and emit a result JSON whose
    /// fault trace and survivor series reflect the schedule — the full
    /// CLI → config → engine → metrics round-trip in tier-1.
    #[test]
    fn train_round_trips_a_fault_schedule_through_the_cli() {
        let out = scratch("fault");
        let status = olsgd()
            .args([
                "train",
                "--quiet",
                "--set", "model=linear",
                "--set", "workers=4",
                "--set", "train_n=256",
                "--set", "test_n=100",
                "--set", "epochs=3",
                "--set", "tau=2",
                "--set", "algo=overlap-m",
                "--fault", "crash@2:1",
                "--fault", "rejoin@3:1",
                "--out", out.to_str().unwrap(),
            ])
            .status()
            .expect("spawning olsgd");
        assert!(status.success(), "olsgd train failed");

        let json_path = out.join("overlap-m_tau2.json");
        let text = std::fs::read_to_string(&json_path)
            .unwrap_or_else(|e| panic!("missing {json_path:?}: {e}"));
        let j = Json::parse(&text).expect("result JSON must parse");
        let trace = j.get("fault_trace").unwrap();
        let trace = trace.as_arr().unwrap();
        assert_eq!(trace.len(), 2, "both fault events must be traced");
        assert_eq!(
            trace[0].get("event").unwrap().as_str().unwrap(),
            "crash@2:1"
        );
        assert_eq!(
            trace[1].get("event").unwrap().as_str().unwrap(),
            "rejoin@3:1"
        );
        let survivors = j.get("survivors").unwrap();
        assert_eq!(survivors.as_arr().unwrap().len(), 2, "3 -> 4 survivor points");
        let acc = j.get("final_acc").unwrap().as_f64().unwrap();
        assert!(acc.is_finite());
        let _ = std::fs::remove_dir_all(&out);
    }

    /// A malformed fault spec is a pre-run, non-zero-exit error with the
    /// offending spec named — never a silent default.
    #[test]
    fn cli_rejects_a_malformed_fault_spec() {
        let output = olsgd()
            .args(["train", "--quiet", "--fault", "crash@two:1"])
            .output()
            .expect("spawning olsgd");
        assert!(!output.status.success(), "malformed --fault must fail");
        let stderr = String::from_utf8_lossy(&output.stderr);
        assert!(
            stderr.contains("crash@two:1"),
            "error must name the bad spec: {stderr}"
        );
    }

    /// Fault-free CLI smoke on the threads backend: the same command the
    /// README quickstart shows, end to end.
    #[test]
    fn train_smoke_runs_on_the_threads_backend() {
        let out = scratch("threads");
        let status = olsgd()
            .args([
                "train",
                "--quiet",
                "--set", "model=linear",
                "--set", "workers=3",
                "--set", "train_n=192",
                "--set", "test_n=100",
                "--set", "epochs=2",
                "--execution", "threads",
                "--out", out.to_str().unwrap(),
            ])
            .status()
            .expect("spawning olsgd");
        assert!(status.success(), "threads-backend train failed");
        assert!(out.join("overlap-m_tau2.json").exists());
        let _ = std::fs::remove_dir_all(&out);
    }
}

/// Integration tests over the real AOT artifacts: python-lowered HLO ->
/// PJRT execution -> Rust coordinator substrates. Requires the `pjrt`
/// feature and `make artifacts`.
#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use std::path::Path;

    use olsgd::data::{self, GenConfig, PX};
    use olsgd::model::{init_params, vecmath};
    use olsgd::runtime::Runtime;
    use olsgd::util::proptest::assert_close;
    use olsgd::util::rng::Rng;

    fn runtime() -> Runtime {
        Runtime::new(Path::new("artifacts")).expect("run `make artifacts` before cargo test")
    }

    #[test]
    fn manifest_layouts_are_consistent_for_all_models() {
        let rt = runtime();
        assert!(!rt.manifest.models.is_empty());
        for (name, m) in &rt.manifest.models {
            m.check_layout().unwrap_or_else(|e| panic!("bad layout for {name}: {e}"));
            for tag in ["train_step", "grad_step", "eval", "pullback", "anchor", "update"] {
                assert!(m.modules.contains_key(tag), "{name} missing module {tag}");
            }
        }
    }

    #[test]
    fn train_step_equals_grad_step_plus_update() {
        // The fused train_step artifact must compose exactly from the
        // grad_step and update artifacts (same kernels, same order).
        let rt = runtime();
        let m = rt.load_model("cnn").unwrap();
        let params = init_params(&m.manifest, 3);
        let mom = vec![0.01f32; m.n];
        let gen = GenConfig::default();
        let ds = data::generate(9, 64, "train", &gen);
        let images = ds.images[..m.train_batch * PX].to_vec();
        let labels = ds.labels[..m.train_batch].to_vec();

        let (p1, m1, loss1) = m
            .train_step(&params, &mom, &images, &labels, 0.05, 0.9, 1e-4)
            .unwrap();
        let (loss2, g) = m.grad_step(&params, &images, &labels).unwrap();
        let (p2, m2) = m.sgd_update(&params, &mom, &g, 0.05, 0.9, 1e-4).unwrap();

        assert!((loss1 - loss2).abs() < 1e-5, "{loss1} vs {loss2}");
        assert_close(&p1, &p2, 1e-4, 1e-6);
        assert_close(&m1, &m2, 1e-4, 1e-6);
    }

    #[test]
    fn pullback_artifact_matches_rust_vecmath() {
        let rt = runtime();
        let m = rt.load_model("cnn").unwrap();
        let mut rng = Rng::seed_from(5);
        let mut x = vec![0.0f32; m.n];
        let mut z = vec![0.0f32; m.n];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut z, 1.0);
        for alpha in [0.0f32, 0.5, 0.6, 1.0] {
            let got = m.pullback(&x, &z, alpha).unwrap();
            let mut want = x.clone();
            vecmath::pullback_inplace(&mut want, &z, alpha);
            assert_close(&got, &want, 1e-5, 1e-6);
        }
    }

    #[test]
    fn anchor_artifact_matches_rust_vecmath() {
        let rt = runtime();
        let m = rt.load_model("cnn").unwrap();
        let mut rng = Rng::seed_from(6);
        let mut z = vec![0.0f32; m.n];
        let mut v = vec![0.0f32; m.n];
        let mut avg = vec![0.0f32; m.n];
        rng.fill_normal(&mut z, 1.0);
        rng.fill_normal(&mut v, 0.3);
        rng.fill_normal(&mut avg, 1.0);
        for beta in [0.0f32, 0.7] {
            let (gz, gv) = m.anchor_update(&z, &v, &avg, beta).unwrap();
            let mut wz = z.clone();
            let mut wv = v.clone();
            vecmath::anchor_update_inplace(&mut wz, &mut wv, &avg, beta);
            assert_close(&gz, &wz, 1e-5, 1e-6);
            assert_close(&gv, &wv, 1e-5, 1e-6);
        }
    }

    #[test]
    fn evaluate_set_is_a_probability() {
        let rt = runtime();
        let m = rt.load_model("cnn").unwrap();
        let params = init_params(&m.manifest, 1);
        let gen = GenConfig::default();
        let test = data::generate(2, 200, "test", &gen);
        let (loss, acc) = m.evaluate_set(&params, &test.images, &test.labels).unwrap();
        assert!(loss > 0.0 && loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
        // random-init accuracy should be near chance
        assert!(acc < 0.5, "untrained model suspiciously good: {acc}");
    }

    #[test]
    fn repeated_training_steps_reduce_loss_mlp() {
        let rt = runtime();
        let m = rt.load_model("mlp").unwrap();
        let mut params = init_params(&m.manifest, 7);
        let mut mom = vec![0.0f32; m.n];
        let gen = GenConfig::default();
        let ds = data::generate(11, 64, "train", &gen);
        let images = ds.images[..m.train_batch * PX].to_vec();
        let labels = ds.labels[..m.train_batch].to_vec();
        let mut first = 0.0;
        let mut last = 0.0;
        for i in 0..10 {
            let (p, mo, loss) = m
                .train_step(&params, &mom, &images, &labels, 0.05, 0.9, 0.0)
                .unwrap();
            params = p;
            mom = mo;
            if i == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(
            last < first * 0.8,
            "loss did not drop fitting one batch: {first} -> {last}"
        );
    }

    #[test]
    fn scalar_hyperparams_change_behaviour() {
        // lr=0 must be a no-op on params; mu=0 must zero momentum influence.
        let rt = runtime();
        let m = rt.load_model("cnn").unwrap();
        let params = init_params(&m.manifest, 3);
        let mom = vec![0.5f32; m.n];
        let mut g = vec![0.0f32; m.n];
        Rng::seed_from(8).fill_normal(&mut g, 0.1);

        let (p0, _) = m.sgd_update(&params, &mom, &g, 0.0, 0.9, 0.0).unwrap();
        assert_close(&p0, &params, 0.0, 0.0);

        let (p1, v1) = m.sgd_update(&params, &mom, &g, 0.1, 0.0, 0.0).unwrap();
        assert_close(&v1, &g, 1e-6, 1e-7);
        let want: Vec<f32> = params.iter().zip(&g).map(|(&p, &gi)| p - 0.1 * gi).collect();
        assert_close(&p1, &want, 1e-5, 1e-7);
    }
}
