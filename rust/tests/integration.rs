//! Integration tests over the real AOT artifacts: python-lowered HLO ->
//! PJRT execution -> Rust coordinator substrates. Requires the `pjrt`
//! feature and `make artifacts`; compiled out otherwise (the artifact-free
//! equivalents live in runtime::tests and tests/algorithms.rs).
#![cfg(feature = "pjrt")]

use std::path::Path;

use olsgd::data::{self, GenConfig, PX};
use olsgd::model::{init_params, vecmath};
use olsgd::runtime::Runtime;
use olsgd::util::proptest::assert_close;
use olsgd::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::new(Path::new("artifacts")).expect("run `make artifacts` before cargo test")
}

#[test]
fn manifest_layouts_are_consistent_for_all_models() {
    let rt = runtime();
    assert!(!rt.manifest.models.is_empty());
    for (name, m) in &rt.manifest.models {
        m.check_layout().unwrap_or_else(|e| panic!("bad layout for {name}: {e}"));
        for tag in ["train_step", "grad_step", "eval", "pullback", "anchor", "update"] {
            assert!(m.modules.contains_key(tag), "{name} missing module {tag}");
        }
    }
}

#[test]
fn train_step_equals_grad_step_plus_update() {
    // The fused train_step artifact must compose exactly from the grad_step
    // and update artifacts (same kernels, same order).
    let rt = runtime();
    let m = rt.load_model("cnn").unwrap();
    let params = init_params(&m.manifest, 3);
    let mom = vec![0.01f32; m.n];
    let gen = GenConfig::default();
    let ds = data::generate(9, 64, "train", &gen);
    let images = ds.images[..m.train_batch * PX].to_vec();
    let labels = ds.labels[..m.train_batch].to_vec();

    let (p1, m1, loss1) = m
        .train_step(&params, &mom, &images, &labels, 0.05, 0.9, 1e-4)
        .unwrap();
    let (loss2, g) = m.grad_step(&params, &images, &labels).unwrap();
    let (p2, m2) = m.sgd_update(&params, &mom, &g, 0.05, 0.9, 1e-4).unwrap();

    assert!((loss1 - loss2).abs() < 1e-5, "{loss1} vs {loss2}");
    assert_close(&p1, &p2, 1e-4, 1e-6);
    assert_close(&m1, &m2, 1e-4, 1e-6);
}

#[test]
fn pullback_artifact_matches_rust_vecmath() {
    let rt = runtime();
    let m = rt.load_model("cnn").unwrap();
    let mut rng = Rng::seed_from(5);
    let mut x = vec![0.0f32; m.n];
    let mut z = vec![0.0f32; m.n];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut z, 1.0);
    for alpha in [0.0f32, 0.5, 0.6, 1.0] {
        let got = m.pullback(&x, &z, alpha).unwrap();
        let mut want = x.clone();
        vecmath::pullback_inplace(&mut want, &z, alpha);
        assert_close(&got, &want, 1e-5, 1e-6);
    }
}

#[test]
fn anchor_artifact_matches_rust_vecmath() {
    let rt = runtime();
    let m = rt.load_model("cnn").unwrap();
    let mut rng = Rng::seed_from(6);
    let mut z = vec![0.0f32; m.n];
    let mut v = vec![0.0f32; m.n];
    let mut avg = vec![0.0f32; m.n];
    rng.fill_normal(&mut z, 1.0);
    rng.fill_normal(&mut v, 0.3);
    rng.fill_normal(&mut avg, 1.0);
    for beta in [0.0f32, 0.7] {
        let (gz, gv) = m.anchor_update(&z, &v, &avg, beta).unwrap();
        let mut wz = z.clone();
        let mut wv = v.clone();
        vecmath::anchor_update_inplace(&mut wz, &mut wv, &avg, beta);
        assert_close(&gz, &wz, 1e-5, 1e-6);
        assert_close(&gv, &wv, 1e-5, 1e-6);
    }
}

#[test]
fn evaluate_set_is_a_probability() {
    let rt = runtime();
    let m = rt.load_model("cnn").unwrap();
    let params = init_params(&m.manifest, 1);
    let gen = GenConfig::default();
    let test = data::generate(2, 200, "test", &gen);
    let (loss, acc) = m.evaluate_set(&params, &test.images, &test.labels).unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
    // random-init accuracy should be near chance
    assert!(acc < 0.5, "untrained model suspiciously good: {acc}");
}

#[test]
fn repeated_training_steps_reduce_loss_mlp() {
    let rt = runtime();
    let m = rt.load_model("mlp").unwrap();
    let mut params = init_params(&m.manifest, 7);
    let mut mom = vec![0.0f32; m.n];
    let gen = GenConfig::default();
    let ds = data::generate(11, 64, "train", &gen);
    let images = ds.images[..m.train_batch * PX].to_vec();
    let labels = ds.labels[..m.train_batch].to_vec();
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..10 {
        let (p, mo, loss) = m
            .train_step(&params, &mom, &images, &labels, 0.05, 0.9, 0.0)
            .unwrap();
        params = p;
        mom = mo;
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first * 0.8,
        "loss did not drop fitting one batch: {first} -> {last}"
    );
}

#[test]
fn scalar_hyperparams_change_behaviour() {
    // lr=0 must be a no-op on params; mu=0 must zero momentum influence.
    let rt = runtime();
    let m = rt.load_model("cnn").unwrap();
    let params = init_params(&m.manifest, 3);
    let mom = vec![0.5f32; m.n];
    let mut g = vec![0.0f32; m.n];
    Rng::seed_from(8).fill_normal(&mut g, 0.1);

    let (p0, _) = m.sgd_update(&params, &mom, &g, 0.0, 0.9, 0.0).unwrap();
    assert_close(&p0, &params, 0.0, 0.0);

    let (p1, v1) = m.sgd_update(&params, &mom, &g, 0.1, 0.0, 0.0).unwrap();
    assert_close(&v1, &g, 1e-6, 1e-7);
    let want: Vec<f32> = params.iter().zip(&g).map(|(&p, &gi)| p - 0.1 * gi).collect();
    assert_close(&p1, &want, 1e-5, 1e-7);
}
