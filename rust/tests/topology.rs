//! Topology subsystem lockdown (DESIGN.md §8, EXPERIMENTS.md E10).
//!
//! Three layers of guarantees:
//!
//! * **Data plane** — property tests: the tree and hierarchical all-reduce
//!   schedules equal `vecmath::mean` on random shapes (including vectors
//!   shorter than the worker count); push-sum gossip converges to the exact
//!   global mean on random connected k-regular graphs; every generated
//!   mixing matrix is doubly stochastic; and the push-sum weight correction
//!   keeps random *partial-participation* rounds exact (the
//!   column-stochastic regime where naive averaging is biased).
//! * **End-to-end wiring** — every exact topology drives the real
//!   algorithms, with the per-worker `neighbor_bytes` accounting engaged
//!   and the gossip graph rejected loudly outside `overlap-gossip`.
//! * **E10's decentralized claim** — on the paper_16node cluster with a 3×
//!   straggler, `overlap-gossip` blocks strictly less per round than
//!   `overlap` on the ring at equal τ while landing within 5 % of its final
//!   eval loss; with no straggler both hide the wire completely.

use olsgd::config::{Algo, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::fault::AliveSet;
use olsgd::metrics::TrainLog;
use olsgd::model::vecmath;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;
use olsgd::topology::Topology;
use olsgd::util::proptest::{assert_close, property};

// ---------------------------------------------------------------------------
// Data plane
// ---------------------------------------------------------------------------

#[test]
fn property_tree_and_hier_allreduce_equal_mean() {
    property("tree/hier == mean", 120, |g| {
        let m = g.usize_in(1, 16);
        // Every third case forces n < m (zero-size ring chunks inside the
        // hierarchy's intra-group rings).
        let n = if g.usize_in(0, 2) == 0 { g.usize_in(1, m) } else { g.usize_in(1, 400) };
        let inputs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 4.0)).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let want = vecmath::mean(&refs);

        let mut tree = inputs.clone();
        Topology::tree(m).allreduce_mean(&mut tree);
        for b in &tree {
            assert_close(b, &want, 1e-4, 1e-5);
        }

        let groups = g.usize_in(1, 8);
        let mut hier = inputs.clone();
        Topology::hier(m, groups).allreduce_mean(&mut hier);
        for b in &hier {
            assert_close(b, &want, 1e-4, 1e-5);
        }
    });
}

#[test]
fn property_pushsum_gossip_converges_to_the_exact_global_mean() {
    property("push-sum -> global mean", 60, |g| {
        let m = g.usize_in(2, 16);
        let degree = g.usize_in(1, m - 1);
        let topo = Topology::gossip(m, degree, g.rng().next_u64()).unwrap();
        let n = g.usize_in(1, 32);
        let inputs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 3.0)).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let want = vecmath::mean(&refs);

        let mut values = inputs.clone();
        let mut weights = vec![1.0f64; m];
        // Worst measured case (m=16 cycle) converges in ~250 rounds; 600 is
        // a comfortable budget and most graphs exit early.
        for _ in 0..600 {
            let (v, w) = topo.gossip_mix(&values, &weights);
            values = v;
            weights = w;
            let worst = estimate_error(&values, &weights, &want);
            if worst < 2e-5 {
                break;
            }
        }
        for (v, &w) in values.iter().zip(&weights) {
            let est: Vec<f32> = v.iter().map(|&x| x / w as f32).collect();
            assert_close(&est, &want, 1e-4, 1e-4);
        }
    });
}

fn estimate_error(values: &[Vec<f32>], weights: &[f64], want: &[f32]) -> f32 {
    let mut worst = 0.0f32;
    for (v, &w) in values.iter().zip(weights) {
        for (i, &x) in v.iter().enumerate() {
            worst = worst.max((x / w as f32 - want[i]).abs());
        }
    }
    worst
}

#[test]
fn property_every_mixing_matrix_is_doubly_stochastic() {
    property("W doubly stochastic", 120, |g| {
        let m = g.usize_in(1, 16);
        let topo = match g.usize_in(0, 3) {
            0 => Topology::ring(m),
            1 => Topology::hier(m, g.usize_in(1, 8)),
            2 => Topology::tree(m),
            _ if m >= 2 => {
                Topology::gossip(m, g.usize_in(1, m - 1), g.rng().next_u64()).unwrap()
            }
            _ => Topology::ring(m),
        };
        let w = topo.mixing_matrix();
        assert_eq!(w.len(), m);
        for row in &w {
            assert!((row.iter().sum::<f64>() - 1.0).abs() < 1e-9, "row sum != 1");
            assert!(row.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
        for j in 0..m {
            let col: f64 = w.iter().map(|row| row[j]).sum();
            assert!((col - 1.0).abs() < 1e-9, "col sum != 1");
        }
    });
}

/// The push-sum correction at work: with random per-round edge dropout the
/// mixing matrix is only column-stochastic (weights drift from 1), yet the
/// de-biased estimates still reach the exact global mean — while the naive
/// (uncorrected) values are measurably biased. This is the invariant the
/// planned partial-participation scenarios build on (E10).
#[test]
fn property_pushsum_weights_keep_dropout_rounds_exact() {
    property("push-sum dropout exactness", 20, |g| {
        let m = g.usize_in(6, 14);
        let topo = Topology::gossip(m, g.usize_in(3, 5), g.rng().next_u64()).unwrap();
        let n = g.usize_in(1, 8);
        let inputs: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 2.0)).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let want = vecmath::mean(&refs);

        let mut values = inputs.clone();
        let mut weights = vec![1.0f64; m];
        let mut weights_drifted = false;
        for _ in 0..800 {
            let active: Vec<Vec<usize>> =
                (0..m).map(|j| g.subset(topo.neighbors(j), 0.7)).collect();
            let (v, w) = topo.gossip_mix_with(&values, &weights, &active);
            values = v;
            weights = w;
            if weights.iter().any(|&w| (w - 1.0).abs() > 1e-6) {
                weights_drifted = true;
            }
        }
        assert!(weights_drifted, "dropout must engage the weight correction");
        for (v, &w) in values.iter().zip(&weights) {
            let est: Vec<f32> = v.iter().map(|&x| x / w as f32).collect();
            assert_close(&est, &want, 1e-4, 1e-4);
        }
    });
}

/// Sampled-cohort framing of the de-biased gossip mix (DESIGN.md §14):
/// over an arbitrary cohort drawn with `Gen::subset` the alive-aware
/// push-sum round conserves cohort mass and push-sum weight exactly and
/// delivers nothing to non-participants — and whenever the drawn cohort is
/// the full population it must be *bit-identical* to the dense
/// `gossip_mix` (the seam an N == k population run rides every round).
#[test]
fn property_sampled_cohort_gossip_mix_is_exact_and_dense_on_full_cohort() {
    property("sampled-cohort gossip mix", 120, |g| {
        let m = g.usize_in(2, 12);
        let topo = Topology::gossip(m, g.usize_in(1, m - 1), g.rng().next_u64()).unwrap();
        let n = g.usize_in(1, 24);
        let all: Vec<usize> = (0..m).collect();
        let mut cohort = g.subset(&all, 0.8);
        if cohort.is_empty() {
            cohort.push(g.usize_in(0, m - 1));
        }
        let full = cohort.len() == m;
        let mut alive = vec![false; m];
        for &w in &cohort {
            alive[w] = true;
        }
        let aset = AliveSet::with_alive(alive);
        let values: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(n, 3.0)).collect();
        let weights = vec![1.0f64; m];
        let (out, w_out) = topo.gossip_mix_alive(&values, &weights, &aset);
        if full {
            let (dense, dense_w) = topo.gossip_mix(&values, &weights);
            for (a, b) in out.iter().zip(&dense) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "full cohort must be the dense mix bit-for-bit (m={m})"
                    );
                }
            }
            for (a, b) in w_out.iter().zip(&dense_w) {
                assert_eq!(a.to_bits(), b.to_bits(), "full-cohort weights drifted (m={m})");
            }
        }
        // Cohort mass (per dimension) and total push-sum weight conserved.
        for d in 0..n {
            let before: f64 = cohort.iter().map(|&j| values[j][d] as f64).sum();
            let after: f64 = out.iter().map(|o| o[d] as f64).sum();
            assert!(
                (before - after).abs() <= 1e-3 * (1.0 + before.abs()),
                "cohort mass leaked at dim {d} (m={m}, cohort={})",
                cohort.len()
            );
        }
        let kn = cohort.len() as f64;
        let total_w: f64 = w_out.iter().sum();
        assert!(
            (total_w - kn).abs() < 1e-5 * kn.max(1.0),
            "push-sum weight leaked: {total_w} vs {kn}"
        );
        // Non-participants receive exactly nothing.
        for i in 0..m {
            if !aset.is_alive(i) {
                assert_eq!(w_out[i], 0.0, "non-participant {i} got weight");
                assert!(out[i].iter().all(|&x| x == 0.0), "non-participant {i} got mass");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// End-to-end wiring
// ---------------------------------------------------------------------------

fn native_run(cfg: &ExperimentConfig) -> TrainLog {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    run_experiment(&rt, cfg, &train, &test).unwrap()
}

fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 4;
    cfg.epochs = 2.0;
    cfg.train_n = 256; // 64/shard -> 2 steps/epoch
    cfg.test_n = 100;
    cfg.eval_every = 2.0;
    cfg
}

#[test]
fn exact_topologies_drive_the_real_algorithms_end_to_end() {
    for topology in ["hier", "tree"] {
        for algo in [Algo::Local, Algo::OverlapM, Algo::Sync] {
            let mut cfg = tiny_cfg();
            cfg.algo = algo;
            cfg.topology = topology.into();
            cfg.hier_groups = 2;
            let log = native_run(&cfg);
            assert!(log.final_loss().is_finite(), "{algo:?} on {topology} diverged");
            assert!(log.steps > 0);
            // the per-worker accounting is engaged off the ring ...
            assert_eq!(log.neighbor_bytes.len(), 4);
            assert!(
                log.neighbor_bytes.iter().all(|&b| b > 0),
                "{algo:?} on {topology}: neighbor bytes not recorded"
            );
            // ... and bytes_sent is exactly their sum
            assert_eq!(log.bytes_sent, log.neighbor_bytes.iter().sum::<u64>());
        }
    }
}

#[test]
fn ring_runs_leave_neighbor_accounting_inert() {
    let mut cfg = tiny_cfg();
    cfg.algo = Algo::Local;
    let log = native_run(&cfg);
    assert!(log.neighbor_bytes.iter().all(|&b| b == 0));
}

#[test]
fn hier_and_tree_cost_more_wall_clock_than_the_ring_at_full_message() {
    // At 44.7 MB the chunked ring is bandwidth-optimal; the unchunked tree
    // and two-handshake hierarchy sit on the critical path of `local`, so
    // the topology choice must show up in total virtual time.
    let mut ring = tiny_cfg();
    ring.algo = Algo::Local;
    ring.hier_groups = 2; // 2 groups of 2 on m=4 (4 singleton groups would
                          // be cost-identical to the ring, by design)
    let base = native_run(&ring);
    for topology in ["hier", "tree"] {
        let mut cfg = ring.clone();
        cfg.topology = topology.into();
        let log = native_run(&cfg);
        assert!(
            log.total_sim_time > base.total_sim_time,
            "{topology} should be slower than ring at full message size: {} vs {}",
            log.total_sim_time,
            base.total_sim_time
        );
        assert_eq!(log.steps, base.steps);
    }
}

#[test]
fn gossip_topology_is_rejected_for_exact_algorithms() {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let mut cfg = tiny_cfg();
    cfg.algo = Algo::Local;
    cfg.topology = "gossip".into();
    cfg.gossip_degree = 2; // feasible graph — the *algorithm* mismatch must trip
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    let err = match run_experiment(&rt, &cfg, &train, &test) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("gossip topology must be rejected for --algo local"),
    };
    assert!(err.contains("overlap-gossip"), "unhelpful error: {err}");
}

#[test]
fn overlap_gossip_rejects_an_explicit_exact_topology() {
    // The inverse mismatch is just as loud: an explicitly requested tree
    // (or hier) must not be silently replaced by a derived gossip graph.
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let mut cfg = tiny_cfg();
    cfg.algo = Algo::OverlapGossip;
    cfg.topology = "tree".into();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    let err = match run_experiment(&rt, &cfg, &train, &test) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("overlap-gossip must reject an explicit tree topology"),
    };
    assert!(err.contains("gossip"), "unhelpful error: {err}");
    // ... while an explicit (feasible) gossip topology and the ring default
    // both work.
    for topology in ["gossip", "ring"] {
        let mut ok = tiny_cfg();
        ok.algo = Algo::OverlapGossip;
        ok.topology = topology.into();
        ok.gossip_degree = 2; // feasible as asked on m = 4
        let log = run_experiment(&rt, &ok, &train, &test).unwrap();
        assert!(log.final_loss().is_finite());
    }
    // An explicitly requested infeasible degree is a hard config error
    // (the derived-graph path may clamp; the explicit path must not).
    let mut bad = tiny_cfg();
    bad.topology = "gossip".into();
    bad.gossip_degree = 1; // m = 4 needs k >= 2 for a connected regular graph
    assert!(bad.topology().is_err(), "infeasible explicit gossip_degree must fail");
}

// ---------------------------------------------------------------------------
// E10 — the decentralized overlap claim (EXPERIMENTS.md E10)
// ---------------------------------------------------------------------------

/// The paper's 16-node cluster with one 3× straggler, equal τ = 2. The ring
/// collective cannot start before the straggler joins, so every worker's
/// anchor arrives late and blocks; the gossip exchange stalls only the
/// straggler's graph neighborhood (one hop per round). Prototyped margins:
/// gossip blocks ≈ 0.55× the ring total and lands within ~0.5 % of the
/// ring's final eval loss — asserted here with wide safety factors.
#[test]
fn e10_overlap_gossip_blocks_less_than_ring_overlap_under_a_straggler() {
    let mut ring = ExperimentConfig::default();
    ring.model = "linear".into();
    ring.algo = Algo::Overlap;
    ring.workers = 16;
    ring.train_n = 1024; // 64/shard -> 2 steps/epoch
    ring.test_n = 100;
    ring.epochs = 6.0; // 12 global steps -> 6 rounds at tau=2
    ring.eval_every = 3.0;
    ring.tau = 2;
    ring.straggler = StragglerModel::SlowNode { node: 0, factor: 3.0 };

    let mut gossip = ring.clone();
    gossip.algo = Algo::OverlapGossip;
    gossip.gossip_degree = 4;

    let lr = native_run(&ring);
    let lg = native_run(&gossip);

    assert_eq!(lr.steps, 12);
    assert_eq!(lg.steps, 12, "equal tau must give equal rounds");

    // The bound is not vacuous: the ring genuinely blocks here.
    assert!(
        lr.total_comm_blocked_s > 1.0,
        "ring overlap should block on the straggled collective: {}",
        lr.total_comm_blocked_s
    );
    // Strictly lower per-round blocked time (equal round counts, so totals
    // compare 1:1); prototype says 0.55×, asserted at 0.9× for slack.
    assert!(
        lg.total_comm_blocked_s < 0.9 * lr.total_comm_blocked_s,
        "overlap-gossip must block strictly less than ring overlap: {} vs {}",
        lg.total_comm_blocked_s,
        lr.total_comm_blocked_s
    );
    // Neither variant ever barriers.
    assert_eq!(lr.total_idle_s, 0.0);
    assert_eq!(lg.total_idle_s, 0.0);

    // Final eval loss within 5 % at the same seed (prototype: ~0.5 %).
    let (fr, fg) = (lr.final_loss(), lg.final_loss());
    assert!(
        (fg - fr).abs() <= 0.05 * fr.abs(),
        "overlap-gossip final loss {fg} drifted >5% from overlap's {fr}"
    );

    // Byte accounting: the ring keeps the legacy m·msg convention; gossip
    // counts true per-neighbor traffic, uniformly degree·msg per worker.
    let msg = 11_173_962u64 * 4;
    assert_eq!(lr.bytes_sent, 6 * 16 * msg);
    assert_eq!(lg.bytes_sent, 6 * 16 * 4 * msg);
    assert_eq!(lg.neighbor_bytes, vec![6 * 4 * msg; 16]);
    assert!(lr.neighbor_bytes.iter().all(|&b| b == 0));
}

/// Straggler-off E10 leg: at τ = 2 both schedules hide their exchange
/// completely (2·188 ms of compute covers the 62 ms ring and the 38 ms
/// degree-4 gossip exchange alike).
#[test]
fn e10_both_overlap_variants_hide_the_wire_without_stragglers() {
    let mut ring = ExperimentConfig::default();
    ring.model = "linear".into();
    ring.algo = Algo::Overlap;
    ring.workers = 16;
    ring.train_n = 1024;
    ring.test_n = 100;
    ring.epochs = 4.0;
    ring.eval_every = 4.0;
    ring.tau = 2;

    let mut gossip = ring.clone();
    gossip.algo = Algo::OverlapGossip;

    let lr = native_run(&ring);
    let lg = native_run(&gossip);
    assert_eq!(lr.total_comm_blocked_s, 0.0, "ring overlap must hide at tau=2");
    assert_eq!(lg.total_comm_blocked_s, 0.0, "overlap-gossip must hide at tau=2");
    assert_eq!(lg.total_idle_s, 0.0);
}

/// `overlap-gossip` honors the τ-family scenario axes: heterogeneous τ runs
/// end-to-end and still completes the nominal schedule.
#[test]
fn overlap_gossip_supports_hetero_tau() {
    let mut cfg = tiny_cfg();
    cfg.algo = Algo::OverlapGossip;
    cfg.tau = 4;
    cfg.epochs = 4.0;
    cfg.tau_hetero = true;
    cfg.straggler = StragglerModel::SlowNode { node: 1, factor: 3.0 };
    let log = native_run(&cfg);
    assert_eq!(log.steps, 8);
    assert!(log.final_loss().is_finite());
}
