//! Cross-backend golden lock for the `net` service plane (ISSUE 7,
//! DESIGN.md §13): real coordinator/worker *processes* over TCP must
//! produce bit-identical `TrainLog` digests to the `sim` and `threads`
//! backends — same losses, same virtual timeline, same byte accounting —
//! for the exact-collective algorithms on the paper's m=16 shape, across
//! topologies and the compression axis.
//!
//! The fault leg is the tentpole's acceptance test: killing a worker
//! process mid-run (the `net_kill` chaos hook makes the child exit after
//! serving N phase requests) must complete the run *and* land on exactly
//! the digest of the equivalent explicit `--fault crash@round:worker`
//! schedule — i.e. a real process death is indistinguishable from a
//! scheduled fault, byte for byte.
//!
//! Every net run here spawns its fleet from `CARGO_BIN_EXE_olsgd` (the
//! test binary is *not* the CLI, so `current_exe()` would be wrong) and
//! binds port 0, so parallel test threads never collide on an address.

use olsgd::config::{Algo, Execution, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::TrainLog;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;

/// The golden fixed-seed shape: jitter stragglers on (so the per-worker
/// RNG replay is actually exercised), 64 samples per shard, 2 epochs of
/// 2 steps each → 4 global steps.
fn base_cfg(m: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = m;
    cfg.epochs = 2.0;
    cfg.train_n = m * 64;
    cfg.test_n = 100;
    cfg.eval_every = 1.0;
    cfg.tau = 2;
    cfg.straggler = StragglerModel::UniformJitter { jitter: 0.2 };
    cfg.set("net_worker_bin", env!("CARGO_BIN_EXE_olsgd")).unwrap();
    cfg.set("net_procs", "4").unwrap();
    // Generous rendezvous budget: CI machines can be slow to exec 4
    // children while other test threads hammer the disk.
    cfg.set("net_timeout_s", "120").unwrap();
    cfg
}

fn run(cfg: &ExperimentConfig) -> TrainLog {
    let rt = ModelRuntime::native(&cfg.model).unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    run_experiment(&rt, cfg, &train, &test).unwrap()
}

fn digest(cfg: &ExperimentConfig) -> u64 {
    run(cfg).digest()
}

#[test]
fn net_execution_is_digest_identical_to_sim_and_threads() {
    // The exact-collective algorithms on the paper's 16-worker ring,
    // served by 4 worker processes of 4 lanes each.
    for algo in [Algo::Sync, Algo::Local, Algo::OverlapM, Algo::Cocod, Algo::Easgd] {
        let mut cfg = base_cfg(16);
        cfg.algo = algo;
        assert_eq!(cfg.execution, Execution::Sim);
        let sim = digest(&cfg);
        cfg.execution = Execution::Threads;
        let thr = digest(&cfg);
        cfg.execution = Execution::Net;
        let net = digest(&cfg);
        assert_eq!(sim, thr, "{algo:?}: threads backend drifted from sim");
        assert_eq!(sim, net, "{algo:?}: net backend drifted from sim");
    }
}

#[test]
fn net_execution_composes_with_topology_and_compression() {
    // The service plane sits on the Executor seam, so the topology and
    // compression axes must pass through untouched.
    let mut tree = base_cfg(16);
    tree.algo = Algo::OverlapM;
    tree.topology = "tree".into();
    let sim = digest(&tree);
    tree.execution = Execution::Net;
    assert_eq!(sim, digest(&tree), "overlap-m on tree: net drifted from sim");

    let mut topk = base_cfg(16);
    topk.algo = Algo::OverlapM;
    topk.set("compress", "topk").unwrap();
    topk.set("compress_k", "64").unwrap();
    let sim = digest(&topk);
    topk.execution = Execution::Net;
    assert_eq!(sim, digest(&topk), "overlap-m + topk: net drifted from sim");
}

#[test]
fn killed_worker_process_replays_as_the_equivalent_crash_fault() {
    // 4 slots on 4 single-lane processes: proc 1 serves exactly worker 1.
    // `net_kill=1:2` makes it exit after serving round 2's phase request,
    // so the boundary poll before round 3 reports crash@3:1 — which must
    // replay bit-identically to scheduling that crash explicitly on sim.
    // 4 epochs → 8 global steps → 4 rounds of τ=2, so the death lands
    // mid-run with two full rounds left for the survivors.
    let mut dead = base_cfg(4);
    dead.algo = Algo::OverlapM;
    dead.epochs = 4.0;
    dead.set("net_kill", "1:2").unwrap();
    dead.execution = Execution::Net;
    let net = digest(&dead);

    let mut explicit = base_cfg(4);
    explicit.algo = Algo::OverlapM;
    explicit.epochs = 4.0;
    explicit.set("fault", "crash@3:1").unwrap();
    let sim = digest(&explicit);

    assert_eq!(
        net, sim,
        "a worker process dying after round 2 must be byte-identical to \
         an explicit --fault crash@3:1 schedule"
    );
}

#[test]
fn net_backend_serves_sampled_cohorts() {
    // PR-9 lifted composition: 8 machine slots over a 24-worker
    // population, served by 4 two-lane processes. The slot → id binding
    // (plus each bound worker's batcher and straggler stream) travels in
    // `PhaseReq`, so cohort churn across rounds must not move a bit
    // relative to the sim backend.
    let mut cfg = base_cfg(8);
    cfg.algo = Algo::OverlapM;
    cfg.epochs = 3.0; // 6 global steps -> 3 rounds of cohort churn
    cfg.set("population", "24").unwrap();
    cfg.set("sample_k", "8").unwrap();
    let sim = run(&cfg);
    cfg.execution = Execution::Net;
    let net = run(&cfg);
    assert_eq!(sim.digest(), net.digest(), "sampled cohorts over net drifted from sim");
    assert_eq!(
        sim.population.unwrap(),
        net.population.unwrap(),
        "store traffic must replay identically on the net backend"
    );
}

#[test]
fn killed_worker_under_population_replays_as_the_per_id_crash() {
    // 4 slots on 4 single-lane processes over a 12-worker population.
    // `net_kill=1:2` kills proc 1 (slot 1) after round 2, so the boundary
    // poll before round 3 reports a slot crash; the engine translates it
    // through the round-2 binding into a per-id crash. Scheduling that
    // exact `crash@3:id` on sim must reproduce the digest byte-for-byte.
    let mut dead = base_cfg(4);
    dead.algo = Algo::OverlapM;
    dead.epochs = 4.0; // 8 global steps -> 4 rounds, death lands mid-run
    dead.set("population", "12").unwrap();
    dead.set("sample_k", "4").unwrap();
    dead.set("net_kill", "1:2").unwrap();
    dead.execution = Execution::Net;
    let net = run(&dead);

    let (round, ev) = net
        .fault_trace
        .first()
        .expect("the killed process must surface as a fault event")
        .clone();
    assert_eq!(round, 3, "proc 1 dies after serving round 2");
    assert!(
        ev.starts_with("crash@3:"),
        "the injected event must be a round-3 per-id crash, got '{ev}'"
    );

    let mut explicit = base_cfg(4);
    explicit.algo = Algo::OverlapM;
    explicit.epochs = 4.0;
    explicit.set("population", "12").unwrap();
    explicit.set("sample_k", "4").unwrap();
    explicit.set("fault", &ev).unwrap();
    let sim = run(&explicit);

    assert_eq!(
        net.digest(),
        sim.digest(),
        "a process death under sampling must be byte-identical to the \
         equivalent per-id --fault {ev} schedule"
    );
    assert_eq!(net.fault_trace, sim.fault_trace);
}
