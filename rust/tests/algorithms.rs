//! Algorithm-level integration tests: every mixing strategy driven through
//! the round engine, plus the cross-algorithm algebraic identities and
//! timing invariants the paper's framing implies.
//!
//! Runs on the native backend (no artifacts, no PJRT) so `cargo test -q`
//! exercises the full coordinator on a sealed machine; the identities are
//! model-independent (they are properties of the *schedules*).

use olsgd::config::{Algo, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, Dataset, GenConfig};
use olsgd::metrics::TrainLog;
use olsgd::runtime::ModelRuntime;
use olsgd::simnet::StragglerModel;

struct Fixture {
    rt: ModelRuntime,
    train: Dataset,
    test: Dataset,
}

fn fixture() -> Fixture {
    let rt = ModelRuntime::native("linear").expect("native runtime");
    let gen = GenConfig::default();
    let train = data::generate(1, 256, "train", &gen);
    let test = data::generate(1, 100, "test", &gen);
    Fixture { rt, train, test }
}

fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 2;
    cfg.epochs = 2.0;
    cfg.train_n = 256;
    cfg.test_n = 100;
    cfg.eval_every = 1.0;
    cfg
}

fn run(f: &Fixture, cfg: &ExperimentConfig) -> TrainLog {
    run_experiment(&f.rt, cfg, &f.train, &f.test).unwrap()
}

#[test]
fn every_algorithm_completes_and_accounts_time() {
    let f = fixture();
    for &algo in Algo::all() {
        let mut cfg = tiny_cfg();
        cfg.algo = algo;
        let log = run(&f, &cfg);
        assert!(log.steps > 0, "{algo:?} took no steps");
        assert!(!log.records.is_empty(), "{algo:?} recorded nothing");
        assert!(log.total_sim_time > 0.0);
        assert!(log.final_loss().is_finite(), "{algo:?} diverged on IID tiny run");
        // time monotone across records
        let mut last = 0.0;
        for r in &log.records {
            assert!(r.sim_time >= last, "{algo:?} time went backwards");
            last = r.sim_time;
        }
        // bytes were sent unless single worker
        assert!(log.bytes_sent > 0, "{algo:?} sent no bytes");
    }
}

#[test]
fn runs_are_deterministic_given_seed() {
    let f = fixture();
    let mut cfg = tiny_cfg();
    cfg.algo = Algo::OverlapM;
    let a = run(&f, &cfg);
    let b = run(&f, &cfg);
    assert_eq!(a.steps, b.steps);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.test_acc, rb.test_acc);
        assert!((ra.train_loss - rb.train_loss).abs() < 1e-12);
    }
    assert_eq!(a.total_sim_time, b.total_sim_time);
    assert_eq!(a.digest(), b.digest());
}

#[test]
fn sync_and_local_tau1_share_mean_trajectory() {
    // Algebraic identity: with τ=1 and common init, Local SGD's averaged
    // replica equals sync SGD's replica (mean of per-worker Nesterov steps
    // = Nesterov step on mean gradient, since params are equal each round).
    let f = fixture();
    let mut c_sync = tiny_cfg();
    c_sync.algo = Algo::Sync;
    let mut c_local = tiny_cfg();
    c_local.algo = Algo::Local;
    c_local.tau = 1;
    let a = run(&f, &c_sync);
    let b = run(&f, &c_local);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert!(
            (ra.test_loss - rb.test_loss).abs() < 2e-3,
            "sync vs local tau=1 test loss diverged: {} vs {}",
            ra.test_loss,
            rb.test_loss
        );
    }
}

#[test]
fn overlap_hides_communication_local_does_not() {
    let f = fixture();
    let mut c_local = tiny_cfg();
    c_local.algo = Algo::Local;
    c_local.tau = 4;
    let mut c_over = c_local.clone();
    c_over.algo = Algo::OverlapM;
    let ll = run(&f, &c_local);
    let lo = run(&f, &c_over);
    assert!(
        lo.total_comm_blocked_s < 0.2 * ll.total_comm_blocked_s,
        "overlap did not hide comm: {} vs local {}",
        lo.total_comm_blocked_s,
        ll.total_comm_blocked_s
    );
    assert!(lo.total_sim_time < ll.total_sim_time);
}

#[test]
fn overlap_comm_surfaces_when_wire_slower_than_round() {
    // With τ=1 and a 10 Gbps wire, the all-reduce takes longer than one
    // step of compute — the anchor is late and waits must appear.
    let f = fixture();
    let mut cfg = tiny_cfg();
    cfg.algo = Algo::OverlapM;
    cfg.tau = 1;
    cfg.net_preset = "slow10g".into();
    cfg.base_step_s = 0.05; // short compute round
    let log = run(&f, &cfg);
    assert!(
        log.total_comm_blocked_s > 0.0,
        "expected anchor waits with slow wire + tau=1"
    );
}

#[test]
fn sync_stalls_on_straggler_overlap_does_not() {
    let f = fixture();
    let straggler = StragglerModel::SlowNode { node: 0, factor: 3.0 };
    let mut c_sync = tiny_cfg();
    c_sync.algo = Algo::Sync;
    c_sync.straggler = straggler.clone();
    let mut c_over = tiny_cfg();
    c_over.algo = Algo::OverlapM;
    c_over.tau = 4;
    c_over.straggler = straggler;
    let ls = run(&f, &c_sync);
    let lo = run(&f, &c_over);
    assert!(ls.total_idle_s > 0.0, "sync must idle on the straggler");
    assert_eq!(lo.total_idle_s, 0.0, "overlap must never barrier-idle");
}

#[test]
fn powersgd_sends_fewer_bytes_than_sync() {
    let f = fixture();
    let mut c_sync = tiny_cfg();
    c_sync.algo = Algo::Sync;
    let mut c_pow = tiny_cfg();
    c_pow.algo = Algo::PowerSgd;
    c_pow.rank = 1;
    let ls = run(&f, &c_sync);
    let lp = run(&f, &c_pow);
    assert!(
        lp.bytes_sent < ls.bytes_sent / 5,
        "powersgd rank-1 compression too weak: {} vs {}",
        lp.bytes_sent,
        ls.bytes_sent
    );
    // ... but its time per step keeps the handshake floor
    assert!(lp.total_comm_blocked_s > 0.0);
}

#[test]
fn noniid_partition_flows_through_training() {
    let f = fixture();
    let mut cfg = tiny_cfg();
    cfg.algo = Algo::OverlapM;
    cfg.noniid = true;
    cfg.reshuffle = false;
    let log = run(&f, &cfg);
    assert!(log.final_loss().is_finite());
}

#[test]
fn eval_cadence_respected() {
    let f = fixture();
    let mut cfg = tiny_cfg();
    cfg.epochs = 3.0;
    cfg.eval_every = 1.0;
    cfg.algo = Algo::Local;
    let log = run(&f, &cfg);
    // one record per epoch + final (final coincides with last cadence point)
    assert!(log.records.len() >= 3, "records: {}", log.records.len());
}

#[test]
fn overlap_ada_shrinks_tau_monotonically_to_floor() {
    // Force a plateau every round (threshold 1.0 means no loss drop ever
    // counts as progress): with patience 1 the controller must halve τ each
    // round until the floor, and record the schedule in the log.
    let f = fixture();
    let mut cfg = tiny_cfg();
    cfg.algo = Algo::OverlapAda;
    cfg.tau = 8;
    cfg.tau_min = 2;
    cfg.ada_patience = 1;
    cfg.ada_threshold = 1.0;
    cfg.epochs = 6.0; // 24 global steps at 4 steps/epoch
    let log = run(&f, &cfg);
    assert_eq!(log.steps, 24);
    assert!(log.final_loss().is_finite());
    assert!(log.tau_trace.len() >= 3, "tau trace: {:?}", log.tau_trace);
    assert_eq!(log.tau_trace[0], (0, 8), "trace starts at the configured τ");
    for pair in log.tau_trace.windows(2) {
        assert!(pair[1].1 < pair[0].1, "τ must shrink monotonically: {:?}", log.tau_trace);
        assert!(pair[1].0 > pair[0].0, "trace steps must advance");
    }
    assert_eq!(log.tau_trace.last().unwrap().1, 2, "τ must reach tau_min");
}

#[test]
fn hetero_tau_runs_end_to_end_for_every_tau_family_algorithm() {
    let f = fixture();
    for algo in [Algo::Local, Algo::Overlap, Algo::OverlapM, Algo::OverlapAda, Algo::Cocod] {
        let mut cfg = tiny_cfg();
        cfg.algo = algo;
        cfg.tau = 4;
        cfg.tau_hetero = true;
        cfg.straggler = StragglerModel::SlowNode { node: 0, factor: 3.0 };
        cfg.epochs = 4.0;
        let log = run(&f, &cfg);
        assert_eq!(log.steps, 16, "{algo:?} must complete the nominal schedule");
        assert!(log.final_loss().is_finite(), "{algo:?} diverged under hetero-τ");
    }
}
