//! Property tests for the paper's central claim (E8): Overlap-Local-SGD
//! *hides* the all-reduce behind τ local steps, while fully-sync SGD pays a
//! communication-to-computation ratio of ≈ 34.6 % on the calibrated 16-node
//! / 40 Gbps cluster — plus the adaptive-τ communication bound.
//!
//! Runs on the native backend; the claims are schedule properties, so tiny
//! workloads suffice.

use olsgd::config::{Algo, ExperimentConfig};
use olsgd::coordinator::run_experiment;
use olsgd::data::{self, GenConfig};
use olsgd::metrics::TrainLog;
use olsgd::runtime::ModelRuntime;
use olsgd::util::proptest::property;

fn run_cfg(cfg: &ExperimentConfig) -> TrainLog {
    let rt = ModelRuntime::native("linear").unwrap();
    let gen = GenConfig::default();
    let train = data::generate(cfg.seed, cfg.train_n, "train", &gen);
    let test = data::generate(cfg.seed, cfg.test_n, "test", &gen);
    run_experiment(&rt, cfg, &train, &test).unwrap()
}

fn paper_cluster_cfg() -> ExperimentConfig {
    // The paper's topology: 16 workers, 40 Gbps ring, ResNet-18-size
    // messages (the config default), 188 ms compute steps.
    let mut cfg = ExperimentConfig::default();
    cfg.model = "linear".into();
    cfg.workers = 16;
    cfg.train_n = 1024; // 64/shard -> 2 steps/epoch
    cfg.test_n = 100;
    cfg.epochs = 2.0;
    cfg.eval_every = 2.0;
    cfg
}

/// E8 headline: sync pays ≈ 34.6 % comm-to-compute; overlap with τ large
/// enough to cover the wire blocks for exactly zero seconds.
#[test]
fn e8_sync_ratio_34_6_percent_overlap_zero() {
    let mut c_sync = paper_cluster_cfg();
    c_sync.algo = Algo::Sync;
    let ls = run_cfg(&c_sync);
    let ratio = ls.comm_ratio();
    assert!(
        (ratio - 0.346).abs() < 0.05,
        "sync comm-to-compute ratio {ratio} not ≈ 34.6%"
    );

    // The paper's headline τ=2: two 188 ms steps cover one 65 ms all-reduce.
    // (4 global steps -> 2 rounds, so the second round genuinely absorbs.)
    let mut c_over = paper_cluster_cfg();
    c_over.algo = Algo::OverlapM;
    c_over.tau = 2;
    let lo = run_cfg(&c_over);
    assert_eq!(
        lo.total_comm_blocked_s, 0.0,
        "overlap must fully hide the collective at large τ"
    );
    assert_eq!(lo.total_idle_s, 0.0, "overlap has no barrier to idle at");
    assert!(lo.total_sim_time < ls.total_sim_time);
}

/// The hiding condition as a property: for any cluster size and any τ with
/// τ · step_time > allreduce_time, the overlapped run never blocks on the
/// wire (and its byte accounting still shows every round's collective).
#[test]
fn property_overlap_hides_whenever_tau_covers_the_wire() {
    property("overlap hiding condition", 6, |g| {
        let m = [4usize, 8][g.usize_in(0, 1)];
        let tau = g.usize_in(4, 10);
        let mut cfg = ExperimentConfig::default();
        cfg.model = "linear".into();
        cfg.workers = m;
        cfg.train_n = m * 64; // 2 steps/epoch per worker
        cfg.test_n = 100;
        cfg.epochs = tau as f64; // exactly 2 rounds of τ steps
        cfg.eval_every = cfg.epochs;
        cfg.seed = 1 + g.usize_in(0, 3) as u64;
        cfg.algo = Algo::OverlapM;
        cfg.tau = tau;
        // hiding condition: τ * 188 ms >= wire time (65 ms at m=16, less here)
        let cluster = cfg.cluster(0).unwrap();
        assert!(tau as f64 * cfg.base_step_s > cluster.allreduce_time());

        let log = run_cfg(&cfg);
        assert_eq!(
            log.total_comm_blocked_s, 0.0,
            "m={m} tau={tau}: wire surfaced despite τ covering it"
        );
        let rounds = log.steps.div_ceil(tau);
        assert_eq!(
            log.bytes_sent,
            (rounds * m * cluster.message_bytes) as u64,
            "every round must account one full-model collective"
        );
    });
}

/// Adaptive τ only ever *shrinks* from τ0 toward `tau_min`, so its round
/// count — hence bytes on the wire and potential blocked-comm — is bounded
/// by a fixed-τ run at the floor. Asserted in the regime where τ = tau_min
/// cannot hide the wire (10 Gbps, 100 ms steps), on the same seed, with the
/// controller forced to shrink maximally fast (threshold 1.0, patience 1).
#[test]
fn adaptive_tau_never_exceeds_fixed_floor_tau_comm() {
    let mut ada = ExperimentConfig::default();
    ada.model = "linear".into();
    ada.workers = 8;
    ada.train_n = 512; // 2 steps/epoch
    ada.test_n = 100;
    ada.epochs = 16.0; // 32 global steps
    ada.eval_every = 8.0;
    ada.net_preset = "slow10g".into();
    ada.base_step_s = 0.1;
    ada.algo = Algo::OverlapAda;
    ada.tau = 8;
    ada.tau_min = 1;
    ada.ada_patience = 1;
    ada.ada_threshold = 1.0;

    let mut fixed = ada.clone();
    fixed.algo = Algo::OverlapM;
    fixed.tau = 1;

    let la = run_cfg(&ada);
    let lf = run_cfg(&fixed);

    // τ=1 on this wire genuinely blocks (the bound below is not vacuous).
    assert!(lf.total_comm_blocked_s > 0.0, "floor-τ run must pay wire time");

    assert!(
        la.bytes_sent <= lf.bytes_sent,
        "adaptive sent more bytes than the τ=tau_min run: {} vs {}",
        la.bytes_sent,
        lf.bytes_sent
    );
    assert!(
        la.total_comm_blocked_s <= lf.total_comm_blocked_s + 1e-9,
        "adaptive blocked longer than the τ=tau_min run: {} vs {}",
        la.total_comm_blocked_s,
        lf.total_comm_blocked_s
    );
    assert!(la.total_sim_time <= lf.total_sim_time + 1e-9);

    // The recorded schedule stays inside [tau_min, τ0] and is monotone.
    assert!(!la.tau_trace.is_empty());
    for pair in la.tau_trace.windows(2) {
        assert!(pair[1].1 <= pair[0].1, "τ must never grow: {:?}", la.tau_trace);
    }
    for &(_, t) in &la.tau_trace {
        assert!((1..=8).contains(&t));
    }
    assert_eq!(la.tau_trace.last().unwrap().1, 1, "forced shrink must reach the floor");
}
