//! Property tests of the paper's mathematical objects, independent of PJRT:
//! the mixing matrix P (Eq. 9), its contraction factor zeta <= 1 - alpha,
//! the virtual sequence y_k (Eq. 19), and the equivalence of our staggered
//! (overlapped) schedule to the paper's instantaneous update rules.

use olsgd::model::vecmath;
use olsgd::util::proptest::{property, Gen};

/// Build the (m+1)x(m+1) mixing matrix P of Eq. (9), row-major.
/// Columns j < m are the local models, column m is the anchor.
fn mixing_matrix(m: usize, alpha: f64) -> Vec<f64> {
    let n = m + 1;
    let mut p = vec![0.0; n * n];
    // x_i' = (1-a) x_i + a z   -> column i gets (1-a) at row i... careful:
    // the paper stacks columns X = [x_1..x_m, z] and multiplies on the
    // right: X' = X P, so P[col j] describes what target j receives:
    // x_j' = (1-a) x_j + a z          => P[j][j] = 1-a, P[m][j] = a
    // z'   = (1/m) sum_i x_i' = (1-a)/m sum_i x_i + a z
    //                                 => P[i][m] = (1-a)/m, P[m][m] = a
    for j in 0..m {
        p[j * n + j] = 1.0 - alpha;
        p[m * n + j] = alpha;
    }
    for i in 0..m {
        p[i * n + m] = (1.0 - alpha) / m as f64;
    }
    p[m * n + m] = alpha;
    p
}

/// v = [(1-a)/m, ..., (1-a)/m, a]: the left-invariant vector with Pv = v.
fn invariant_v(m: usize, alpha: f64) -> Vec<f64> {
    let mut v = vec![(1.0 - alpha) / m as f64; m + 1];
    v[m] = alpha;
    v
}

fn matvec(p: &[f64], n: usize, x: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        for j in 0..n {
            y[i] += p[i * n + j] * x[j];
        }
    }
    y
}

/// ||M||_2 via power iteration on MᵀM.
fn spectral_norm(mat: &[f64], n: usize) -> f64 {
    let mut x: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64) * 0.37).collect();
    let mt_m = {
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += mat[k * n + i] * mat[k * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    };
    let mut lambda = 0.0;
    for _ in 0..200 {
        let y = matvec(&mt_m, n, &x);
        lambda = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if lambda == 0.0 {
            return 0.0;
        }
        x = y.iter().map(|v| v / lambda).collect();
    }
    lambda.sqrt()
}

#[test]
fn mixing_matrix_is_column_stochastic() {
    property("P column-stochastic", 100, |g: &mut Gen| {
        let m = g.usize_in(1, 12);
        let alpha = g.f64_in(0.01, 0.99);
        let n = m + 1;
        let p = mixing_matrix(m, alpha);
        for j in 0..n {
            let col: f64 = (0..n).map(|i| p[i * n + j]).sum();
            assert!((col - 1.0).abs() < 1e-12, "col {j} sums to {col}");
        }
    });
}

#[test]
fn p_fixes_its_invariant_vector() {
    property("Pv = v", 100, |g: &mut Gen| {
        let m = g.usize_in(1, 12);
        let alpha = g.f64_in(0.01, 0.99);
        let p = mixing_matrix(m, alpha);
        let v = invariant_v(m, alpha);
        let pv = matvec(&p, m + 1, &v);
        for (a, b) in pv.iter().zip(&v) {
            assert!((a - b).abs() < 1e-12, "Pv != v");
        }
    });
}

#[test]
fn zeta_bounded_by_one_minus_alpha() {
    // The paper's key spectral fact (via Haveliwala & Kamvar):
    // zeta = ||P - v 1ᵀ||_2 <= 1 - alpha, strictly < 1 for alpha > 0.
    property("zeta <= 1 - alpha", 60, |g: &mut Gen| {
        let m = g.usize_in(1, 10);
        let alpha = g.f64_in(0.05, 0.95);
        let n = m + 1;
        let p = mixing_matrix(m, alpha);
        let v = invariant_v(m, alpha);
        let mut diff = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                diff[i * n + j] = p[i * n + j] - v[i];
            }
        }
        let zeta = spectral_norm(&diff, n);
        assert!(
            zeta <= (1.0 - alpha) + 1e-6,
            "zeta {zeta} > 1 - alpha = {}",
            1.0 - alpha
        );
    });
}

/// Reference: the paper's *instantaneous* update rules (Eqs. 3-5, beta=0):
/// at each boundary, pull back toward z_k, then z_{k+1} = avg(x_{k+1}).
fn run_instantaneous(
    g: &mut Gen,
    m: usize,
    d: usize,
    tau: usize,
    steps: usize,
    alpha: f32,
    gamma: f32,
    grads: &[Vec<Vec<f32>>],
) -> (Vec<Vec<f32>>, Vec<f32>, Vec<Vec<f32>>) {
    let x0: Vec<Vec<f32>> = (0..m).map(|_| g.vec_f32(d, 1.0)).collect();
    let mut xs = x0.clone();
    let mut z = vecmath::mean(&xs.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
    // paper init: all equal; force x_i = z
    for x in xs.iter_mut() {
        x.copy_from_slice(&z);
    }
    let mut ys = Vec::new();
    for k in 0..steps {
        for (i, x) in xs.iter_mut().enumerate() {
            vecmath::axpy(-gamma, &grads[k][i], x);
        }
        if (k + 1) % tau == 0 {
            for x in xs.iter_mut() {
                vecmath::pullback_inplace(x, &z, alpha);
            }
            z = vecmath::mean(&xs.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
        }
        // y_k+1 = (1-a) avg x + a z
        let mut y = vecmath::mean(&xs.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
        for (j, yj) in y.iter_mut().enumerate() {
            *yj = (1.0 - alpha) * *yj + alpha * z[j];
        }
        ys.push(y);
    }
    (xs, z, ys)
}

#[test]
fn virtual_sequence_follows_eq_19() {
    // y_{k+1} = y_k - gamma_eff * avg_i g_k^i  with gamma_eff = (1-a)gamma,
    // at EVERY k including pullback boundaries. This is the identity the
    // whole convergence proof rests on.
    property("Eq.19 virtual sequence", 40, |g: &mut Gen| {
        let m = g.usize_in(1, 6);
        let d = g.usize_in(1, 20);
        let tau = g.usize_in(1, 5);
        let steps = tau * g.usize_in(1, 6);
        let alpha = g.f32_in(0.05, 0.95);
        let gamma = g.f32_in(0.001, 0.1);
        let grads: Vec<Vec<Vec<f32>>> = (0..steps)
            .map(|_| (0..m).map(|_| g.vec_f32(d, 1.0)).collect())
            .collect();
        let (_, _, ys) = run_instantaneous(g, m, d, tau, steps, alpha, gamma, &grads);

        // y_0 = common init z0; reconstruct from first step:
        // y_1 = y_0 - geff avg g_0  => y_0 = y_1 + geff avg g_0
        let geff = (1.0 - alpha) * gamma;
        for k in 1..steps {
            let refs: Vec<&[f32]> = grads[k].iter().map(|v| v.as_slice()).collect();
            let gbar = vecmath::mean(&refs);
            for j in 0..d {
                let want = ys[k - 1][j] - geff * gbar[j];
                let got = ys[k][j];
                assert!(
                    (want - got).abs() <= 1e-4 * (1.0 + want.abs()),
                    "Eq.19 violated at k={k}, j={j}: {got} vs {want}"
                );
            }
        }
    });
}

/// Our coordinator's *staggered* schedule: the average computed at boundary
/// B_{r-1} is only absorbed into z at boundary B_r (communication runs
/// under round r's compute). The paper's Eq. (5) notes z_{a tau} is first
/// USED at (a+1) tau — so both schedules must produce identical local-model
/// trajectories.
fn run_staggered(
    m: usize,
    d: usize,
    tau: usize,
    steps: usize,
    alpha: f32,
    gamma: f32,
    x0: &[f32],
    grads: &[Vec<Vec<f32>>],
) -> Vec<Vec<f32>> {
    let mut xs: Vec<Vec<f32>> = (0..m).map(|_| x0.to_vec()).collect();
    let mut z = x0.to_vec();
    let mut pending: Option<Vec<f32>> = None;
    for k in 0..steps {
        for (i, x) in xs.iter_mut().enumerate() {
            vecmath::axpy(-gamma, &grads[k][i], x);
        }
        if (k + 1) % tau == 0 {
            if let Some(avg) = pending.take() {
                z = avg; // absorb previous boundary's collective
            }
            for x in xs.iter_mut() {
                vecmath::pullback_inplace(x, &z, alpha);
            }
            pending = Some(vecmath::mean(
                &xs.iter().map(|v| v.as_slice()).collect::<Vec<_>>(),
            ));
        }
    }
    xs
}

#[test]
fn staggered_absorb_equals_instantaneous_rule() {
    property("staggered == Eqs.(3)-(5)", 40, |g: &mut Gen| {
        let m = g.usize_in(1, 6);
        let d = g.usize_in(1, 16);
        let tau = g.usize_in(1, 4);
        let rounds = g.usize_in(1, 6);
        let steps = tau * rounds;
        let alpha = g.f32_in(0.05, 0.95);
        let gamma = g.f32_in(0.001, 0.1);
        let grads: Vec<Vec<Vec<f32>>> = (0..steps)
            .map(|_| (0..m).map(|_| g.vec_f32(d, 1.0)).collect())
            .collect();
        let x0 = g.vec_f32(d, 1.0);

        // Instantaneous per the paper: z used at boundary r is the average
        // formed at boundary r-1.
        let mut xs_a: Vec<Vec<f32>> = (0..m).map(|_| x0.clone()).collect();
        let mut z_hist = vec![x0.clone()]; // z values in boundary order
        for k in 0..steps {
            for (i, x) in xs_a.iter_mut().enumerate() {
                vecmath::axpy(-gamma, &grads[k][i], x);
            }
            if (k + 1) % tau == 0 {
                let r = (k + 1) / tau; // boundary index, 1-based
                let z_used = z_hist[r - 1].clone();
                for x in xs_a.iter_mut() {
                    vecmath::pullback_inplace(x, &z_used, alpha);
                }
                z_hist.push(vecmath::mean(
                    &xs_a.iter().map(|v| v.as_slice()).collect::<Vec<_>>(),
                ));
            }
        }

        let xs_b = run_staggered(m, d, tau, steps, alpha, gamma, &x0, &grads);
        for i in 0..m {
            for j in 0..d {
                assert!(
                    (xs_a[i][j] - xs_b[i][j]).abs() <= 1e-5 * (1.0 + xs_a[i][j].abs()),
                    "trajectory mismatch worker {i} dim {j}"
                );
            }
        }
    });
}
